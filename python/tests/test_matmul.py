"""Pallas matmul kernel vs the jnp oracle (the paper's CUBLAS-analog)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref

SIZES = [8, 16, 32, 64, 128, 256]


def rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


@pytest.mark.parametrize("n", SIZES)
def test_square_matches_oracle(key, n):
    k1, k2 = jax.random.split(jax.random.fold_in(key, n))
    a, b = rand(k1, (n, n)), rand(k2, (n, n))
    got = matmul.matmul(a, b)
    want = ref.matmul(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_rectangular_blocks(key):
    # m, n, k all different, multiple blocks in each dimension
    k1, k2 = jax.random.split(key)
    a, b = rand(k1, (256, 128)), rand(k2, (128, 384))
    got = matmul.matmul(a, b)
    np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-4, atol=1e-4)


def test_indivisible_raises(key):
    a = rand(key, (100, 100))
    with pytest.raises(ValueError, match="divisible"):
        matmul.matmul(a, a, bm=64, bn=64, bk=64)


def test_vmem_budget():
    # default tiling must fit VMEM with headroom for double buffering
    assert matmul.vmem_bytes() <= 16 * 2**20 / 8


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([8, 16, 32, 64]),
    n=st.sampled_from([8, 16, 32, 64]),
    k=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shapes(m, n, k, seed):
    kk = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(kk)
    a, b = rand(k1, (m, k)), rand(k2, (k, n))
    got = matmul.matmul(a, b, bm=min(8, m), bn=min(8, n), bk=min(8, k))
    np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2**31 - 1))
def test_hypothesis_scaling_invariance(scale, seed):
    # (sA) @ B == s (A @ B) within float tolerance
    kk = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(kk)
    a, b = rand(k1, (32, 32)), rand(k2, (32, 32))
    s = jnp.float32(scale)
    left = matmul.matmul(a * s, b)
    right = matmul.matmul(a, b) * s
    np.testing.assert_allclose(left, right, rtol=1e-3, atol=1e-3)


def test_identity(key):
    a = rand(key, (64, 64))
    eye = jnp.eye(64, dtype=jnp.float32)
    np.testing.assert_allclose(matmul.matmul(a, eye), a, rtol=1e-5, atol=1e-5)
