"""L2 model entries + the AOT pipeline: every entry lowers to HLO text
that xla_extension 0.5.1 can parse conceptually (no typed-FFI custom
calls), and the manifest schema matches what the Rust side expects."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_entry_inventory_covers_all_apps():
    apps = {e.app for e in model.entries()}
    assert apps == set(model.APP_BUILDERS)


def test_every_app_has_two_variants():
    for app in model.APP_BUILDERS:
        es = [e for e in model.entries(apps={app})]
        variants = {e.variant for e in es}
        assert {"jnp", "pallas"} <= variants, f"{app}: {variants}"


def test_entry_names_unique():
    names = [e.name for e in model.entries(full=True)]
    assert len(names) == len(set(names))


@pytest.mark.parametrize("app", sorted(model.APP_BUILDERS))
def test_smallest_entry_lowers_and_matches_variants(app):
    size = model.DEFAULT_SIZES[app][0]
    es = [e for e in model.entries(apps={app}, sizes=[size])]
    outs = {}
    for e in es:
        # run the traced function directly — same graph that gets lowered
        args = [
            jnp.asarray(
                np.random.default_rng(0).standard_normal(s.shape, dtype=np.float32)
            )
            for s in e.specs
        ]
        if app == "hotspot" or app == "hotspot3d":
            args[0] = jnp.abs(args[0]) + 70.0
        if app == "lud":
            n = args[0].shape[0]
            args[0] = args[0] + n * jnp.eye(n, dtype=jnp.float32)
        outs[e.variant] = e.fn(*args)[0]
    np.testing.assert_allclose(
        outs["jnp"], outs["pallas"], rtol=5e-3, atol=5e-3
    )


@pytest.mark.parametrize("app", sorted(model.APP_BUILDERS))
def test_hlo_text_has_no_ffi_custom_calls(app):
    # xla_extension 0.5.1 rejects API_VERSION_TYPED_FFI custom calls; the
    # artifacts must lower to plain HLO (see kernels/lud.py note)
    size = model.DEFAULT_SIZES[app][0]
    for e in model.entries(apps={app}, sizes=[size]):
        text = aot.lower_entry(e)
        assert "api_version=API_VERSION_TYPED_FFI" not in text, (
            f"{e.name} contains a typed-FFI custom call"
        )
        assert "ENTRY" in text  # sanity: looks like HLO text


def test_manifest_roundtrip(tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path), "--apps", "sort", "--sizes", "256"])
    assert rc == 0
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["artifacts"], "empty manifest"
    a = manifest["artifacts"][0]
    for field in ("name", "app", "variant", "size", "file", "inputs"):
        assert field in a
    assert (tmp_path / a["file"]).exists()
    # incremental: a second run with same inputs writes nothing new
    mtime = (tmp_path / a["file"]).stat().st_mtime
    aot.main(["--out-dir", str(tmp_path), "--apps", "sort", "--sizes", "256"])
    assert (tmp_path / a["file"]).stat().st_mtime == mtime


def test_manifest_merges_filtered_runs(tmp_path):
    aot.main(["--out-dir", str(tmp_path), "--apps", "sort", "--sizes", "256"])
    aot.main(["--out-dir", str(tmp_path), "--apps", "matmul", "--sizes", "8"])
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    apps = {a["app"] for a in manifest["artifacts"]}
    assert apps == {"sort", "matmul"}, "filtered runs must merge, not replace"


def test_fingerprint_changes_invalidate(tmp_path, monkeypatch):
    aot.main(["--out-dir", str(tmp_path), "--apps", "sort", "--sizes", "256"])
    monkeypatch.setattr(aot, "_source_fingerprint", lambda: "different")
    # force=False but fingerprint mismatch -> rebuild happens (no crash)
    rc = aot.main(["--out-dir", str(tmp_path), "--apps", "sort", "--sizes", "256"])
    assert rc == 0


def test_stencil_loops_are_in_module():
    # the hotspot time loop must be inside the lowered module (a while op)
    e = next(iter(model.entries(apps={"hotspot"}, sizes=[64])))
    text = aot.lower_entry(e)
    assert "while" in text, "time loop not fused into the module"
