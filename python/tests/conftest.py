"""Shared pytest fixtures for the kernel test suite."""

import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(20230710)


def pytest_configure(config):
    # interpret-mode Pallas on CPU is slow; keep example counts sane
    config.addinivalue_line("markers", "slow: long-running sweeps")
