"""hotspot / hotspot3D Pallas kernels vs the jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hotspot, hotspot3d, ref


def grids(key, n):
    k1, k2 = jax.random.split(key)
    temp = ref.HS_AMB_TEMP + 5.0 * jax.random.normal(k1, (n, n), jnp.float32)
    power = jnp.abs(jax.random.normal(k2, (n, n), jnp.float32))
    return temp, power


@pytest.mark.parametrize("n", [64, 128, 256])
@pytest.mark.parametrize("steps", [1, 4])
def test_hotspot_matches_oracle(key, n, steps):
    t, p = grids(jax.random.fold_in(key, n), n)
    got = hotspot.hotspot(t, p, steps)
    want = ref.hotspot(t, p, steps)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_hotspot_band_size_invariance(key):
    # the banded decomposition must not change results
    t, p = grids(key, 128)
    a = hotspot.hotspot_step(t, p, band=32)
    b = hotspot.hotspot_step(t, p, band=128)
    np.testing.assert_allclose(a, b, rtol=0, atol=0)


def test_hotspot_bad_band_raises(key):
    t, p = grids(key, 100)
    with pytest.raises(ValueError, match="divisible"):
        hotspot.hotspot_step(t, p, band=64)


def test_hotspot_equilibrium_drift(key):
    # with zero power and uniform ambient temperature the field is a
    # fixed point of the stencil
    n = 64
    t = jnp.full((n, n), ref.HS_AMB_TEMP, jnp.float32)
    p = jnp.zeros((n, n), jnp.float32)
    out = hotspot.hotspot(t, p, 8)
    np.testing.assert_allclose(out, t, rtol=0, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([32, 64, 96]),
    steps=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_hotspot_hypothesis(n, steps, seed):
    t, p = grids(jax.random.PRNGKey(seed), n)
    band = 32
    got = hotspot.hotspot(t, p, steps, band=band)
    want = ref.hotspot(t, p, steps)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


# ----------------------------------------------------------- hotspot3D


def grids3d(key, n, nz=8):
    k1, k2 = jax.random.split(key)
    temp = ref.HS_AMB_TEMP + 5.0 * jax.random.normal(k1, (nz, n, n), jnp.float32)
    power = jnp.abs(jax.random.normal(k2, (nz, n, n), jnp.float32))
    return temp, power


@pytest.mark.parametrize("n", [32, 64, 128])
def test_hotspot3d_matches_oracle(key, n):
    t, p = grids3d(jax.random.fold_in(key, 3 * n), n)
    got = hotspot3d.hotspot3d(t, p, 3)
    want = ref.hotspot3d(t, p, 3)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(
    nz=st.sampled_from([2, 4, 8]),
    n=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hotspot3d_hypothesis(nz, n, seed):
    t, p = grids3d(jax.random.PRNGKey(seed), n, nz)
    got = hotspot3d.hotspot3d(t, p, 2)
    want = ref.hotspot3d(t, p, 2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_hotspot3d_coefficients_stable():
    c = ref.hotspot3d_coeffs(64, 64, 8)
    # explicit scheme stability: center coefficient must stay positive
    assert c["cc"] > 0.0
    assert all(v >= 0.0 for k, v in c.items() if k != "cc")
