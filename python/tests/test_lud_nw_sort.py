"""lud / nw / sort Pallas kernels vs the jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lud, nw, ref, sort

# -------------------------------------------------------------------- lud


def dd_matrix(key, n):
    return ref.make_diag_dominant(jax.random.normal(key, (n, n), jnp.float32))


@pytest.mark.parametrize("n", [32, 64, 96, 128, 256])
def test_lud_matches_oracle(key, n):
    m = dd_matrix(jax.random.fold_in(key, n), n)
    got = lud.lud(m)
    want = ref.lud(m)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("n", [64, 128])
def test_lud_reconstructs_input(key, n):
    m = dd_matrix(jax.random.fold_in(key, 7 * n), n)
    packed = lud.lud(m)
    l, u = ref.lud_unpack(packed)
    np.testing.assert_allclose(np.array(l @ u), np.array(m), rtol=1e-3, atol=5e-2)


def test_lud_identity_fixed_point():
    eye = jnp.eye(64, dtype=jnp.float32)
    np.testing.assert_allclose(lud.lud(eye), eye, rtol=0, atol=0)


def test_lud_indivisible_raises(key):
    m = dd_matrix(key, 100)
    with pytest.raises(ValueError, match="divisible"):
        lud.lud(m, block=32)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([32, 64]), seed=st.integers(0, 2**31 - 1))
def test_lud_hypothesis(n, seed):
    m = dd_matrix(jax.random.PRNGKey(seed), n)
    np.testing.assert_allclose(lud.lud(m), ref.lud(m), rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------- nw


@pytest.mark.parametrize("n", [16, 64, 128])
def test_nw_matches_oracle(key, n):
    r = ref.nw_reference_matrix(jax.random.fold_in(key, n), n)
    got = nw.nw(r, 10)
    want = ref.nw(r, 10)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_nw_borders():
    n = 32
    r = jnp.zeros((n + 1, n + 1), jnp.float32)
    m = nw.nw(r, 10)
    ar = np.arange(n + 1, dtype=np.float32)
    np.testing.assert_allclose(np.array(m)[0, :], -ar * 10.0)
    np.testing.assert_allclose(np.array(m)[:, 0], -ar * 10.0)


@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([8, 16, 32]),
    penalty=st.sampled_from([1.0, 5.0, 10.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_nw_hypothesis(n, penalty, seed):
    r = ref.nw_reference_matrix(jax.random.PRNGKey(seed), n)
    got = nw.nw(r, penalty)
    want = ref.nw(r, penalty)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_nw_monotone_in_penalty(key):
    # larger gap penalty can only decrease (or keep) the final score
    r = ref.nw_reference_matrix(key, 32)
    lo = np.array(nw.nw(r, 1.0))[-1, -1]
    hi = np.array(nw.nw(r, 20.0))[-1, -1]
    assert hi <= lo


# ------------------------------------------------------------------- sort


@pytest.mark.parametrize("n", [16, 256, 1024, 4096])
def test_sort_matches_oracle(key, n):
    x = jax.random.normal(jax.random.fold_in(key, n), (n,), jnp.float32)
    got = sort.sort(x)
    np.testing.assert_allclose(got, ref.sort(x), rtol=0, atol=0)


def test_sort_non_power_of_two_raises(key):
    x = jax.random.normal(key, (100,), jnp.float32)
    with pytest.raises(ValueError, match="power-of-two"):
        sort.sort(x)


@settings(max_examples=15, deadline=None)
@given(
    logn=st.integers(2, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_sort_hypothesis(logn, seed):
    n = 1 << logn
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32)
    out = np.array(sort.sort(x))
    assert (np.diff(out) >= 0).all()
    np.testing.assert_allclose(np.sort(np.array(x)), out, rtol=0, atol=0)


def test_sort_duplicates_and_negatives():
    x = jnp.array([3.0, -1.0, 3.0, 0.0, -1.0, 2.5, 2.5, -7.0], jnp.float32)
    np.testing.assert_allclose(sort.sort(x), np.sort(np.array(x)))
