"""AOT bridge: lower every (app, variant, size) graph to HLO text.

HLO *text* (not ``lowered.compile().serialize()`` and not the serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (the version behind the
published ``xla`` 0.1.6 crate) rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Outputs land in artifacts/:
  <app>_<variant>_<size>.hlo.txt   — one module per entry
  manifest.json                    — schema the Rust ArtifactRegistry reads

Incremental: an entry is skipped when its .hlo.txt already exists and the
manifest fingerprint (source mtime hash) matches — `make artifacts` is a
no-op on an unchanged tree.

Usage: python -m compile.aot [--out-dir ../artifacts] [--apps a,b] [--full]
"""

import argparse
import hashlib
import json
import pathlib
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _source_fingerprint() -> str:
    """Hash of every .py under compile/ — invalidates artifacts on edits."""
    root = pathlib.Path(__file__).parent
    h = hashlib.sha256()
    for p in sorted(root.rglob("*.py")):
        h.update(p.read_bytes())
    return h.hexdigest()[:16]


def _spec_json(s):
    return {"shape": list(s.shape), "dtype": "f32"}


def lower_entry(entry: model.Entry) -> str:
    lowered = jax.jit(entry.fn).lower(*entry.specs)
    return to_hlo_text(lowered)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--apps", default=None, help="comma-separated app filter")
    ap.add_argument("--sizes", default=None, help="comma-separated size override")
    ap.add_argument("--full", action="store_true", help="extended size grid")
    ap.add_argument("--force", action="store_true", help="rebuild everything")
    args = ap.parse_args(argv)

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    apps = set(args.apps.split(",")) if args.apps else None
    sizes = [int(s) for s in args.sizes.split(",")] if args.sizes else None

    fingerprint = _source_fingerprint()
    manifest_path = out / "manifest.json"
    old = {}
    if manifest_path.exists() and not args.force:
        try:
            prev = json.loads(manifest_path.read_text())
            if prev.get("fingerprint") == fingerprint:
                old = {a["name"]: a for a in prev.get("artifacts", [])}
        except (json.JSONDecodeError, KeyError):
            pass

    artifacts = []
    t_all = time.time()
    for entry in model.entries(apps=apps, sizes=sizes, full=args.full):
        fname = f"{entry.name}.hlo.txt"
        fpath = out / fname
        meta = {
            "name": entry.name,
            "app": entry.app,
            "variant": entry.variant,
            "size": entry.size,
            "file": fname,
            "inputs": [_spec_json(s) for s in entry.specs],
            "params": entry.params,
        }
        if entry.name in old and fpath.exists():
            artifacts.append(meta)
            continue
        t0 = time.time()
        try:
            text = lower_entry(entry)
        except Exception as e:  # keep going; report at the end
            print(f"FAIL {entry.name}: {e}", file=sys.stderr)
            continue
        fpath.write_text(text)
        artifacts.append(meta)
        print(f"  {entry.name}: {len(text) / 1e6:.2f} MB in {time.time() - t0:.1f}s")

    # Merge with prior manifest entries (an --apps/--sizes filtered run
    # must not drop artifacts it did not regenerate).
    have = {a["name"] for a in artifacts}
    for name, meta in old.items():
        if name not in have and (out / meta["file"]).exists():
            artifacts.append(meta)

    manifest = {
        "fingerprint": fingerprint,
        "hotspot_steps": model.HOTSPOT_STEPS,
        "hotspot3d_steps": model.HOTSPOT3D_STEPS,
        "hotspot3d_layers": model.HOTSPOT3D_LAYERS,
        "nw_penalty": model.NW_PENALTY,
        "artifacts": artifacts,
    }
    manifest_path.write_text(json.dumps(manifest, indent=1))
    print(f"wrote {len(artifacts)} artifacts in {time.time() - t_all:.1f}s -> {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
