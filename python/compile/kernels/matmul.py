"""Pallas blocked matmul — the "CUBLAS"-analog implementation variant.

TPU adaptation of the CUDA tiled-GEMM the paper benchmarks: instead of
threadblock tiles staged through shared memory, the BlockSpec grid stages
(bm, bk)/(bk, bn) tiles through VMEM and the inner product targets the MXU
(128x128 systolic array), accumulating in f32.

VMEM footprint per grid step = (bm*bk + bk*bn + bm*bn) * 4 B; with the
default 128-cube that is 192 KiB, far under the ~16 MiB VMEM budget, which
leaves room for double buffering by the Mosaic pipeliner.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated on the interpret path and TPU
performance is estimated from the BlockSpec (see DESIGN.md §Perf).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MXU_TILE = 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ y[k,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def block_sizes(m, n, k, bm=MXU_TILE, bn=MXU_TILE, bk=MXU_TILE):
    """Clamp the MXU-shaped tile to the problem; sizes must divide evenly."""
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"matmul dims ({m},{n},{k}) must be divisible by tiles ({bm},{bn},{bk})"
        )
    return bm, bn, bk


def matmul(x, y, *, bm=MXU_TILE, bn=MXU_TILE, bk=MXU_TILE, interpret=True):
    """C = A @ B via the blocked Pallas kernel. f32[M,K] @ f32[K,N]."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm, bn, bk = block_sizes(m, n, k, bm, bn, bk)
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=interpret,
    )(x, y)


def vmem_bytes(bm=MXU_TILE, bn=MXU_TILE, bk=MXU_TILE):
    """VMEM working set of one grid step (single-buffered), in bytes."""
    return 4 * (bm * bk + bk * bn + bm * bn)
