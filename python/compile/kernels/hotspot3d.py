"""Pallas hotspot3D 7-point stencil — the "CUDA"-analog Rodinia 3D kernel.

TPU adaptation: Rodinia's 3D CUDA kernel marches z-planes through shared
memory (three resident planes). Here each grid step owns one z-plane of the
output and reads the (z-1, z, z+1) planes from the VMEM-resident field.
The plane-per-step schedule is exactly the CUDA kernel's z-march expressed
as a BlockSpec grid instead of a software pipeline.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _hs3d_kernel(temp_ref, power_ref, o_ref, *, nz, cc, cw, ce, cn, cs, ct, cb, step_div_cap):
    z = pl.program_id(0)
    zb = jnp.maximum(z - 1, 0)
    zu = jnp.minimum(z + 1, nz - 1)
    t = temp_ref[z, :, :]
    below = temp_ref[zb, :, :]
    above = temp_ref[zu, :, :]
    w = jnp.concatenate([t[:, :1], t[:, :-1]], axis=1)
    e = jnp.concatenate([t[:, 1:], t[:, -1:]], axis=1)
    n_ = jnp.concatenate([t[:1, :], t[:-1, :]], axis=0)
    s = jnp.concatenate([t[1:, :], t[-1:, :]], axis=0)
    p = power_ref[0, :, :]
    o_ref[0, :, :] = (
        cc * t
        + cw * w
        + ce * e
        + cn * n_
        + cs * s
        + cb * below
        + ct * above
        + step_div_cap * p
        + ct * ref.HS_AMB_TEMP
    )


def hotspot3d_step(temp, power, *, interpret=True):
    """One step of the 7-point stencil on f32[NZ,NY,NX]."""
    nz, ny, nx = temp.shape
    c = ref.hotspot3d_coeffs(nx, ny, nz)
    kernel = lambda t, p, o: _hs3d_kernel(t, p, o, nz=nz, **c)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), jnp.float32),
        grid=(nz,),
        in_specs=[
            pl.BlockSpec((nz, ny, nx), lambda z: (0, 0, 0)),  # full field
            pl.BlockSpec((1, ny, nx), lambda z: (z, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ny, nx), lambda z: (z, 0, 0)),
        interpret=interpret,
    )(temp, power)


def hotspot3d(temp, power, steps, *, interpret=True):
    def body(_, t):
        return hotspot3d_step(t, power, interpret=interpret)

    return jax.lax.fori_loop(0, steps, body, temp)
