"""Pure-jnp reference oracles for every benchmark kernel.

These are the correctness ground truth: each Pallas kernel in this package
must match its oracle here to within float tolerance (pytest enforces it,
hypothesis sweeps shapes/dtypes). They are also lowered to HLO as the
"CUDA"-analog implementation variants (plain XLA, no Pallas) so the Rust
runtime has at least two real executable variants per interface.

All functions are shape-polymorphic pure functions of jnp arrays; no
Python-side randomness or I/O.
"""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

# ---------------------------------------------------------------------------
# matmul — Fig 1e. C = A @ B over f32[N,N].
# ---------------------------------------------------------------------------


def matmul(a, b):
    """Plain jnp matrix multiply (the BLAS/CUBLAS oracle)."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# hotspot — Fig 1a. Rodinia 2D thermal simulation.
#
# Rodinia's hotspot iterates a 5-point stencil over a power grid:
#   T'[i,j] = T[i,j] + (dt/cap) * ( P[i,j]
#             + (T[i+1,j] + T[i-1,j] - 2 T[i,j]) / Ry
#             + (T[i,j+1] + T[i,j-1] - 2 T[i,j]) / Rx
#             + (Tamb - T[i,j]) / Rz )
# with clamped (edge-replicate) boundaries, `steps` times.
# Constants follow Rodinia's hotspot defaults scaled to grid size; we fold
# them into precomputed coefficients (step_div_cap, rx1, ry1, rz1).
# ---------------------------------------------------------------------------

HS_AMB_TEMP = 80.0


def hotspot_coeffs(n):
    """Rodinia hotspot coefficient set for an n x n grid (f32 scalars)."""
    # Chip parameters from Rodinia hotspot.c
    t_chip = 0.0005
    chip_height = 0.016
    chip_width = 0.016
    k_si = 100.0
    cap_factor = 0.5
    precision = 0.001
    max_pd = 3.0e6
    spec_heat_si = 1.75e6

    grid_height = chip_height / n
    grid_width = chip_width / n
    cap = cap_factor * spec_heat_si * t_chip * grid_width * grid_height
    rx = grid_width / (2.0 * k_si * t_chip * grid_height)
    ry = grid_height / (2.0 * k_si * t_chip * grid_width)
    rz = t_chip / (k_si * grid_height * grid_width)
    max_slope = max_pd / (spec_heat_si * t_chip)
    step = precision / max_slope
    # Plain Python floats: callers embed these as compile-time constants
    # (both in jnp traces and inside Pallas kernels).
    return dict(
        step_div_cap=float(np.float32(step / cap)),
        rx1=float(np.float32(1.0 / rx)),
        ry1=float(np.float32(1.0 / ry)),
        rz1=float(np.float32(1.0 / rz)),
    )


def _hotspot_step(temp, power, step_div_cap, rx1, ry1, rz1):
    """One explicit-Euler step of the Rodinia hotspot stencil (edge clamp)."""
    up = jnp.concatenate([temp[:1, :], temp[:-1, :]], axis=0)
    down = jnp.concatenate([temp[1:, :], temp[-1:, :]], axis=0)
    left = jnp.concatenate([temp[:, :1], temp[:, :-1]], axis=1)
    right = jnp.concatenate([temp[:, 1:], temp[:, -1:]], axis=1)
    delta = step_div_cap * (
        power
        + (down + up - 2.0 * temp) * ry1
        + (right + left - 2.0 * temp) * rx1
        + (HS_AMB_TEMP - temp) * rz1
    )
    return temp + delta


def hotspot(temp, power, steps):
    """Run `steps` hotspot iterations on f32[N,N] grids."""
    c = hotspot_coeffs(temp.shape[0])
    step = partial(_hotspot_step, **c)

    def body(_, t):
        return step(t, power)

    return lax.fori_loop(0, steps, body, temp)


# ---------------------------------------------------------------------------
# hotspot3D — Fig 1b. Rodinia 3D thermal simulation (7-point stencil).
#
# T'[z,y,x] = cc*T + cw*W + ce*E + cn*N + cs*S + cb*B + ct*U
#             + step/cap * P + ct*amb_temp
# Coefficients follow Rodinia's 3D.c (with edge-replicate boundaries).
# ---------------------------------------------------------------------------


def hotspot3d_coeffs(nx, ny, nz):
    t_chip = 0.0005
    chip_height = 0.016
    chip_width = 0.016
    k_si = 100.0
    cap_factor = 0.5
    precision = 0.001
    max_pd = 3.0e6
    spec_heat_si = 1.75e6

    dx = chip_height / nx
    dy = chip_width / ny
    dz = t_chip / nz
    cap = cap_factor * spec_heat_si * t_chip * dx * dy
    rx = dy / (2.0 * k_si * t_chip * dx)
    ry = dx / (2.0 * k_si * t_chip * dy)
    rz = dz / (k_si * dx * dy)
    max_slope = max_pd / (spec_heat_si * t_chip)
    dt = precision / max_slope
    step_div_cap = dt / cap
    ce = cw = step_div_cap / rx
    cn = cs = step_div_cap / ry
    ct = cb = step_div_cap / rz
    cc = 1.0 - (2.0 * ce + 2.0 * cn + 3.0 * ct)
    return dict(
        cc=float(np.float32(cc)),
        cw=float(np.float32(cw)),
        ce=float(np.float32(ce)),
        cn=float(np.float32(cn)),
        cs=float(np.float32(cs)),
        ct=float(np.float32(ct)),
        cb=float(np.float32(cb)),
        step_div_cap=float(np.float32(step_div_cap)),
    )


def _shift(a, off, axis):
    """Edge-replicated shift of `a` by one along `axis` (off in {-1,+1})."""
    n = a.shape[axis]
    if off == 1:
        idx = jnp.concatenate([jnp.arange(1, n), jnp.array([n - 1])])
    else:
        idx = jnp.concatenate([jnp.array([0]), jnp.arange(0, n - 1)])
    return jnp.take(a, idx, axis=axis)


def _hotspot3d_step(t, p, cc, cw, ce, cn, cs, ct, cb, step_div_cap):
    w = _shift(t, -1, 2)
    e = _shift(t, 1, 2)
    n = _shift(t, -1, 1)
    s = _shift(t, 1, 1)
    b = _shift(t, -1, 0)
    u = _shift(t, 1, 0)
    return (
        cc * t
        + cw * w
        + ce * e
        + cn * n
        + cs * s
        + cb * b
        + ct * u
        + step_div_cap * p
        + ct * HS_AMB_TEMP
    )


def hotspot3d(temp, power, steps):
    """Run `steps` iterations of the 7-point stencil on f32[NZ,NY,NX]."""
    c = hotspot3d_coeffs(temp.shape[2], temp.shape[1], temp.shape[0])
    step = partial(_hotspot3d_step, **c)

    def body(_, t):
        return step(t, power)

    return lax.fori_loop(0, steps, body, temp)


# ---------------------------------------------------------------------------
# lud — Fig 1c. In-place LU decomposition (Doolittle, no pivoting),
# matching Rodinia's lud: returns a single matrix with U on/above the
# diagonal and the unit-lower-triangular L (without its 1s) below.
# ---------------------------------------------------------------------------


def lud(a):
    """LU decomposition without pivoting of f32[N,N]; Rodinia packed form."""
    n = a.shape[0]

    def outer(k, m):
        pivot = m[k, k]
        # L column below the diagonal
        col = m[:, k] / pivot
        row_mask = jnp.arange(n) > k
        m = m.at[:, k].set(jnp.where(row_mask, col, m[:, k]))
        # trailing update: m[i,j] -= l[i,k] * u[k,j] for i>k, j>k
        lcol = jnp.where(row_mask, m[:, k], 0.0)
        urow = jnp.where(jnp.arange(n) > k, m[k, :], 0.0)
        return m - jnp.outer(lcol, urow)

    return lax.fori_loop(0, n, outer, a)


def lud_unpack(m):
    """Split packed LU into (L with unit diag, U)."""
    l = jnp.tril(m, -1) + jnp.eye(m.shape[0], dtype=m.dtype)
    u = jnp.triu(m)
    return l, u


def make_diag_dominant(a):
    """Make a random matrix safely factorable without pivoting."""
    n = a.shape[0]
    return a + n * jnp.eye(n, dtype=a.dtype)


# ---------------------------------------------------------------------------
# nw — Fig 1d. Needleman-Wunsch global sequence alignment score matrix.
#
# Rodinia nw fills an (N+1)x(N+1) DP matrix:
#   M[i,j] = max(M[i-1,j-1] + ref[i,j], M[i,j-1] - penalty, M[i-1,j] - penalty)
# with M[i,0] = -i*penalty, M[0,j] = -j*penalty. `reference` is the
# substitution score matrix (Rodinia precomputes it from BLOSUM62 lookups).
# The wavefront recurrence is expressed over anti-diagonals so it lowers to
# a lax.fori_loop of vectorized ops (this is also how the GPU kernel works).
# ---------------------------------------------------------------------------


def nw(reference, penalty):
    """DP score matrix for f32[N+1,N+1] reference (row/col 0 ignored).

    `reference` carries the substitution scores at [i,j] for i,j >= 1.
    Returns the filled f32[N+1,N+1] matrix.
    """
    n = reference.shape[0]  # N+1
    pen = jnp.float32(penalty)
    init = jnp.zeros((n, n), jnp.float32)
    ar = jnp.arange(n, dtype=jnp.float32)
    init = init.at[:, 0].set(-ar * pen)
    init = init.at[0, :].set(-ar * pen)

    rows = jnp.arange(n)

    def diag_body(d, m):
        # cells (i, j) with i + j == d, 1 <= i, j <= n-1
        i = rows
        j = d - i
        valid = (i >= 1) & (j >= 1) & (j <= n - 1)
        jc = jnp.clip(j, 0, n - 1)
        nw_ = m[jnp.clip(i - 1, 0, n - 1), jnp.clip(jc - 1, 0, n - 1)]
        up = m[jnp.clip(i - 1, 0, n - 1), jc]
        left = m[i, jnp.clip(jc - 1, 0, n - 1)]
        sub = reference[i, jc]
        val = jnp.maximum(nw_ + sub, jnp.maximum(up - pen, left - pen))
        cur = m[i, jc]
        new = jnp.where(valid, val, cur)
        return m.at[i, jc].set(new)

    return lax.fori_loop(2, 2 * n - 1, diag_body, init)


def nw_reference_matrix(key, n):
    """Random substitution-score matrix like Rodinia's BLOSUM62 lookups."""
    return jax.random.randint(key, (n + 1, n + 1), -10, 11).astype(jnp.float32)


# ---------------------------------------------------------------------------
# sort — quickstart app (paper Listing 1.3). Ascending sort of f32[N].
# ---------------------------------------------------------------------------


def sort(arr):
    return jnp.sort(arr)
