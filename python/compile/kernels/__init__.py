"""L1 Pallas kernels + pure-jnp reference oracles (ref.py).

Each module exposes the paper benchmark's hot-spot as a Pallas kernel
(interpret=True; see DESIGN.md §Hardware-Adaptation) plus helpers. ref.py
carries the oracles the kernels are tested against.
"""

from . import hotspot, hotspot3d, lud, matmul, nw, ref, sort  # noqa: F401
