"""Pallas Needleman-Wunsch wavefront — the "CUDA"-analog Rodinia nw kernel.

Rodinia's CUDA nw sweeps the DP matrix in anti-diagonal waves of
threadblocks, each block buffering its tile in shared memory. TPU
adaptation: the whole (N+1)^2 f32 matrix for our AOT sizes (<= 2049^2 =
16 MiB... we cap at 1025^2 = 4 MiB) fits VMEM, so the kernel keeps the
matrix resident and runs the anti-diagonal recurrence as a fori_loop of
full-row gathers — the wave parallelism maps to the VPU lanes instead of
threadblocks. Grid = (1,): a single kernel instance owns the matrix, like
one cooperative CUDA grid launch.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nw_kernel(ref_ref, o_ref, *, n, penalty):
    pen = jnp.float32(penalty)
    ar = jnp.arange(n, dtype=jnp.float32)
    m = jnp.zeros((n, n), jnp.float32)
    m = m.at[:, 0].set(-ar * pen)
    m = m.at[0, :].set(-ar * pen)
    sub = ref_ref[...]
    rows = jnp.arange(n)

    def diag_body(d, m):
        i = rows
        j = d - i
        valid = (i >= 1) & (j >= 1) & (j <= n - 1)
        jc = jnp.clip(j, 0, n - 1)
        diag = m[jnp.clip(i - 1, 0, n - 1), jnp.clip(jc - 1, 0, n - 1)]
        up = m[jnp.clip(i - 1, 0, n - 1), jc]
        left = m[i, jnp.clip(jc - 1, 0, n - 1)]
        val = jnp.maximum(diag + sub[i, jc], jnp.maximum(up - pen, left - pen))
        return m.at[i, jc].set(jnp.where(valid, val, m[i, jc]))

    m = jax.lax.fori_loop(2, 2 * n - 1, diag_body, m)
    o_ref[...] = m


def nw(reference, penalty, *, interpret=True):
    """Fill the NW DP matrix for f32[N+1,N+1] substitution scores."""
    n = reference.shape[0]
    kernel = lambda r, o: _nw_kernel(r, o, n=n, penalty=float(penalty))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        grid=(1,),
        in_specs=[pl.BlockSpec((n, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        interpret=interpret,
    )(reference)
