"""Pallas bitonic sort — the "CUDA"-analog sort variant from Listing 1.3.

A bitonic sorting network over a power-of-two f32 vector. On a GPU this is
the classic shared-memory bitonic kernel; the TPU mapping keeps the whole
vector in VMEM and performs each compare-exchange stage as a vectorized
gather + min/max over the full vector (VPU lanes play the role of threads).
log2(N)*(log2(N)+1)/2 stages, all inside one kernel instance.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_kernel(x_ref, o_ref, *, n):
    logn = n.bit_length() - 1
    idx = jnp.arange(n)
    arr = x_ref[...]

    def stage(arr, k, j):
        partner = idx ^ j
        a = arr
        b = arr[partner]
        ascending = (idx & k) == 0
        keep_min = (idx < partner) == ascending
        lo = jnp.minimum(a, b)
        hi = jnp.maximum(a, b)
        return jnp.where(keep_min, lo, hi)

    # Static double loop: network depth is log-sized so full unroll is fine.
    for kk in range(1, logn + 1):
        k = 1 << kk
        for jj in range(kk - 1, -1, -1):
            arr = stage(arr, k, 1 << jj)
    o_ref[...] = arr


def sort(x, *, interpret=True):
    """Ascending sort of f32[N], N a power of two, via a bitonic network."""
    n = x.shape[0]
    if n & (n - 1):
        raise ValueError(f"bitonic sort needs power-of-two length, got {n}")
    kernel = lambda i, o: _bitonic_kernel(i, o, n=n)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=(1,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,))],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        interpret=interpret,
    )(x)
