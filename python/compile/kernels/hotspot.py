"""Pallas hotspot 2D stencil — the "CUDA"-analog Rodinia hotspot kernel.

TPU adaptation: Rodinia's CUDA kernel tiles the grid into threadblocks with
halo rows staged through shared memory. Here the grid is tiled into row
bands; each grid step streams a (band, N) output block through VMEM while
the temperature field is read from a full-array block (the band's +-1 halo
rows come from the same VMEM-resident block — for the sizes we AOT-compile,
N <= 1024, the f32 field is <= 4 MiB and fits VMEM whole, so the schedule
is: load field once, stream power/output bands across it).

One pallas_call performs ONE Euler step; the time loop lives in the L2
model (lax.fori_loop) so the whole simulation lowers to a single HLO
module (no per-step dispatch from Rust).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BAND = 64


def _hotspot_kernel(
    temp_ref, power_ref, o_ref, *, band, n, step_div_cap, rx1, ry1, rz1
):
    """Compute one row band of the 5-point clamped stencil."""
    i = pl.program_id(0)
    r0 = i * band  # first absolute row of this band
    temp = temp_ref[...]  # full (n, n) field in VMEM
    rows = r0 + jax.lax.iota(jnp.int32, band)
    up_idx = jnp.maximum(rows - 1, 0)
    down_idx = jnp.minimum(rows + 1, n - 1)
    center = jax.lax.dynamic_slice(temp, (r0, 0), (band, n))
    up = jnp.take(temp, up_idx, axis=0)
    down = jnp.take(temp, down_idx, axis=0)
    left = jnp.concatenate([center[:, :1], center[:, :-1]], axis=1)
    right = jnp.concatenate([center[:, 1:], center[:, -1:]], axis=1)
    power = power_ref[...]
    delta = step_div_cap * (
        power
        + (down + up - 2.0 * center) * ry1
        + (right + left - 2.0 * center) * rx1
        + (ref.HS_AMB_TEMP - center) * rz1
    )
    o_ref[...] = center + delta


def hotspot_step(temp, power, *, band=DEFAULT_BAND, interpret=True):
    """One hotspot Euler step on f32[N,N] via the banded Pallas kernel."""
    n = temp.shape[0]
    band = min(band, n)
    if n % band:
        raise ValueError(f"grid size {n} not divisible by band {band}")
    c = ref.hotspot_coeffs(n)
    kernel = lambda t, p, o: _hotspot_kernel(t, p, o, band=band, n=n, **c)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        grid=(n // band,),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),  # full field (halo source)
            pl.BlockSpec((band, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((band, n), lambda i: (i, 0)),
        interpret=interpret,
    )(temp, power)


def hotspot(temp, power, steps, *, band=DEFAULT_BAND, interpret=True):
    """`steps` iterations; the loop is traced so it fuses into one module."""

    def body(_, t):
        return hotspot_step(t, power, band=band, interpret=interpret)

    return jax.lax.fori_loop(0, steps, body, temp)
