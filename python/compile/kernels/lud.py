"""Pallas blocked LU decomposition — Rodinia lud's three-kernel structure.

Rodinia's CUDA lud factors the matrix in B-sized panels with three kernels:
  lud_diagonal  — factor the B x B diagonal block,
  lud_perimeter — solve the row panel (U) and column panel (L),
  lud_internal  — rank-B GEMM update of the trailing submatrix (the hot
                  spot, >90% of the FLOPs).

TPU adaptation: diagonal + perimeter are tiny and latency-bound, so they
stay as traced jnp (XLA fuses them); the internal update — the hot spot —
is the Pallas kernel, a (bm, B) x (B, bn) tile GEMM-subtract streamed
through VMEM, MXU-shaped like kernels/matmul.py.

The panel loop runs at trace time (Python range over a static size), so a
fixed-size problem lowers to one HLO module.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_B = 32  # panel width (Rodinia uses 16; 32 suits 8-lane VPU rows)


def _internal_kernel(l_ref, u_ref, a_ref, o_ref):
    """o = a - l @ u for one (bm, bn) trailing tile."""
    o_ref[...] = a_ref[...] - jnp.dot(
        l_ref[...], u_ref[...], preferred_element_type=jnp.float32
    )


def _fit_block(dim, pref):
    """Largest divisor of `dim` that is <= pref (trailing dims shrink by B
    each panel step, so a fixed 128 tile rarely divides them evenly)."""
    b = min(pref, dim)
    while dim % b:
        b -= 1
    return b


def _internal_update(lpanel, upanel, trailing, *, bm, bn, interpret):
    """trailing -= lpanel @ upanel via the Pallas tile kernel."""
    m, b = lpanel.shape
    _, n = upanel.shape
    bm, bn = _fit_block(m, bm), _fit_block(n, bn)
    return pl.pallas_call(
        _internal_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, b), lambda i, j: (i, 0)),
            pl.BlockSpec((b, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(lpanel, upanel, trailing)


def _factor_diag(d):
    """Unblocked Doolittle LU of the B x B diagonal block (packed form)."""
    b = d.shape[0]

    def outer(k, m):
        pivot = m[k, k]
        col = m[:, k] / pivot
        below = jnp.arange(b) > k
        m = m.at[:, k].set(jnp.where(below, col, m[:, k]))
        lcol = jnp.where(below, m[:, k], 0.0)
        urow = jnp.where(jnp.arange(b) > k, m[k, :], 0.0)
        return m - jnp.outer(lcol, urow)

    return jax.lax.fori_loop(0, b, outer, d)


# NOTE: jax.scipy.linalg.solve_triangular lowers to a typed-FFI custom
# call that xla_extension 0.5.1 (the version behind the rust `xla` crate)
# rejects at compile time, so both substitutions are written as explicit
# fori_loops over the B=32 panel — they lower to plain HLO ops.


def _solve_lower_unit(lu, rhs):
    """Solve L X = rhs with L unit-lower from packed lu (forward subst).

    Row i only reads already-final rows j < i (strictly-lower L), so the
    loop-carried X is safe.
    """
    l = jnp.tril(lu, -1)
    b = lu.shape[0]

    def body(i, x):
        xi = rhs[i, :] - l[i, :] @ x
        return x.at[i, :].set(xi)

    return jax.lax.fori_loop(0, b, body, jnp.zeros_like(rhs))


def _solve_upper_right(lu, rhs):
    """Solve X U = rhs with U upper from packed lu (column substitution)."""
    u = jnp.triu(lu)
    b = lu.shape[0]

    def body(j, x):
        col = (rhs[:, j] - x @ u[:, j]) / u[j, j]
        return x.at[:, j].set(col)

    return jax.lax.fori_loop(0, b, body, jnp.zeros_like(rhs))


def lud(a, *, block=DEFAULT_B, bm=128, bn=128, interpret=True):
    """Blocked LU (no pivoting) of f32[N,N]; returns Rodinia packed LU."""
    n = a.shape[0]
    b = min(block, n)
    if n % b:
        raise ValueError(f"matrix size {n} not divisible by block {b}")
    m = a
    for k0 in range(0, n, b):
        d = _factor_diag(m[k0 : k0 + b, k0 : k0 + b])
        m = m.at[k0 : k0 + b, k0 : k0 + b].set(d)
        rest = k0 + b
        if rest >= n:
            break
        # perimeter: U row panel and L column panel
        urow = _solve_lower_unit(d, m[k0 : k0 + b, rest:])
        lcol = _solve_upper_right(d, m[rest:, k0 : k0 + b])
        m = m.at[k0 : k0 + b, rest:].set(urow)
        m = m.at[rest:, k0 : k0 + b].set(lcol)
        # internal: trailing -= L @ U  (the Pallas hot spot)
        trailing = _internal_update(
            lcol, urow, m[rest:, rest:], bm=bm, bn=bn, interpret=interpret
        )
        m = m.at[rest:, rest:].set(trailing)
    return m
