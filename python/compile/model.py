"""L2: benchmark compute graphs, one per (app, variant), ready to lower.

Each entry pairs a pure jax function with example input specs so aot.py can
`jax.jit(fn).lower(*specs)` and dump HLO text. Two executable variants per
interface come from here:

  * ``jnp``    — the pure-XLA graph from kernels/ref.py. This plays the
                 role of the paper's hand-written CUDA variant (a
                 straightforwardly-parallel implementation the XLA
                 compiler maps to the device).
  * ``pallas`` — the hand-tiled Pallas kernel (interpret=True). This plays
                 the role of the *tuned* device library variant (CUBLAS
                 for mmul, the hand-optimized Rodinia CUDA kernel for the
                 others); its tiling is chosen for the TPU memory
                 hierarchy (DESIGN.md §Hardware-Adaptation).

The native CPU variants ("Seq"/"OMP" analogs) live in rust/src/apps/*.

The stencil time loops run INSIDE the lowered module (lax.fori_loop), so
one artifact = one full simulation — Rust never dispatches per step.
"""

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import hotspot as k_hotspot
from .kernels import hotspot3d as k_hotspot3d
from .kernels import lud as k_lud
from .kernels import matmul as k_matmul
from .kernels import nw as k_nw
from .kernels import ref
from .kernels import sort as k_sort

F32 = jnp.float32

# Iteration counts baked into the stencil artifacts. Rodinia's defaults are
# larger; 8 keeps CPU execution of the biggest AOT size < seconds while
# still exercising the loop structure. Rust mirrors these in apps/*.
HOTSPOT_STEPS = 8
HOTSPOT3D_STEPS = 8
HOTSPOT3D_LAYERS = 8
NW_PENALTY = 10.0


@dataclass
class Entry:
    """One lowerable artifact: (app, variant, size) -> HLO module."""

    app: str
    variant: str
    size: int
    fn: Callable
    specs: tuple  # ShapeDtypeStructs of the inputs
    params: dict = field(default_factory=dict)

    @property
    def name(self) -> str:
        return f"{self.app}_{self.variant}_{self.size}"


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def _matmul_entries(size: int):
    s = (_spec(size, size), _spec(size, size))
    yield Entry("matmul", "jnp", size, lambda a, b: (ref.matmul(a, b),), s)
    if size >= 8:
        # clamp tiles to the problem so tiny sizes still build
        bm = bn = bk = min(128, size)
        yield Entry(
            "matmul",
            "pallas",
            size,
            lambda a, b: (k_matmul.matmul(a, b, bm=bm, bn=bn, bk=bk),),
            s,
            {"bm": bm, "bn": bn, "bk": bk},
        )


def _hotspot_entries(size: int):
    s = (_spec(size, size), _spec(size, size))
    p = {"steps": HOTSPOT_STEPS}
    yield Entry(
        "hotspot", "jnp", size, lambda t, pw: (ref.hotspot(t, pw, HOTSPOT_STEPS),), s, p
    )
    band = min(k_hotspot.DEFAULT_BAND, size)
    yield Entry(
        "hotspot",
        "pallas",
        size,
        lambda t, pw: (k_hotspot.hotspot(t, pw, HOTSPOT_STEPS, band=band),),
        s,
        {**p, "band": band},
    )


def _hotspot3d_entries(size: int):
    nz = HOTSPOT3D_LAYERS
    s = (_spec(nz, size, size), _spec(nz, size, size))
    p = {"steps": HOTSPOT3D_STEPS, "layers": nz}
    yield Entry(
        "hotspot3d",
        "jnp",
        size,
        lambda t, pw: (ref.hotspot3d(t, pw, HOTSPOT3D_STEPS),),
        s,
        p,
    )
    yield Entry(
        "hotspot3d",
        "pallas",
        size,
        lambda t, pw: (k_hotspot3d.hotspot3d(t, pw, HOTSPOT3D_STEPS),),
        s,
        p,
    )


def _lud_entries(size: int):
    s = (_spec(size, size),)
    yield Entry("lud", "jnp", size, lambda a: (ref.lud(a),), s)
    yield Entry("lud", "pallas", size, lambda a: (k_lud.lud(a),), s)


def _nw_entries(size: int):
    # `size` is N; the DP matrix is (N+1)^2
    n1 = size + 1
    s = (_spec(n1, n1),)
    p = {"penalty": NW_PENALTY}
    yield Entry("nw", "jnp", size, lambda r: (ref.nw(r, NW_PENALTY),), s, p)
    yield Entry("nw", "pallas", size, lambda r: (k_nw.nw(r, NW_PENALTY),), s, p)


def _sort_entries(size: int):
    s = (_spec(size),)
    yield Entry("sort", "jnp", size, lambda a: (ref.sort(a),), s)
    yield Entry("sort", "pallas", size, lambda a: (k_sort.sort(a),), s)


APP_BUILDERS = {
    "matmul": _matmul_entries,
    "hotspot": _hotspot_entries,
    "hotspot3d": _hotspot3d_entries,
    "lud": _lud_entries,
    "nw": _nw_entries,
    "sort": _sort_entries,
}

# Default AOT size grids. These are the sizes the Rust runtime can execute
# for real; the Fig. 1 sweeps extrapolate beyond them through the
# calibrated device model (DESIGN.md §3). Kept modest so `make artifacts`
# finishes in minutes on CPU.
DEFAULT_SIZES = {
    "matmul": [8, 16, 32, 64, 128, 256, 512],
    "hotspot": [64, 128, 256, 512],
    "hotspot3d": [64, 128, 256],
    "lud": [64, 128, 256],
    "nw": [63, 127, 255, 511],  # DP matrix is size+1 (power-of-two friendly)
    "sort": [256, 1024, 4096, 16384],
}

FULL_SIZES = {
    "matmul": DEFAULT_SIZES["matmul"] + [1024],
    "hotspot": DEFAULT_SIZES["hotspot"] + [1024],
    "hotspot3d": DEFAULT_SIZES["hotspot3d"] + [512],
    "lud": DEFAULT_SIZES["lud"] + [512],
    "nw": DEFAULT_SIZES["nw"] + [1023],
    "sort": DEFAULT_SIZES["sort"] + [65536],
}


def entries(apps=None, sizes=None, full=False):
    """Yield every Entry for the requested apps/size grid."""
    table = FULL_SIZES if full else DEFAULT_SIZES
    for app, builder in APP_BUILDERS.items():
        if apps and app not in apps:
            continue
        for size in sizes or table[app]:
            yield from builder(size)
