//! Integration tests for the elastic control plane: live worker
//! migration between scheduling contexts under a real backlog (workers
//! flow in, p95 drops vs a static control, workers flow home after the
//! drain, pinned variants are unaffected throughout), and shard
//! elasticity in a cluster (a burst spawns a gossip-seeded shard that
//! is calibrated from its first request; retirement drains cleanly).

use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

use compar::autoscale::{AutoscaleOptions, Autoscaler};
use compar::cluster::{ClusterScaleOptions, LocalCluster, RouterOptions};
use compar::runtime::Tensor;
use compar::serve::protocol::SubmitReq;
use compar::serve::{loadgen, Client, LoadgenOptions, ServeOptions};
use compar::taskrt::{
    AccessMode, Arch, Codelet, Config, Runtime, SchedPolicy, SelectorKind, TaskId, TaskSpec,
};

/// A CPU codelet whose variants really sleep, so a burst builds an
/// observable backlog the control loop must relieve.
fn sleeper_codelet(ms: u64) -> Codelet {
    let napping: compar::taskrt::NativeFn = Arc::new(move |_| {
        std::thread::sleep(Duration::from_millis(ms));
        Ok(())
    });
    Codelet::new("duo", "sort", vec![AccessMode::Read])
        .with_native("omp", Arch::Cpu, napping.clone())
        .with_native("seq", Arch::Cpu, napping)
}

/// 6 CPU workers partitioned into hot:[0,1] and pool:[2..6].
fn hot_pool_runtime() -> (Arc<Runtime>, usize, usize) {
    let rt = Runtime::new(
        Config {
            ncpu: 6,
            ncuda: 0,
            sched: SchedPolicy::Eager,
            ..Config::default()
        },
        None,
    )
    .unwrap();
    let hot = rt
        .create_context_with("hot", &[0, 1], SchedPolicy::Eager, SelectorKind::Greedy)
        .unwrap();
    let pool = rt
        .create_context_with("pool", &[2, 3, 4, 5], SchedPolicy::Eager, SelectorKind::Greedy)
        .unwrap();
    (Arc::new(rt), hot, pool)
}

/// Submit a 40-task burst into `ctx` and return (task ids, p95 sojourn
/// seconds). Sojourn is measured from the burst's first task start to
/// each task's completion — with a fixed worker count the tail waits
/// behind the whole queue, so p95 tracks the backlog directly.
fn run_burst(rt: &Runtime, cl: &Arc<Codelet>, ctx: usize, probes: &mut Vec<TaskId>) -> f64 {
    let mut ids = Vec::new();
    for _ in 0..40 {
        let h = rt.register_data(Tensor::vector(vec![0.0; 4]));
        ids.push(
            rt.submit(TaskSpec::new(cl.clone(), vec![h], 4096).in_context(ctx))
                .unwrap(),
        );
    }
    // a pinned probe submitted while the backlog is at its deepest: the
    // Forced path must be unaffected by any migration underneath it
    let h = rt.register_data(Tensor::vector(vec![0.0; 4]));
    probes.push(
        rt.submit(
            TaskSpec::new(cl.clone(), vec![h], 4096)
                .in_context(ctx)
                .with_variant("seq"),
        )
        .unwrap(),
    );
    rt.wait_all().unwrap();
    let results = rt.drain_results();
    let burst: Vec<&compar::taskrt::TaskResult> =
        results.iter().filter(|r| ids.contains(&r.task)).collect();
    assert_eq!(burst.len(), 40);
    let t0 = burst
        .iter()
        .map(|r| r.t_start)
        .fold(f64::INFINITY, f64::min);
    let mut sojourns: Vec<f64> = burst.iter().map(|r| r.t_end - t0).collect();
    sojourns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let probe_variant = results
        .iter()
        .find(|r| Some(&r.task) == probes.last())
        .map(|r| r.variant.clone());
    assert_eq!(
        probe_variant.as_deref(),
        Some("seq"),
        "pinned variant must survive the burst (and any migration)"
    );
    compar::util::stats::percentile(&sojourns, 95.0)
}

/// Acceptance criterion: under a sustained 40-task backlog on one
/// context, workers migrate into it, p95 drops vs a no-autoscale
/// control, and after the drain the workers return home — while a
/// forced-variant probe is honored throughout.
#[test]
fn workers_migrate_into_pressured_context_and_return_home() {
    // control: static partitions
    let (rt, hot, _pool) = hot_pool_runtime();
    let cl = rt.register_codelet(sleeper_codelet(5));
    let mut probes = Vec::new();
    let p95_off = run_burst(&rt, &cl, hot, &mut probes);
    drop(rt);

    // elastic: same topology, control loop on
    let (rt, hot, pool) = hot_pool_runtime();
    let cl = rt.register_codelet(sleeper_codelet(5));
    let scaler = Autoscaler::start(
        rt.clone(),
        AutoscaleOptions {
            period: Duration::from_millis(10),
            cooldown: Duration::from_millis(40),
            sustain: 1,
            ..AutoscaleOptions::default()
        },
    );

    // watch the hot context grow while the burst runs
    let rt2 = rt.clone();
    let watcher = std::thread::spawn(move || {
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut peak = 0usize;
        while Instant::now() < deadline {
            peak = peak.max(rt2.worker_count_in(hot));
            if peak > 2 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        peak
    });
    let mut probes = Vec::new();
    let p95_on = run_burst(&rt, &cl, hot, &mut probes);
    let peak = watcher.join().unwrap();
    assert!(
        peak > 2,
        "no worker ever migrated into the pressured context (peak {peak})"
    );

    // give-back: once calm, the borrowed workers return home
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let (h, p) = (rt.worker_count_in(hot), rt.worker_count_in(pool));
        if h == 2 && p == 4 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "workers never returned home (hot {h}, pool {p})"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    let status = scaler.status();
    assert!(status.moves >= 2, "expected scale-up and give-back: {status:?}");
    scaler.stop();

    // elasticity must pay off on the tail: generous margin against CI
    // noise — the structural gap (2 workers vs up to 5) is far larger
    assert!(
        p95_on < p95_off * 0.9,
        "p95 with autoscale ({p95_on:.4}s) not better than control ({p95_off:.4}s)"
    );

    // the runtime still works after all the churn
    let h = rt.register_data(Tensor::vector(vec![0.0; 4]));
    rt.submit(TaskSpec::new(cl.clone(), vec![h], 4096).in_context(hot))
        .unwrap();
    rt.wait_all().unwrap();
}

/// The runtime-level floor: a migration may never empty a context or
/// remove the last worker of an architecture; unknown contexts error.
#[test]
fn move_workers_respects_floors_and_validates() {
    let (rt, hot, pool) = hot_pool_runtime();
    assert!(rt.move_workers(hot, hot, 1).is_err(), "self-move");
    assert!(rt.move_workers(99, hot, 1).is_err(), "unknown source");
    assert!(rt.move_workers(hot, 99, 1).is_err(), "unknown destination");
    // asking for far more than the donor can give moves all but one
    let moved = rt.move_workers(pool, hot, 100).unwrap();
    assert_eq!(moved, 3, "pool must keep its last worker");
    assert_eq!(rt.worker_count_in(pool), 1);
    assert_eq!(rt.worker_count_in(hot), 5);
    // nothing left to give
    assert_eq!(rt.move_workers(pool, hot, 1).unwrap(), 0);
    // resize_context exchanges with the default (empty here) pool
    assert!(rt.resize_context(0, 3).is_err(), "ctx 0 is the pool itself");
}

fn submit(id: u64, seed: u64, verify: bool) -> SubmitReq {
    SubmitReq {
        id,
        app: "matmul".into(),
        size: 48,
        tasks: 1,
        ctx: None,
        seed,
        variant: None,
        verify,
        trace: 0,
    }
}

/// Acceptance criterion: the router spawns a shard under burst, the
/// newcomer serves requests with gossip-seeded perf models (no
/// recalibration sweep on its first requests), and retirement drains
/// cleanly with zero failed client requests.
#[test]
fn cluster_spawns_gossip_seeded_shard_under_burst_and_retires_it() {
    let serve = ServeOptions {
        addr: "127.0.0.1:0".into(),
        ncpu: 2,
        ncuda: 0,
        selector: Some(SelectorKind::Calibrating),
        ..ServeOptions::default()
    };
    let ropts = RouterOptions {
        listen: "127.0.0.1:0".into(),
        health_period: Duration::from_millis(100),
        gossip_period: Duration::from_millis(120),
        ..RouterOptions::default()
    };
    let scale = ClusterScaleOptions {
        min_shards: 1,
        max_shards: 3,
        up_load: 3,
        down_load: 0,
        sustain: 1,
        // long enough that the newcomer cannot be retired while the
        // test is still talking to it directly
        cooldown: Duration::from_millis(1500),
        period: Duration::from_millis(100),
        ..ClusterScaleOptions::default()
    };
    let (cluster, launcher) = LocalCluster::start_elastic(2, &serve, ropts, scale).unwrap();
    let initial: BTreeSet<String> = cluster
        .router
        .shards()
        .iter()
        .map(|d| d.addr.clone())
        .collect();

    // calibrate (matmul, 48) on shard A only, then give the router a
    // gossip round to pull the buckets it will seed newcomers with
    let addr_a = cluster.shards[0].local_addr().to_string();
    let mut c = Client::connect(&addr_a).unwrap();
    for r in 0..12u64 {
        c.submit(submit(r, 100 + r, false)).unwrap();
    }
    c.quit().unwrap();
    // two pull periods are enough for the router's gossip cache to hold
    // shard A's buckets (what seed_newcomer ships to spawned shards)
    std::thread::sleep(Duration::from_millis(300));

    // burst through the router until the scaler spawns a third shard
    let lg = LoadgenOptions {
        clients: 6,
        requests: 30,
        app: "matmul".into(),
        // heavy enough that the health poll's in-flight gauge stays
        // above the spawn band for the whole burst
        size: 128,
        tasks: 2,
        pipeline: 8,
        verify: false,
        ..LoadgenOptions::default()
    };
    let addr = cluster.addr();
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut errors = 0usize;
    loop {
        let report = loadgen::run(&addr, &lg).unwrap();
        errors += report.errors;
        let (spawned, _) = cluster.router.scale_counters();
        if spawned >= 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "burst load never triggered a shard spawn"
        );
    }
    assert_eq!(errors, 0, "requests failed during the burst");

    // the newcomer is in the table; connect to it directly — its first
    // requests must already exploit (one variant, no calibration sweep)
    let newcomer = cluster
        .router
        .shards()
        .iter()
        .map(|d| d.addr.clone())
        .find(|a| !initial.contains(a))
        .expect("spawned shard missing from the table");
    let mut c = Client::connect(&newcomer).unwrap();
    let mut variants = BTreeSet::new();
    for r in 0..6u64 {
        let resp = c.submit(submit(r, 500 + r, false)).unwrap();
        variants.extend(resp.variants.clone());
    }
    c.quit().unwrap();
    assert_eq!(
        variants.len(),
        1,
        "gossip-seeded newcomer still ran a calibration sweep: {variants:?}"
    );

    // idle: the scaler retires back down, and the shrunk cluster still
    // serves flawlessly
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (_, retired) = cluster.router.scale_counters();
        if retired >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "idle cluster never retired a shard");
        std::thread::sleep(Duration::from_millis(100));
    }
    let tail = LoadgenOptions {
        clients: 2,
        requests: 6,
        app: "matmul".into(),
        size: 48,
        verify: true,
        ..LoadgenOptions::default()
    };
    let report = loadgen::run(&addr, &tail).unwrap();
    assert_eq!(report.errors, 0, "requests failed after the retire");

    // the v5 status reflects the churn
    let mut c = Client::connect(&addr).unwrap();
    let status = c.autoscale_status().unwrap();
    let _ = c.quit();
    assert!(status.enabled);
    assert!(status.shards_spawned >= 1 && status.shards_retired >= 1, "{status:?}");

    launcher.shutdown_all();
    cluster.shutdown().unwrap();
}
