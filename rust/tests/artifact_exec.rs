//! End-to-end artifact execution: manifest -> PJRT compile -> execute ->
//! numerics vs native Rust reference. Requires `make artifacts`.

use compar::runtime::{Manifest, Tensor, XlaEngine, XlaService};
use compar::util::rng::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    compar::runtime::manifest::default_dir()
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Naive f32 matmul for checking artifact numerics.
fn matmul_ref(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            for j in 0..n {
                c[i * n + j] += aik * b[k * n + j];
            }
        }
    }
    c
}

#[test]
fn matmul_jnp_artifact_matches_reference() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let m = Manifest::load(&artifacts_dir()).unwrap();
    let meta = m.find("matmul", "jnp", 64).expect("matmul_jnp_64 artifact");
    let mut engine = XlaEngine::new().unwrap();
    let mut rng = Rng::new(42);
    let a = rng.vec_f32(64 * 64, -1.0, 1.0);
    let b = rng.vec_f32(64 * 64, -1.0, 1.0);
    let out = engine
        .run(
            meta,
            &[
                Tensor::matrix(64, 64, a.clone()),
                Tensor::matrix(64, 64, b.clone()),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 1);
    let want = matmul_ref(&a, &b, 64);
    let got = out[0].data();
    let max_diff = want
        .iter()
        .zip(got)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "max diff {max_diff}");
}

#[test]
fn pallas_and_jnp_variants_agree() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let m = Manifest::load(&artifacts_dir()).unwrap();
    let mut engine = XlaEngine::new().unwrap();
    let mut rng = Rng::new(7);
    for size in [64usize, 128] {
        let jnp = m.find("matmul", "jnp", size).unwrap();
        let pal = m.find("matmul", "pallas", size).unwrap();
        let a = Tensor::matrix(size, size, rng.vec_f32(size * size, -1.0, 1.0));
        let b = Tensor::matrix(size, size, rng.vec_f32(size * size, -1.0, 1.0));
        let o1 = engine.run(jnp, &[a.clone(), b.clone()]).unwrap();
        let o2 = engine.run(pal, &[a, b]).unwrap();
        let diff = o1[0].max_abs_diff(&o2[0]);
        assert!(diff < 1e-3, "size {size}: pallas vs jnp diff {diff}");
    }
}

#[test]
fn service_thread_executes() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let m = Manifest::load(&artifacts_dir()).unwrap();
    let svc = XlaService::spawn().unwrap();
    let meta = m.find("sort", "jnp", 256).unwrap().clone();
    let handle = svc.handle();
    let mut rng = Rng::new(3);
    let input = Tensor::vector(rng.vec_f32(256, -10.0, 10.0));
    // run from two threads to exercise the channel protocol
    let h2 = handle.clone();
    let m2 = meta.clone();
    let i2 = input.clone();
    let t = std::thread::spawn(move || h2.run(&m2, vec![i2]).unwrap());
    let (out, dur) = handle.run(&meta, vec![input]).unwrap();
    let (out2, _) = t.join().unwrap();
    assert!(dur.as_nanos() > 0);
    let sorted = out[0].data();
    assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "not sorted");
    assert_eq!(out[0].data(), out2[0].data());
}
