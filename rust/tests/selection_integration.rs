//! Integration tests for the unified variant-selection engine: two
//! scheduling contexts running different policies over the same
//! workload select differently; a Greedy context converges to the
//! model-best variant; per-task policy overrides beat the context
//! policy; unknown forced variants are rejected at submit time; and
//! the context-aware `contextual` policy flips its variant choice for
//! the same (app, size) stream between an idle and a loaded machine
//! (while Forced pins keep winning over any snapshot state).

use std::sync::Arc;
use std::time::Duration;

use compar::runtime::Tensor;
use compar::taskrt::selection::Forced;
use compar::taskrt::{
    AccessMode, Arch, Codelet, Config, Runtime, SchedPolicy, SelectorKind, TaskSpec,
};

fn sort_codelet() -> Codelet {
    // app "sort" so the analytic device model knows both variants:
    // at size 4096 "omp" is modeled ~5x faster than "seq"
    Codelet::new("duo", "sort", vec![AccessMode::Read])
        .with_native("omp", Arch::Cpu, Arc::new(|_| Ok(())))
        .with_native("seq", Arch::Cpu, Arc::new(|_| Ok(())))
}

fn cpu_runtime(ncpu: usize) -> Runtime {
    let cfg = Config {
        ncpu,
        ncuda: 0,
        sched: SchedPolicy::Eager,
        ..Config::default()
    };
    Runtime::new(cfg, None).unwrap()
}

#[test]
fn contexts_with_different_policies_select_differently() {
    let rt = cpu_runtime(4);
    let a = rt
        .create_context_with("a", &[0, 1], SchedPolicy::Eager, SelectorKind::Forced("seq".into()))
        .unwrap();
    let b = rt
        .create_context_with("b", &[2, 3], SchedPolicy::Eager, SelectorKind::Forced("omp".into()))
        .unwrap();
    let infos = rt.contexts();
    assert_eq!(infos[a].selector, "forced:seq");
    assert_eq!(infos[b].selector, "forced:omp");

    let cl = rt.register_codelet(sort_codelet());
    for _ in 0..6 {
        let ha = rt.register_data(Tensor::vector(vec![0.0; 4]));
        let hb = rt.register_data(Tensor::vector(vec![0.0; 4]));
        rt.submit(TaskSpec::new(cl.clone(), vec![ha], 4096).in_context(a))
            .unwrap();
        rt.submit(TaskSpec::new(cl.clone(), vec![hb], 4096).in_context(b))
            .unwrap();
    }
    rt.wait_all().unwrap();
    let results = rt.drain_results();
    assert_eq!(results.len(), 12);
    for r in &results {
        if r.ctx == a {
            assert_eq!(r.variant, "seq", "context a pinned to seq");
        } else {
            assert_eq!(r.ctx, b);
            assert_eq!(r.variant, "omp", "context b pinned to omp");
        }
    }
}

#[test]
fn greedy_converges_to_model_best_variant() {
    let rt = cpu_runtime(2);
    let cl = rt.register_codelet(sort_codelet());
    // one task at a time: deterministic sample accumulation
    let mut variants = Vec::new();
    for _ in 0..16 {
        let h = rt.register_data(Tensor::vector(vec![0.0; 4]));
        let id = rt.submit(TaskSpec::new(cl.clone(), vec![h], 4096)).unwrap();
        rt.wait_all().unwrap();
        let r = rt
            .drain_results()
            .into_iter()
            .find(|r| r.task == id)
            .unwrap();
        variants.push(r.variant);
    }
    // both variants must have been explored while cold...
    assert!(variants.iter().any(|v| v == "omp"), "{variants:?}");
    assert!(variants.iter().any(|v| v == "seq"), "{variants:?}");
    // ...and the tail must exploit the model-best variant (omp)
    for v in &variants[variants.len() - 5..] {
        assert_eq!(v, "omp", "converged tail: {variants:?}");
    }
}

#[test]
fn per_task_selector_overrides_context_policy() {
    let rt = cpu_runtime(2);
    let cl = rt.register_codelet(sort_codelet());
    // warm the models so the Greedy context policy would pick omp
    for _ in 0..8 {
        let h = rt.register_data(Tensor::vector(vec![0.0; 4]));
        rt.submit(TaskSpec::new(cl.clone(), vec![h], 4096)).unwrap();
        rt.wait_all().unwrap();
    }
    rt.drain_results();
    let h = rt.register_data(Tensor::vector(vec![0.0; 4]));
    let id = rt
        .submit(
            TaskSpec::new(cl.clone(), vec![h], 4096)
                .with_selector(Arc::new(Forced::new("seq"))),
        )
        .unwrap();
    rt.wait_all().unwrap();
    let r = rt
        .drain_results()
        .into_iter()
        .find(|r| r.task == id)
        .unwrap();
    assert_eq!(r.variant, "seq", "per-task Forced must beat the context policy");
}

/// The context-aware headline: the same (app, size) stream selects the
/// device variant on an idle machine and the CPU variant while the
/// device is buried under a backlog — through the public API, with the
/// pressure created by real queued tasks. A `forced` pin submitted
/// under the same pressure still runs its pinned variant.
#[test]
fn contextual_flips_variant_under_queue_pressure_and_forced_pin_still_wins() {
    const SIZE: usize = 16384;
    let cfg = Config {
        ncpu: 1,
        ncuda: 1,
        sched: SchedPolicy::Dmda,
        selector: SelectorKind::Contextual,
        ..Config::default()
    };
    let rt = Runtime::new(cfg, None).unwrap();
    // native variant on each arch; the device body sleeps so a burst of
    // pinned tasks creates a real, observable backlog on its queue
    let cl = rt.register_codelet(
        Codelet::new("duo", "sort", vec![AccessMode::Read])
            .with_native("omp", Arch::Cpu, Arc::new(|_| Ok(())))
            .with_native(
                "cuda",
                Arch::Cuda,
                Arc::new(|_| {
                    std::thread::sleep(Duration::from_millis(1));
                    Ok(())
                }),
            ),
    );
    let submit_probe = |selector: Option<&str>| {
        let h = rt.register_data(Tensor::vector(vec![0.0; 8]));
        let mut spec = TaskSpec::new(cl.clone(), vec![h], SIZE);
        if let Some(v) = selector {
            spec = spec.with_variant(v);
        }
        rt.submit(spec).unwrap()
    };
    let variant_in = |results: &[compar::taskrt::TaskResult], id| {
        results
            .iter()
            .find(|r| r.task == id)
            .map(|r| r.variant.clone())
            .unwrap()
    };

    // warm the models under both variants (modeled sort times at this
    // size: cuda ≈ 50 µs, omp ≈ 330 µs — the device wins when idle)
    for v in ["cuda", "omp"] {
        for _ in 0..4 {
            let id = submit_probe(Some(v));
            rt.wait_tasks(&[id]).unwrap();
        }
    }
    rt.wait_all().unwrap();
    rt.drain_results();

    // idle machine: the stream picks the device variant
    let idle_probe = submit_probe(None);
    rt.wait_all().unwrap();
    let results = rt.drain_results();
    assert_eq!(
        variant_in(&results, idle_probe),
        "cuda",
        "idle: device variant wins"
    );

    // bury the device: a burst of pinned tasks queues ~40 ms of work on
    // its lane, then the SAME (app, size) probe arrives while the
    // backlog is still queued
    for _ in 0..40 {
        submit_probe(Some("cuda"));
    }
    let loaded_probe = submit_probe(None);
    // a pin submitted under the same pressure must ignore it entirely
    let pinned_probe = submit_probe(Some("cuda"));
    rt.wait_all().unwrap();
    let results = rt.drain_results();
    assert_eq!(
        variant_in(&results, loaded_probe),
        "omp",
        "loaded: the contextual policy must flip to the idle architecture"
    );
    assert_eq!(
        variant_in(&results, pinned_probe),
        "cuda",
        "a Forced pin wins over any snapshot state"
    );

    // backlog drained: the stream returns to the device variant
    let recovered_probe = submit_probe(None);
    rt.wait_all().unwrap();
    let results = rt.drain_results();
    assert_eq!(
        variant_in(&results, recovered_probe),
        "cuda",
        "recovers when idle again"
    );
}

#[test]
fn forced_unknown_variant_rejected_at_submit() {
    let rt = cpu_runtime(2);
    let cl = rt.register_codelet(sort_codelet());
    let h = rt.register_data(Tensor::vector(vec![0.0; 4]));
    let err = rt
        .submit(TaskSpec::new(cl, vec![h], 64).with_variant("nope"))
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("no selectable implementation"), "{msg}");
    assert!(msg.contains("forced:nope"), "{msg}");
    // the runtime stays healthy afterwards
    rt.wait_all().unwrap();
}
