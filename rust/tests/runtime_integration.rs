//! Integration tests over the full taskrt runtime: workers, schedulers,
//! dependencies, coherence, perf-model learning, and artifact-backed
//! variants (require `make artifacts`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use compar::apps;
use compar::runtime::{Manifest, Tensor};
use compar::taskrt::{
    AccessMode, Arch, Codelet, Config, Runtime, SchedPolicy, TaskSpec, TimeMode,
};

fn manifest() -> Option<Arc<Manifest>> {
    let dir = compar::runtime::manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Arc::new(Manifest::load(&dir).unwrap()))
    } else {
        None
    }
}

fn cpu_runtime(sched: SchedPolicy) -> Runtime {
    let cfg = Config {
        ncpu: 2,
        ncuda: 0,
        sched,
        ..Config::default()
    };
    Runtime::new(cfg, None).unwrap()
}

#[test]
fn native_task_executes_and_completes() {
    let rt = cpu_runtime(SchedPolicy::Eager);
    let counter = Arc::new(AtomicUsize::new(0));
    let c2 = counter.clone();
    let cl = rt.register_codelet(
        Codelet::new("count", "sort", vec![AccessMode::ReadWrite]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(move |bufs| {
                c2.fetch_add(1, Ordering::SeqCst);
                bufs.write(0).data_mut()[0] += 1.0;
                Ok(())
            }),
        ),
    );
    let h = rt.register_data(Tensor::vector(vec![0.0]));
    for _ in 0..10 {
        rt.submit(TaskSpec::new(cl.clone(), vec![h], 1)).unwrap();
    }
    rt.wait_all().unwrap();
    assert_eq!(counter.load(Ordering::SeqCst), 10);
    // RW chain => strictly sequential increments
    assert_eq!(rt.snapshot(h).unwrap().data()[0], 10.0);
}

#[test]
fn implicit_dependencies_serialize_rw_chain() {
    let rt = cpu_runtime(SchedPolicy::WorkStealing);
    let cl = rt.register_codelet(
        Codelet::new("mul2", "sort", vec![AccessMode::ReadWrite]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(|bufs| {
                let mut t = bufs.write(0);
                for x in t.data_mut() {
                    *x *= 2.0;
                }
                Ok(())
            }),
        ),
    );
    let h = rt.register_data(Tensor::vector(vec![1.0]));
    for _ in 0..8 {
        rt.submit(TaskSpec::new(cl.clone(), vec![h], 1)).unwrap();
    }
    rt.wait_all().unwrap();
    assert_eq!(rt.snapshot(h).unwrap().data()[0], 256.0);
}

#[test]
fn all_schedulers_run_a_batch() {
    for sched in [
        SchedPolicy::Eager,
        SchedPolicy::Random,
        SchedPolicy::WorkStealing,
        SchedPolicy::Dmda,
        SchedPolicy::Heft,
    ] {
        let rt = cpu_runtime(sched);
        let cl = rt.register_codelet(
            Codelet::new("noop", "sort", vec![AccessMode::Read]).with_native(
                "omp",
                Arch::Cpu,
                Arc::new(|_| Ok(())),
            ),
        );
        // independent data => parallelism allowed
        for _ in 0..20 {
            let h = rt.register_data(Tensor::vector(vec![0.0]));
            rt.submit(TaskSpec::new(cl.clone(), vec![h], 1)).unwrap();
        }
        rt.wait_all()
            .unwrap_or_else(|e| panic!("{:?} failed: {e}", sched));
        assert_eq!(
            rt.metrics().tasks_executed.load(Ordering::Relaxed),
            20,
            "{sched:?}"
        );
    }
}

#[test]
fn failing_task_reports_error() {
    let rt = cpu_runtime(SchedPolicy::Eager);
    let cl = rt.register_codelet(
        Codelet::new("boom", "sort", vec![AccessMode::Read]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(|_| anyhow::bail!("deliberate failure")),
        ),
    );
    let h = rt.register_data(Tensor::vector(vec![0.0]));
    rt.submit(TaskSpec::new(cl, vec![h], 1)).unwrap();
    let err = rt.wait_all().unwrap_err();
    assert!(format!("{err:#}").contains("deliberate failure"));
}

#[test]
fn submit_rejects_impossible_tasks() {
    // CPU-only runtime, CUDA-only codelet
    let rt = cpu_runtime(SchedPolicy::Dmda);
    let cl = rt.register_codelet(
        Codelet::new("gpu_only", "matmul", vec![AccessMode::Read]).with_artifact(
            "cuda",
            Arch::Cuda,
            "jnp",
        ),
    );
    let h = rt.register_data(Tensor::vector(vec![0.0]));
    assert!(rt.submit(TaskSpec::new(cl, vec![h], 64)).is_err());
}

#[test]
fn perf_models_learn_from_execution() {
    let rt = cpu_runtime(SchedPolicy::Dmda);
    let cl = rt.register_codelet(
        Codelet::new("mmul", "matmul", vec![AccessMode::Read]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(|_| Ok(())),
        ),
    );
    for _ in 0..5 {
        let h = rt.register_data(Tensor::vector(vec![0.0; 64]));
        rt.submit(TaskSpec::new(cl.clone(), vec![h], 64)).unwrap();
    }
    rt.wait_all().unwrap();
    // modeled times for matmul/omp at 64 should now be learned
    let est = rt.perf_models().estimate("mmul", "omp", 64);
    assert!(est.is_some());
    let expected = compar::taskrt::device::exec_model("matmul", "omp", 64);
    let got = est.unwrap();
    assert!(
        (got - expected).abs() / expected < 0.2,
        "learned {got}, device model {expected}"
    );
}

#[test]
fn wall_time_mode_records_real_time() {
    let cfg = Config {
        ncpu: 1,
        ncuda: 0,
        sched: SchedPolicy::Eager,
        time_mode: TimeMode::Wall,
        ..Config::default()
    };
    let rt = Runtime::new(cfg, None).unwrap();
    let cl = rt.register_codelet(
        Codelet::new("sleepy", "sort", vec![AccessMode::Read]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(|_| {
                std::thread::sleep(std::time::Duration::from_millis(5));
                Ok(())
            }),
        ),
    );
    let h = rt.register_data(Tensor::vector(vec![0.0]));
    rt.submit(TaskSpec::new(cl, vec![h], 1)).unwrap();
    rt.wait_all().unwrap();
    let r = &rt.metrics().results()[0];
    assert!(r.modeled_exec >= 5e-3, "wall mode should reflect sleep");
}

#[test]
fn scheduling_contexts_partition_workers() {
    let cfg = Config {
        ncpu: 4,
        ncuda: 0,
        sched: SchedPolicy::Dmda,
        ..Config::default()
    };
    let rt = Runtime::new(cfg, None).unwrap();
    let a = rt
        .create_context("a", &[0, 1], SchedPolicy::Eager)
        .unwrap();
    let b = rt
        .create_context("b", &[2, 3], SchedPolicy::WorkStealing)
        .unwrap();
    assert_eq!(rt.context_id("a"), Some(a));
    assert_eq!(rt.context_id("b"), Some(b));
    assert!(rt.context_id("nope").is_none());
    let infos = rt.contexts();
    assert_eq!(infos.len(), 3);
    assert!(infos[0].workers.is_empty(), "default ctx donated everything");
    assert_eq!(infos[a].workers, vec![0, 1]);
    assert_eq!(infos[b].workers, vec![2, 3]);

    let cl = rt.register_codelet(
        Codelet::new("noop", "sort", vec![AccessMode::Read]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(|_| Ok(())),
        ),
    );
    let mut in_a = Vec::new();
    let mut in_b = Vec::new();
    for i in 0..24 {
        let h = rt.register_data(Tensor::vector(vec![0.0]));
        let ctx = if i % 2 == 0 { a } else { b };
        let id = rt
            .submit(TaskSpec::new(cl.clone(), vec![h], 1).in_context(ctx))
            .unwrap();
        if ctx == a {
            in_a.push(id);
        } else {
            in_b.push(id);
        }
    }
    rt.wait_all().unwrap();
    for r in rt.drain_results() {
        if in_a.contains(&r.task) {
            assert!(r.worker <= 1, "ctx a task on worker {}", r.worker);
            assert_eq!(r.ctx, a);
        } else {
            assert!(in_b.contains(&r.task));
            assert!(r.worker >= 2, "ctx b task on worker {}", r.worker);
            assert_eq!(r.ctx, b);
        }
    }

    // the default context donated all its workers: submitting to it
    // must fail fast rather than strand the task
    let h = rt.register_data(Tensor::vector(vec![0.0]));
    assert!(rt.submit(TaskSpec::new(cl.clone(), vec![h], 1)).is_err());
    // duplicate context names are rejected
    assert!(rt.create_context("a", &[0], SchedPolicy::Eager).is_err());
    // out-of-range workers are rejected
    assert!(rt.create_context("c", &[9], SchedPolicy::Eager).is_err());
}

#[test]
fn create_context_requires_quiescence() {
    let rt = cpu_runtime(SchedPolicy::Eager);
    let gate = Arc::new(AtomicUsize::new(0));
    let g2 = gate.clone();
    let cl = rt.register_codelet(
        Codelet::new("slow", "sort", vec![AccessMode::Read]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(move |_| {
                while g2.load(Ordering::SeqCst) == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                Ok(())
            }),
        ),
    );
    let h = rt.register_data(Tensor::vector(vec![0.0]));
    rt.submit(TaskSpec::new(cl, vec![h], 1)).unwrap();
    let err = rt.create_context("x", &[0], SchedPolicy::Eager).unwrap_err();
    assert!(format!("{err:#}").contains("quiescent"), "{err:#}");
    gate.store(1, Ordering::SeqCst);
    rt.wait_all().unwrap();
    // quiescent now: reconfiguration succeeds
    rt.create_context("x", &[0], SchedPolicy::Eager).unwrap();
}

#[test]
fn wait_tasks_waits_only_its_request() {
    let rt = cpu_runtime(SchedPolicy::Eager);
    let cl = rt.register_codelet(
        Codelet::new("bump", "sort", vec![AccessMode::ReadWrite]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(|bufs| {
                bufs.write(0).data_mut()[0] += 1.0;
                Ok(())
            }),
        ),
    );
    let h1 = rt.register_data(Tensor::vector(vec![0.0]));
    let h2 = rt.register_data(Tensor::vector(vec![0.0]));
    let t1 = rt.submit(TaskSpec::new(cl.clone(), vec![h1], 1)).unwrap();
    let t2 = rt.submit(TaskSpec::new(cl.clone(), vec![h2], 1)).unwrap();
    rt.wait_tasks(&[t1, t2]).unwrap();
    assert_eq!(rt.snapshot(h1).unwrap().data()[0], 1.0);
    assert_eq!(rt.snapshot(h2).unwrap().data()[0], 1.0);
    // reaped tasks are treated as done; results can be taken per-request
    let taken = rt.metrics().take_results_for(&[t1]);
    assert_eq!(taken.len(), 1);
    rt.reap_tasks(&[t1, t2]);
    assert!(rt.task_state(t1).is_none());
    rt.wait_tasks(&[t1, t2]).unwrap();
    // handle recycling after a request completes
    rt.unregister_data(h1).unwrap();
    let h3 = rt.register_data(Tensor::vector(vec![9.0]));
    assert_eq!(h3, h1, "slot reuse");
}

// ------------------------------------------------------------------
// artifact-backed heterogeneous tests (need `make artifacts`)
// ------------------------------------------------------------------

#[test]
fn heterogeneous_matmul_verifies_and_selects() {
    let Some(m) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let cfg = Config {
        ncpu: 2,
        ncuda: 1,
        sched: SchedPolicy::Dmda,
        ..Config::default()
    };
    let rt = Runtime::new(cfg, Some(m)).unwrap();
    // repeated runs: calibration first (5 variants x MIN_SAMPLES each),
    // then informed selection
    let runs = 20;
    for i in 0..runs {
        let run = apps::run_once(&rt, "matmul", 64, 100 + i, None, true).unwrap();
        assert!(run.rel_err <= apps::tolerance("matmul"));
    }
    let hist = rt.metrics().variant_histogram();
    let total: usize = hist.values().sum();
    assert_eq!(total as u64, runs);
    // after calibration, estimates exist for every paper variant
    for v in apps::paper_variants("matmul") {
        assert!(
            rt.perf_models().estimate("mmul", v, 64).is_some(),
            "variant {v} never calibrated: {hist:?}"
        );
    }
}

#[test]
fn gpu_only_runs_artifacts() {
    let Some(m) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let cfg = Config {
        ncpu: 0,
        ncuda: 1,
        sched: SchedPolicy::Eager,
        ..Config::default()
    };
    let rt = Runtime::new(cfg, Some(m)).unwrap();
    let run = apps::run_once(&rt, "hotspot", 64, 5, None, true).unwrap();
    assert_eq!(run.variant, "cuda");
}

#[test]
fn every_app_verifies_on_heterogeneous_runtime() {
    let Some(m) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let cfg = Config {
        ncpu: 2,
        ncuda: 1,
        sched: SchedPolicy::Dmda,
        ..Config::default()
    };
    let rt = Runtime::new(cfg, Some(m)).unwrap();
    for (app, size) in [
        ("hotspot", 64),
        ("hotspot3d", 64),
        ("lud", 64),
        ("nw", 63),
        ("matmul", 64),
        ("sort", 256),
    ] {
        // force both paper variants to execute + verify
        for variant in apps::paper_variants(app) {
            let run = apps::run_once(&rt, app, size, 9, Some(variant), true)
                .unwrap_or_else(|e| panic!("{app}/{variant}: {e:#}"));
            assert_eq!(&run.variant, variant, "{app}");
        }
    }
}
