//! Integration tests over the full taskrt runtime: workers, schedulers,
//! dependencies, coherence, perf-model learning, and artifact-backed
//! variants (require `make artifacts`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use compar::apps;
use compar::runtime::{Manifest, Tensor};
use compar::taskrt::{
    AccessMode, Arch, Codelet, Config, Runtime, SchedPolicy, TaskSpec, TimeMode,
};

fn manifest() -> Option<Arc<Manifest>> {
    let dir = compar::runtime::manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Arc::new(Manifest::load(&dir).unwrap()))
    } else {
        None
    }
}

fn cpu_runtime(sched: SchedPolicy) -> Runtime {
    let cfg = Config {
        ncpu: 2,
        ncuda: 0,
        sched,
        ..Config::default()
    };
    Runtime::new(cfg, None).unwrap()
}

#[test]
fn native_task_executes_and_completes() {
    let rt = cpu_runtime(SchedPolicy::Eager);
    let counter = Arc::new(AtomicUsize::new(0));
    let c2 = counter.clone();
    let cl = rt.register_codelet(
        Codelet::new("count", "sort", vec![AccessMode::ReadWrite]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(move |bufs| {
                c2.fetch_add(1, Ordering::SeqCst);
                bufs.write(0).data_mut()[0] += 1.0;
                Ok(())
            }),
        ),
    );
    let h = rt.register_data(Tensor::vector(vec![0.0]));
    for _ in 0..10 {
        rt.submit(TaskSpec::new(cl.clone(), vec![h], 1)).unwrap();
    }
    rt.wait_all().unwrap();
    assert_eq!(counter.load(Ordering::SeqCst), 10);
    // RW chain => strictly sequential increments
    assert_eq!(rt.snapshot(h).unwrap().data()[0], 10.0);
}

#[test]
fn implicit_dependencies_serialize_rw_chain() {
    let rt = cpu_runtime(SchedPolicy::WorkStealing);
    let cl = rt.register_codelet(
        Codelet::new("mul2", "sort", vec![AccessMode::ReadWrite]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(|bufs| {
                let mut t = bufs.write(0);
                for x in t.data_mut() {
                    *x *= 2.0;
                }
                Ok(())
            }),
        ),
    );
    let h = rt.register_data(Tensor::vector(vec![1.0]));
    for _ in 0..8 {
        rt.submit(TaskSpec::new(cl.clone(), vec![h], 1)).unwrap();
    }
    rt.wait_all().unwrap();
    assert_eq!(rt.snapshot(h).unwrap().data()[0], 256.0);
}

#[test]
fn all_schedulers_run_a_batch() {
    for sched in [
        SchedPolicy::Eager,
        SchedPolicy::Random,
        SchedPolicy::WorkStealing,
        SchedPolicy::Dmda,
        SchedPolicy::Heft,
    ] {
        let rt = cpu_runtime(sched);
        let cl = rt.register_codelet(
            Codelet::new("noop", "sort", vec![AccessMode::Read]).with_native(
                "omp",
                Arch::Cpu,
                Arc::new(|_| Ok(())),
            ),
        );
        // independent data => parallelism allowed
        for _ in 0..20 {
            let h = rt.register_data(Tensor::vector(vec![0.0]));
            rt.submit(TaskSpec::new(cl.clone(), vec![h], 1)).unwrap();
        }
        rt.wait_all()
            .unwrap_or_else(|e| panic!("{:?} failed: {e}", sched));
        assert_eq!(
            rt.metrics().tasks_executed.load(Ordering::Relaxed),
            20,
            "{sched:?}"
        );
    }
}

#[test]
fn failing_task_reports_error() {
    let rt = cpu_runtime(SchedPolicy::Eager);
    let cl = rt.register_codelet(
        Codelet::new("boom", "sort", vec![AccessMode::Read]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(|_| anyhow::bail!("deliberate failure")),
        ),
    );
    let h = rt.register_data(Tensor::vector(vec![0.0]));
    rt.submit(TaskSpec::new(cl, vec![h], 1)).unwrap();
    let err = rt.wait_all().unwrap_err();
    assert!(format!("{err:#}").contains("deliberate failure"));
}

#[test]
fn submit_rejects_impossible_tasks() {
    // CPU-only runtime, CUDA-only codelet
    let rt = cpu_runtime(SchedPolicy::Dmda);
    let cl = rt.register_codelet(
        Codelet::new("gpu_only", "matmul", vec![AccessMode::Read]).with_artifact(
            "cuda",
            Arch::Cuda,
            "jnp",
        ),
    );
    let h = rt.register_data(Tensor::vector(vec![0.0]));
    assert!(rt.submit(TaskSpec::new(cl, vec![h], 64)).is_err());
}

#[test]
fn perf_models_learn_from_execution() {
    let rt = cpu_runtime(SchedPolicy::Dmda);
    let cl = rt.register_codelet(
        Codelet::new("mmul", "matmul", vec![AccessMode::Read]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(|_| Ok(())),
        ),
    );
    for _ in 0..5 {
        let h = rt.register_data(Tensor::vector(vec![0.0; 64]));
        rt.submit(TaskSpec::new(cl.clone(), vec![h], 64)).unwrap();
    }
    rt.wait_all().unwrap();
    // modeled times for matmul/omp at 64 should now be learned
    let est = rt.perf_models().estimate("mmul", "omp", 64);
    assert!(est.is_some());
    let expected = compar::taskrt::device::exec_model("matmul", "omp", 64);
    let got = est.unwrap();
    assert!(
        (got - expected).abs() / expected < 0.2,
        "learned {got}, device model {expected}"
    );
}

#[test]
fn wall_time_mode_records_real_time() {
    let cfg = Config {
        ncpu: 1,
        ncuda: 0,
        sched: SchedPolicy::Eager,
        time_mode: TimeMode::Wall,
        ..Config::default()
    };
    let rt = Runtime::new(cfg, None).unwrap();
    let cl = rt.register_codelet(
        Codelet::new("sleepy", "sort", vec![AccessMode::Read]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(|_| {
                std::thread::sleep(std::time::Duration::from_millis(5));
                Ok(())
            }),
        ),
    );
    let h = rt.register_data(Tensor::vector(vec![0.0]));
    rt.submit(TaskSpec::new(cl, vec![h], 1)).unwrap();
    rt.wait_all().unwrap();
    let r = &rt.metrics().results()[0];
    assert!(r.modeled_exec >= 5e-3, "wall mode should reflect sleep");
}

// ------------------------------------------------------------------
// artifact-backed heterogeneous tests (need `make artifacts`)
// ------------------------------------------------------------------

#[test]
fn heterogeneous_matmul_verifies_and_selects() {
    let Some(m) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let cfg = Config {
        ncpu: 2,
        ncuda: 1,
        sched: SchedPolicy::Dmda,
        ..Config::default()
    };
    let rt = Runtime::new(cfg, Some(m)).unwrap();
    // repeated runs: calibration first (5 variants x MIN_SAMPLES each),
    // then informed selection
    let runs = 20;
    for i in 0..runs {
        let run = apps::run_once(&rt, "matmul", 64, 100 + i, None, true).unwrap();
        assert!(run.rel_err <= apps::tolerance("matmul"));
    }
    let hist = rt.metrics().variant_histogram();
    let total: usize = hist.values().sum();
    assert_eq!(total as u64, runs);
    // after calibration, estimates exist for every paper variant
    for v in apps::paper_variants("matmul") {
        assert!(
            rt.perf_models().estimate("mmul", v, 64).is_some(),
            "variant {v} never calibrated: {hist:?}"
        );
    }
}

#[test]
fn gpu_only_runs_artifacts() {
    let Some(m) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let cfg = Config {
        ncpu: 0,
        ncuda: 1,
        sched: SchedPolicy::Eager,
        ..Config::default()
    };
    let rt = Runtime::new(cfg, Some(m)).unwrap();
    let run = apps::run_once(&rt, "hotspot", 64, 5, None, true).unwrap();
    assert_eq!(run.variant, "cuda");
}

#[test]
fn every_app_verifies_on_heterogeneous_runtime() {
    let Some(m) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let cfg = Config {
        ncpu: 2,
        ncuda: 1,
        sched: SchedPolicy::Dmda,
        ..Config::default()
    };
    let rt = Runtime::new(cfg, Some(m)).unwrap();
    for (app, size) in [
        ("hotspot", 64),
        ("hotspot3d", 64),
        ("lud", 64),
        ("nw", 63),
        ("matmul", 64),
        ("sort", 256),
    ] {
        // force both paper variants to execute + verify
        for variant in apps::paper_variants(app) {
            let run = apps::run_once(&rt, app, size, 9, Some(variant), true)
                .unwrap_or_else(|e| panic!("{app}/{variant}: {e:#}"));
            assert_eq!(&run.variant, variant, "{app}");
        }
    }
}
