//! Integration tests for the `compar cluster` subsystem: two in-process
//! `serve` shards behind the router. Covers end-to-end loadgen traffic
//! through the unchanged client protocol, stats aggregation + shard
//! drain, the perf-model wire ops, and the headline property — with
//! gossip enabled, a variant calibrated on shard A is selected on shard
//! B without recalibrating from scratch (and *is* recalibrated from
//! scratch when gossip is off).

use std::collections::BTreeSet;
use std::time::{Duration, Instant};

use compar::cluster::{LocalCluster, PlacementKind, RouterOptions};
use compar::serve::{loadgen, Client, LoadgenOptions, ServeOptions, Server, SubmitReq};
use compar::taskrt::{SchedPolicy, SelectorKind};

fn serve_opts(selector: SelectorKind) -> ServeOptions {
    ServeOptions {
        addr: "127.0.0.1:0".into(),
        contexts: Vec::new(),
        sched: SchedPolicy::Dmda,
        selector: Some(selector),
        ncpu: 2,
        ncuda: 0,
        max_inflight: 16,
        autoscale: None,
        batch_window: Duration::from_micros(200),
        max_batch: 8,
        ..ServeOptions::default()
    }
}

fn router_opts(gossip: bool) -> RouterOptions {
    RouterOptions {
        listen: "127.0.0.1:0".into(),
        shards: Vec::new(),
        placement: PlacementKind::RoundRobin,
        health_period: Duration::from_millis(100),
        gossip_period: Duration::from_millis(100),
        gossip,
        autoscale: None,
    }
}

fn submit(id: u64, app: &str, size: usize, seed: u64, verify: bool) -> SubmitReq {
    SubmitReq {
        id,
        app: app.into(),
        size,
        tasks: 1,
        ctx: None,
        seed,
        variant: None,
        verify,
        trace: 0,
    }
}

#[test]
fn two_shard_cluster_serves_loadgen_end_to_end() {
    let cluster =
        LocalCluster::start(2, &serve_opts(SelectorKind::Greedy), router_opts(true)).unwrap();
    let lg = LoadgenOptions {
        clients: 4,
        requests: 6,
        app: "matmul".into(),
        size: 32,
        tasks: 1,
        ctxs: Vec::new(),
        pipeline: 2,
        policy: None,
        profile: None,
        verify: true,
        seed: 3,
        ..LoadgenOptions::default()
    };
    let report = loadgen::run(&cluster.addr(), &lg).unwrap();
    assert_eq!(report.errors, 0);
    assert_eq!(report.requests, 24);
    assert!(report.rps > 0.0);
    // results come back tagged with the serving shard; round-robin
    // placement spreads the requests over both
    assert!(
        report.per_ctx.keys().any(|k| k.starts_with("shard0/")),
        "{:?}",
        report.per_ctx
    );
    assert!(
        report.per_ctx.keys().any(|k| k.starts_with("shard1/")),
        "{:?}",
        report.per_ctx
    );
    let stats = cluster.shutdown().unwrap();
    assert_eq!(stats.len(), 2);
    let total: u64 = stats.iter().map(|s| s.requests_ok).sum();
    assert_eq!(total, 24, "every request accounted for across shards");
    for s in &stats {
        assert_eq!(s.inflight, 0, "clean drain on every shard");
        assert!(s.requests_ok > 0, "both shards served traffic: {stats:?}");
    }
}

#[test]
fn router_aggregates_stats_and_drains_shards_out_of_rotation() {
    let cluster =
        LocalCluster::start(2, &serve_opts(SelectorKind::Greedy), router_opts(true)).unwrap();
    let mut c = Client::connect(&cluster.addr()).unwrap();
    for r in 0..4u64 {
        c.submit(submit(r, "matmul", 32, 50 + r, true)).unwrap();
    }
    // shard table: both healthy, none draining
    let shards = c.shards().unwrap();
    assert_eq!(shards.len(), 2);
    assert!(shards.iter().all(|s| s.healthy && !s.draining), "{shards:?}");
    // aggregated stats sum the shard counters, shard-prefixed tables
    let stats = c.stats().unwrap();
    assert_eq!(stats.requests_ok, 4);
    assert_eq!(stats.inflight, 0);
    assert!(
        stats.ctx_tasks.keys().all(|k| k.starts_with("shard")),
        "{:?}",
        stats.ctx_tasks
    );
    // drain shard0: subsequent submits all land on shard1
    let drained = c.drain_shard(&shards[0].addr).unwrap();
    assert_eq!(drained, shards[0].addr);
    for r in 10..16u64 {
        let resp = c.submit(submit(r, "matmul", 32, 80 + r, true)).unwrap();
        assert!(
            resp.ctx.starts_with("shard1/"),
            "request routed to drained shard: {}",
            resp.ctx
        );
    }
    let shards = c.shards().unwrap();
    assert!(shards[0].draining && !shards[1].draining, "{shards:?}");
    // unknown shard name is an error, session survives
    assert!(c.drain_shard("nope:1").is_err());
    c.quit().unwrap();
    cluster.shutdown().unwrap();
}

/// Selector validation is uniform across the cluster: the router
/// rejects an unknown session policy with a protocol error naming the
/// valid set (exactly like a shard does), and accepts `contextual`.
#[test]
fn router_validates_session_policy_names_against_the_valid_set() {
    let cluster =
        LocalCluster::start(1, &serve_opts(SelectorKind::Greedy), router_opts(false)).unwrap();
    let err = Client::connect_with_policy(&cluster.addr(), Some("bogus")).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown selection policy 'bogus'"), "{msg}");
    for name in ["greedy", "calibrating", "epsilon-decayed", "contextual", "forced"] {
        assert!(msg.contains(name), "valid set must name {name}: {msg}");
    }
    // the new selector name routes end-to-end (router hello -> shard
    // hello -> per-task override on the shard's runtime)
    let mut c = Client::connect_with_policy(&cluster.addr(), Some("contextual")).unwrap();
    let resp = c.submit(submit(1, "matmul", 32, 9, true)).unwrap();
    assert_eq!(resp.policy, "contextual");
    c.quit().unwrap();
    cluster.shutdown().unwrap();
}

#[test]
fn perf_pull_and_push_roundtrip_over_the_wire() {
    let server = Server::start(serve_opts(SelectorKind::Greedy)).unwrap();
    let addr = server.local_addr().to_string();
    let mut c = Client::connect(&addr).unwrap();
    for r in 0..3u64 {
        c.submit(submit(r, "matmul", 32, 7 + r, false)).unwrap();
    }
    // pull: the executed tasks left local observations for the codelet
    let models = c.perf_pull().unwrap();
    let obj = models.as_obj().expect("perf_pull returns an object");
    assert!(
        obj.keys().any(|k| k.starts_with("mmul:")),
        "{:?}",
        obj.keys().collect::<Vec<_>>()
    );
    // push: installing an overlay acks with the bucket count
    let merged = c.perf_push(&models).unwrap();
    assert!(merged > 0, "no buckets accepted");
    assert_eq!(server.perf_models().remote_buckets(), merged as usize);
    c.quit().unwrap();
    server.shutdown().unwrap();
}

/// The acceptance-criteria property: calibrate (matmul, 48) on shard A
/// only, wait for a gossip round, and shard B selects the model-best
/// variant from its very first request — no per-shard recalibration.
#[test]
fn gossip_transfers_calibration_from_shard_a_to_shard_b() {
    let cluster =
        LocalCluster::start(2, &serve_opts(SelectorKind::Calibrating), router_opts(true)).unwrap();
    let shard_b_models = cluster.shards[1].perf_models();
    // drive shard A directly so B sees no traffic at all
    let addr_a = cluster.shards[0].local_addr().to_string();
    let mut c = Client::connect(&addr_a).unwrap();
    for r in 0..12u64 {
        c.submit(submit(r, "matmul", 48, 100 + r, false)).unwrap();
    }
    c.quit().unwrap();
    assert!(!cluster.shards[0]
        .perf_models()
        .needs_calibration("mmul", "omp", 48));
    // shard A's buckets reach shard B through the router's gossip round
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let calibrated = ["omp", "seq"]
            .iter()
            .all(|v| !shard_b_models.needs_calibration("mmul", v, 48));
        if calibrated {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "gossip never calibrated shard B (remote buckets: {})",
            shard_b_models.remote_buckets()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    // every selection on B exploits immediately: one variant, never the
    // round-robin calibration sweep
    let addr_b = cluster.shards[1].local_addr().to_string();
    let mut c = Client::connect(&addr_b).unwrap();
    let mut variants = BTreeSet::new();
    for r in 0..6u64 {
        let resp = c.submit(submit(r, "matmul", 48, 200 + r, false)).unwrap();
        variants.extend(resp.variants.clone());
    }
    c.quit().unwrap();
    assert_eq!(
        variants.len(),
        1,
        "gossip-seeded shard B still explored: {variants:?}"
    );
    cluster.shutdown().unwrap();
}

/// Control for the test above: gossip off, shard B recalibrates from
/// scratch (the Calibrating policy's round-robin sweep visits every
/// variant again).
#[test]
fn without_gossip_each_shard_recalibrates_from_scratch() {
    let cluster = LocalCluster::start(
        2,
        &serve_opts(SelectorKind::Calibrating),
        router_opts(false),
    )
    .unwrap();
    let addr_a = cluster.shards[0].local_addr().to_string();
    let mut c = Client::connect(&addr_a).unwrap();
    for r in 0..12u64 {
        c.submit(submit(r, "matmul", 48, 100 + r, false)).unwrap();
    }
    c.quit().unwrap();
    // give the (pull-only) gossip thread several rounds: B must stay cold
    std::thread::sleep(Duration::from_millis(400));
    let shard_b_models = cluster.shards[1].perf_models();
    assert!(
        shard_b_models.needs_calibration("mmul", "omp", 48),
        "calibration leaked to shard B with gossip off"
    );
    assert_eq!(shard_b_models.remote_buckets(), 0);
    let addr_b = cluster.shards[1].local_addr().to_string();
    let mut c = Client::connect(&addr_b).unwrap();
    let mut variants = BTreeSet::new();
    for r in 0..6u64 {
        let resp = c.submit(submit(r, "matmul", 48, 200 + r, false)).unwrap();
        variants.extend(resp.variants.clone());
    }
    c.quit().unwrap();
    assert!(
        variants.len() >= 2,
        "shard B should have explored both variants while recalibrating: {variants:?}"
    );
    cluster.shutdown().unwrap();
}

/// A dead shard is detected and traffic fails over to the survivor —
/// the retry-on-other-shard path.
#[test]
fn submits_fail_over_when_a_shard_dies() {
    let mut cluster =
        LocalCluster::start(2, &serve_opts(SelectorKind::Greedy), router_opts(false)).unwrap();
    let addr = cluster.addr();
    // kill shard 0 out from under the router
    let dead = cluster.shards.remove(0);
    let survivor_ok_before = {
        let mut c = Client::connect(&cluster.shards[0].local_addr().to_string()).unwrap();
        let s = c.stats().unwrap();
        let _ = c.quit();
        s.requests_ok
    };
    dead.shutdown().unwrap();
    let mut c = Client::connect(&addr).unwrap();
    // every request still answers, routed around the dead shard
    for r in 0..8u64 {
        let resp = c.submit(submit(r, "matmul", 32, 300 + r, true)).unwrap();
        assert!(resp.ctx.starts_with("shard1/"), "{}", resp.ctx);
    }
    c.quit().unwrap();
    let stats = cluster.shutdown().unwrap();
    assert_eq!(stats.len(), 1);
    assert_eq!(stats[0].requests_ok - survivor_ok_before, 8);
}
