//! Integration tests for the v7 transport layer: binary framing
//! negotiated alongside ndjson sessions on one server, the epoll
//! readiness transport end to end (submits, pipelining, streams), the
//! router forwarding both framings to its shards, and a
//! many-connection fan-out that a thread-per-connection client count
//! would never reach per thread of server.

use std::time::Duration;

use compar::serve::{
    loadgen, parse_contexts, Client, ClientConfig, Framing, GraphNodeReq, LoadgenOptions,
    Response, ServeOptions, Server, SubmitGraphReq, SubmitReq, TransportKind,
};
use compar::taskrt::{SchedPolicy, SelectorKind};

fn opts(contexts: &str, transport: TransportKind) -> ServeOptions {
    ServeOptions {
        addr: "127.0.0.1:0".into(),
        contexts: parse_contexts(contexts).unwrap(),
        sched: SchedPolicy::Dmda,
        selector: Some(SelectorKind::Greedy),
        ncpu: 4,
        ncuda: 0,
        max_inflight: 16,
        batch_window: Duration::from_micros(200),
        max_batch: 8,
        autoscale: None,
        transport,
        ..ServeOptions::default()
    }
}

fn submit(id: u64, size: usize, ctx: Option<&str>, seed: u64) -> SubmitReq {
    SubmitReq {
        id,
        app: "matmul".into(),
        size,
        tasks: 1,
        ctx: ctx.map(str::to_string),
        seed,
        variant: None,
        verify: true,
        trace: 0,
    }
}

fn binary_cfg() -> ClientConfig {
    ClientConfig {
        framing: Framing::Binary,
        ..ClientConfig::default()
    }
}

/// One server, two live sessions in different framings: the binary
/// session really negotiates binary (server echo), both compute
/// correct results, and neither corrupts the other's stream.
#[test]
fn mixed_framing_clients_share_one_server() {
    for transport in [TransportKind::Threads, TransportKind::Epoll] {
        let server = Server::start(opts("", transport)).unwrap();
        let addr = server.local_addr().to_string();

        let mut bin = Client::connect_cfg(&addr, &binary_cfg()).unwrap();
        assert_eq!(bin.framing(), Framing::Binary, "hello echo accepted");
        let mut nd = Client::connect(&addr).unwrap();
        assert_eq!(nd.framing(), Framing::Ndjson, "default stays ndjson");

        // interleave submits across the two sessions
        for r in 0..4u64 {
            let rb = bin.submit(submit(r, 32, None, 100 + r)).unwrap();
            assert!(rb.rel_err <= 5e-3, "binary client rel_err {}", rb.rel_err);
            let rn = nd.submit(submit(r, 32, None, 200 + r)).unwrap();
            assert!(rn.rel_err <= 5e-3, "ndjson client rel_err {}", rn.rel_err);
        }
        // protocol errors come back on the negotiated framing too
        let e = bin.submit(submit(9, 32, Some("nope"), 1)).unwrap_err();
        assert!(format!("{e:#}").contains("unknown context"), "{e:#}");

        bin.quit().unwrap();
        nd.quit().unwrap();
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.requests_ok, 8, "transport {}", transport.name());
        assert_eq!(stats.requests_err, 1);
        assert_eq!(stats.inflight, 0);
    }
}

/// A hello asking for a framing the server does not speak is rejected
/// with an error (in ndjson, since the session never switched), and
/// the session keeps working after a corrected hello.
#[test]
fn unknown_framing_is_rejected_in_hello() {
    use std::io::{BufRead, BufReader, Write};
    for transport in [TransportKind::Threads, TransportKind::Epoll] {
        let server = Server::start(opts("", transport)).unwrap();
        let addr = server.local_addr().to_string();
        let mut raw = std::net::TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(raw.try_clone().unwrap());
        raw.write_all(b"{\"op\":\"hello\",\"client\":\"raw\",\"framing\":\"msgpack\"}\n")
            .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"error\""), "{line}");
        assert!(line.contains("unknown framing"), "{line}");
        // the session survives and a valid hello still negotiates
        raw.write_all(b"{\"op\":\"hello\",\"client\":\"raw\",\"framing\":\"binary\"}\n")
            .unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"hello\""), "{line}");
        assert!(line.contains("\"binary\""), "echo confirms switch: {line}");
        drop(raw);
        server.shutdown().unwrap();
    }
}

/// Pipelined binary traffic over the epoll transport: out-of-order
/// completions, correlation ids, and the coalesced reply path.
#[test]
fn epoll_transport_pipelines_binary_sessions() {
    let server = Server::start(opts("alpha:2,beta:2", TransportKind::Epoll)).unwrap();
    let addr = server.local_addr().to_string();
    let lg = LoadgenOptions {
        clients: 4,
        requests: 6,
        app: "matmul".into(),
        size: 32,
        ctxs: vec!["alpha".into(), "beta".into()],
        pipeline: 3,
        framing: Framing::Binary,
        ..LoadgenOptions::default()
    };
    let report = loadgen::run(&addr, &lg).unwrap();
    assert_eq!(report.errors, 0);
    assert_eq!(report.requests, 24);
    assert!(report.rps > 0.0);
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests_ok, 24);
    assert_eq!(stats.inflight, 0, "epoll drain left requests behind");
}

/// v6 stream sessions ride the epoll transport: open, credit-gated
/// chunks, acks with latency, clean close. Exercises the queued reply
/// lane from a stream worker thread.
#[test]
fn epoll_transport_runs_stream_sessions() {
    let server = Server::start(opts("", TransportKind::Epoll)).unwrap();
    let addr = server.local_addr().to_string();
    let mut c = Client::connect_cfg(&addr, &binary_cfg()).unwrap();
    let opened = c
        .stream_open(compar::serve::StreamOpenReq {
            id: 1,
            app: "sort".into(),
            size: 256,
            stages: 1,
            window: 0,
            slide: 0,
            ctx: None,
            slo_ms: None,
            trace: 0,
        })
        .unwrap();
    assert!(opened.credit >= 1);
    let mut acked = 0usize;
    let mut inflight = 0u64;
    let mut credit = opened.credit.max(1);
    for seq in 0..6u64 {
        while inflight >= credit {
            match c.recv_response().unwrap() {
                Response::StreamAck(a) => {
                    credit = a.credit.max(1);
                    inflight -= 1;
                    acked += 1;
                }
                Response::StreamCredit(cr) => credit = cr.credit.max(1),
                other => panic!("{other:?}"),
            }
        }
        c.send_stream_chunk(1, seq, 40 + seq).unwrap();
        inflight += 1;
    }
    while inflight > 0 {
        match c.recv_response().unwrap() {
            Response::StreamAck(_) => {
                inflight -= 1;
                acked += 1;
            }
            Response::StreamCredit(_) => {}
            other => panic!("{other:?}"),
        }
    }
    assert_eq!(acked, 6, "every chunk acked");
    let closed = c.stream_close(1).unwrap();
    assert_eq!(closed.chunks, 6);
    c.quit().unwrap();
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.streams, 0, "stream closed before drain");
}

/// v8 graph submission rides both transports and both framings: a
/// binary and an ndjson session on one server each ship a three-node
/// producer→consumer DAG, get a planned per-node report back on their
/// own framing, and a malformed dep comes back as a protocol error —
/// not a dead session.
#[test]
fn graph_submission_works_on_both_transports_and_framings() {
    fn chain(id: u64) -> SubmitGraphReq {
        let node = |name: &str, deps: Vec<String>| GraphNodeReq {
            name: name.into(),
            app: "sort".into(),
            size: 4096,
            deps,
            variant: None,
        };
        SubmitGraphReq {
            id,
            nodes: vec![
                node("produce", vec![]),
                node("transform", vec!["produce".into()]),
                node("consume", vec!["transform".into()]),
            ],
            ctx: None,
            mode: None,
            trace: 0,
        }
    }
    for transport in [TransportKind::Threads, TransportKind::Epoll] {
        let server = Server::start(opts("", transport)).unwrap();
        let addr = server.local_addr().to_string();

        let mut bin = Client::connect_cfg(&addr, &binary_cfg()).unwrap();
        assert_eq!(bin.framing(), Framing::Binary);
        let mut nd = Client::connect(&addr).unwrap();

        for (tag, c) in [("binary", &mut bin), ("ndjson", &mut nd)] {
            let g = c.submit_graph(chain(31)).unwrap();
            assert_eq!(g.id, 31, "{tag}: correlation id echoed");
            assert_eq!(g.mode, "planned", "{tag}: uncontended submit plans");
            assert_eq!(g.nodes.len(), 3, "{tag}: every node reported");
            for node in &g.nodes {
                assert!(!node.variant.is_empty(), "{tag}: {} ran", node.name);
                assert!(node.planned, "{tag}: {} carries a prior", node.name);
            }
            assert!(g.makespan > 0.0, "{tag}: modeled makespan present");
        }
        // a dep naming a nonexistent node is a protocol error on the
        // negotiated framing, and the session survives it
        let mut bad = chain(32);
        bad.nodes[1].deps = vec!["ghost".into()];
        let e = bin.submit_graph(bad).unwrap_err();
        assert!(format!("{e:#}").contains("deps must name earlier"), "{e:#}");
        let g = bin.submit_graph(chain(33)).unwrap();
        assert_eq!(g.nodes.len(), 3, "session usable after graph error");

        bin.quit().unwrap();
        nd.quit().unwrap();
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.plans, 3, "transport {}", transport.name());
        assert_eq!(stats.planned_tasks, 9);
        assert_eq!(stats.requests_err, 1);
        assert_eq!(stats.inflight, 0);
    }
}

/// The router forwards each session's negotiated framing to its
/// backend hops: a binary client and an ndjson client drive the same
/// two-shard cluster and both see shard-tagged results.
#[test]
fn router_forwards_both_framings() {
    use compar::cluster::{LocalCluster, RouterOptions};
    let serve = opts("", TransportKind::Threads);
    let ropts = RouterOptions {
        listen: "127.0.0.1:0".into(),
        ..RouterOptions::default()
    };
    let cluster = LocalCluster::start(2, &serve, ropts).unwrap();
    let addr = cluster.addr();

    let mut bin = Client::connect_cfg(&addr, &binary_cfg()).unwrap();
    assert_eq!(bin.framing(), Framing::Binary);
    let mut nd = Client::connect(&addr).unwrap();
    for r in 0..6u64 {
        let rb = bin.submit(submit(r, 32, None, 500 + r)).unwrap();
        assert!(rb.ctx.starts_with("shard"), "router tags ctx: {}", rb.ctx);
        assert!(rb.rel_err <= 5e-3);
        let rn = nd.submit(submit(r, 32, None, 600 + r)).unwrap();
        assert!(rn.ctx.starts_with("shard"), "router tags ctx: {}", rn.ctx);
    }
    bin.quit().unwrap();
    nd.quit().unwrap();
    let stats = cluster.shutdown().unwrap();
    let ok: u64 = stats.iter().map(|s| s.requests_ok).sum();
    assert_eq!(ok, 12, "both framings' submits reached the shards");
}

/// Many-connection fan-out against the epoll transport: far more
/// concurrent connections than worker threads, every one served, zero
/// connect failures, and the report carries the connect-latency tail.
#[test]
fn epoll_sustains_many_concurrent_connections() {
    let server = Server::start(opts("", TransportKind::Epoll)).unwrap();
    let addr = server.local_addr().to_string();
    let lg = LoadgenOptions {
        requests: 1,
        app: "matmul".into(),
        size: 24,
        connections: 64,
        framing: Framing::Binary,
        ..LoadgenOptions::default()
    };
    let report = loadgen::run(&addr, &lg).unwrap();
    assert_eq!(report.connections, 64);
    assert_eq!(report.connect_failures, 0, "every connection established");
    assert_eq!(report.errors, 0);
    assert_eq!(report.requests, 64);
    assert!(report.connect_p99 >= report.connect_p50);
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests_ok, 64);
    assert_eq!(stats.sessions, 0, "all fan-out sessions drained");
}
