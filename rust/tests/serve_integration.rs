//! Integration tests for the multi-tenant component service: an
//! in-process server partitioned into two scheduling contexts, driven by
//! ≥8 concurrent clients submitting matmul/nw task graphs. Asserts
//! numerically correct results, strict per-context worker isolation, and
//! a clean drain (zero in-flight, every request accounted for).

use std::collections::BTreeSet;
use std::time::Duration;

use compar::serve::{loadgen, parse_contexts, Client, LoadgenOptions, ServeOptions, Server, SubmitReq};
use compar::taskrt::{SchedPolicy, SelectorKind};

fn opts(contexts: &str) -> ServeOptions {
    ServeOptions {
        addr: "127.0.0.1:0".into(),
        contexts: parse_contexts(contexts).unwrap(),
        sched: SchedPolicy::Dmda,
        selector: Some(SelectorKind::Greedy),
        ncpu: 4,
        ncuda: 0,
        max_inflight: 16,
        batch_window: Duration::from_micros(200),
        max_batch: 8,
        autoscale: None,
        ..ServeOptions::default()
    }
}

fn submit(id: u64, app: &str, size: usize, tasks: usize, ctx: Option<&str>, seed: u64) -> SubmitReq {
    SubmitReq {
        id,
        app: app.into(),
        size,
        tasks,
        ctx: ctx.map(str::to_string),
        seed,
        variant: None,
        verify: true,
        trace: 0,
    }
}

#[test]
fn concurrent_clients_two_contexts_isolated() {
    let server = Server::start(opts("alpha:2,beta:2")).unwrap();
    let addr = server.local_addr().to_string();
    let table = server.context_table();
    let partition = |name: &str| -> BTreeSet<usize> {
        table
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, w)| w.iter().copied().collect())
            .unwrap_or_else(|| panic!("context {name} missing from {table:?}"))
    };
    let alpha = partition("alpha");
    let beta = partition("beta");
    assert_eq!(alpha.len(), 2);
    assert_eq!(beta.len(), 2);
    assert!(alpha.is_disjoint(&beta), "partitions overlap: {alpha:?} {beta:?}");

    let handles: Vec<_> = (0..8)
        .map(|i: usize| {
            let addr = addr.clone();
            let alpha = alpha.clone();
            let beta = beta.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let ctx = if i % 2 == 0 { "alpha" } else { "beta" };
                let allowed = if i % 2 == 0 { &alpha } else { &beta };
                for r in 0..4usize {
                    // alternate apps so same-codelet batching gets company
                    let (app, size, tol) = if (i + r) % 2 == 0 {
                        ("matmul", 48, 5e-3)
                    } else {
                        ("nw", 32, 1e-3)
                    };
                    let seed = 1000 + (i * 10 + r) as u64;
                    let resp = c
                        .submit(submit(r as u64, app, size, 2, Some(ctx), seed))
                        .unwrap_or_else(|e| panic!("client {i} req {r}: {e:#}"));
                    assert_eq!(resp.ctx, ctx);
                    assert_eq!(resp.workers.len(), 2, "chain of 2 tasks");
                    assert_eq!(resp.variants.len(), 2);
                    for w in &resp.workers {
                        assert!(
                            allowed.contains(w),
                            "context {ctx} task ran on worker {w}, partition {allowed:?}"
                        );
                    }
                    assert!(
                        resp.rel_err <= tol,
                        "{app} rel_err {} over {tol}",
                        resp.rel_err
                    );
                }
                c.quit().unwrap();
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // clean drain: nothing in flight, every request + task accounted
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.inflight, 0, "drain left requests in flight");
    assert_eq!(stats.requests_err, 0);
    assert_eq!(stats.requests_ok, 32, "8 clients x 4 requests");
    assert_eq!(stats.tasks_executed, 64, "32 requests x 2-task chains");
    assert!(stats.ctx_tasks["alpha"] > 0, "{:?}", stats.ctx_tasks);
    assert!(stats.ctx_tasks["beta"] > 0, "{:?}", stats.ctx_tasks);
    assert_eq!(stats.ctx_tasks["alpha"] + stats.ctx_tasks["beta"], 64);
}

#[test]
fn loadgen_reports_throughput_and_percentiles() {
    let server = Server::start(opts("alpha:2,beta:2")).unwrap();
    let addr = server.local_addr().to_string();
    let lg = LoadgenOptions {
        clients: 4,
        requests: 6,
        app: "matmul".into(),
        size: 32,
        tasks: 1,
        ctxs: vec!["alpha".into(), "beta".into()],
        pipeline: 1,
        policy: None,
        profile: None,
        verify: true,
        seed: 7,
        ..LoadgenOptions::default()
    };
    let report = loadgen::run(&addr, &lg).unwrap();
    assert_eq!(report.errors, 0);
    assert_eq!(report.requests, 24);
    assert!(report.rps > 0.0);
    assert!(report.p50 <= report.p95 && report.p95 <= report.p99);
    assert!(report.lat_min <= report.p50 && report.p99 <= report.lat_max);
    assert_eq!(report.per_ctx.values().sum::<usize>(), 24);
    assert!(report.per_ctx.contains_key("alpha"), "{:?}", report.per_ctx);
    assert!(report.per_ctx.contains_key("beta"), "{:?}", report.per_ctx);
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests_err, 0);
    assert_eq!(stats.requests_ok, 24);
}

#[test]
fn pipelined_loadgen_matches_out_of_order_replies() {
    let server = Server::start(opts("alpha:2,beta:2")).unwrap();
    let addr = server.local_addr().to_string();
    let lg = LoadgenOptions {
        clients: 3,
        requests: 8,
        app: "matmul".into(),
        size: 32,
        tasks: 1,
        ctxs: vec!["alpha".into(), "beta".into()],
        pipeline: 4,
        policy: None,
        profile: None,
        verify: true,
        seed: 21,
        ..LoadgenOptions::default()
    };
    let report = loadgen::run(&addr, &lg).unwrap();
    assert_eq!(report.errors, 0);
    assert_eq!(report.requests, 24);
    assert_eq!(report.pipeline, 4);
    assert!(report.rps > 0.0);
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests_ok, 24);
    assert_eq!(stats.inflight, 0, "pipelined drain left requests behind");
}

#[test]
fn session_policy_pins_selection_and_is_reported() {
    let server = Server::start(opts("")).unwrap();
    let addr = server.local_addr().to_string();

    // a bogus policy is rejected in the handshake, and the error names
    // the full valid set (uniform validation across serve and route)
    let err = Client::connect_with_policy(&addr, Some("bogus")).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown selection policy"), "{msg}");
    for name in ["greedy", "calibrating", "epsilon-decayed", "contextual", "forced"] {
        assert!(msg.contains(name), "valid set must name {name}: {msg}");
    }

    // forced:omp session: every task must run the omp variant
    let mut c = Client::connect_with_policy(&addr, Some("forced:omp")).unwrap();
    for r in 0..3u64 {
        let resp = c.submit(submit(r, "matmul", 32, 1, None, 100 + r)).unwrap();
        assert_eq!(resp.policy, "forced:omp");
        assert!(resp.variants.iter().all(|v| v == "omp"), "{:?}", resp.variants);
    }
    // per-request variant pin overrides the session policy
    let mut req = submit(9, "matmul", 32, 1, None, 5);
    req.variant = Some("seq".into());
    let resp = c.submit(req).unwrap();
    assert_eq!(resp.policy, "forced:seq");
    assert!(resp.variants.iter().all(|v| v == "seq"), "{:?}", resp.variants);

    // selection counts surface per context in stats
    let stats = c.stats().unwrap();
    let default_hist = stats.ctx_variants.get("default").expect("default ctx histogram");
    assert_eq!(default_hist.get("omp").copied().unwrap_or(0), 3);
    assert_eq!(default_hist.get("seq").copied().unwrap_or(0), 1);

    // context descriptors expose their selection policy
    let contexts = c.contexts().unwrap();
    assert_eq!(contexts[0].selector, "greedy");
    c.quit().unwrap();
    server.shutdown().unwrap();
}

#[test]
fn contextual_session_policy_accepted_and_v4_stats_report_snapshot() {
    let server = Server::start(opts("")).unwrap();
    let addr = server.local_addr().to_string();
    let mut c = Client::connect_with_policy(&addr, Some("contextual")).unwrap();
    for r in 0..3u64 {
        let resp = c.submit(submit(r, "matmul", 32, 1, None, 300 + r)).unwrap();
        assert_eq!(resp.policy, "contextual");
        assert_eq!(resp.variants.len(), 1);
    }
    // v4: stats carry the runtime-snapshot features
    let stats = c.stats().unwrap();
    assert_eq!(stats.sessions, 1, "one live session (this one)");
    assert_eq!(stats.total_workers, 4);
    assert!(stats.busy_workers <= stats.total_workers);
    c.quit().unwrap();
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests_ok, 3);
    assert_eq!(stats.sessions, 0, "drained server has no live sessions");
    assert_eq!(stats.queue_depth, 0, "drained server has nothing queued");
}

#[test]
fn unknown_variant_is_a_protocol_error() {
    let server = Server::start(opts("")).unwrap();
    let addr = server.local_addr().to_string();
    let mut c = Client::connect(&addr).unwrap();
    let mut req = submit(1, "matmul", 32, 1, None, 1);
    req.variant = Some("tpu".into());
    let e = c.submit(req).unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("unknown variant 'tpu'"), "{msg}");
    assert!(msg.contains("registered:"), "{msg}");
    // the session still works afterwards with a valid pin
    let mut req = submit(2, "matmul", 32, 1, None, 2);
    req.variant = Some("omp".into());
    let ok = c.submit(req).unwrap();
    assert!(ok.variants.iter().all(|v| v == "omp"));
    c.quit().unwrap();
    server.shutdown().unwrap();
}

#[test]
fn shared_input_registration_for_identical_instances() {
    use compar::apps;
    use compar::taskrt::{Config, Runtime, TaskSpec};
    let rt = Runtime::new(
        Config {
            ncpu: 2,
            ncuda: 0,
            ..Config::default()
        },
        None,
    )
    .unwrap();
    // matmul shares its two read-only inputs; the output stays private
    assert_eq!(apps::shared_input_indices("matmul"), &[0, 1]);
    assert_eq!(apps::shared_input_indices("nw"), &[0]);
    assert!(apps::shared_input_indices("lud").is_empty());
    let mut donor = apps::prepare(&rt, "matmul", 24, 9).unwrap();
    let donated = donor.donate_handles(&[0, 1]);
    assert_eq!(donated.len(), 2);
    // the donor no longer owns the donated inputs
    assert_eq!(donor.owned_handles(), vec![donor.handles[2]]);
    let rider = apps::prepare_with_inputs(&rt, "matmul", 24, 9, &donated).unwrap();
    assert_eq!(rider.handles[0], donor.handles[0], "input a shared");
    assert_eq!(rider.handles[1], donor.handles[1], "input b shared");
    assert_ne!(rider.handles[2], donor.handles[2], "outputs are private");
    assert_eq!(rider.owned_handles(), vec![rider.handles[2]]);
    // both instances compute the same (correct) product concurrently
    let cl = rt.register_codelet(apps::codelet("matmul").unwrap());
    let t1 = rt
        .submit(TaskSpec::new(cl.clone(), donor.handles.clone(), 24))
        .unwrap();
    let t2 = rt
        .submit(TaskSpec::new(cl, rider.handles.clone(), 24))
        .unwrap();
    rt.wait_tasks(&[t1, t2]).unwrap();
    let want = apps::expected(&donor).unwrap();
    for inst in [&donor, &rider] {
        let got = rt.snapshot(apps::output_handle(inst)).unwrap();
        assert!(got.rel_l2_error(&want) <= 5e-3);
    }
    // cleanup order: riders first, then the shared inputs
    for h in donor.owned_handles() {
        rt.unregister_data(h).unwrap();
    }
    for h in rider.owned_handles() {
        rt.unregister_data(h).unwrap();
    }
    for (_, h) in donated {
        rt.unregister_data(h).unwrap();
    }
}

#[test]
fn identical_pipelined_requests_batch_and_verify() {
    // identical (app, size, seed) requests fired back-to-back share
    // input registrations inside a batch; results must stay correct and
    // every reply must come back
    let server = Server::start(opts("")).unwrap();
    let addr = server.local_addr().to_string();
    let mut c = Client::connect(&addr).unwrap();
    let n = 8u64;
    for id in 0..n {
        // same seed on purpose: all riders in a batch are identical
        c.send_submit(submit(id, "matmul", 32, 1, None, 77)).unwrap();
    }
    let mut seen = BTreeSet::new();
    for _ in 0..n {
        match c.recv_response().unwrap() {
            compar::serve::Response::Result(r) => {
                assert!(r.rel_err <= 5e-3, "{}", r.rel_err);
                seen.insert(r.id);
            }
            other => panic!("{other:?}"),
        }
    }
    assert_eq!(seen.len(), n as usize, "every identical request answered");
    c.quit().unwrap();
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests_ok, n);
    assert_eq!(stats.requests_err, 0);
    assert_eq!(stats.inflight, 0);
}

#[test]
fn server_rejects_bad_requests_and_recovers() {
    let server = Server::start(opts("")).unwrap();
    let addr = server.local_addr().to_string();
    let mut c = Client::connect(&addr).unwrap();

    // unknown app
    let e = c.submit(submit(1, "bogus", 32, 1, None, 1)).unwrap_err();
    assert!(format!("{e:#}").contains("unknown app"), "{e:#}");
    // unknown context
    let e = c
        .submit(submit(2, "matmul", 32, 1, Some("nope"), 1))
        .unwrap_err();
    assert!(format!("{e:#}").contains("unknown context"), "{e:#}");
    // verified chains only for idempotent apps
    let e = c.submit(submit(3, "hotspot", 64, 2, None, 1)).unwrap_err();
    assert!(format!("{e:#}").contains("idempotent"), "{e:#}");

    // the session still works afterwards
    let ok = c.submit(submit(4, "matmul", 32, 1, None, 5)).unwrap();
    assert_eq!(ok.ctx, "default");
    assert_eq!(ok.workers.len(), 1);

    let contexts = c.contexts().unwrap();
    assert_eq!(contexts.len(), 1);
    assert_eq!(contexts[0].name, "default");
    assert_eq!(contexts[0].workers, vec![0, 1, 2, 3]);

    let stats = c.stats().unwrap();
    assert_eq!(stats.requests_ok, 1);
    assert_eq!(stats.requests_err, 3);
    c.quit().unwrap();

    let stats = server.shutdown().unwrap();
    assert_eq!(stats.inflight, 0);
}
