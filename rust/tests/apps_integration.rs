//! Cross-variant numerics: for every app and every available size, all
//! implementation variants (native seq/omp + jnp/pallas artifacts) must
//! produce the same result within tolerance. This is the deepest
//! correctness net in the repo: it catches divergence between the Rust
//! reimplementations, the jnp oracles and the Pallas kernels after they
//! went through AOT lowering + PJRT compilation.

use std::sync::Arc;

use compar::apps;
use compar::runtime::Manifest;
use compar::taskrt::{Config, Runtime, SchedPolicy};

fn manifest() -> Option<Arc<Manifest>> {
    let dir = compar::runtime::manifest::default_dir();
    Manifest::load(&dir).ok().map(Arc::new)
}

fn runtime(m: &Arc<Manifest>) -> Runtime {
    Runtime::new(
        Config {
            ncpu: 2,
            ncuda: 1,
            sched: SchedPolicy::Eager,
            ..Config::default()
        },
        Some(m.clone()),
    )
    .unwrap()
}

/// Variants to exercise per app: all native + all artifact-backed.
fn all_variants(app: &str) -> Vec<&'static str> {
    match app {
        "matmul" => vec!["blas", "omp", "seq", "cuda", "cublas"],
        _ => vec!["omp", "seq", "cuda"],
    }
}

fn sizes_under_test(app: &str, m: &Manifest) -> Vec<usize> {
    // sizes with a pallas artifact, capped for test runtime
    m.sizes(app, "pallas")
        .into_iter()
        .filter(|&s| s <= 256)
        .collect()
}

#[test]
fn every_variant_agrees_with_reference() {
    let Some(m) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let rt = runtime(&m);
    for app in apps::ALL {
        for size in sizes_under_test(app, &m) {
            for variant in all_variants(app) {
                let run = apps::run_once(&rt, app, size, 31337, Some(variant), true)
                    .unwrap_or_else(|e| panic!("{app}/{variant}/{size}: {e:#}"));
                assert_eq!(&run.variant, variant);
                assert!(
                    run.rel_err <= apps::tolerance(app),
                    "{app}/{variant}/{size}: rel_err {}",
                    run.rel_err
                );
            }
        }
    }
}

#[test]
fn repeated_runs_are_deterministic() {
    let Some(m) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let rt = runtime(&m);
    // same seed => identical outputs, for a native and an artifact variant
    for variant in ["omp", "cuda"] {
        let mut outputs = Vec::new();
        for _ in 0..2 {
            let inst = apps::prepare(&rt, "hotspot", 64, 777).unwrap();
            let cl = rt
                .codelet("hotspot")
                .unwrap_or_else(|| rt.register_codelet(apps::codelet("hotspot").unwrap()));
            let spec = compar::taskrt::TaskSpec::new(cl, inst.handles.clone(), 64)
                .with_variant(variant);
            rt.submit(spec).unwrap();
            rt.wait_all().unwrap();
            outputs.push(rt.snapshot(apps::output_handle(&inst)).unwrap());
        }
        assert_eq!(
            outputs[0], outputs[1],
            "{variant}: nondeterministic output"
        );
    }
}

#[test]
fn matmul_blas_and_cublas_share_numerics() {
    // blas (jnp on cpu) and cublas (pallas on gpu) must agree: they run
    // through different devices and different kernels
    let Some(m) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let rt = runtime(&m);
    let mut results = Vec::new();
    for variant in ["blas", "cublas"] {
        let inst = apps::prepare(&rt, "matmul", 128, 2024).unwrap();
        let cl = rt
            .codelet("mmul")
            .unwrap_or_else(|| rt.register_codelet(apps::codelet("matmul").unwrap()));
        let spec =
            compar::taskrt::TaskSpec::new(cl, inst.handles.clone(), 128).with_variant(variant);
        rt.submit(spec).unwrap();
        rt.wait_all().unwrap();
        results.push(rt.snapshot(apps::output_handle(&inst)).unwrap());
    }
    let err = results[0].rel_l2_error(&results[1]);
    assert!(err < 1e-5, "blas vs cublas rel err {err}");
}

#[test]
fn mixed_app_stream_on_one_runtime() {
    // interleave tasks of all apps in one runtime instance — exercises
    // codelet registry, manifest lookups and scheduler fairness together
    let Some(m) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let rt = runtime(&m);
    let stream: Vec<(&str, usize)> = vec![
        ("matmul", 64),
        ("hotspot", 64),
        ("sort", 256),
        ("matmul", 128),
        ("nw", 63),
        ("lud", 64),
        ("hotspot3d", 64),
        ("sort", 1024),
    ];
    for (i, (app, size)) in stream.iter().enumerate() {
        apps::run_once(&rt, app, *size, 400 + i as u64, None, true)
            .unwrap_or_else(|e| panic!("{app}: {e:#}"));
    }
    assert_eq!(
        rt.metrics()
            .tasks_executed
            .load(std::sync::atomic::Ordering::Relaxed),
        stream.len()
    );
}
