//! Integration tests for v6 stream sessions: a heterogeneous server
//! (CPU workers + an emulated device lane) accepts a chunk pipeline,
//! selects every chunk's variant per-chunk, and answers overload with
//! SLO-driven credit backpressure — shedding window granularity and
//! shrinking the chunk window instead of dropping chunks. Also covers
//! the autoscale coupling (sustained stream pressure migrates workers,
//! the stream's SLO shows up in `stats`) and the protocol error paths.

use std::collections::BTreeSet;
use std::time::{Duration, Instant};

use compar::autoscale::AutoscaleOptions;
use compar::serve::{parse_contexts, Client, Response, ServeOptions, Server, StreamOpenReq};
use compar::stream::{self, BASE_CREDIT};
use compar::taskrt::SelectorKind;

fn open_req(id: u64, app: &str, size: usize, stages: usize) -> StreamOpenReq {
    StreamOpenReq {
        id,
        app: app.into(),
        size,
        stages,
        window: 0,
        slide: 0,
        ctx: None,
        slo_ms: None,
        trace: 0,
    }
}

/// Client-side mirror of the credit window for stream 1: tracks the
/// live grant, how low it sank, and every variant any chunk stage ran.
struct Flow {
    credit: u64,
    min_credit: u64,
    inflight: u64,
    credit_signals: u64,
    variants_seen: BTreeSet<String>,
}

impl Flow {
    fn new(initial_credit: u64) -> Flow {
        let credit = initial_credit.max(1);
        Flow {
            credit,
            min_credit: credit,
            inflight: 0,
            credit_signals: 0,
            variants_seen: BTreeSet::new(),
        }
    }

    fn recv_one(&mut self, c: &mut Client) {
        match c.recv_response().unwrap() {
            Response::StreamAck(a) => {
                assert_eq!(a.stream, 1);
                assert!(
                    a.variants.len() >= 2,
                    "2 pipeline stages expected per chunk: {:?}",
                    a.variants
                );
                assert_eq!(a.variants.len(), a.workers.len());
                for v in a.variants {
                    self.variants_seen.insert(v);
                }
                self.credit = a.credit.max(1);
                self.min_credit = self.min_credit.min(self.credit);
                self.inflight -= 1;
            }
            Response::StreamCredit(cr) => {
                assert_eq!(cr.stream, 1);
                self.credit = cr.credit.max(1);
                self.min_credit = self.min_credit.min(self.credit);
                self.credit_signals += 1;
            }
            Response::Error { error, .. } => panic!("stream error: {error}"),
            other => panic!("unexpected response {other:?}"),
        }
    }
}

/// The tentpole contract end-to-end: chunks pushed faster than a tight
/// SLO allows must see the credit window shrink (`stream_credit`
/// backpressure), windows keep firing, no chunk is ever dropped, and
/// the per-chunk variant record shows both the device lane and the
/// host lanes executing — selection flipping chunk by chunk.
#[test]
fn overload_sheds_credit_not_chunks_and_flips_variants() {
    let server = Server::start(ServeOptions {
        addr: "127.0.0.1:0".into(),
        ncpu: 2,
        ncuda: 1,
        selector: Some(SelectorKind::Contextual),
        ..ServeOptions::default()
    })
    .unwrap();
    // the real cuda variant is a Pallas artifact; emulate the device
    // lane natively so the heterogeneous story runs on a bare image
    server.register_codelet(stream::emulated_device_sort(Duration::from_millis(5)));
    let addr = server.local_addr().to_string();

    let mut c = Client::connect(&addr).unwrap();
    let opened = c
        .stream_open(StreamOpenReq {
            window: 4,
            slide: 2,
            slo_ms: Some(20.0),
            ..open_req(1, "sort", 32_768, 2)
        })
        .unwrap();
    assert_eq!(opened.credit, BASE_CREDIT);
    assert_eq!((opened.window, opened.slide), (4, 2));
    assert_eq!(opened.slo_ms, Some(20.0));

    const CHUNKS: u64 = 60;
    let mut flow = Flow::new(opened.credit);
    for seq in 0..CHUNKS {
        // respect the live credit grant, exactly like a real client
        while flow.inflight >= flow.credit {
            flow.recv_one(&mut c);
        }
        c.send_stream_chunk(1, seq, 0xbeef ^ seq).unwrap();
        flow.inflight += 1;
    }
    while flow.inflight > 0 {
        flow.recv_one(&mut c);
    }

    let closed = c.stream_close(1).unwrap();
    assert_eq!(closed.chunks, CHUNKS, "every chunk acked");
    assert_eq!(closed.dropped, 0, "backpressure must never drop chunks");
    assert!(closed.windows >= 1, "windows kept firing: {closed:?}");
    assert!(
        flow.credit_signals >= 1 && closed.credit_signals >= 1,
        "overload never engaged credit backpressure (client saw {}, server counted {})",
        flow.credit_signals,
        closed.credit_signals
    );
    assert!(
        flow.min_credit < BASE_CREDIT,
        "credit window never shrank below the base grant"
    );
    assert!(closed.p95_ms > 0.0);
    assert!(
        flow.variants_seen.contains("cuda"),
        "device lane never executed a chunk stage: {:?}",
        flow.variants_seen
    );
    assert!(
        flow.variants_seen.contains("omp") || flow.variants_seen.contains("seq"),
        "host lanes never executed a chunk stage: {:?}",
        flow.variants_seen
    );

    c.quit().unwrap();
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests_err, 0, "no chunk may error: {stats:?}");
    assert_eq!(stats.streams, 0, "stream gauge must return to zero");
}

/// Autoscale coupling: a stream pinned to a 1-worker context with a
/// loose SLO (so credit never throttles the queue away) builds
/// sustained pressure; the control loop must migrate pool workers in —
/// observable through `autoscale_status` — and the stream's declared
/// SLO must surface as the default context's effective `stats.slo_ms`
/// while the stream lives.
#[test]
fn sustained_stream_pressure_migrates_workers_and_surfaces_slo() {
    let server = Server::start(ServeOptions {
        addr: "127.0.0.1:0".into(),
        contexts: parse_contexts("hot:1,pool:3").unwrap(),
        ncpu: 4,
        ncuda: 0,
        autoscale: Some(AutoscaleOptions {
            period: Duration::from_millis(10),
            cooldown: Duration::from_millis(40),
            sustain: 1,
            ..AutoscaleOptions::default()
        }),
        ..ServeOptions::default()
    })
    .unwrap();
    let addr = server.local_addr().to_string();

    let mut c = Client::connect(&addr).unwrap();
    let mut mon = Client::connect(&addr).unwrap();
    let opened = c
        .stream_open(StreamOpenReq {
            ctx: Some("hot".into()),
            slo_ms: Some(200.0),
            ..open_req(7, "sort", 65_536, 2)
        })
        .unwrap();
    assert_eq!(opened.slo_ms, Some(200.0));

    let deadline = Instant::now() + Duration::from_secs(30);
    let mut credit = opened.credit.max(1);
    let mut inflight: u64 = 0;
    let mut seq: u64 = 0;
    let mut migrated = false;
    while Instant::now() < deadline && !migrated {
        for _ in 0..16 {
            while inflight >= credit {
                match c.recv_response().unwrap() {
                    Response::StreamAck(a) => {
                        credit = a.credit.max(1);
                        inflight -= 1;
                    }
                    Response::StreamCredit(cr) => credit = cr.credit.max(1),
                    Response::Error { error, .. } => panic!("stream error: {error}"),
                    other => panic!("unexpected response {other:?}"),
                }
            }
            c.send_stream_chunk(7, seq, 0x5eed ^ seq).unwrap();
            inflight += 1;
            seq += 1;
        }
        let st = mon.autoscale_status().unwrap();
        assert!(st.enabled);
        if st.moves >= 1 && st.moved_workers >= 1 {
            migrated = true;
        }
    }
    assert!(
        migrated,
        "autoscaler never migrated a worker into the pressured stream context \
         ({seq} chunks pushed)"
    );

    // the stream-scoped declaration tightened the default ("hot")
    // context's target — visible server-wide while the stream is open
    let stats = mon.stats().unwrap();
    assert!(
        (stats.slo_ms - 200.0).abs() < 1e-6,
        "stats.slo_ms = {} (expected the stream's 200 ms declaration)",
        stats.slo_ms
    );
    assert!(stats.streams >= 1, "open-stream gauge: {stats:?}");

    let closed = c.stream_close(7).unwrap();
    assert_eq!(closed.chunks, seq, "every submitted chunk acked");
    assert_eq!(closed.dropped, 0);
    c.quit().unwrap();
    mon.quit().unwrap();
    server.shutdown().unwrap();
}

/// Protocol error paths: chunks for unknown streams, duplicate stream
/// ids, and non-idempotent apps in multi-stage pipelines are rejected
/// with telling errors — and a healthy stream on the same session keeps
/// working through all of it.
#[test]
fn stream_protocol_rejects_bad_opens_and_orphan_chunks() {
    let server = Server::start(ServeOptions {
        addr: "127.0.0.1:0".into(),
        ncpu: 2,
        ..ServeOptions::default()
    })
    .unwrap();
    let addr = server.local_addr().to_string();
    let mut c = Client::connect(&addr).unwrap();

    // chunk for a stream nobody opened
    c.send_stream_chunk(99, 0, 1).unwrap();
    match c.recv_response().unwrap() {
        Response::Error { error, .. } => {
            assert!(error.contains("unknown stream 99"), "{error}")
        }
        other => panic!("expected an error, got {other:?}"),
    }

    let opened = c.stream_open(open_req(1, "sort", 4096, 1)).unwrap();
    assert_eq!(opened.stream, 1);
    assert_eq!(opened.window, 0, "no windowed operator declared");

    // same id again on the same session
    let err = c.stream_open(open_req(1, "sort", 4096, 1)).unwrap_err();
    assert!(format!("{err:#}").contains("already open"), "{err:#}");

    // hotspot's stencil is not idempotent: fine single-stage, but a
    // pipeline would re-apply it to its own output
    let err = c.stream_open(open_req(2, "hotspot", 4096, 2)).unwrap_err();
    assert!(format!("{err:#}").contains("not idempotent"), "{err:#}");

    // zero-sized chunks and unknown apps are rejected up front
    let err = c.stream_open(open_req(3, "sort", 0, 1)).unwrap_err();
    assert!(format!("{err:#}").contains("size"), "{err:#}");
    let err = c.stream_open(open_req(4, "nope", 64, 1)).unwrap_err();
    assert!(format!("{err:#}").contains("unknown app"), "{err:#}");

    // the healthy stream still works after every rejection
    for seq in 0..3u64 {
        c.send_stream_chunk(1, seq, 7 + seq).unwrap();
        match c.recv_response().unwrap() {
            Response::StreamAck(a) => {
                assert_eq!((a.stream, a.seq), (1, seq));
                assert_eq!(a.variants.len(), 1, "single-stage pipeline");
            }
            other => panic!("expected an ack, got {other:?}"),
        }
    }
    let closed = c.stream_close(1).unwrap();
    assert_eq!((closed.chunks, closed.dropped, closed.windows), (3, 0, 0));
    c.quit().unwrap();
    server.shutdown().unwrap();
}
