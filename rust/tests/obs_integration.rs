//! Integration tests for the v9 observability plane: a live server
//! must answer `metrics` scrapes whose end-to-end latency histogram
//! reconciles exactly with the load the generator reports, a
//! `decisions` query against a contextual-policy session must show the
//! device→host variant flip annotated with the load band that caused
//! it (the paper's selection story, now auditable), `dump_trace` must
//! hand back chrome://tracing JSON keyed by request trace ids, and the
//! audit ring must stay bounded under overflow.

use std::time::Duration;

use compar::serve::{
    loadgen, Client, LoadgenOptions, Response, ServeOptions, Server, StreamOpenReq, SubmitReq,
};
use compar::stream;
use compar::taskrt::SelectorKind;
use compar::util::json::Json;

fn submit_req(id: u64, app: &str, size: usize) -> SubmitReq {
    SubmitReq {
        id,
        app: app.into(),
        size,
        tasks: 1,
        ctx: None,
        seed: 7 + id,
        variant: None,
        verify: true,
        trace: 0,
    }
}

/// Pull a named histogram out of a registry scrape.
fn hist<'a>(metrics: &'a Json, name: &str) -> &'a Json {
    metrics
        .get("histograms")
        .and_then(|h| h.get(name))
        .unwrap_or_else(|| panic!("scrape is missing histogram {name}: {metrics:?}"))
}

/// A counter's value in a registry scrape (0 when absent).
fn counter(metrics: &Json, name: &str) -> f64 {
    metrics
        .get("counters")
        .and_then(|c| c.get(name))
        .and_then(Json::as_f64)
        .unwrap_or(0.0)
}

/// The e2e-histogram acceptance contract: after a loadgen run, the
/// `serve_e2e_seconds` histogram's count equals the generator's
/// successful-request count, its bucket counts sum to that count, and
/// every counter in the registry is monotonic between two scrapes.
#[test]
fn metrics_scrape_reconciles_with_loadgen() {
    let server = Server::start(ServeOptions {
        addr: "127.0.0.1:0".into(),
        ncpu: 2,
        ..ServeOptions::default()
    })
    .unwrap();
    let addr = server.local_addr().to_string();
    let mut mon = Client::connect(&addr).unwrap();

    // baseline scrape: instruments exist before any request ran
    let m0 = mon.metrics(None).unwrap();
    let e2e0 = hist(&m0.metrics, "serve_e2e_seconds");
    assert_eq!(e2e0.get("count").and_then(Json::as_f64), Some(0.0));

    let load = LoadgenOptions {
        clients: 2,
        requests: 10,
        app: "matmul".into(),
        size: 32,
        ..LoadgenOptions::default()
    };
    let report = loadgen::run(&addr, &load).unwrap();
    assert_eq!(report.errors, 0, "load must succeed: {report:?}");
    let ok = (report.requests - report.errors) as f64;
    assert_eq!(ok, 20.0);

    let m1 = mon.metrics(None).unwrap();
    let e2e = hist(&m1.metrics, "serve_e2e_seconds");
    // the acceptance reconcile: one e2e observation per successful
    // request, no more (scrapes and handshakes are not requests)
    assert_eq!(
        e2e.get("count").and_then(Json::as_f64),
        Some(ok),
        "serve_e2e_seconds count must equal loadgen successes: {e2e:?}"
    );
    // histogram internal consistency: bucket counts (incl. overflow)
    // sum to the observation count, bounds ladder is intact
    let le = e2e.get("le").and_then(Json::as_arr).unwrap();
    let counts = e2e.get("counts").and_then(Json::as_arr).unwrap();
    assert_eq!(counts.len(), le.len() + 1, "per-bound buckets + overflow");
    let bucket_sum: f64 = counts.iter().filter_map(Json::as_f64).sum();
    assert_eq!(bucket_sum, ok, "bucket counts must sum to count");
    let sum = e2e.get("sum").and_then(Json::as_f64).unwrap();
    assert!(sum > 0.0, "observed seconds must accumulate: {e2e:?}");
    // each request's server-side interval nests inside the client's
    // observed latency, so the summed e2e is bounded by the load side
    assert!(
        sum <= ok * report.lat_max + 0.5,
        "summed e2e {sum}s cannot exceed {ok} requests at the client's \
         max latency {}s",
        report.lat_max
    );

    // every counter is monotonic across scrapes, and the selection
    // plane counted at least one decision per executed task
    let c0 = m0.metrics.get("counters").and_then(Json::as_obj).unwrap();
    let c1 = m1.metrics.get("counters").and_then(Json::as_obj).unwrap();
    for (name, v0) in c0 {
        let v0 = v0.as_f64().unwrap();
        let v1 = c1.get(name).and_then(Json::as_f64).unwrap_or_else(|| {
            panic!("counter {name} disappeared between scrapes");
        });
        assert!(v1 >= v0, "counter {name} went backwards: {v0} -> {v1}");
    }
    assert!(counter(&m1.metrics, "select_decisions_total") >= ok);

    // prometheus text mode renders the same registry
    let prom = mon.metrics(Some("prometheus")).unwrap();
    let text = prom.text.expect("text mode must fill `text`");
    assert!(text.contains("# TYPE"), "{text}");
    assert!(text.contains("serve_e2e_seconds"), "{text}");
    // unknown formats are rejected, not guessed
    let err = mon.metrics(Some("xml")).unwrap_err();
    assert!(format!("{err:#}").contains("unknown metrics format"));

    // stats satellite: the monotonic totals move with the load and a
    // scalar submit answers with a minted trace id
    let s1 = mon.stats().unwrap();
    assert_eq!(s1.requests_ok, 20);
    assert!(s1.tasks_completed >= 20, "{s1:?}");
    assert!(s1.decisions >= 20, "{s1:?}");
    let r = mon.submit(submit_req(900, "matmul", 32)).unwrap();
    assert_ne!(r.trace, 0, "server must mint a trace id: {r:?}");
    let s2 = mon.stats().unwrap();
    assert!(s2.tasks_completed > s1.tasks_completed, "{s1:?} -> {s2:?}");
    assert!(s2.bytes_transferred >= s1.bytes_transferred);

    mon.quit().unwrap();
    server.shutdown().unwrap();
}

/// The decision-audit acceptance contract on the emulated device lane:
/// drive a contextual-policy stream from an idle start into credit-
/// gated overload, then ask `decisions` for the sort codelet — the
/// audit must show the device lane chosen at a lower load band than a
/// host lane (the device→host flip, annotated with the band that
/// caused it), and `dump_trace` must return request-keyed spans.
#[test]
fn decisions_audit_shows_load_band_flip_on_device_lane() {
    let server = Server::start(ServeOptions {
        addr: "127.0.0.1:0".into(),
        ncpu: 2,
        ncuda: 1,
        selector: Some(SelectorKind::Contextual),
        // every decision of this run must stay resident for the query
        audit_cap: 8192,
        ..ServeOptions::default()
    })
    .unwrap();
    // the real cuda variant is a Pallas artifact; emulate the device
    // lane natively so the heterogeneous story runs on a bare image
    server.register_codelet(stream::emulated_device_sort(Duration::from_millis(5)));
    let addr = server.local_addr().to_string();

    let mut c = Client::connect(&addr).unwrap();
    let opened = c
        .stream_open(StreamOpenReq {
            id: 1,
            app: "sort".into(),
            size: 32_768,
            stages: 2,
            window: 4,
            slide: 2,
            ctx: None,
            slo_ms: Some(20.0),
            trace: 0,
        })
        .unwrap();

    // phase 1 — idle: one chunk at a time, fully drained before the
    // next, so its selections are audited at load band 0
    let mut seq: u64 = 0;
    for _ in 0..3 {
        c.send_stream_chunk(1, seq, 0xbeef ^ seq).unwrap();
        seq += 1;
        loop {
            match c.recv_response().unwrap() {
                Response::StreamAck(a) => {
                    assert_eq!(a.seq, seq - 1);
                    break;
                }
                Response::StreamCredit(_) => {}
                Response::Error { error, .. } => panic!("stream error: {error}"),
                other => panic!("unexpected response {other:?}"),
            }
        }
    }

    // phase 2 — overload: pipeline chunks up to the live credit grant,
    // building the backlog that pushes selections into higher bands
    let mut credit = opened.credit.max(1);
    let mut inflight: u64 = 0;
    while seq < 60 {
        while inflight >= credit {
            match c.recv_response().unwrap() {
                Response::StreamAck(a) => {
                    credit = a.credit.max(1);
                    inflight -= 1;
                }
                Response::StreamCredit(cr) => credit = cr.credit.max(1),
                Response::Error { error, .. } => panic!("stream error: {error}"),
                other => panic!("unexpected response {other:?}"),
            }
        }
        c.send_stream_chunk(1, seq, 0xbeef ^ seq).unwrap();
        inflight += 1;
        seq += 1;
    }
    while inflight > 0 {
        match c.recv_response().unwrap() {
            Response::StreamAck(_) => inflight -= 1,
            Response::StreamCredit(_) => {}
            Response::Error { error, .. } => panic!("stream error: {error}"),
            other => panic!("unexpected response {other:?}"),
        }
    }
    let closed = c.stream_close(1).unwrap();
    assert_eq!(closed.dropped, 0);

    let mut mon = Client::connect(&addr).unwrap();
    let d = mon.decisions(Some(0), Some("sort")).unwrap();
    assert!(d.total > 0, "{d:?}");
    let records = d.decisions.as_arr().unwrap();
    assert!(!records.is_empty(), "audit returned no records: {d:?}");

    let mut cuda_bands: Vec<f64> = Vec::new();
    let mut host_bands: Vec<f64> = Vec::new();
    for rec in records {
        assert_eq!(rec.get("codelet").and_then(Json::as_str), Some("sort"));
        let reason = rec.get("reason").and_then(Json::as_str).unwrap();
        assert!(!reason.is_empty(), "{rec:?}");
        assert!(rec.get("queue_depth").and_then(Json::as_f64).is_some());
        assert!(rec.get("candidates").and_then(Json::as_arr).is_some());
        let band = rec.get("load_band").and_then(Json::as_f64).unwrap();
        match rec.get("chosen").and_then(Json::as_str).unwrap() {
            "cuda" => cuda_bands.push(band),
            "omp" | "seq" => host_bands.push(band),
            other => panic!("unexpected variant {other} in {rec:?}"),
        }
    }
    assert!(!cuda_bands.is_empty(), "device lane never audited");
    assert!(!host_bands.is_empty(), "host lanes never audited");
    let cuda_min = cuda_bands.iter().cloned().fold(f64::INFINITY, f64::min);
    let host_max = host_bands.iter().cloned().fold(0.0, f64::max);
    assert_eq!(cuda_min, 0.0, "idle phase must audit the device at band 0");
    assert!(
        host_max > cuda_min,
        "no device→host flip across load bands (cuda bands {cuda_bands:?}, \
         host bands {host_bands:?})"
    );

    // the trace ring serves the same run as chrome://tracing JSON,
    // spans keyed by the stream's minted trace id
    let t = mon.dump_trace().unwrap();
    assert!(t.events > 0, "{t:?}");
    let events = t.trace.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert!(events.len() as u64 >= t.events, "metadata rides along");
    let traced = events.iter().any(|ev| {
        ev.get("ph").and_then(Json::as_str) == Some("X")
            && ev
                .get("args")
                .and_then(|a| a.get("trace"))
                .and_then(Json::as_f64)
                .map(|tr| tr > 0.0)
                .unwrap_or(false)
    });
    assert!(traced, "no span carries a request trace id");

    c.quit().unwrap();
    mon.quit().unwrap();
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.requests_err, 0, "{stats:?}");
}

/// The audit ring never grows past its configured capacity: overflow
/// evicts oldest records (counted, surfaced in `metrics`), retention
/// accounting stays exact, and `limit`/codelet filters behave.
#[test]
fn audit_ring_stays_bounded_and_counts_eviction() {
    let server = Server::start(ServeOptions {
        addr: "127.0.0.1:0".into(),
        ncpu: 2,
        audit_cap: 8,
        ..ServeOptions::default()
    })
    .unwrap();
    let addr = server.local_addr().to_string();
    let mut c = Client::connect(&addr).unwrap();
    for id in 0..20 {
        c.submit(submit_req(id, "matmul", 24)).unwrap();
    }

    let d = c.decisions(Some(0), None).unwrap();
    let retained = d.decisions.as_arr().unwrap().len() as u64;
    assert!(retained <= 8, "ring exceeded its capacity: {retained}");
    assert!(d.evicted > 0, "overflow must evict: {d:?}");
    assert_eq!(
        d.total,
        retained + d.evicted + d.dropped,
        "retention accounting must balance: {d:?}"
    );
    // the eviction counter is also a scrapeable metric
    let m = c.metrics(None).unwrap();
    assert_eq!(counter(&m.metrics, "audit_evicted_total"), d.evicted as f64);

    // explicit limits cap the slice; a foreign codelet filter matches
    // nothing but leaves the lifetime counters untouched
    let d3 = c.decisions(Some(3), None).unwrap();
    assert_eq!(d3.decisions.as_arr().unwrap().len(), 3);
    assert_eq!(d3.total, d.total);
    let none = c.decisions(Some(0), Some("no-such-codelet")).unwrap();
    assert!(none.decisions.as_arr().unwrap().is_empty());
    assert_eq!(none.total, d.total);

    c.quit().unwrap();
    server.shutdown().unwrap();
}
