//! Integration tests for the verified concurrency core (`compar
//! verify model`): the generative explorer at CI scale, the
//! injected-bug self-test, the differential mode against a real
//! runtime, and a live gated-eviction scenario that exercises the
//! audited snapshot under genuine concurrency.

use std::sync::{Arc, Mutex};

use compar::model::{self, explore, self_test, ExploreOptions, Fault, ModelConfig};
use compar::runtime::Tensor;
use compar::taskrt::{
    AccessMode, Arch, Codelet, Config, Runtime, SchedPolicy, SelectorKind, TaskSpec,
};

#[test]
fn explorer_is_clean_at_scale() {
    // a real slice of the CI smoke (the full 10k sequences run in
    // ci.sh via `compar verify model --smoke`)
    let opts = ExploreOptions {
        sequences: 2_000,
        ops_per_seq: 48,
        honor_env_seed: false,
        ..ExploreOptions::default()
    };
    let stats = explore(&opts).unwrap_or_else(|v| panic!("{v}"));
    assert_eq!(stats.sequences, 2_000);
    assert!(
        stats.ops_applied >= 2_000 * 48,
        "explorer stopped early: {} ops",
        stats.ops_applied
    );
}

#[test]
fn explorer_is_deterministic_end_to_end() {
    // same options, same seeds: the full run — including the violation
    // found under an injected fault, and its shrunk counterexample —
    // must be byte-identical across invocations
    let opts = ExploreOptions {
        sequences: 500,
        ops_per_seq: 32,
        fault: Some(Fault::DropEvictedTask),
        honor_env_seed: false,
        ..ExploreOptions::default()
    };
    let a = explore(&opts).expect_err("the injected fault must be caught");
    let b = explore(&opts).expect_err("the injected fault must be caught again");
    assert_eq!(a.seed, b.seed);
    assert_eq!(a.step, b.step);
    assert_eq!(a.message, b.message);
    assert_eq!(a.ops, b.ops);
    assert_eq!(a.shrunk, b.shrunk);
}

#[test]
fn self_test_proves_the_harness_catches_bugs() {
    let v = self_test(&ModelConfig::default()).unwrap_or_else(|msg| panic!("{msg}"));
    // the conservation bug needs a submit, an eviction that hits the
    // task's lane, and nothing else — the shrinker must get close to
    // that minimal shape
    assert!(!v.shrunk.is_empty());
    assert!(v.shrunk.len() < v.ops.len(), "shrinking removed nothing");
    // the printed report must carry the replay seed
    let report = v.to_string();
    assert!(
        report.contains("COMPAR_MODEL_SEED"),
        "no replay seed in:\n{report}"
    );
}

#[test]
fn differential_mode_agrees_with_the_real_runtime() {
    if compar::util::rng::env_seed().is_some() {
        // a replay seed narrows diff::run to one sequence; the count
        // assertions below only describe the full run
        return;
    }
    let stats = model::diff::run(&model::DiffOptions {
        sequences: 8,
        steps_per_seq: 10,
        ..model::DiffOptions::default()
    })
    .unwrap();
    assert_eq!(stats.sequences, 8);
    assert!(stats.steps >= 80, "diff ran only {} steps", stats.steps);
}

#[test]
fn gated_eviction_live_runtime_passes_audit() {
    // a real runtime under genuine concurrency: one worker of a small
    // context is blocked mid-task behind a mutex gate while a backlog
    // queues up; workers are then migrated out (forcing eviction and
    // re-placement of the queued tasks) while the audited snapshot —
    // the same validate_occupancy the model checks — runs throughout
    let rt = Runtime::new(
        Config {
            ncpu: 3,
            ncuda: 0,
            sched: SchedPolicy::Eager,
            ..Config::default()
        },
        None,
    )
    .unwrap();
    let ctx = rt
        .create_context_with("gated", &[0, 1], SchedPolicy::Eager, SelectorKind::Greedy)
        .unwrap();

    let gate = Arc::new(Mutex::new(()));
    let g2 = gate.clone();
    let blocker = rt.register_codelet(
        Codelet::new("blocker", "sort", vec![AccessMode::Read]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(move |_| {
                drop(g2.lock().unwrap());
                Ok(())
            }),
        ),
    );
    let quick = rt.register_codelet(
        Codelet::new("quick", "sort", vec![AccessMode::Read]).with_native(
            "omp",
            Arch::Cpu,
            Arc::new(|_| Ok(())),
        ),
    );

    let guard = gate.lock().unwrap();
    let h = rt.register_data(Tensor::vector(vec![0.0]));
    rt.submit(TaskSpec::new(blocker, vec![h], 1).in_context(ctx))
        .unwrap();
    // let a worker pick the blocker up, then build a backlog behind it
    std::thread::sleep(std::time::Duration::from_millis(30));
    for _ in 0..12 {
        let h = rt.register_data(Tensor::vector(vec![0.0]));
        rt.submit(TaskSpec::new(quick.clone(), vec![h], 1).in_context(ctx))
            .unwrap();
    }
    let audited = rt.audited_state().unwrap();
    assert_eq!(audited.contexts.len(), 2);

    // migrate under load: queued tasks must be evicted and re-placed,
    // the blocked worker's charge stays on the source context
    let moved = rt.move_workers(ctx, 0, 1).unwrap();
    assert_eq!(moved, 1, "one worker should migrate (the other may be gated)");
    rt.audited_state()
        .unwrap_or_else(|e| panic!("audit failed mid-migration: {e:#}"));

    drop(guard);
    rt.wait_all().unwrap();
    let audited = rt.audited_state().unwrap();
    let members: usize = audited.contexts.iter().map(|c| c.members.len()).sum();
    assert_eq!(members, audited.total_workers, "worker leaked or duplicated");
    for c in &audited.contexts {
        assert_eq!(c.queue_depth, 0, "context {} still has queued work", c.id);
    }
    assert_eq!(rt.drain_results().len(), 13, "a task was lost in migration");
    rt.shutdown().unwrap();
}
