//! Pre-compiler integration: full pipeline over the paper's Listing 1.3
//! and the bundled benchmark sources; CLI-equivalent flows; backward
//! compatibility of the transformed source.

use compar::bench_harness::{bundled_sources, table1f};
use compar::compar::{analyze, compile};

/// The paper's Listing 1.3, reconstructed in full.
const LISTING_1_3: &str = r#"
#pragma compar include

#pragma compar method_declare interface(sort) target(cuda) name(sort_cuda)
#pragma compar parameter name(arr) type(float*) size(N) access_mode(readwrite)
#pragma compar parameter name(N) type(int)
void sort_cuda(float* arr, int N) {}

#pragma compar method_declare interface(sort) target(openmp) name(sort_omp)
void sort_omp(float* arr, int N) {}

#pragma compar method_declare interface(mmul) target(cuda) name(mmul_cuda)
#pragma compar parameter name(A) type(float*) size(N, M) access_mode(read)
#pragma compar parameter name(B) type(float*) size(N, M) access_mode(read)
#pragma compar parameter name(N) type(int)
#pragma compar parameter name(M) type(int)
void mmul_cuda(float* A, float* B, int N, int M) {}

#pragma compar method_declare interface(mmul) target(openmp) name(mmul_omp)
void mmul_omp(float* A, float* B, int N, int M) {}

int main(int argc, char **argv) {
#pragma compar initialize
    sort(arr, N);
    mmul(A, B, N, M);
#pragma compar terminate
}
"#;

#[test]
fn listing_1_3_full_pipeline() {
    let out = compile(LISTING_1_3, "listing13.c").unwrap();
    // two interfaces -> two generated C units (paper: "separate code
    // files ... for each defined interface")
    assert_eq!(out.c_units.len(), 2);
    let names: Vec<&str> = out.c_units.iter().map(|(n, _)| n.as_str()).collect();
    assert!(names.contains(&"compar_sort.c"));
    assert!(names.contains(&"compar_mmul.c"));

    // sort glue: Listing 1.4 structure
    let sort_glue = &out.c_units.iter().find(|(n, _)| n == "compar_sort.c").unwrap().1;
    assert!(sort_glue.contains("extern void sort_cuda(float* arr, int N);"));
    assert!(sort_glue.contains(".cuda_funcs = { sort_cuda_wrapper }"));
    assert!(sort_glue.contains(".cpu_funcs = { sort_omp_wrapper }"));

    // mmul glue: matrix registration for A and B
    let mmul_glue = &out.c_units.iter().find(|(n, _)| n == "compar_mmul.c").unwrap().1;
    assert!(mmul_glue.contains("starpu_matrix_data_register(&A_handle"));
    assert!(mmul_glue.contains("starpu_matrix_data_register(&B_handle"));
    assert!(mmul_glue.contains(".modes = { STARPU_R, STARPU_R }"));

    // header declares both entry points
    assert!(out.header.contains("void sort(float* arr, int N);"));
    assert!(out.header.contains("void mmul(float* A, float* B, int N, int M);"));

    // transformed source: directives replaced, C code untouched
    assert!(out.transformed.contains("#include \"compar.h\""));
    assert!(out.transformed.contains("compar_init();"));
    assert!(out.transformed.contains("compar_terminate();"));
    assert!(out.transformed.contains("sort(arr, N);"));
    assert!(!out.transformed.contains("#pragma compar"));

    // rust glue registers both codelets
    assert!(out.rust_glue.contains("Codelet::new(\"sort\""));
    assert!(out.rust_glue.contains("Codelet::new(\"mmul\""));
}

#[test]
fn backward_compatibility_directives_are_pragmas() {
    // Paper §2.1: unprocessed COMPAR directives must not change the code.
    // Every directive line must be a #pragma (ignored by C compilers
    // that do not know the namespace).
    for line in LISTING_1_3.lines() {
        if line.contains("compar") && line.trim_start().starts_with('#') {
            assert!(line.trim_start().starts_with("#pragma compar"));
        }
    }
}

#[test]
fn all_bundled_sources_analyze_and_generate() {
    for (app, src, file) in bundled_sources() {
        let program = analyze(&src, &file).unwrap_or_else(|e| panic!("{app}: {e:#}"));
        assert!(
            !program.interfaces.is_empty(),
            "{app}: no interfaces found"
        );
        for iface in &program.interfaces {
            assert!(
                iface.variants.len() >= 2,
                "{app}/{}: fewer than 2 variants",
                iface.name
            );
            assert!(!iface.params.is_empty());
        }
    }
}

#[test]
fn table1f_ordering_holds() {
    // the paper's programmability claim: COMPAR directives << generated
    // (== hand-written StarPU) glue, for every app
    let rows = table1f::measure(&bundled_sources()).unwrap();
    assert_eq!(rows.len(), 6);
    for r in &rows {
        assert!(
            r.compar_directives * 3 < r.generated_glue,
            "{}: directives {} vs glue {}",
            r.app,
            r.compar_directives,
            r.generated_glue
        );
        // and our directive counts are in the same regime as the paper's
        // COMPAR numbers (single digits to low tens)
        assert!(r.compar_directives >= 5 && r.compar_directives <= 30, "{}", r.app);
    }
}

#[test]
fn diagnostics_carry_locations() {
    let bad = "#pragma compar method_declare interface(f) target(vulkan) name(f1)\n";
    let err = format!("{:#}", analyze(bad, "bad.c").unwrap_err());
    assert!(err.contains("unknown target 'vulkan'"));
    assert!(err.contains("bad.c:1:"), "missing location: {err}");
}

#[test]
fn cli_compile_writes_files() {
    let dir = std::env::temp_dir().join(format!("compar_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let src_path = dir.join("app.compar.c");
    std::fs::write(&src_path, LISTING_1_3).unwrap();
    let exe = env!("CARGO_BIN_EXE_compar");
    let out = std::process::Command::new(exe)
        .args([
            "compile",
            src_path.to_str().unwrap(),
            "--out-dir",
            dir.join("gen").to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(dir.join("gen/compar_sort.c").exists());
    assert!(dir.join("gen/compar_mmul.c").exists());
    assert!(dir.join("gen/compar.h").exists());
    assert!(dir.join("gen/compar_glue.rs").exists());
    let _ = std::fs::remove_dir_all(&dir);
}
