//! Property-based tests (hand-rolled quickcheck style — proptest is not
//! available offline): randomized inputs over the coordinator's
//! invariants — routing/eligibility, dependency ordering, coherence
//! state, perf-model math, JSON round-trips, shard retirement, and the
//! pre-compiler's passthrough guarantee.
//!
//! Every test runs through [`run_cases`]: each case gets its own
//! derived seed, a failing case prints `replay with
//! COMPAR_MODEL_SEED=<seed>`, and setting that variable re-runs
//! exactly the failing case.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use compar::cluster::PlacementKind;
use compar::model::ShardTableModel;
use compar::runtime::Tensor;
use compar::taskrt::{AccessMode, Arch, Codelet, Config, Runtime, SchedPolicy, TaskSpec};
use compar::util::json::{self, Json};
use compar::util::rng::{run_cases, Rng};

const CASES: usize = 64;

/// Random JSON value generator for round-trip fuzzing.
fn gen_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num((rng.next_f32() * 1e6).round() as f64 / 64.0),
        3 => {
            let len = rng.below(12);
            let s: String = (0..len)
                .map(|_| {
                    // printable ascii + some escapes + some unicode
                    match rng.below(10) {
                        0 => '"',
                        1 => '\\',
                        2 => '\n',
                        3 => 'π',
                        _ => (b'a' + rng.below(26) as u8) as char,
                    }
                })
                .collect();
            Json::Str(s)
        }
        4 => {
            let len = rng.below(5);
            Json::Arr((0..len).map(|_| gen_json(rng, depth - 1)).collect())
        }
        _ => {
            let len = rng.below(5);
            let mut m = std::collections::BTreeMap::new();
            for i in 0..len {
                m.insert(format!("k{i}"), gen_json(rng, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    run_cases(0x1a50, CASES * 4, |seed| {
        let mut rng = Rng::new(seed);
        let v = gen_json(&mut rng, 3);
        let s = json::to_string(&v);
        let back = json::parse(&s).unwrap_or_else(|e| panic!("{s}: {e}"));
        assert_eq!(v, back, "roundtrip failed for {s}");
    });
}

#[test]
fn prop_dependency_order_respected() {
    // Random interleavings of reads/writes on a handful of handles must
    // execute in an order consistent with sequential consistency:
    // writers see all prior accesses' effects. We verify with a counter
    // tensor: each write task increments, each read task records.
    run_cases(42, 8, |seed| {
        let mut rng = Rng::new(seed);
        let rt = Runtime::new(
            Config {
                ncpu: 3,
                ncuda: 0,
                sched: SchedPolicy::WorkStealing,
                ..Config::default()
            },
            None,
        )
        .unwrap();
        let observed = Arc::new(Mutex::new(Vec::<(usize, f32)>::new()));
        let obs2 = observed.clone();
        let write_cl = rt.register_codelet(
            Codelet::new("w", "sort", vec![AccessMode::ReadWrite]).with_native(
                "omp",
                Arch::Cpu,
                Arc::new(|b| {
                    b.write(0).data_mut()[0] += 1.0;
                    Ok(())
                }),
            ),
        );
        let seq = Arc::new(AtomicUsize::new(0));
        let seq2 = seq.clone();
        let read_cl = rt.register_codelet(
            Codelet::new("r", "sort", vec![AccessMode::Read]).with_native(
                "omp",
                Arch::Cpu,
                Arc::new(move |b| {
                    let v = b.read(0).data()[0];
                    let k = seq2.fetch_add(1, Ordering::SeqCst);
                    obs2.lock().unwrap().push((k, v));
                    Ok(())
                }),
            ),
        );
        let h = rt.register_data(Tensor::vector(vec![0.0]));
        let mut writes_before: Vec<f32> = Vec::new();
        let mut nwrites = 0.0f32;
        for _ in 0..30 {
            if rng.below(2) == 0 {
                rt.submit(TaskSpec::new(write_cl.clone(), vec![h], 1)).unwrap();
                nwrites += 1.0;
            } else {
                rt.submit(TaskSpec::new(read_cl.clone(), vec![h], 1)).unwrap();
                writes_before.push(nwrites);
            }
        }
        rt.wait_all().unwrap();
        // each read must observe exactly the number of writes submitted
        // before it (sequential consistency)
        let mut obs = observed.lock().unwrap().clone();
        obs.sort_by_key(|(k, _)| *k);
        // reads between the same writes may complete in any relative
        // order; collect observed values as a multiset
        let mut got: Vec<f32> = obs.iter().map(|(_, v)| *v).collect();
        let mut want = writes_before.clone();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, want);
        assert_eq!(rt.snapshot(h).unwrap().data()[0], nwrites);
    });
}

#[test]
fn prop_msi_coherence_never_loses_data() {
    // random acquire sequences across 3 nodes: after any prefix, at
    // least one node holds a valid copy, and a read on any node after a
    // write sees the written value (single-tensor model).
    run_cases(7, CASES, |seed| {
        let mut rng = Rng::new(seed);
        let reg = compar::taskrt::DataRegistry::new();
        let h = reg.register(Tensor::vector(vec![1.0]));
        for _ in 0..20 {
            let node = rng.below(3);
            let mode = match rng.below(3) {
                0 => AccessMode::Read,
                1 => AccessMode::Write,
                _ => AccessMode::ReadWrite,
            };
            reg.acquire(h, node, mode).unwrap();
            let valid = reg.valid_nodes(h).unwrap();
            assert!(!valid.is_empty(), "no valid copy left");
            if mode.writes() {
                assert_eq!(valid, vec![node], "write must invalidate others");
            } else {
                assert!(valid.contains(&node));
            }
            // transfer_bytes is 0 iff resident
            for n in 0..3 {
                let tb = reg.transfer_bytes(h, n).unwrap();
                assert_eq!(tb == 0, valid.contains(&n));
            }
        }
    });
}

#[test]
fn prop_perfmodel_regression_recovers_exponent() {
    // for random power laws t = a*n^b, the fitted exponent is close
    run_cases(99, CASES, |seed| {
        let mut rng = Rng::new(seed);
        let a = 10f64.powf(-9.0 + 3.0 * rng.next_f32() as f64);
        let b = 1.0 + 2.5 * rng.next_f32() as f64;
        let mut m = compar::taskrt::perfmodel::VariantModel::default();
        for n in [32usize, 64, 128, 256, 512] {
            for _ in 0..3 {
                m.record(n, a * (n as f64).powf(b));
            }
        }
        let (fa, fb) = m.regression().unwrap();
        assert!((fb - b).abs() < 0.02, "exponent {fb} vs {b}");
        assert!((fa - a).abs() / a < 0.1, "coeff {fa} vs {a}");
    });
}

#[test]
fn prop_scheduler_eligibility_is_safe() {
    // whatever the scheduler does, the executed variant must be
    // arch-compatible and honor force_variant
    for &sched in &[
        SchedPolicy::Eager,
        SchedPolicy::Random,
        SchedPolicy::WorkStealing,
        SchedPolicy::Dmda,
        SchedPolicy::Heft,
    ] {
        let rt = Runtime::new(
            Config {
                ncpu: 2,
                ncuda: 0,
                sched,
                ..Config::default()
            },
            None,
        )
        .unwrap();
        let cl = rt.register_codelet(
            Codelet::new("multi", "sort", vec![AccessMode::Read])
                .with_native("omp", Arch::Cpu, Arc::new(|_| Ok(())))
                .with_native("seq", Arch::Cpu, Arc::new(|_| Ok(()))),
        );
        run_cases(5, 20, |seed| {
            let mut rng = Rng::new(seed);
            let h = rt.register_data(Tensor::vector(vec![0.0]));
            let forced = match rng.below(3) {
                0 => Some("omp"),
                1 => Some("seq"),
                _ => None,
            };
            let mut spec = TaskSpec::new(cl.clone(), vec![h], 1);
            if let Some(f) = forced {
                spec = spec.with_variant(f);
            }
            rt.submit(spec).unwrap();
            rt.wait_all().unwrap();
            let r = rt.drain_results().pop().unwrap();
            if let Some(f) = forced {
                assert_eq!(r.variant, f, "{sched:?} ignored forced variant");
            }
            assert!(r.variant == "omp" || r.variant == "seq");
        });
    }
}

#[test]
fn prop_precompiler_passthrough_is_lossless() {
    // random C-ish sources with NO compar directives must transform to
    // themselves
    let fragments = [
        "int x = 42;",
        "/* comment with #pragma omp */",
        "#pragma omp parallel for",
        "void f() { g(); }",
        "  indented();",
        "#include <stdio.h>",
        "char *s = \"#pragma compar in a string\";",
        "",
    ];
    run_cases(12, CASES, |seed| {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(12);
        let src: String = (0..n)
            .map(|_| fragments[rng.below(fragments.len())])
            .collect::<Vec<_>>()
            .join("\n")
            + "\n";
        let out = compar::compar::codegen::c_glue::transform_source(&src);
        assert_eq!(out, src, "passthrough altered plain source");
    });
}

#[test]
fn prop_tensor_error_metrics_sane() {
    run_cases(31, CASES, |seed| {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(64);
        let data = rng.vec_f32(n, -10.0, 10.0);
        let t = Tensor::vector(data.clone());
        // self-distance is zero
        assert_eq!(t.max_abs_diff(&t), 0.0);
        assert!(t.rel_l2_error(&t) < 1e-9);
        // perturbation is detected
        let mut d2 = data;
        let k = rng.below(n);
        d2[k] += 1.0;
        let t2 = Tensor::vector(d2);
        assert!(t.max_abs_diff(&t2) >= 1.0);
    });
}

#[test]
fn prop_generated_directive_programs_always_compile() {
    // grammar-directed generator: every syntactically valid program the
    // generator emits must pass the full front-end + codegen
    let targets = ["cuda", "openmp", "seq", "opencl", "blas", "cublas"];
    let types = ["int", "float*", "double*", "char"];
    let modes = ["read", "write", "readwrite"];
    run_cases(2718, CASES, |seed| {
        let mut rng = Rng::new(seed);
        let mut src = String::from("#pragma compar include\n");
        let n_ifaces = 1 + rng.below(4);
        for f in 0..n_ifaces {
            let n_params = 1 + rng.below(4);
            let n_variants = 1 + rng.below(3);
            // variants must have distinct targets-names
            for v in 0..n_variants {
                let tgt = targets[(v + rng.below(2)) % targets.len()];
                src.push_str(&format!(
                    "#pragma compar method_declare interface(f{f}) target({tgt}) name(f{f}_v{v})\n"
                ));
                if v == 0 {
                    for p in 0..n_params {
                        let ty = types[rng.below(types.len())];
                        let dims = if ty.ends_with('*') {
                            let d = 1 + rng.below(4);
                            let names: Vec<String> =
                                (0..d).map(|k| format!("D{k}")).collect();
                            format!(" size({})", names.join(", "))
                        } else {
                            String::new()
                        };
                        let m = modes[rng.below(modes.len())];
                        src.push_str(&format!(
                            "#pragma compar parameter name(p{p}) type({ty}){dims} access_mode({m})\n"
                        ));
                    }
                }
            }
        }
        src.push_str("#pragma compar initialize\n#pragma compar terminate\n");
        let out = compar::compar::compile(&src, "gen.c")
            .unwrap_or_else(|e| panic!("seed {seed:#x}:\n{src}\n{e:#}"));
        assert_eq!(out.c_units.len(), n_ifaces);
    });
}

#[test]
fn prop_priority_order_on_single_worker() {
    // with one worker and a blocked queue, strictly higher-priority
    // tasks must run before lower ones
    run_cases(4, 2, |seed| {
        let rt = Runtime::new(
            Config {
                ncpu: 1,
                ncuda: 0,
                sched: SchedPolicy::Dmda,
                ..Config::default()
            },
            None,
        )
        .unwrap();
        let order = Arc::new(Mutex::new(Vec::new()));
        let o2 = order.clone();
        let gate = Arc::new(Mutex::new(()));
        let cl = rt.register_codelet(
            Codelet::new("ordered", "sort", vec![AccessMode::Read]).with_native(
                "omp",
                Arch::Cpu,
                Arc::new(move |b| {
                    o2.lock().unwrap().push(b.size);
                    Ok(())
                }),
            ),
        );
        // hold the worker with a sleeper so the queue builds up
        let guard = gate.lock().unwrap();
        let g2 = gate.clone();
        let sleeper = rt.register_codelet(
            Codelet::new("sleeper", "sort", vec![AccessMode::Read]).with_native(
                "omp",
                Arch::Cpu,
                Arc::new(move |_| {
                    drop(g2.lock().unwrap());
                    Ok(())
                }),
            ),
        );
        let h = rt.register_data(Tensor::vector(vec![0.0]));
        rt.submit(TaskSpec::new(sleeper, vec![h], 0)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(30));
        // enqueue in mixed priority order while the worker is blocked
        let mut rng = Rng::new(seed);
        let mut expect: Vec<(i32, usize)> = Vec::new();
        for i in 0..12 {
            let h = rt.register_data(Tensor::vector(vec![0.0]));
            let pri = rng.below(3) as i32;
            rt.submit(TaskSpec::new(cl.clone(), vec![h], 100 + i).with_priority(pri))
                .unwrap();
            expect.push((pri, 100 + i));
        }
        drop(guard); // release the worker
        rt.wait_all().unwrap();
        let got = order.lock().unwrap().clone();
        // expected: stable sort by descending priority
        let mut want = expect.clone();
        want.sort_by_key(|(p, _)| std::cmp::Reverse(*p));
        let want: Vec<usize> = want.into_iter().map(|(_, s)| s).collect();
        assert_eq!(got, want, "priority order violated");
    });
}

#[test]
fn prop_explicit_deps_compose_with_implicit() {
    run_cases(77, 6, |seed| {
        let mut rng = Rng::new(seed);
        let rt = Runtime::new(
            Config {
                ncpu: 2,
                ncuda: 0,
                sched: SchedPolicy::WorkStealing,
                ..Config::default()
            },
            None,
        )
        .unwrap();
        let log = Arc::new(Mutex::new(Vec::new()));
        let l2 = log.clone();
        let cl = rt.register_codelet(
            Codelet::new("dep", "sort", vec![AccessMode::Read]).with_native(
                "omp",
                Arch::Cpu,
                Arc::new(move |b| {
                    l2.lock().unwrap().push(b.size);
                    Ok(())
                }),
            ),
        );
        // chain of explicit deps over INDEPENDENT data
        let mut prev: Option<compar::taskrt::TaskId> = None;
        let n = 5 + rng.below(10);
        for i in 0..n {
            let h = rt.register_data(Tensor::vector(vec![0.0]));
            let mut spec = TaskSpec::new(cl.clone(), vec![h], i);
            if let Some(p) = prev {
                spec = spec.after(&[p]);
            }
            prev = Some(rt.submit(spec).unwrap());
        }
        rt.wait_all().unwrap();
        let got = log.lock().unwrap().clone();
        let want: Vec<usize> = (0..n).collect();
        assert_eq!(got, want, "explicit dependency chain violated");
    });
}

// --------------------------------------------------- shard retirement
// Driven through compar::model::ShardTableModel, which wraps the REAL
// router ShardState flags and the real placement::pick — these are
// properties of the production placement code, not of a re-model.

const ALL_PLACEMENTS: &[PlacementKind] = &[
    PlacementKind::RoundRobin,
    PlacementKind::LeastLoaded,
    PlacementKind::Calibrated,
];

#[test]
fn prop_shard_indices_stable_across_retirement() {
    // the table is append-only and retirement is terminal: under any
    // spawn/retire/place/complete interleaving, indices never shift, a
    // retired shard stays retired-and-unavailable forever, and the
    // pending map always resolves (ShardTableModel::check)
    run_cases(0x57ab1e, CASES, |seed| {
        let mut rng = Rng::new(seed);
        let mut sh = ShardTableModel::new();
        let mut ever_retired: Vec<usize> = Vec::new();
        for _ in 0..24 {
            match rng.below(5) {
                0 => {
                    sh.spawn();
                }
                1 => {
                    let i = rng.below(sh.len());
                    sh.retire(i).unwrap();
                    ever_retired.push(i);
                }
                2 => {
                    let _ = sh.place(ALL_PLACEMENTS[rng.below(3)], "matmul", 64);
                }
                3 => {
                    let _ = sh.complete(rng.below(sh.pending_len().max(1)));
                }
                _ => {
                    let i = rng.below(sh.len());
                    sh.set_load(i, rng.below(8) as u64, rng.below(8) as u64)
                        .unwrap();
                }
            }
            sh.check().unwrap_or_else(|e| panic!("{e}"));
            for &i in &ever_retired {
                assert!(sh.retired(i), "shard {i} un-retired itself");
                assert!(!sh.available(i), "retired shard {i} became available");
            }
        }
    });
}

#[test]
fn prop_retired_shards_never_placed() {
    // under every placement policy and any load pattern, a retired
    // shard is never chosen; with the whole table retired, placement
    // reports "no shard available" instead of resurrecting one
    run_cases(0x2e71, CASES, |seed| {
        let mut rng = Rng::new(seed);
        let mut sh = ShardTableModel::new();
        for _ in 0..(1 + rng.below(4)) {
            sh.spawn();
        }
        for i in 0..sh.len() {
            sh.set_load(i, rng.below(16) as u64, rng.below(16) as u64)
                .unwrap();
        }
        let mut live = sh.len();
        for _ in 0..rng.below(sh.len()) {
            let i = rng.below(sh.len());
            if !sh.retired(i) {
                live -= 1;
            }
            sh.retire(i).unwrap();
        }
        for &kind in ALL_PLACEMENTS {
            for _ in 0..6 {
                let placed = sh.place(kind, "matmul", 64);
                assert_eq!(
                    placed.is_ok(),
                    live > 0,
                    "{kind:?}: placement with {live} live shard(s) returned {placed:?}"
                );
            }
        }
        // the corrupt latch inside place() fires if any pick landed on
        // an unavailable shard — check() surfaces it
        sh.check().unwrap_or_else(|e| panic!("{e}"));
        while live > 0 {
            let i = (0..sh.len()).find(|&i| !sh.retired(i)).unwrap();
            sh.retire(i).unwrap();
            live -= 1;
        }
        for &kind in ALL_PLACEMENTS {
            assert!(
                sh.place(kind, "matmul", 64).is_err(),
                "{kind:?} placed on a fully retired table"
            );
        }
        sh.check().unwrap_or_else(|e| panic!("{e}"));
    });
}

#[test]
fn prop_pending_map_survives_retirement() {
    // requests routed before a retirement stay resolvable: retiring
    // shards (even the ones the requests sit on) never invalidates or
    // reorders the pending map, and every request completes exactly once
    run_cases(0x9e4d, CASES, |seed| {
        let mut rng = Rng::new(seed);
        let mut sh = ShardTableModel::new();
        for _ in 0..(1 + rng.below(3)) {
            sh.spawn();
        }
        let k = 1 + rng.below(8);
        let mut reqs = Vec::new();
        for _ in 0..k {
            reqs.push(sh.place(ALL_PLACEMENTS[rng.below(3)], "matmul", 64).unwrap());
        }
        for _ in 0..rng.below(sh.len() + 1) {
            sh.retire(rng.below(sh.len())).unwrap();
        }
        sh.check().unwrap_or_else(|e| panic!("{e}"));
        let mut done = Vec::new();
        while sh.pending_len() > 0 {
            let pick = rng.below(sh.pending_len());
            done.push(sh.complete(pick).unwrap_or_else(|e| panic!("{e}")));
            sh.check().unwrap_or_else(|e| panic!("{e}"));
        }
        done.sort_unstable();
        reqs.sort_unstable();
        assert_eq!(done, reqs, "requests lost or duplicated across retirement");
    });
}
