//! Thread-owned XLA execution service.
//!
//! The xla crate's PJRT handles are raw pointers (not Send/Sync), so a
//! single dedicated thread owns the `XlaEngine`; any worker can submit
//! execution requests through a cloneable `XlaHandle`. This mirrors
//! StarPU's device-worker design: one pinned thread per accelerator owns
//! the device context, everyone else talks to it via queues.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::manifest::ArtifactMeta;
use super::tensor::Tensor;

enum Request {
    /// Compile an artifact ahead of time (warm the executable cache).
    Load {
        name: String,
        path: std::path::PathBuf,
        reply: Sender<Result<()>>,
    },
    /// Execute a loaded (or loadable) artifact.
    Run {
        meta: ArtifactMeta,
        inputs: Vec<Tensor>,
        reply: Sender<Result<(Vec<Tensor>, Duration)>>,
    },
    Shutdown,
}

/// Cloneable handle for submitting work to the engine thread.
#[derive(Clone)]
pub struct XlaHandle {
    tx: Sender<Request>,
}

// Sender<T> is Send but not Sync; XlaHandle is cloned per worker instead.

impl XlaHandle {
    /// Pre-compile an artifact (off the measured path).
    pub fn load(&self, meta: &ArtifactMeta) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Load {
                name: meta.name.clone(),
                path: meta.path.clone(),
                reply,
            })
            .map_err(|_| anyhow!("xla service is down"))?;
        rx.recv().map_err(|_| anyhow!("xla service dropped reply"))?
    }

    /// Execute `meta` with `inputs`; returns outputs plus the pure
    /// execution time measured inside the service thread (excludes queue
    /// wait, so perf models see device time, not congestion).
    pub fn run(&self, meta: &ArtifactMeta, inputs: Vec<Tensor>) -> Result<(Vec<Tensor>, Duration)> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Run {
                meta: meta.clone(),
                inputs,
                reply,
            })
            .map_err(|_| anyhow!("xla service is down"))?;
        rx.recv().map_err(|_| anyhow!("xla service dropped reply"))?
    }
}

/// The service: spawn once, hand out handles, `shutdown()` at exit.
pub struct XlaService {
    tx: Sender<Request>,
    join: Option<JoinHandle<()>>,
}

impl XlaService {
    /// Spawn the engine thread. Fails fast if PJRT cannot initialize.
    pub fn spawn() -> Result<XlaService> {
        // silence the TfrtCpuClient created/destroyed info logs
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("xla-engine".into())
            .spawn(move || Self::serve(rx, ready_tx))
            .expect("spawning xla-engine thread");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("xla engine thread died during init"))??;
        Ok(XlaService {
            tx,
            join: Some(join),
        })
    }

    fn serve(rx: Receiver<Request>, ready: Sender<Result<()>>) {
        let mut engine = match super::engine::XlaEngine::new() {
            Ok(e) => {
                let _ = ready.send(Ok(()));
                e
            }
            Err(e) => {
                let _ = ready.send(Err(e));
                return;
            }
        };
        while let Ok(req) = rx.recv() {
            match req {
                Request::Load { name, path, reply } => {
                    let _ = reply.send(engine.load(&name, &path));
                }
                Request::Run {
                    meta,
                    inputs,
                    reply,
                } => {
                    let r = (|| {
                        engine.load(&meta.name, &meta.path)?;
                        let t0 = Instant::now();
                        let out = engine.execute(&meta.name, &inputs)?;
                        Ok((out, t0.elapsed()))
                    })();
                    let _ = reply.send(r);
                }
                Request::Shutdown => break,
            }
        }
    }

    pub fn handle(&self) -> XlaHandle {
        XlaHandle {
            tx: self.tx.clone(),
        }
    }
}

impl Drop for XlaService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
