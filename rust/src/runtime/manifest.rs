//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. aot.py writes `artifacts/manifest.json`; this module
//! parses it into typed records the `ArtifactRegistry` serves.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

/// One AOT-compiled HLO module: (app, variant, size) -> file.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub app: String,
    pub variant: String,
    pub size: usize,
    /// Path to the .hlo.txt, absolute (joined with the manifest dir).
    pub path: PathBuf,
    /// Input specs in call order.
    pub inputs: Vec<Vec<usize>>,
    /// Free-form lowering parameters (steps, tiles, penalty, ...).
    pub params: BTreeMap<String, f64>,
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub fingerprint: String,
    pub hotspot_steps: usize,
    pub hotspot3d_steps: usize,
    pub hotspot3d_layers: usize,
    pub nw_penalty: f32,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&v, dir)
    }

    pub fn from_json(v: &Json, dir: &Path) -> Result<Manifest> {
        let req_num = |key: &str| -> Result<f64> {
            v.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("manifest missing numeric '{key}'"))
        };
        let mut artifacts = Vec::new();
        for a in v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?
        {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing 'name'"))?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact {name} missing 'file'"))?;
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact {name} missing 'inputs'"))?
                .iter()
                .map(|spec| {
                    spec.get("shape")
                        .and_then(Json::as_arr)
                        .map(|dims| dims.iter().filter_map(Json::as_usize).collect())
                        .ok_or_else(|| anyhow!("artifact {name}: bad input spec"))
                })
                .collect::<Result<Vec<Vec<usize>>>>()?;
            let mut params = BTreeMap::new();
            if let Some(p) = a.get("params").and_then(Json::as_obj) {
                for (k, val) in p {
                    if let Some(n) = val.as_f64() {
                        params.insert(k.clone(), n);
                    }
                }
            }
            artifacts.push(ArtifactMeta {
                app: a
                    .get("app")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact {name} missing 'app'"))?
                    .to_string(),
                variant: a
                    .get("variant")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact {name} missing 'variant'"))?
                    .to_string(),
                size: a
                    .get("size")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("artifact {name} missing 'size'"))?,
                path: dir.join(file),
                inputs,
                name,
                params,
            });
        }
        Ok(Manifest {
            fingerprint: v
                .get("fingerprint")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            hotspot_steps: req_num("hotspot_steps")? as usize,
            hotspot3d_steps: req_num("hotspot3d_steps")? as usize,
            hotspot3d_layers: req_num("hotspot3d_layers")? as usize,
            nw_penalty: req_num("nw_penalty")? as f32,
            artifacts,
        })
    }

    /// Artifacts for one app, sorted by size.
    pub fn for_app(&self, app: &str) -> Vec<&ArtifactMeta> {
        let mut v: Vec<_> = self.artifacts.iter().filter(|a| a.app == app).collect();
        v.sort_by_key(|a| (a.size, a.variant.clone()));
        v
    }

    /// Exact lookup.
    pub fn find(&self, app: &str, variant: &str, size: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.app == app && a.variant == variant && a.size == size)
    }

    /// Sizes available for (app, variant), ascending.
    pub fn sizes(&self, app: &str, variant: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.app == app && a.variant == variant)
            .map(|a| a.size)
            .collect();
        v.sort_unstable();
        v
    }
}

/// Default artifacts directory: $COMPAR_ARTIFACTS or ./artifacts.
pub fn default_dir() -> PathBuf {
    std::env::var_os("COMPAR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "fingerprint": "abc",
      "hotspot_steps": 8, "hotspot3d_steps": 8, "hotspot3d_layers": 8,
      "nw_penalty": 10.0,
      "artifacts": [
        {"name": "matmul_jnp_64", "app": "matmul", "variant": "jnp",
         "size": 64, "file": "matmul_jnp_64.hlo.txt",
         "inputs": [{"shape": [64, 64], "dtype": "f32"},
                    {"shape": [64, 64], "dtype": "f32"}],
         "params": {}},
        {"name": "matmul_pallas_64", "app": "matmul", "variant": "pallas",
         "size": 64, "file": "matmul_pallas_64.hlo.txt",
         "inputs": [{"shape": [64, 64], "dtype": "f32"},
                    {"shape": [64, 64], "dtype": "f32"}],
         "params": {"bm": 64}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let v = json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.nw_penalty, 10.0);
        let a = m.find("matmul", "pallas", 64).unwrap();
        assert_eq!(a.path, Path::new("/tmp/a/matmul_pallas_64.hlo.txt"));
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.params["bm"], 64.0);
        assert_eq!(m.sizes("matmul", "jnp"), vec![64]);
    }

    #[test]
    fn missing_fields_error() {
        let v = json::parse(r#"{"artifacts": [{"name": "x"}]}"#).unwrap();
        assert!(Manifest::from_json(&v, Path::new(".")).is_err());
    }
}
