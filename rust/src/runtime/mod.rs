//! Runtime bridge: load and execute the AOT-compiled HLO artifacts via
//! the `xla` crate's PJRT CPU client.
//!
//! Layering (DESIGN.md §2): python lowers each (app, variant, size) graph
//! to `artifacts/*.hlo.txt` once at build time; this module is the only
//! code that touches PJRT. Python never runs on the request path.

pub mod engine;
pub mod manifest;
pub mod service;
pub mod tensor;

pub use engine::XlaEngine;
pub use manifest::{ArtifactMeta, Manifest};
pub use service::{XlaHandle, XlaService};
pub use tensor::Tensor;
