//! Dense f32 tensors exchanged between the coordinator and the PJRT
//! executables. All benchmark interfaces are f32 (matching the paper's
//! applications), so a single concrete tensor type keeps the hot path
//! monomorphic and allocation-friendly.

use std::fmt;

/// A dense row-major f32 tensor of rank 1..=4.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(
            n,
            data.len(),
            "shape {:?} wants {} elements, got {}",
            shape,
            n,
            data.len()
        );
        assert!(
            (1..=4).contains(&shape.len()),
            "rank must be 1..=4 (paper size clause arity)"
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::new(shape, vec![0.0; n])
    }

    pub fn vector(data: Vec<f32>) -> Tensor {
        Tensor::new(vec![data.len()], data)
    }

    pub fn matrix(rows: usize, cols: usize, data: Vec<f32>) -> Tensor {
        Tensor::new(vec![rows, cols], data)
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size in bytes (used by the transfer model and footprint hashing).
    pub fn byte_size(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// 2D element access (row-major); debug-asserted bounds.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Max |a - b| over both tensors; shapes must match.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative L2 error ||a-b|| / (||b|| + eps).
    pub fn rel_l2_error(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        (num.sqrt() / (den.sqrt() + 1e-30)) as f32
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let t = Tensor::matrix(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.byte_size(), 24);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn diff_metrics() {
        let a = Tensor::vector(vec![1.0, 2.0]);
        let b = Tensor::vector(vec![1.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
        assert!(a.rel_l2_error(&a) < 1e-9);
    }
}
