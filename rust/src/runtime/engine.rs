//! XLA/PJRT execution engine: loads `artifacts/*.hlo.txt`, compiles them
//! on the PJRT CPU client, and executes them with `Tensor` inputs.
//!
//! HLO **text** is the interchange format (see python/compile/aot.py and
//! /opt/xla-example/README.md): jax >= 0.5 emits HloModuleProtos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids, so text round-trips cleanly.
//!
//! The engine is deliberately **not** Send/Sync (the xla crate's PJRT
//! handles are raw pointers); `service.rs` wraps it in a dedicated owner
//! thread, which is also how StarPU drives a CUDA device (one worker
//! thread owns the device context).
//!
//! The `xla` crate is an optional dependency (cargo feature `xla`): the
//! offline build compiles a stub engine with the same API whose
//! constructor fails, so the runtime degrades to native-only variants
//! (`taskrt::Runtime::new` handles that degradation).

#[cfg(not(feature = "xla"))]
pub use stub::XlaEngine;

#[cfg(not(feature = "xla"))]
mod stub {
    use std::path::Path;

    use anyhow::{anyhow, Result};

    use super::super::manifest::ArtifactMeta;
    use super::super::tensor::Tensor;

    /// API-compatible stand-in compiled when the `xla` feature is off.
    /// Construction fails, so no other method is ever reachable.
    pub struct XlaEngine {
        _private: (),
    }

    impl XlaEngine {
        pub fn new() -> Result<XlaEngine> {
            Err(anyhow!(
                "built without the `xla` cargo feature; artifact variants \
                 are unavailable (rebuild with `--features xla`)"
            ))
        }

        pub fn platform_name(&self) -> String {
            "stub".to_string()
        }

        pub fn cached(&self) -> usize {
            0
        }

        pub fn load(&mut self, _name: &str, _path: &Path) -> Result<()> {
            Err(anyhow!("xla feature disabled"))
        }

        pub fn execute(&self, _name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            Err(anyhow!("xla feature disabled"))
        }

        pub fn run(&mut self, _meta: &ArtifactMeta, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            Err(anyhow!("xla feature disabled"))
        }
    }
}

#[cfg(feature = "xla")]
pub use real::XlaEngine;

#[cfg(feature = "xla")]
mod real {

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::super::manifest::ArtifactMeta;
use super::super::tensor::Tensor;

/// Owns the PJRT client plus a compiled-executable cache keyed by
/// artifact name. One compiled executable per model variant, reused for
/// every execution (compilation happens once, off the hot path).
pub struct XlaEngine {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl XlaEngine {
    pub fn new() -> Result<XlaEngine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(XlaEngine {
            client,
            cache: HashMap::new(),
        })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }

    /// Compile (or fetch from cache) the artifact's executable.
    pub fn load(&mut self, name: &str, path: &Path) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("loading HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a loaded artifact. Inputs must match the manifest specs;
    /// outputs are the flattened tuple elements (our modules lower with
    /// return_tuple=True, so the single PJRT output is a tuple literal).
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let exe = self
            .cache
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(t.data())
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshaping input for {name}: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let first = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("{name}: empty result"))?;
        let lit = first
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: fetching result: {e:?}"))?;
        let mut parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("{name}: untupling result: {e:?}"))?;
        parts
            .drain(..)
            .map(|p| {
                let shape = p
                    .array_shape()
                    .map_err(|e| anyhow!("{name}: result shape: {e:?}"))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = p
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("{name}: result data: {e:?}"))?;
                Ok(Tensor::new(dims, data))
            })
            .collect()
    }

    /// Load-and-execute helper for ArtifactMeta records.
    pub fn run(&mut self, meta: &ArtifactMeta, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        // validate against the manifest input specs before touching PJRT
        if inputs.len() != meta.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                meta.name,
                meta.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (t, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if t.shape() != spec.as_slice() {
                return Err(anyhow!(
                    "{}: input {} shape {:?} != manifest {:?}",
                    meta.name,
                    i,
                    t.shape(),
                    spec
                ));
            }
        }
        self.load(&meta.name, &meta.path)
            .with_context(|| format!("loading {}", meta.name))?;
        self.execute(&meta.name, inputs)
    }
}

}
