//! `compar` — the leader binary: pre-compiler driver, benchmark runner
//! and evaluation-harness entry point.
//!
//! ```text
//! compar compile <file.compar.c> [--out-dir DIR]      run the pre-compiler
//! compar run --app A --size N [options]               run one benchmark task
//! compar bench <fig1a|fig1b|fig1c|fig1d|fig1e|table1f|selection|cluster|autoscale|stream|dag|all>
//! compar bench validate <FILE> [--prev FILE]          check a bench JSON record
//! compar calibrate --app A [--sizes a,b,c]            warm the perf models
//! compar serve [--addr A --contexts cpu:4,gpu:1 ...]  multi-tenant component service
//! compar serve --autoscale [--scale-min/-max --slo-ms --cooldown-ms]  elastic contexts
//! compar route --shards H:P,... [--listen A]          cluster router + perf gossip
//! compar route --autoscale [--min/max-shards ...]     elastic shard set
//! compar loadgen [--clients N --requests M --app A]   drive a server, report latency
//! compar loadgen --shards N ...                       drive an in-process cluster
//! compar loadgen --profile burst:H:L:P                time-varying offered load
//! compar loadgen --profile stream:R:KB:S              v6 stream sessions (credit-gated)
//! compar loadgen --metrics-out FILE                   v9 post-run metrics snapshot
//! compar verify model [--smoke|--seqs N --ops K ...]  generative model checking
//! compar list                                         inventory: apps, variants, artifacts
//! ```
//!
//! Argument parsing is hand-rolled: the offline build environment ships
//! no clap; see DESIGN.md §5.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use compar::apps;
use compar::bench_harness::{self, fig1, selection, table1f};
use compar::compar as precompiler;
use compar::runtime::Manifest;
use compar::taskrt::{Config, Runtime, SchedPolicy, SelectorKind, VALID_SELECTORS};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Split args into positional and --key value (or --flag) options.
fn parse_opts(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut opts = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                opts.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                opts.insert(key.to_string(), "1".to_string());
                i += 1;
            }
        } else {
            pos.push(a.clone());
            i += 1;
        }
    }
    (pos, opts)
}

fn load_manifest() -> Result<Arc<Manifest>> {
    let dir = compar::runtime::manifest::default_dir();
    Manifest::load(&dir).map(Arc::new)
}

fn config_from_opts(opts: &HashMap<String, String>) -> Result<Config> {
    let mut cfg = Config::from_env();
    if let Some(v) = opts.get("ncpu") {
        cfg.ncpu = v.parse().context("--ncpu")?;
    }
    if let Some(v) = opts.get("ncuda") {
        cfg.ncuda = v.parse().context("--ncuda")?;
    }
    if let Some(v) = opts.get("sched") {
        cfg.sched = SchedPolicy::parse(v).ok_or_else(|| anyhow!("unknown scheduler '{v}'"))?;
    }
    if let Some(v) = opts.get("selector") {
        cfg.selector = SelectorKind::parse(v)
            .ok_or_else(|| anyhow!("unknown selection policy '{v}' (want {VALID_SELECTORS})"))?;
    }
    if opts.contains_key("calibrate") {
        cfg.calibrate = true;
    }
    if let Some(v) = opts.get("seed") {
        cfg.seed = v.parse().context("--seed")?;
    }
    Ok(cfg)
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "compile" => cmd_compile(rest),
        "run" => cmd_run(rest),
        "bench" => cmd_bench(rest),
        "calibrate" => cmd_calibrate(rest),
        "serve" => cmd_serve(rest),
        "route" => cmd_route(rest),
        "loadgen" => cmd_loadgen(rest),
        "verify" => cmd_verify(rest),
        "list" => cmd_list(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `compar help`)"),
    }
}

fn print_usage() {
    println!(
        "compar — component-based parallel programming with dynamic variant selection\n\
         \n\
         USAGE:\n\
         \x20 compar compile <file.compar.c> [--out-dir DIR] [--emit c|rust|all]\n\
         \x20 compar run --app APP --size N [--variant V] [--sched S] [--selector P] [--ncpu N] [--ncuda N] [--reps R]\n\
         \x20 compar bench <fig1a|fig1b|fig1c|fig1d|fig1e|table1f|selection|cluster|autoscale|stream|dag|all> [--reps R] [--max-measured N] [--smoke]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 (selection: [--out FILE]; cluster: [--shards N] [--placement PL];\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 dag: [--transport epoll|threads] [--framing ndjson|binary] [--out FILE])\n\
         \x20 compar bench validate <FILE> [--prev FILE]\n\
         \x20 compar calibrate --app APP [--sizes a,b,c]\n\
         \x20 compar serve [--addr HOST:PORT] [--contexts NAME:N[:POLICY],...] [--sched S] [--selector P] [--cap N]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--batch-window-us U] [--max-batch B] [--ncpu N] [--ncuda N] [--transport epoll|threads]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--audit-cap N] [--trace-cap N]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--autoscale [--scale-min N|name=N,..] [--scale-max N|name=N,..] [--slo-ms F]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--cooldown-ms T] [--scale-period-ms T] [--scale-high F] [--scale-low F]]\n\
         \x20 compar route --shards HOST:PORT,... [--listen HOST:PORT] [--placement PL]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--health-ms T] [--gossip-ms T] [--no-gossip]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--autoscale [--min-shards N] [--max-shards N] [--scale-up L] [--scale-down L]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--cooldown-ms T] [--spawn-ncpu N] [--spawn-args \"SERVE FLAGS\"]]\n\
         \x20 compar verify model [--smoke] [--seqs N] [--ops K] [--seed S] [--diff N] [--proofs]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--self-test] [--fault leak-worker|drop-task] [--ncpu N] [--ncuda N]\n\
         \x20 compar loadgen [--clients N] [--requests M] [--app APP] [--size N] [--tasks K]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--pipeline N] [--policy P] [--ctxs a,b] [--addr HOST:PORT | --contexts SPEC]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--shards N [--placement PL] [--no-gossip]] [--out FILE] [--no-verify]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--profile burst:<high_rps>:<low_rps>:<period_ms>]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--profile stream:<rate>:<chunk_kb>:<stages> [--slo-ms F] [--window W] [--slide S]]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--framing ndjson|binary] [--connections N] [--transport epoll|threads]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--metrics-out FILE]\n\
         \x20 compar list\n\
         \n\
         Selection policies P: greedy | calibrating | epsilon[:E] | epsilon-decayed[:E] | contextual | planned | forced:VARIANT\n\
         Shard placement PL:   round-robin | least-loaded | calibrated\n\
         Environment: COMPAR_NCPU, COMPAR_NCUDA, COMPAR_SCHED, COMPAR_SELECTOR, COMPAR_CALIBRATE,\n\
         \x20 COMPAR_TIME_MODE=modeled|wall, COMPAR_PERFMODEL_DIR, COMPAR_ARTIFACTS,\n\
         \x20 COMPAR_MODEL_SEED (replay one verify/property seed)\n\
         (STARPU_NCPU / STARPU_NCUDA / STARPU_SCHED / STARPU_CALIBRATE are accepted aliases.)"
    );
}

// ---------------------------------------------------------------- compile

fn cmd_compile(args: &[String]) -> Result<()> {
    let (pos, opts) = parse_opts(args);
    let file = pos
        .first()
        .ok_or_else(|| anyhow!("usage: compar compile <file.compar.c>"))?;
    let source = std::fs::read_to_string(file).with_context(|| format!("reading {file}"))?;
    let mut out = precompiler::compile(&source, file)?;
    // --prune: compile-time variant pruning (paper §5 future work)
    if opts.contains_key("prune") {
        let margin: f64 = opts
            .get("prune")
            .and_then(|v| v.parse().ok())
            .filter(|m: &f64| *m > 1.0)
            .unwrap_or(1.25);
        let reports = precompiler::opt::prune_variants(&mut out.program, margin);
        for r in &reports {
            for (func, why) in &r.removed {
                println!("  pruned {}::{func}: {why}", r.interface);
            }
        }
        // regenerate glue from the pruned program
        out.c_units = precompiler::codegen::c_glue::generate_units(&out.program);
        out.header = precompiler::codegen::header::generate(&out.program);
        out.rust_glue = precompiler::codegen::rust_glue::generate(&out.program);
    }
    let emit = opts.get("emit").map(String::as_str).unwrap_or("all");
    let out_dir = std::path::PathBuf::from(
        opts.get("out-dir").cloned().unwrap_or_else(|| "compar_gen".into()),
    );
    std::fs::create_dir_all(&out_dir)?;

    let mut written = Vec::new();
    if emit == "c" || emit == "all" {
        for (name, contents) in &out.c_units {
            let p = out_dir.join(name);
            std::fs::write(&p, contents)?;
            written.push(p);
        }
        let p = out_dir.join("compar.h");
        std::fs::write(&p, &out.header)?;
        written.push(p);
        let stem = std::path::Path::new(file)
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("app");
        let p = out_dir.join(format!("{stem}.transformed.c"));
        std::fs::write(&p, &out.transformed)?;
        written.push(p);
    }
    if emit == "rust" || emit == "all" {
        let p = out_dir.join("compar_glue.rs");
        std::fs::write(&p, &out.rust_glue)?;
        written.push(p);
    }
    println!(
        "compiled {} interface(s), {} variant(s):",
        out.program.interfaces.len(),
        out.program
            .interfaces
            .iter()
            .map(|i| i.variants.len())
            .sum::<usize>()
    );
    for i in &out.program.interfaces {
        let vs: Vec<&str> = i.variants.iter().map(|v| v.target.as_str()).collect();
        println!("  {}({} params) <- [{}]", i.name, i.params.len(), vs.join(", "));
    }
    for p in written {
        println!("  wrote {}", p.display());
    }
    Ok(())
}

// -------------------------------------------------------------------- run

fn cmd_run(args: &[String]) -> Result<()> {
    let (_, opts) = parse_opts(args);
    let app = opts
        .get("app")
        .ok_or_else(|| anyhow!("--app is required (one of {:?})", apps::ALL))?;
    let size: usize = opts
        .get("size")
        .ok_or_else(|| anyhow!("--size is required"))?
        .parse()
        .context("--size")?;
    let reps: usize = opts.get("reps").map(|v| v.parse()).transpose()?.unwrap_or(1);
    let variant = opts.get("variant").map(String::as_str);
    let verify = !opts.contains_key("no-verify");

    let cfg = config_from_opts(&opts)?;
    let manifest = load_manifest().ok();
    let rt = Runtime::new(cfg, manifest)?;
    println!(
        "runtime: ncpu={} ncuda={} sched={}",
        rt.config().ncpu,
        rt.config().ncuda,
        rt.config().sched.name()
    );
    for rep in 0..reps {
        let run = apps::run_once(&rt, app, size, 42 + rep as u64, variant, verify)?;
        println!(
            "rep {rep}: variant={} modeled={} wall={} rel_err={:.2e}",
            run.variant,
            compar::util::stats::fmt_time(run.modeled),
            compar::util::stats::fmt_time(run.wall),
            run.rel_err
        );
    }
    let hist = rt.metrics().variant_histogram();
    println!("selection histogram: {hist:?}");
    Ok(())
}

// ------------------------------------------------------------------ bench

fn cmd_bench(args: &[String]) -> Result<()> {
    let (pos, opts) = parse_opts(args);
    let which = pos.first().map(String::as_str).unwrap_or("all");
    if which == "validate" {
        let file = pos
            .get(1)
            .ok_or_else(|| anyhow!("usage: compar bench validate <FILE> [--prev FILE]"))?;
        return validate_bench_record(file, opts.get("prev").map(String::as_str));
    }
    let reps: usize = opts.get("reps").map(|v| v.parse()).transpose()?.unwrap_or(3);
    let max_measured: usize = opts
        .get("max-measured")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(256);
    let manifest = load_manifest().ok();

    let figs: &[(&str, &str)] = &[
        ("fig1a", "hotspot"),
        ("fig1b", "hotspot3d"),
        ("fig1c", "lud"),
        ("fig1d", "nw"),
        ("fig1e", "matmul"),
    ];

    let mut ran = false;
    for (fig, app) in figs {
        if which == *fig || which == "all" {
            let pts = fig1::series(app, manifest.as_ref(), reps, max_measured)?;
            println!("{}", fig1::render(app, &pts));
            if *fig == "fig1e" {
                println!("{}", fig1::matmul_variant_table());
            }
            ran = true;
        }
    }
    if which == "table1f" || which == "all" {
        let rows = table1f::measure(&bench_harness::bundled_sources())?;
        println!("{}", table1f::render(&rows));
        ran = true;
    }
    if which == "selection" || which == "all" {
        let smoke = opts.contains_key("smoke");
        // without artifacts the bench degrades to the native variant
        // pool (regret stays comparable: the oracle is restricted too)
        if manifest.is_none() {
            println!("(selection bench: no artifacts; native variant pool only)");
        }
        let tasks = if smoke { 8 } else { 30 };
        let pairs: Vec<(&str, usize)> = if smoke {
            vec![("matmul", 48), ("sort", 4096), ("hotspot", 64)]
        } else if manifest.is_some() {
            vec![
                ("hotspot", 128),
                ("hotspot3d", 64),
                ("lud", 256),
                ("nw", 256),
                ("matmul", 64),
                ("matmul", 256),
                ("sort", 16384),
            ]
        } else {
            vec![
                ("hotspot", 64),
                ("hotspot3d", 32),
                ("lud", 64),
                ("nw", 64),
                ("matmul", 48),
                ("matmul", 128),
                ("sort", 4096),
            ]
        };
        let traces = selection::compare_policies(&pairs, tasks, manifest.as_ref())?;
        println!("{}", selection::render(&traces));
        println!("{}", selection::render_comparison(&traces));
        // contended scenario: phase-alternating device pressure that a
        // global (codelet, size) model cannot represent — the measure
        // behind the context-aware selection work
        let contended = selection::contended_compare(if smoke { 40 } else { 200 });
        println!("{}", selection::render_contended(&contended));
        if smoke {
            // a missing policy name must fail the gate, not skip it
            let regret = |name: &str| -> Result<f64> {
                contended
                    .iter()
                    .find(|o| o.policy == name)
                    .map(|o| o.regret)
                    .ok_or_else(|| anyhow!("contended scenario ran no '{name}' policy"))
            };
            let (ctx_regret, greedy_regret) = (regret("contextual")?, regret("greedy")?);
            if ctx_regret > greedy_regret {
                bail!(
                    "contended scenario: contextual regret {ctx_regret:.6} \
                     exceeds greedy {greedy_regret:.6}"
                );
            }
        }
        if let Some(out) = opts.get("out") {
            bench_harness::serve_bench::write_atomic(out, &(selection::to_json(&traces) + "\n"))?;
            println!("wrote {out}");
        }
        ran = true;
    }
    // autoscale is explicit-only (it boots servers and a cluster per run)
    if which == "autoscale" {
        let smoke = opts.contains_key("smoke");
        let off = bench_harness::autoscale_bench::context_run(false, smoke)?;
        let on = bench_harness::autoscale_bench::context_run(true, smoke)?;
        let shards = bench_harness::autoscale_bench::shard_run(smoke)?;
        print!("{}", bench_harness::autoscale_bench::render(&off, &on, &shards));
        if smoke {
            // CI gates: the burst must trigger a scale-up (observed via
            // autoscale_status), the drain must give the workers back,
            // the router must spawn AND retire a shard, and no client
            // request may fail at any point
            if on.moves == 0 || on.moved_workers == 0 {
                bail!("autoscale smoke: the burst never triggered a worker migration");
            }
            if on.hot_workers_after != on.hot_home {
                bail!(
                    "autoscale smoke: 'hot' kept {} worker(s) after the drain (home {})",
                    on.hot_workers_after,
                    on.hot_home
                );
            }
            if off.errors + on.errors > 0 {
                bail!("autoscale smoke: {} request(s) failed", off.errors + on.errors);
            }
            if shards.spawned == 0 || shards.retired == 0 {
                bail!(
                    "autoscale smoke: shard churn missing (spawned {}, retired {})",
                    shards.spawned,
                    shards.retired
                );
            }
            if shards.errors > 0 {
                bail!(
                    "autoscale smoke: {} request(s) failed during shard churn",
                    shards.errors
                );
            }
        }
        ran = true;
    }
    // stream is explicit-only (it boots a server per phase)
    if which == "stream" {
        let smoke = opts.contains_key("smoke");
        let run = bench_harness::stream_bench::run(smoke)?;
        print!("{}", bench_harness::stream_bench::render(&run));
        if smoke {
            // CI gates, both sides of the backpressure contract: the
            // calibrated rate must land every chunk inside the SLO with
            // nothing dropped; overload must engage credit backpressure
            // (and shed granularity) instead of dropping chunks
            let slo_s = bench_harness::stream_bench::SLO_MS / 1e3;
            if run.calibrated.report.errors > 0 {
                bail!(
                    "stream smoke: {} chunk(s) failed at the calibrated rate",
                    run.calibrated.report.errors
                );
            }
            if run.calibrated.report.p95 > slo_s {
                bail!(
                    "stream smoke: calibrated p95 {:.1} ms exceeds the {} ms SLO",
                    run.calibrated.report.p95 * 1e3,
                    bench_harness::stream_bench::SLO_MS
                );
            }
            if run.overload.report.stream_credits == 0 {
                bail!("stream smoke: overload never engaged credit backpressure");
            }
            if run.overload.report.errors > 0 {
                bail!(
                    "stream smoke: {} chunk(s) dropped under overload \
                     (backpressure must shed granularity, not chunks)",
                    run.overload.report.errors
                );
            }
        }
        if let Some(out) = opts.get("out") {
            bench_harness::serve_bench::write_atomic(
                out,
                &(bench_harness::stream_bench::to_json(&run) + "\n"),
            )?;
            println!("wrote {out}");
        }
        ran = true;
    }
    // dag is explicit-only (it boots a server and drives three graphs)
    if which == "dag" {
        let smoke = opts.contains_key("smoke");
        let transport = match opts.get("transport") {
            Some(v) => compar::serve::TransportKind::parse(v).context("--transport")?,
            None => compar::serve::TransportKind::default(),
        };
        let framing = match opts.get("framing") {
            Some(v) => compar::serve::Framing::parse(v).context("--framing")?,
            None => compar::serve::Framing::default(),
        };
        let run = bench_harness::dag_bench::run(transport, framing, smoke)?;
        print!("{}", bench_harness::dag_bench::render(&run));
        if smoke {
            // CI gates: planned makespan <= greedy, >= 1 transfer
            // elided, every node reports a result, and the contended
            // submit degrades to per-task greedy
            bench_harness::dag_bench::check_gates(&run)?;
        }
        if let Some(out) = opts.get("out") {
            bench_harness::serve_bench::write_atomic(
                out,
                &(bench_harness::dag_bench::to_json(&run) + "\n"),
            )?;
            println!("wrote {out}");
        }
        ran = true;
    }
    // cluster is explicit-only (it boots several servers per run)
    if which == "cluster" {
        let smoke = opts.contains_key("smoke");
        let shards: usize = opts
            .get("shards")
            .map(|v| v.parse())
            .transpose()
            .context("--shards")?
            .unwrap_or(2);
        let placement = match opts.get("placement") {
            Some(v) => compar::cluster::PlacementKind::parse(v)
                .ok_or_else(|| anyhow!("unknown placement policy '{v}'"))?,
            None => compar::cluster::PlacementKind::RoundRobin,
        };
        let serve = compar::serve::ServeOptions {
            addr: "127.0.0.1:0".into(),
            ncpu: 2,
            ncuda: 0,
            ..compar::serve::ServeOptions::default()
        };
        let load = compar::serve::LoadgenOptions {
            clients: 4,
            requests: if smoke { 8 } else { 40 },
            app: "matmul".into(),
            size: 48,
            pipeline: 2,
            ..compar::serve::LoadgenOptions::default()
        };
        let reports =
            bench_harness::cluster_bench::compare(shards, placement, &serve, &load)?;
        println!("{}", bench_harness::cluster_bench::render(&reports));
        ran = true;
    }
    if !ran {
        bail!("unknown bench target '{which}'");
    }
    Ok(())
}

/// `compar bench validate FILE [--prev FILE]`: check a bench JSON
/// record against the current schema (ci.sh runs this on
/// BENCH_serve.json and on freshly generated records, so the
/// pending-toolchain placeholder flow cannot rot silently). For
/// `compar-obs` metrics snapshots, `--prev` additionally gates counter
/// monotonicity against an earlier scrape of the same server.
fn validate_bench_record(file: &str, prev: Option<&str>) -> Result<()> {
    use compar::util::json::Json;
    let text = std::fs::read_to_string(file).with_context(|| format!("reading {file}"))?;
    let v = compar::util::json::parse(text.trim())
        .map_err(|e| anyhow!("{file}: invalid json: {e}"))?;
    let bench = v
        .get("bench")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("{file}: missing 'bench' name"))?
        .to_string();
    let status = v
        .get("status")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("{file}: missing 'status'"))?
        .to_string();
    match status.as_str() {
        "pending-toolchain" => {
            // the documented placeholder shape (see BENCH_serve.json):
            // a 'note' explaining why the measurement is missing, a
            // 'regenerate' command that replaces the record, and the
            // measurement fields explicitly null — a partially measured
            // record must not hide behind the marker
            if v.get("regenerate").and_then(Json::as_str).is_none() {
                bail!(
                    "{file}: 'pending-toolchain' placeholder without a \
                     'regenerate' command"
                );
            }
            if v.get("note").and_then(Json::as_str).is_none() {
                bail!("{file}: 'pending-toolchain' placeholder without a 'note'");
            }
            for k in ["load", "server"] {
                match v.get(k) {
                    None | Some(Json::Null) => {}
                    Some(_) => bail!(
                        "{file}: 'pending-toolchain' placeholder carries a \
                         non-null '{k}' — measured data must use status \
                         'measured'"
                    ),
                }
            }
        }
        "measured" => {
            let schema = v
                .get("schema")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("{file}: measured record missing 'schema'"))?
                as u64;
            if schema != compar::bench_harness::serve_bench::BENCH_SCHEMA {
                bail!(
                    "{file}: schema v{schema}, tool expects v{}",
                    compar::bench_harness::serve_bench::BENCH_SCHEMA
                );
            }
            match bench.as_str() {
                "compar-loadgen" => {
                    let rps = v
                        .get("load")
                        .and_then(|l| l.get("rps"))
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("{file}: missing load.rps"))?;
                    if !rps.is_finite() || rps <= 0.0 {
                        bail!("{file}: non-positive load.rps {rps}");
                    }
                    if v.get("server").and_then(Json::as_obj).is_none() {
                        bail!("{file}: missing 'server' counters");
                    }
                    // v4: every record names its lane so threaded/ndjson
                    // and epoll/binary measurements are never conflated
                    for k in ["transport", "framing"] {
                        let lane = v
                            .get("config")
                            .and_then(|c| c.get(k))
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("{file}: missing config.{k}"))?;
                        let known: &[&str] = if k == "transport" {
                            &["threads", "epoll"]
                        } else {
                            &["ndjson", "binary"]
                        };
                        if !known.contains(&lane) {
                            bail!("{file}: unknown config.{k} '{lane}'");
                        }
                    }
                }
                "compar-selection" => {
                    let rows = v
                        .get("rows")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("{file}: missing 'rows'"))?;
                    if rows.is_empty() {
                        bail!("{file}: empty 'rows'");
                    }
                    for (i, row) in rows.iter().enumerate() {
                        for k in ["app", "policy"] {
                            if row.get(k).and_then(Json::as_str).is_none() {
                                bail!("{file}: row {i} missing '{k}'");
                            }
                        }
                        for k in ["size", "regret_s", "accuracy"] {
                            if row.get(k).and_then(Json::as_f64).is_none() {
                                bail!("{file}: row {i} missing '{k}'");
                            }
                        }
                    }
                }
                "compar-dag" => {
                    for phase in ["planned", "greedy", "contended"] {
                        let g = v
                            .get(phase)
                            .and_then(Json::as_obj)
                            .ok_or_else(|| anyhow!("{file}: missing '{phase}' run"))?;
                        let mode = g
                            .get("mode")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("{file}: missing {phase}.mode"))?;
                        if !["planned", "greedy"].contains(&mode) {
                            bail!("{file}: unknown {phase}.mode '{mode}'");
                        }
                        let ms = g
                            .get("makespan")
                            .and_then(Json::as_f64)
                            .ok_or_else(|| anyhow!("{file}: missing {phase}.makespan"))?;
                        if !ms.is_finite() || ms <= 0.0 {
                            bail!("{file}: non-positive {phase}.makespan {ms}");
                        }
                        let nodes = g
                            .get("nodes")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow!("{file}: missing {phase}.nodes"))?;
                        if nodes.is_empty() {
                            bail!("{file}: empty {phase}.nodes");
                        }
                    }
                }
                "compar-stream" => {
                    if v.get("slo_ms").and_then(Json::as_f64).is_none() {
                        bail!("{file}: missing 'slo_ms'");
                    }
                    for phase in ["calibrated", "overload"] {
                        let load = v
                            .get(phase)
                            .and_then(|p| p.get("load"))
                            .ok_or_else(|| anyhow!("{file}: missing {phase}.load"))?;
                        let rps = load
                            .get("rps")
                            .and_then(Json::as_f64)
                            .ok_or_else(|| anyhow!("{file}: missing {phase}.load.rps"))?;
                        if !rps.is_finite() || rps <= 0.0 {
                            bail!("{file}: non-positive {phase}.load.rps {rps}");
                        }
                        if load.get("stream_credits").and_then(Json::as_f64).is_none() {
                            bail!("{file}: missing {phase}.load.stream_credits");
                        }
                    }
                }
                "compar-obs" => {
                    // v9 loadgen --metrics-out snapshot: a full registry
                    // scrape plus the loadgen's own success count
                    let m = v
                        .get("metrics")
                        .ok_or_else(|| anyhow!("{file}: missing 'metrics' scrape"))?;
                    for k in ["counters", "gauges", "histograms"] {
                        if m.get(k).and_then(Json::as_obj).is_none() {
                            bail!("{file}: metrics scrape missing '{k}'");
                        }
                    }
                    // every histogram must be internally consistent: a
                    // bucket ladder of N bounds plus one overflow bucket
                    // whose counts sum exactly to `count`
                    for (name, h) in m.get("histograms").and_then(Json::as_obj).unwrap() {
                        let le = h
                            .get("le")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow!("{file}: histogram {name} missing 'le'"))?;
                        let counts = h.get("counts").and_then(Json::as_arr).ok_or_else(|| {
                            anyhow!("{file}: histogram {name} missing 'counts'")
                        })?;
                        if counts.len() != le.len() + 1 {
                            bail!(
                                "{file}: histogram {name} has {} buckets for {} \
                                 bounds (want bounds+1)",
                                counts.len(),
                                le.len()
                            );
                        }
                        let count = h
                            .get("count")
                            .and_then(Json::as_f64)
                            .ok_or_else(|| anyhow!("{file}: histogram {name} missing 'count'"))?;
                        let bucket_sum: f64 = counts.iter().filter_map(Json::as_f64).sum();
                        if (bucket_sum - count).abs() > 0.5 {
                            bail!(
                                "{file}: histogram {name} bucket counts sum to \
                                 {bucket_sum}, count says {count}"
                            );
                        }
                    }
                    let ok = v
                        .get("requests_ok")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("{file}: missing 'requests_ok'"))?;
                    // the end-to-end histogram is observed once per
                    // successful request, so its count must cover the
                    // loadgen's successes (it may exceed them when other
                    // clients also drove the server)
                    if let Some(count) = m
                        .get("histograms")
                        .and_then(|h| h.get("serve_e2e_seconds"))
                        .and_then(|h| h.get("count"))
                        .and_then(Json::as_f64)
                    {
                        if count + 0.5 < ok {
                            bail!(
                                "{file}: serve_e2e_seconds count {count} does not \
                                 cover requests_ok {ok}"
                            );
                        }
                    }
                    // --prev: an earlier scrape of the same server; every
                    // counter it knew must not have gone backwards
                    if let Some(pfile) = prev {
                        let ptext = std::fs::read_to_string(pfile)
                            .with_context(|| format!("reading {pfile}"))?;
                        let pv = compar::util::json::parse(ptext.trim())
                            .map_err(|e| anyhow!("{pfile}: invalid json: {e}"))?;
                        if pv.get("bench").and_then(Json::as_str) != Some("compar-obs") {
                            bail!("{pfile}: --prev must name a compar-obs record");
                        }
                        let pc = pv
                            .get("metrics")
                            .and_then(|pm| pm.get("counters"))
                            .and_then(Json::as_obj)
                            .ok_or_else(|| anyhow!("{pfile}: missing metrics.counters"))?;
                        let cc = m.get("counters").and_then(Json::as_obj).unwrap();
                        for (name, pval) in pc {
                            let pval = pval.as_f64().unwrap_or(0.0);
                            let cur = cc.get(name).and_then(Json::as_f64).unwrap_or(f64::MIN);
                            if cur + 1e-9 < pval {
                                bail!(
                                    "{file}: counter {name} went backwards vs \
                                     {pfile}: {pval} -> {cur}"
                                );
                            }
                        }
                        println!("{file}: counters monotonic vs {pfile}");
                    }
                }
                other => bail!("{file}: unknown bench kind '{other}'"),
            }
        }
        other => bail!("{file}: unknown status '{other}'"),
    }
    if prev.is_some() && bench != "compar-obs" {
        bail!("{file}: --prev is only supported for compar-obs records");
    }
    println!("{file}: valid {bench} record ({status})");
    Ok(())
}

// ------------------------------------------------------------------ serve

/// The `compar autoscale` flag group (shared by `serve` and in-process
/// loadgen clusters): `--autoscale` enables the elastic control loop;
/// `--scale-min` / `--scale-max` bound each context's worker count
/// (either a bare number for every context or `name=N,name2=M`),
/// `--slo-ms` sets the latency target, `--cooldown-ms` the token-bucket
/// refill window.
fn autoscale_options_from(
    opts: &HashMap<String, String>,
) -> Result<Option<compar::autoscale::AutoscaleOptions>> {
    if !opts.contains_key("autoscale") {
        return Ok(None);
    }
    let mut a = compar::autoscale::AutoscaleOptions::default();
    if let Some(v) = opts.get("cooldown-ms") {
        a.cooldown = std::time::Duration::from_millis(v.parse().context("--cooldown-ms")?);
    }
    if let Some(v) = opts.get("scale-period-ms") {
        a.period = std::time::Duration::from_millis(v.parse().context("--scale-period-ms")?);
    }
    if let Some(v) = opts.get("slo-ms") {
        a.slo_ms = Some(v.parse().context("--slo-ms")?);
    }
    if let Some(v) = opts.get("scale-high") {
        a.high = v.parse().context("--scale-high")?;
    }
    if let Some(v) = opts.get("scale-low") {
        a.low = v.parse().context("--scale-low")?;
    }
    if let Some(v) = opts.get("scale-sustain") {
        a.sustain = v.parse().context("--scale-sustain")?;
    }
    // min/max: a bare number applies to every context; name=N entries
    // override per context
    let mut per: HashMap<String, (Option<usize>, Option<usize>)> = HashMap::new();
    for (flag, is_min) in [("scale-min", true), ("scale-max", false)] {
        let Some(v) = opts.get(flag) else { continue };
        for part in v.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            match part.split_once('=') {
                Some((name, n)) => {
                    let n: usize = n.parse().with_context(|| format!("--{flag} '{part}'"))?;
                    let e = per.entry(name.to_string()).or_default();
                    if is_min {
                        e.0 = Some(n);
                    } else {
                        e.1 = Some(n);
                    }
                }
                None => {
                    let n: usize = part.parse().with_context(|| format!("--{flag}"))?;
                    if is_min {
                        a.min_workers = n;
                    } else {
                        a.max_workers = n;
                    }
                }
            }
        }
    }
    for (name, (min, max)) in per {
        a.per_ctx.insert(
            name,
            compar::autoscale::CtxLimits {
                min: min.unwrap_or(a.min_workers),
                max: max
                    .or(if a.max_workers == 0 { None } else { Some(a.max_workers) })
                    .unwrap_or(usize::MAX),
                slo_ms: a.slo_ms,
            },
        );
    }
    Ok(Some(a))
}

fn serve_options_from(opts: &HashMap<String, String>) -> Result<compar::serve::ServeOptions> {
    let mut so = compar::serve::ServeOptions::default();
    if let Some(a) = opts.get("addr") {
        so.addr = a.clone();
    }
    if let Some(c) = opts.get("contexts") {
        so.contexts = compar::serve::parse_contexts(c)?;
    }
    if let Some(v) = opts.get("sched") {
        so.sched = SchedPolicy::parse(v).ok_or_else(|| anyhow!("unknown scheduler '{v}'"))?;
    }
    if let Some(v) = opts.get("selector") {
        so.selector = Some(SelectorKind::parse(v).ok_or_else(|| {
            anyhow!("unknown selection policy '{v}' (want {VALID_SELECTORS})")
        })?);
    }
    if let Some(v) = opts.get("ncpu") {
        so.ncpu = v.parse().context("--ncpu")?;
    }
    if let Some(v) = opts.get("ncuda") {
        so.ncuda = v.parse().context("--ncuda")?;
    }
    if let Some(v) = opts.get("cap") {
        so.max_inflight = v.parse().context("--cap")?;
    }
    if let Some(v) = opts.get("batch-window-us") {
        so.batch_window = std::time::Duration::from_micros(v.parse().context("--batch-window-us")?);
    }
    if let Some(v) = opts.get("max-batch") {
        so.max_batch = v.parse().context("--max-batch")?;
    }
    if let Some(v) = opts.get("transport") {
        so.transport = compar::serve::TransportKind::parse(v).context("--transport")?;
    }
    // v9 observability rings (0 disables retention; recording still counts)
    if let Some(v) = opts.get("audit-cap") {
        so.audit_cap = v.parse().context("--audit-cap")?;
    }
    if let Some(v) = opts.get("trace-cap") {
        so.trace_cap = v.parse().context("--trace-cap")?;
    }
    so.autoscale = autoscale_options_from(opts)?;
    Ok(so)
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let (_, opts) = parse_opts(args);
    let so = serve_options_from(&opts)?;
    let autoscale_on = so.autoscale.is_some();
    let server = compar::serve::Server::start(so)?;
    println!("compar serve listening on {}", server.local_addr());
    for (name, workers) in server.context_table() {
        println!("  context {name:12} workers {workers:?}");
    }
    if autoscale_on {
        println!("  autoscale: enabled (query with {{\"op\":\"autoscale_status\"}})");
    }
    println!("(send {{\"op\":\"shutdown\"}} or run `compar loadgen --shutdown` to stop)");
    let stats = server.serve_forever()?;
    println!(
        "drained: {} ok, {} errors, {} tasks executed over {:.1} s",
        stats.requests_ok, stats.requests_err, stats.tasks_executed, stats.uptime
    );
    Ok(())
}

// ------------------------------------------------------------------ route

/// Router options shared by `compar route` and `loadgen --shards`.
fn router_options_from(opts: &HashMap<String, String>) -> Result<compar::cluster::RouterOptions> {
    let mut ro = compar::cluster::RouterOptions::default();
    if let Some(v) = opts.get("listen") {
        ro.listen = v.clone();
    }
    if let Some(v) = opts.get("placement") {
        ro.placement = compar::cluster::PlacementKind::parse(v)
            .ok_or_else(|| anyhow!("unknown placement policy '{v}'"))?;
    }
    if let Some(v) = opts.get("health-ms") {
        ro.health_period = std::time::Duration::from_millis(v.parse().context("--health-ms")?);
    }
    if let Some(v) = opts.get("gossip-ms") {
        ro.gossip_period = std::time::Duration::from_millis(v.parse().context("--gossip-ms")?);
    }
    if opts.contains_key("no-gossip") {
        ro.gossip = false;
    }
    // --autoscale at the router level scales the *shard set*
    if opts.contains_key("autoscale") {
        let mut sc = compar::cluster::ClusterScaleOptions::default();
        if let Some(v) = opts.get("min-shards") {
            sc.min_shards = v.parse().context("--min-shards")?;
        }
        if let Some(v) = opts.get("max-shards") {
            sc.max_shards = v.parse().context("--max-shards")?;
        }
        if let Some(v) = opts.get("scale-up") {
            sc.up_load = v.parse().context("--scale-up")?;
        }
        if let Some(v) = opts.get("scale-down") {
            sc.down_load = v.parse().context("--scale-down")?;
        }
        if let Some(v) = opts.get("scale-sustain") {
            sc.sustain = v.parse().context("--scale-sustain")?;
        }
        if let Some(v) = opts.get("cooldown-ms") {
            sc.cooldown = std::time::Duration::from_millis(v.parse().context("--cooldown-ms")?);
        }
        if let Some(v) = opts.get("scale-period-ms") {
            sc.period = std::time::Duration::from_millis(v.parse().context("--scale-period-ms")?);
        }
        if let Some(v) = opts.get("spawn-ncpu") {
            sc.spawn_ncpu = v.parse().context("--spawn-ncpu")?;
        }
        if let Some(v) = opts.get("spawn-args") {
            // extra `compar serve` flags so spawned shards match the
            // existing shards' topology (contexts, selector, cap, ...)
            sc.spawn_args = v.split_whitespace().map(str::to_string).collect();
        }
        ro.autoscale = Some(sc);
    }
    Ok(ro)
}

fn cmd_route(args: &[String]) -> Result<()> {
    let (_, opts) = parse_opts(args);
    let mut ro = router_options_from(&opts)?;
    ro.shards = opts
        .get("shards")
        .ok_or_else(|| anyhow!("--shards host:port,... is required"))?
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    let gossip = ro.gossip;
    let placement = ro.placement;
    let router = compar::cluster::Router::start(ro)?;
    println!(
        "compar route listening on {} (placement {}, gossip {})",
        router.local_addr(),
        placement.name(),
        if gossip { "on" } else { "off" }
    );
    for d in router.shards() {
        println!("  shard {}", d.addr);
    }
    println!("(send {{\"op\":\"shutdown\"}} or run `compar loadgen --shutdown` to stop the cluster)");
    router.serve_forever()?;
    println!("router drained");
    Ok(())
}

fn cmd_loadgen(args: &[String]) -> Result<()> {
    let (_, opts) = parse_opts(args);
    let mut lg = compar::serve::LoadgenOptions::default();
    if let Some(v) = opts.get("clients") {
        lg.clients = v.parse().context("--clients")?;
    }
    if let Some(v) = opts.get("requests") {
        lg.requests = v.parse().context("--requests")?;
    }
    if let Some(v) = opts.get("app") {
        lg.app = v.clone();
    }
    if let Some(v) = opts.get("size") {
        lg.size = v.parse().context("--size")?;
    }
    if let Some(v) = opts.get("tasks") {
        lg.tasks = v.parse::<usize>().context("--tasks")?.max(1);
    }
    if let Some(v) = opts.get("ctxs") {
        lg.ctxs = v
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect();
    }
    if let Some(v) = opts.get("pipeline") {
        lg.pipeline = v.parse::<usize>().context("--pipeline")?.max(1);
    }
    if let Some(v) = opts.get("policy") {
        if SelectorKind::parse(v).is_none() {
            bail!("unknown selection policy '{v}' for --policy (want {VALID_SELECTORS})");
        }
        lg.policy = Some(v.clone());
    }
    if let Some(v) = opts.get("profile") {
        lg.profile = Some(compar::serve::LoadProfile::parse(v)?);
    }
    if let Some(v) = opts.get("slo-ms") {
        lg.slo_ms = Some(v.parse().context("--slo-ms")?);
    }
    if let Some(v) = opts.get("window") {
        lg.window = v.parse().context("--window")?;
    }
    if let Some(v) = opts.get("slide") {
        lg.slide = v.parse().context("--slide")?;
    }
    if let Some(v) = opts.get("seed") {
        lg.seed = v.parse().context("--seed")?;
    }
    if opts.contains_key("no-verify") {
        lg.verify = false;
    }
    if let Some(v) = opts.get("framing") {
        lg.framing = compar::serve::Framing::parse(v).context("--framing")?;
    }
    if let Some(v) = opts.get("connections") {
        lg.connections = v.parse().context("--connections")?;
    }
    if let Some(v) = opts.get("metrics-out") {
        lg.metrics_out = Some(v.clone());
    }
    // the transport lane drives the in-process server (via
    // serve_options_from) and labels the bench record either way
    let transport = match opts.get("transport") {
        Some(v) => compar::serve::TransportKind::parse(v).context("--transport")?,
        None => compar::serve::TransportKind::default(),
    };

    // --shutdown: just ask a running server to drain and exit
    if opts.contains_key("shutdown") {
        let addr = opts
            .get("addr")
            .cloned()
            .unwrap_or_else(|| compar::serve::ServeOptions::default().addr);
        let mut c = compar::serve::Client::connect(&addr)?;
        c.shutdown_server()?;
        println!("shutdown requested at {addr}");
        return Ok(());
    }

    let contexts_desc = opts.get("contexts").cloned().unwrap_or_default();
    let (report, stats) = match (opts.get("shards"), opts.get("addr")) {
        // --shards N: boot an in-process cluster (N serve shards behind
        // a router on ephemeral loopback ports) and drive the router
        (Some(n), _) => {
            let n: usize = n.parse().context("--shards")?;
            let mut so = serve_options_from(&opts)?;
            so.addr = "127.0.0.1:0".into();
            let mut ro = router_options_from(&opts)?;
            ro.listen = "127.0.0.1:0".into();
            let cluster = compar::cluster::LocalCluster::start(n, &so, ro)?;
            let addr = cluster.addr();
            println!("in-process cluster: {n} shard(s) behind {addr}");
            let report = compar::serve::loadgen::run(&addr, &lg)?;
            let mut c = compar::serve::Client::connect(&addr)?;
            let stats = c.stats()?;
            let _ = c.quit();
            let (routed, retried) = cluster.router.routing_counters();
            cluster.shutdown()?;
            println!("router: {routed} submit(s) routed, {retried} retried on another shard");
            (report, stats)
        }
        // external server (or router): drive it over the wire
        (None, Some(addr)) => {
            let report = compar::serve::loadgen::run(addr, &lg)?;
            let mut c = compar::serve::Client::connect(addr)?;
            let stats = c.stats()?;
            let _ = c.quit();
            (report, stats)
        }
        // default: boot an in-process server on an ephemeral port
        (None, None) => {
            let mut so = serve_options_from(&opts)?;
            so.addr = "127.0.0.1:0".into();
            compar::bench_harness::serve_bench::run_inprocess(so, &lg)?
        }
    };
    print!(
        "{}",
        compar::bench_harness::serve_bench::render(&report, &stats)
    );
    if report.errors > 0 {
        bail!("{} request(s) failed", report.errors);
    }
    if let Some(p) = &lg.metrics_out {
        println!("wrote metrics snapshot {p}");
    }
    if let Some(out) = opts.get("out") {
        let json = compar::bench_harness::serve_bench::to_json(
            &report,
            &stats,
            &lg,
            &contexts_desc,
            transport,
        );
        // atomic replace: the pending-toolchain placeholder (or a prior
        // measurement) is swapped in one rename
        compar::bench_harness::serve_bench::write_atomic(out, &(json + "\n"))?;
        println!("wrote {out}");
    }
    Ok(())
}

// ----------------------------------------------------------------- verify

/// `compar verify model`: the verified-concurrency-core entry point.
/// Default run: the generative explorer over the pure model. `--smoke`
/// is the CI gate: a clean 10k-sequence exploration, the injected-bug
/// self-test (the harness must catch and shrink it), the concrete run
/// of every kani proof body, and a short differential pass against the
/// real runtime.
fn cmd_verify(args: &[String]) -> Result<()> {
    let (pos, opts) = parse_opts(args);
    match pos.first().map(String::as_str) {
        Some("model") => {}
        other => bail!(
            "usage: compar verify model [--smoke] [--seqs N] [--ops K] [--seed S] \
             [--diff N] [--proofs] [--self-test] [--fault KIND] (got {other:?})"
        ),
    }
    let smoke = opts.contains_key("smoke");
    let mut cfg = compar::model::ModelConfig::default();
    if let Some(v) = opts.get("ncpu") {
        cfg.ncpu = v.parse().context("--ncpu")?;
    }
    if let Some(v) = opts.get("ncuda") {
        cfg.ncuda = v.parse().context("--ncuda")?;
    }
    if cfg.ncpu + cfg.ncuda == 0 {
        bail!("verify model: need at least one worker (--ncpu/--ncuda)");
    }
    let mut explore_opts = compar::model::ExploreOptions {
        config: cfg,
        ..compar::model::ExploreOptions::default()
    };
    if smoke {
        explore_opts.ops_per_seq = 32;
    }
    if let Some(v) = opts.get("seqs") {
        explore_opts.sequences = v.parse().context("--seqs")?;
    }
    if let Some(v) = opts.get("ops") {
        explore_opts.ops_per_seq = v.parse().context("--ops")?;
    }
    if let Some(v) = opts.get("seed") {
        explore_opts.seed = parse_seed(v).context("--seed")?;
    }
    if let Some(v) = opts.get("fault") {
        // fault injection demo: the explorer MUST find a violation and
        // print the shrunk counterexample; a clean run is the failure
        let fault = compar::model::Fault::parse(v)
            .ok_or_else(|| anyhow!("unknown fault '{v}' (want {})", compar::model::VALID_FAULTS))?;
        explore_opts.fault = Some(fault);
        return match compar::model::explore(&explore_opts) {
            Err(v) => {
                println!("injected fault '{}' caught as expected:", fault.name());
                println!("{v}");
                Ok(())
            }
            Ok(stats) => bail!(
                "injected fault '{}' survived {} sequences ({} ops) undetected",
                fault.name(),
                stats.sequences,
                stats.ops_applied
            ),
        };
    }

    // explore by default; with a sub-mode flag (--proofs/--self-test/
    // --diff) run only that lane — except under --smoke, which runs all
    let run_explore = smoke
        || (!opts.contains_key("proofs")
            && !opts.contains_key("self-test")
            && !opts.contains_key("diff"));
    if run_explore {
        match compar::model::explore(&explore_opts) {
            Ok(stats) => println!(
                "explore: {} sequences x {} ops ({} ops applied), all invariants held",
                stats.sequences, explore_opts.ops_per_seq, stats.ops_applied
            ),
            Err(v) => bail!("model invariant violated:\n{v}"),
        }
    }
    if smoke || opts.contains_key("self-test") {
        match compar::model::self_test(&cfg) {
            Ok(v) => println!(
                "self-test: injected {} bug caught at step {} and shrunk {} -> {} op(s)",
                compar::model::Fault::DropEvictedTask.name(),
                v.step,
                v.ops.len(),
                v.shrunk.len()
            ),
            Err(msg) => bail!("self-test failed: {msg}"),
        }
    }
    if smoke || opts.contains_key("proofs") {
        let cases = if smoke { 64 } else { 256 };
        compar::model::proofs::run_concrete(cases);
        println!(
            "proofs: 4 kani harness bodies x {cases} concrete cases passed \
             (run `cargo kani` for the bounded proofs)"
        );
    }
    if smoke || opts.contains_key("diff") {
        let mut diff_opts = compar::model::DiffOptions {
            config: cfg,
            ..compar::model::DiffOptions::default()
        };
        if smoke {
            diff_opts.sequences = 8;
        }
        if let Some(v) = opts.get("diff") {
            if v != "1" {
                diff_opts.sequences = v.parse().context("--diff")?;
            }
        }
        let stats = compar::model::diff::run(&diff_opts)?;
        println!(
            "diff: {} sequences x {} steps against the real runtime \
             ({} tasks executed), no divergence",
            stats.sequences, diff_opts.steps_per_seq, stats.tasks_executed
        );
    }
    println!("verify model OK");
    Ok(())
}

/// Seeds accept decimal or 0x-hex (matching COMPAR_MODEL_SEED).
fn parse_seed(v: &str) -> Result<u64> {
    match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).map_err(Into::into),
        None => v.parse().map_err(Into::into),
    }
}

// -------------------------------------------------------------- calibrate

fn cmd_calibrate(args: &[String]) -> Result<()> {
    let (_, opts) = parse_opts(args);
    let app = opts
        .get("app")
        .ok_or_else(|| anyhow!("--app is required"))?;
    let manifest = load_manifest()?;
    let sizes: Vec<usize> = match opts.get("sizes") {
        Some(s) => s.split(',').map(|v| v.trim().parse()).collect::<Result<_, _>>()?,
        None => manifest.sizes(app, "pallas"),
    };
    let mut cfg = config_from_opts(&opts)?;
    cfg.calibrate = true;
    // the whole point of this subcommand is per-size calibration: pin
    // the Calibrating policy even if COMPAR_SELECTOR says otherwise
    cfg.selector = SelectorKind::Calibrating;
    if cfg.perfmodel_dir.is_none() {
        cfg.perfmodel_dir = Some("perfmodels".into());
    }
    let rt = Runtime::new(cfg, Some(manifest))?;
    for &size in &sizes {
        let rounds = 3 * apps::paper_variants(app).len();
        for i in 0..rounds {
            let run = apps::run_once(&rt, app, size, 9000 + i as u64, None, false)?;
            println!("size {size} round {i}: {}", run.variant);
        }
    }
    rt.save_perf_models()?;
    println!("perf models saved");
    Ok(())
}

// ------------------------------------------------------------------- list

fn cmd_list() -> Result<()> {
    println!("benchmark applications (paper Table 2):");
    for app in apps::ALL {
        let c = apps::codelet(app)?;
        let variants: Vec<String> = c
            .impls
            .iter()
            .map(|i| format!("{}({})", i.name, i.arch.name()))
            .collect();
        println!(
            "  {:10} codelet={:9} variants=[{}] sizes={:?}",
            app,
            c.name,
            variants.join(", "),
            apps::paper_sizes(app)
        );
    }
    let hw = compar::taskrt::hwloc::MachineTopology::detect();
    println!(
        "\nhost machine (hwloc probe): {} logical / {} physical cores, {} socket(s){}",
        hw.logical_cpus,
        hw.physical_cores,
        hw.sockets,
        hw.model_name
            .as_deref()
            .map(|m| format!(" — {m}"))
            .unwrap_or_default()
    );
    println!("  recommended COMPAR_NCPU: {}", hw.recommended_ncpu());

    println!("\ndevice topology (paper Table 1):");
    for d in compar::taskrt::device::paper_topology(4, 1) {
        println!("  node {} {:5} x{} — {}", d.mem_node, d.arch.name(), d.workers, d.name);
    }
    match load_manifest() {
        Ok(m) => {
            println!("\nartifacts: {} compiled HLO modules", m.artifacts.len());
            for app in apps::ALL {
                let sizes = m.sizes(app, "pallas");
                let jnp = m.sizes(app, "jnp");
                println!("  {app:10} pallas={sizes:?} jnp={jnp:?}");
            }
        }
        Err(_) => println!("\nartifacts: none (run `make artifacts`)"),
    }
    Ok(())
}
