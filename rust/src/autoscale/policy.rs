//! Scale policies: given per-context load samples, decide which elastic
//! actions to take. Pluggable behind [`ScalePolicy`], mirroring the
//! selection engine's shape (a small closed set, picked by config).
//!
//! The shipped [`Threshold`] policy is deliberately boring control
//! theory: pressure bands with hysteresis (an action needs `sustain`
//! consecutive pressured samples, so one noisy snapshot never moves a
//! worker) plus a token-bucket cooldown (at most `burst` actions per
//! cooldown window, so the loop cannot flap workers back and forth
//! faster than the runtime can observe the effect). Time is passed in
//! explicitly (`dt`) rather than read from a wall clock, so decisions
//! are deterministic and property-testable.

use std::time::Duration;

use crate::taskrt::CtxId;

/// One scheduling context as the policy sees it: the runtime's
/// [`crate::taskrt::CtxLoad`] plus the operator-configured limits.
#[derive(Debug, Clone)]
pub struct CtxSample {
    pub ctx: CtxId,
    pub name: String,
    /// Current member workers.
    pub workers: usize,
    /// Tasks pushed, not yet popped.
    pub queue_depth: usize,
    /// Members currently executing a task.
    pub busy: usize,
    /// Modeled backlog seconds on the least-loaded member.
    pub queued_secs: f64,
    /// Serve-layer sessions sharing the runtime (co-tenancy; policies
    /// may weigh multi-tenant contexts differently).
    pub tenants: usize,
    /// Worker count when the control loop started — the "home" size
    /// calm rebalancing drifts back to.
    pub home: usize,
    /// Floor: this context never donates below `min` workers.
    pub min: usize,
    /// Ceiling: this context never grows above `max` workers.
    pub max: usize,
    /// Latency SLO target; modeled backlog beyond it counts as
    /// pressure even when the queue-depth band does not.
    pub slo_ms: Option<f64>,
}

impl CtxSample {
    /// Outstanding work per worker — the banded pressure signal.
    pub fn pressure(&self) -> f64 {
        (self.queue_depth + self.busy) as f64 / self.workers.max(1) as f64
    }

    /// The SLO term: best-case modeled wait already exceeds the target.
    pub fn slo_violated(&self) -> bool {
        match self.slo_ms {
            Some(ms) => self.queued_secs * 1e3 > ms,
            None => false,
        }
    }
}

/// One elastic action. Every action *moves* capacity — none creates or
/// destroys it — so the total worker count is conserved by construction
/// (the property tests pin this down).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    Move { from: CtxId, to: CtxId, n: usize },
}

/// A scale policy: consumes load samples, emits actions. `dt` is the
/// time elapsed since the previous call; it drives the cooldown, so a
/// test can replay a schedule deterministically.
pub trait ScalePolicy: Send {
    fn name(&self) -> &'static str;
    fn decide(&mut self, samples: &[CtxSample], dt: Duration) -> Vec<ScaleAction>;
}

/// Token bucket: at most `capacity` actions per `cooldown` refill
/// window. Shared by the in-process worker scaler and the cluster
/// shard scaler.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    capacity: f64,
    tokens: f64,
    cooldown: Duration,
}

impl TokenBucket {
    /// Starts full, so the first pressured sample can act immediately.
    pub fn new(capacity: usize, cooldown: Duration) -> TokenBucket {
        let capacity = capacity.max(1) as f64;
        TokenBucket {
            capacity,
            tokens: capacity,
            cooldown,
        }
    }

    /// Refill for `dt` of elapsed time (one token per cooldown window).
    pub fn advance(&mut self, dt: Duration) {
        if self.cooldown.is_zero() {
            self.tokens = self.capacity;
            return;
        }
        let refill = dt.as_secs_f64() / self.cooldown.as_secs_f64();
        self.tokens = (self.tokens + refill).min(self.capacity);
    }

    /// Consume one token if available.
    pub fn try_take(&mut self) -> bool {
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Configuration of the [`Threshold`] policy.
#[derive(Debug, Clone)]
pub struct ThresholdConfig {
    /// Pressure (outstanding tasks per worker) at which a context wants
    /// more workers.
    pub high: f64,
    /// Pressure at or below which a context may donate workers.
    pub low: f64,
    /// Consecutive pressured samples required before acting
    /// (hysteresis).
    pub sustain: usize,
    /// Token-bucket refill window.
    pub cooldown: Duration,
    /// Token-bucket capacity (actions per cooldown window).
    pub burst: usize,
}

impl Default for ThresholdConfig {
    fn default() -> ThresholdConfig {
        ThresholdConfig {
            high: 2.0,
            low: 0.5,
            sustain: 2,
            cooldown: Duration::from_millis(250),
            burst: 1,
        }
    }
}

/// Threshold hysteresis with a token-bucket cooldown; also drifts
/// worker counts back to their home sizes once every context is calm.
pub struct Threshold {
    cfg: ThresholdConfig,
    bucket: TokenBucket,
    /// ctx id -> consecutive samples over the high band.
    hot_streak: Vec<usize>,
    /// Consecutive samples where *every* context was calm.
    calm_streak: usize,
}

impl Threshold {
    pub fn new(cfg: ThresholdConfig) -> Threshold {
        let bucket = TokenBucket::new(cfg.burst, cfg.cooldown);
        Threshold {
            cfg,
            bucket,
            hot_streak: Vec::new(),
            calm_streak: 0,
        }
    }

    fn streak(&mut self, ctx: CtxId) -> &mut usize {
        if self.hot_streak.len() <= ctx {
            self.hot_streak.resize(ctx + 1, 0);
        }
        &mut self.hot_streak[ctx]
    }

    /// How many workers the receiver needs to come back under the high
    /// band (at least one).
    fn deficit(&self, s: &CtxSample) -> usize {
        let outstanding = (s.queue_depth + s.busy) as f64;
        let want = (outstanding / self.cfg.high).ceil() as usize;
        want.saturating_sub(s.workers).max(1)
    }
}

impl ScalePolicy for Threshold {
    fn name(&self) -> &'static str {
        "threshold"
    }

    fn decide(&mut self, samples: &[CtxSample], dt: Duration) -> Vec<ScaleAction> {
        self.bucket.advance(dt);
        let (high, sustain) = (self.cfg.high, self.cfg.sustain);
        // 1) classify and update hysteresis streaks
        let mut hottest: Option<&CtxSample> = None;
        let mut any_hot = false;
        for s in samples {
            let hot = (s.pressure() >= high || s.slo_violated()) && s.workers < s.max;
            let streak = {
                let e = self.streak(s.ctx);
                *e = if hot { *e + 1 } else { 0 };
                *e
            };
            any_hot = any_hot || hot;
            if hot
                && streak >= sustain
                && hottest
                    .map(|h| s.pressure() > h.pressure())
                    .unwrap_or(true)
            {
                hottest = Some(s);
            }
        }

        // 2) a sustained-hot context pulls workers from the calmest
        //    donor that sits above its floor
        if let Some(recv) = hottest {
            self.calm_streak = 0;
            let donor = samples
                .iter()
                .filter(|s| s.ctx != recv.ctx && s.workers > s.min && s.pressure() <= self.cfg.low)
                .min_by(|a, b| a.pressure().partial_cmp(&b.pressure()).unwrap());
            if let Some(donor) = donor {
                let n = self
                    .deficit(recv)
                    .min(donor.workers - donor.min)
                    .min(recv.max - recv.workers);
                if n > 0 && self.bucket.try_take() {
                    return vec![ScaleAction::Move {
                        from: donor.ctx,
                        to: recv.ctx,
                        n,
                    }];
                }
            }
            return Vec::new();
        }

        // 3) everyone calm: drift back to home sizes (the borrowed
        //    workers return once the burst has drained). An SLO still
        //    in violation is not calm — giving its workers back now
        //    would re-trigger the scale-up on the next samples, the
        //    exact flapping the hysteresis exists to prevent.
        let all_calm = samples
            .iter()
            .all(|s| s.pressure() <= self.cfg.low && !s.slo_violated());
        if !all_calm || any_hot {
            self.calm_streak = 0;
            return Vec::new();
        }
        self.calm_streak += 1;
        if self.calm_streak < self.cfg.sustain {
            return Vec::new();
        }
        let over = samples
            .iter()
            .filter(|s| s.workers > s.home && s.workers > s.min)
            .max_by_key(|s| s.workers - s.home);
        let under = samples
            .iter()
            .filter(|s| s.workers < s.home && s.workers < s.max)
            .max_by_key(|s| s.home - s.workers);
        if let (Some(over), Some(under)) = (over, under) {
            let n = (over.workers - over.home)
                .min(over.workers - over.min)
                .min(under.home - under.workers)
                .min(under.max - under.workers);
            if n > 0 && self.bucket.try_take() {
                return vec![ScaleAction::Move {
                    from: over.ctx,
                    to: under.ctx,
                    n,
                }];
            }
        }
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{run_cases, Rng};

    fn sample(ctx: usize, workers: usize, depth: usize) -> CtxSample {
        CtxSample {
            ctx,
            name: format!("c{ctx}"),
            workers,
            queue_depth: depth,
            busy: 0,
            queued_secs: 0.0,
            tenants: 0,
            home: workers,
            min: 1,
            max: usize::MAX,
            slo_ms: None,
        }
    }

    fn cfg(sustain: usize, cooldown_ms: u64) -> ThresholdConfig {
        ThresholdConfig {
            high: 2.0,
            low: 0.5,
            sustain,
            cooldown: Duration::from_millis(cooldown_ms),
            burst: 1,
        }
    }

    #[test]
    fn pressured_context_pulls_from_idle_donor() {
        let mut p = Threshold::new(cfg(2, 100));
        let samples = vec![sample(0, 2, 0), sample(1, 2, 12)];
        let dt = Duration::from_millis(50);
        // first sample: hysteresis holds the action back
        assert!(p.decide(&samples, dt).is_empty(), "one sample must not act");
        let actions = p.decide(&samples, dt);
        // deficit is 4 (ceil(12/2) wanted, has 2) but the donor's floor
        // caps the grant at one worker
        assert_eq!(actions, vec![ScaleAction::Move { from: 0, to: 1, n: 1 }]);
    }

    #[test]
    fn slo_violation_counts_as_pressure() {
        let mut p = Threshold::new(cfg(1, 100));
        let mut hot = sample(1, 2, 1); // below the queue-depth band
        hot.queued_secs = 0.050;
        hot.slo_ms = Some(10.0);
        let actions = p.decide(&[sample(0, 2, 0), hot], Duration::from_millis(50));
        assert_eq!(actions.len(), 1, "SLO breach must trigger a move");
    }

    #[test]
    fn calm_cluster_rebalances_to_home_sizes() {
        let mut p = Threshold::new(cfg(1, 50));
        // ctx1 borrowed two workers (home 2, now 4); everyone idle
        let mut borrowed = sample(1, 4, 0);
        borrowed.home = 2;
        let mut lender = sample(0, 2, 0);
        lender.home = 4;
        let dt = Duration::from_millis(100);
        let actions = p.decide(&[lender.clone(), borrowed.clone()], dt);
        assert_eq!(actions, vec![ScaleAction::Move { from: 1, to: 0, n: 2 }]);
    }

    #[test]
    fn donor_floor_is_respected() {
        let mut p = Threshold::new(cfg(1, 50));
        let mut donor = sample(0, 2, 0);
        donor.min = 2; // at its floor: nothing to give
        let actions = p.decide(&[donor, sample(1, 2, 40)], Duration::from_millis(100));
        assert!(actions.is_empty(), "a donor at its floor must not shrink");
    }

    /// Property: over random sample streams, applying every emitted
    /// action to a model cluster conserves the total worker count, never
    /// drops a donor below its floor, and never grows a receiver past
    /// its ceiling. (Hand-rolled quickcheck style — proptest is not
    /// available offline; shapes follow tests/properties.rs. Replay a
    /// failing case with COMPAR_MODEL_SEED=<printed seed>.)
    #[test]
    fn prop_actions_conserve_workers_and_respect_bounds() {
        run_cases(0x5ca1e, 64, |case| {
            let mut rng = Rng::new(case);
            let n_ctx = 2 + rng.below(4);
            let mut workers: Vec<usize> = (0..n_ctx).map(|_| 1 + rng.below(6)).collect();
            let homes = workers.clone();
            let mins: Vec<usize> = workers.iter().map(|&w| 1 + rng.below(w)).collect();
            let maxs: Vec<usize> = workers.iter().map(|&w| w + rng.below(8)).collect();
            let total: usize = workers.iter().sum();
            let mut p = Threshold::new(cfg(1 + rng.below(3), 10));
            for step in 0..40 {
                let samples: Vec<CtxSample> = (0..n_ctx)
                    .map(|c| {
                        let mut s = sample(c, workers[c], rng.below(20));
                        s.home = homes[c];
                        s.min = mins[c];
                        s.max = maxs[c];
                        s
                    })
                    .collect();
                let dt = Duration::from_millis(rng.below(30) as u64);
                for a in p.decide(&samples, dt) {
                    let ScaleAction::Move { from, to, n } = a;
                    assert!(n >= 1, "case {case} step {step}: empty move");
                    assert!(from != to, "case {case} step {step}: self-move");
                    workers[from] -= n;
                    workers[to] += n;
                    assert!(
                        workers[from] >= mins[from],
                        "case {case} step {step}: ctx {from} below floor"
                    );
                    assert!(
                        workers[to] <= maxs[to],
                        "case {case} step {step}: ctx {to} above ceiling"
                    );
                }
                assert_eq!(
                    workers.iter().sum::<usize>(),
                    total,
                    "case {case} step {step}: workers created or destroyed"
                );
            }
        });
    }

    /// Property: with a capacity-1 bucket, two actions are never closer
    /// than the cooldown window (measured in accumulated `dt`).
    #[test]
    fn prop_cooldown_spaces_actions() {
        run_cases(0xc001, 32, |seed| {
            let mut rng = Rng::new(seed);
            let cooldown_ms = 50 + rng.below(200) as u64;
            let mut p = Threshold::new(ThresholdConfig {
                sustain: 1,
                cooldown: Duration::from_millis(cooldown_ms),
                burst: 1,
                ..ThresholdConfig::default()
            });
            // drain the initial token so every action is refill-paced
            let primed = vec![sample(0, 4, 0), sample(1, 1, 40)];
            assert_eq!(p.decide(&primed, Duration::ZERO).len(), 1);
            let mut clock_ms = 0u64;
            let mut last_action: Option<u64> = None;
            for _ in 0..200 {
                let dt = rng.below(20) as u64;
                clock_ms += dt;
                // keep ctx1 permanently starved so only the bucket gates
                let samples = vec![sample(0, 4, 0), sample(1, 1, 40)];
                let acted = !p.decide(&samples, Duration::from_millis(dt)).is_empty();
                if acted {
                    if let Some(prev) = last_action {
                        assert!(
                            clock_ms - prev >= cooldown_ms,
                            "actions {prev} ms and {clock_ms} ms violate the \
                             {cooldown_ms} ms cooldown"
                        );
                    }
                    last_action = Some(clock_ms);
                }
            }
            assert!(last_action.is_some(), "the loop never acted at all");
        });
    }
}
