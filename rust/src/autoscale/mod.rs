//! `autoscale` — the elastic control plane (`compar autoscale`).
//!
//! COMPAR's selection layer adapts *which variant* runs to runtime
//! context, but until this subsystem the *capacity* side was static:
//! scheduling contexts were fixed at startup, so under bursty
//! multi-tenant traffic the contextual policy could only route around
//! pressure it had no way to relieve. This module closes that loop,
//! following the optimized-composition line (Kessler & Dastgeer,
//! arXiv:1405.2915 — co-optimizing composition decisions with resource
//! allocation at runtime) and HSTREAM (arXiv:1809.09387 — sizing
//! heterogeneous work distribution from observed throughput):
//!
//! ```text
//!            ┌───────────────── Autoscaler thread ─────────────────┐
//!            │ sample: Runtime::context_loads()                    │
//!            │   (queue depth · occupancy · modeled backlog ·      │
//!            │    tenants — the RuntimeSnapshot features, per ctx) │
//!            │ decide: ScalePolicy (threshold hysteresis +         │
//!            │   token-bucket cooldown; SLO-aware)                 │
//!            │ act:    Runtime::move_workers(from, to, n)          │
//!            └─────────────────────────────────────────────────────┘
//! ```
//!
//! The same control shape runs at two levels: in-process (this module,
//! moving *workers* between scheduling contexts without quiescing the
//! runtime — see [`crate::taskrt::Runtime::move_workers`]) and across
//! processes ([`crate::cluster::autoscale`], spawning and retiring
//! `compar serve` shards behind the router). Both report through the
//! protocol-v5 `autoscale_status` request.
//!
//! Layers:
//! * [`policy`] — [`ScalePolicy`] + the threshold/hysteresis/cooldown
//!   implementation and its property tests.
//! * this module — the sampling loop, per-context limits and SLOs, and
//!   the live status the serve layer exposes.

pub mod policy;

pub use policy::{CtxSample, ScaleAction, ScalePolicy, Threshold, ThresholdConfig, TokenBucket};

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::taskrt::{CtxId, CtxLoad, Runtime};

/// What the control loop needs from the thing it scales. [`Runtime`]
/// implements it directly; the serve layer adapts its shared state.
pub trait ScaleTarget: Send + Sync {
    /// Per-context load samples (see [`CtxLoad`]).
    fn loads(&self) -> Vec<CtxLoad>;
    /// Migrate up to `n` workers; returns how many actually moved.
    fn move_workers(&self, from: CtxId, to: CtxId, n: usize) -> Result<usize>;
}

impl ScaleTarget for Runtime {
    fn loads(&self) -> Vec<CtxLoad> {
        self.context_loads()
    }

    fn move_workers(&self, from: CtxId, to: CtxId, n: usize) -> Result<usize> {
        Runtime::move_workers(self, from, to, n)
    }
}

/// Per-context limits (`--scale-min` / `--scale-max` / `--slo-ms`).
#[derive(Debug, Clone, Copy)]
pub struct CtxLimits {
    pub min: usize,
    /// `usize::MAX` = unbounded.
    pub max: usize,
    pub slo_ms: Option<f64>,
}

/// Control-loop configuration (`compar serve --autoscale ...`).
#[derive(Debug, Clone)]
pub struct AutoscaleOptions {
    /// Sampling period of the control loop.
    pub period: Duration,
    /// Token-bucket refill window (`--cooldown-ms`).
    pub cooldown: Duration,
    /// Actions allowed per cooldown window.
    pub burst: usize,
    /// Pressure (outstanding tasks per worker) triggering scale-up.
    pub high: f64,
    /// Pressure at or below which a context may donate workers.
    pub low: f64,
    /// Consecutive pressured samples before acting (hysteresis).
    pub sustain: usize,
    /// Default floor for every context (`--scale-min`).
    pub min_workers: usize,
    /// Default ceiling (`--scale-max`; 0 = unbounded).
    pub max_workers: usize,
    /// Default latency SLO (`--slo-ms`; modeled backlog beyond it is
    /// pressure even below the queue-depth band).
    pub slo_ms: Option<f64>,
    /// Per-context overrides, keyed by context name.
    pub per_ctx: HashMap<String, CtxLimits>,
}

impl Default for AutoscaleOptions {
    fn default() -> AutoscaleOptions {
        AutoscaleOptions {
            period: Duration::from_millis(50),
            cooldown: Duration::from_millis(250),
            burst: 1,
            high: 2.0,
            low: 0.5,
            sustain: 2,
            min_workers: 1,
            max_workers: 0,
            slo_ms: None,
            per_ctx: HashMap::new(),
        }
    }
}

impl AutoscaleOptions {
    fn limits_for(&self, name: &str) -> CtxLimits {
        self.per_ctx.get(name).copied().unwrap_or(CtxLimits {
            min: self.min_workers,
            max: if self.max_workers == 0 {
                usize::MAX
            } else {
                self.max_workers
            },
            slo_ms: self.slo_ms,
        })
    }
}

/// One context in the live status (`autoscale_status`).
#[derive(Debug, Clone, PartialEq)]
pub struct CtxStatus {
    pub name: String,
    pub workers: usize,
    pub home: usize,
    pub min: usize,
    /// 0 encodes "unbounded" on the wire.
    pub max: usize,
    pub queue_depth: usize,
    /// 0.0 encodes "no SLO".
    pub slo_ms: f64,
}

/// Live view of the control loop, served through `autoscale_status`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AutoscaleStatus {
    pub enabled: bool,
    pub policy: String,
    /// Scale actions executed (each one worker-migration batch).
    pub moves: u64,
    /// Workers migrated in total.
    pub moved_workers: u64,
    /// Human-readable description of the last executed action.
    pub last_action: Option<String>,
    pub contexts: Vec<CtxStatus>,
}

/// State shared between the control-loop thread and status readers
/// (the serve layer holds one of these per server).
pub struct AutoscaleShared {
    stop: AtomicBool,
    status: Mutex<AutoscaleStatus>,
    /// Live SLO declarations (protocol v5): context name -> declaring
    /// session -> target. Session-scoped — a declaration is dropped
    /// when its session ends, so one aggressive short-lived client
    /// cannot skew the control loop forever. The tightest live
    /// declaration (and the configured default) wins.
    slo: Mutex<HashMap<String, HashMap<u64, f64>>>,
}

impl AutoscaleShared {
    pub fn status(&self) -> AutoscaleStatus {
        self.status.lock().unwrap().clone()
    }

    /// Register session `sid`'s declared target for `ctx` (a session
    /// re-declaring keeps only its latest value).
    pub fn tighten_slo(&self, ctx: &str, sid: u64, ms: f64) {
        if ms.is_nan() || ms <= 0.0 {
            return;
        }
        self.slo
            .lock()
            .unwrap()
            .entry(ctx.to_string())
            .or_default()
            .insert(sid, ms);
    }

    /// Drop every declaration session `sid` made (session end).
    pub fn release_session(&self, sid: u64) {
        let mut slo = self.slo.lock().unwrap();
        slo.retain(|_, by_session| {
            by_session.remove(&sid);
            !by_session.is_empty()
        });
    }

    /// Effective SLO for `ctx`: the tightest of the configured default
    /// and the live session-declared targets.
    pub fn effective_slo(&self, ctx: &str, configured: Option<f64>) -> Option<f64> {
        let slo = self.slo.lock().unwrap();
        let declared = slo.get(ctx).and_then(|by_session| {
            let min = by_session.values().copied().fold(f64::INFINITY, f64::min);
            min.is_finite().then_some(min)
        });
        drop(slo);
        match (configured, declared) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }
}

/// The elastic control loop: samples a [`ScaleTarget`], runs a
/// [`ScalePolicy`], executes the actions. Owns its thread; stopping
/// (or dropping) joins it.
pub struct Autoscaler {
    shared: Arc<AutoscaleShared>,
    handle: Option<JoinHandle<()>>,
}

impl Autoscaler {
    pub fn start(target: Arc<dyn ScaleTarget>, opts: AutoscaleOptions) -> Autoscaler {
        let shared = Arc::new(AutoscaleShared {
            stop: AtomicBool::new(false),
            status: Mutex::new(AutoscaleStatus {
                enabled: true,
                policy: "threshold".into(),
                ..AutoscaleStatus::default()
            }),
            slo: Mutex::new(HashMap::new()),
        });
        let handle = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("autoscale".into())
                .spawn(move || control_loop(target, opts, shared))
                .expect("spawning autoscale thread")
        };
        Autoscaler {
            shared,
            handle: Some(handle),
        }
    }

    /// The shared status handle (for the serve layer's
    /// `autoscale_status` path).
    pub fn shared(&self) -> Arc<AutoscaleShared> {
        self.shared.clone()
    }

    pub fn status(&self) -> AutoscaleStatus {
        self.shared.status()
    }

    /// Stop the loop and join its thread.
    pub fn stop(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Autoscaler {
    fn drop(&mut self) {
        self.halt();
    }
}

fn control_loop(
    target: Arc<dyn ScaleTarget>,
    opts: AutoscaleOptions,
    shared: Arc<AutoscaleShared>,
) {
    let mut policy = Threshold::new(ThresholdConfig {
        high: opts.high,
        low: opts.low,
        sustain: opts.sustain,
        cooldown: opts.cooldown,
        burst: opts.burst,
    });
    // home sizes: the partition the operator configured at startup
    let mut homes: HashMap<CtxId, usize> = HashMap::new();
    let mut last = Instant::now();
    while !shared.stop.load(Ordering::SeqCst) {
        let loads = target.loads();
        let samples: Vec<CtxSample> = loads
            .iter()
            .map(|l| {
                let home = *homes.entry(l.id).or_insert(l.workers);
                let limits = opts.limits_for(&l.name);
                CtxSample {
                    ctx: l.id,
                    name: l.name.clone(),
                    workers: l.workers,
                    queue_depth: l.queue_depth,
                    busy: l.busy,
                    queued_secs: l.queued_secs,
                    tenants: l.tenants,
                    home,
                    // the configured floor stands as declared: a floor
                    // above the current size simply means the context
                    // never donates (the loop does not grow contexts to
                    // meet floors — that is the operator's partitioning)
                    min: limits.min,
                    max: limits.max,
                    slo_ms: shared.effective_slo(&l.name, limits.slo_ms),
                }
            })
            .collect();
        let now = Instant::now();
        let dt = now.duration_since(last);
        last = now;
        let actions = policy.decide(&samples, dt);
        let mut executed: Option<String> = None;
        let mut moved = 0usize;
        for a in actions {
            let ScaleAction::Move { from, to, n } = a;
            if let Ok(k) = target.move_workers(from, to, n) {
                if k > 0 {
                    moved += k;
                    let name = |id: CtxId| {
                        samples
                            .iter()
                            .find(|s| s.ctx == id)
                            .map(|s| s.name.clone())
                            .unwrap_or_else(|| format!("ctx{id}"))
                    };
                    executed = Some(format!("moved {k} worker(s) {} -> {}", name(from), name(to)));
                }
            }
        }
        {
            let mut st = shared.status.lock().unwrap();
            if moved > 0 {
                st.moves += 1;
                st.moved_workers += moved as u64;
                st.last_action = executed;
            }
            st.contexts = samples
                .iter()
                .map(|s| CtxStatus {
                    name: s.name.clone(),
                    workers: s.workers,
                    home: s.home,
                    min: s.min,
                    max: if s.max == usize::MAX { 0 } else { s.max },
                    queue_depth: s.queue_depth,
                    slo_ms: s.slo_ms.unwrap_or(0.0),
                })
                .collect();
        }
        // sleep in small slices so stop is observed promptly
        let deadline = Instant::now() + opts.period;
        while Instant::now() < deadline && !shared.stop.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(5).min(opts.period));
        }
    }
}
