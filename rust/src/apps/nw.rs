//! Rodinia Needleman-Wunsch (global sequence alignment DP) — Fig 1d.
//! Matches `python/compile/kernels/ref.py::nw`: the (N+1)^2 score matrix
//! with penalty-initialized borders and max(diag+sub, up-p, left-p).

use std::sync::Arc;

use anyhow::Result;

use super::common::omp_threads;
use crate::taskrt::{AccessMode, Arch, Codelet, ExecBuffers};

pub const APP: &str = "nw";
/// Gap penalty baked into the artifacts (model.py NW_PENALTY).
pub const PENALTY: f32 = 10.0;

/// Sequential row-sweep DP fill. `reference` and `out` are (n1 x n1),
/// n1 = N + 1; row/col 0 of `reference` are ignored.
pub fn nw_seq(reference: &[f32], out: &mut [f32], n1: usize, penalty: f32) {
    for i in 0..n1 {
        out[i * n1] = -(i as f32) * penalty;
        out[i] = -(i as f32) * penalty;
    }
    for i in 1..n1 {
        for j in 1..n1 {
            let diag = out[(i - 1) * n1 + (j - 1)] + reference[i * n1 + j];
            let up = out[(i - 1) * n1 + j] - penalty;
            let left = out[i * n1 + (j - 1)] - penalty;
            out[i * n1 + j] = diag.max(up).max(left);
        }
    }
}

/// Anti-diagonal wavefront fill, parallel across the diagonal's cells —
/// the same decomposition as Rodinia's GPU kernel (the OpenMP variant).
pub fn nw_omp(reference: &[f32], out: &mut [f32], n1: usize, penalty: f32) {
    for i in 0..n1 {
        out[i * n1] = -(i as f32) * penalty;
        out[i] = -(i as f32) * penalty;
    }
    let threads = omp_threads();
    // out is written one anti-diagonal at a time; cells on a diagonal are
    // independent, so they can be computed from a snapshot pointer.
    for d in 2..(2 * n1 - 1) {
        let lo = 1.max(d as i64 - (n1 as i64 - 1)) as usize;
        let hi = (d - 1).min(n1 - 1);
        if lo > hi {
            continue;
        }
        let cells: Vec<usize> = (lo..=hi).collect();
        let nchunk = cells.len().div_ceil(threads).max(64);
        // Safety of the raw-pointer share: every (i, d-i) cell on this
        // diagonal is distinct, and reads only touch diagonals d-1, d-2.
        let out_ptr = out.as_mut_ptr() as usize;
        std::thread::scope(|s| {
            for chunk in cells.chunks(nchunk) {
                let chunk = chunk.to_vec();
                s.spawn(move || {
                    let out = out_ptr as *mut f32;
                    for i in chunk {
                        let j = d - i;
                        unsafe {
                            let diag = *out.add((i - 1) * n1 + (j - 1))
                                + reference[i * n1 + j];
                            let up = *out.add((i - 1) * n1 + j) - penalty;
                            let left = *out.add(i * n1 + (j - 1)) - penalty;
                            *out.add(i * n1 + j) = diag.max(up).max(left);
                        }
                    }
                });
            }
        });
    }
}

fn native(f: fn(&[f32], &mut [f32], usize, f32)) -> crate::taskrt::NativeFn {
    Arc::new(move |bufs: &ExecBuffers| -> Result<()> {
        let n1 = bufs.size + 1;
        let reference = bufs.read(0).data().to_vec();
        let mut out = bufs.write(1);
        f(&reference, out.data_mut(), n1, PENALTY);
        Ok(())
    })
}

pub fn codelet() -> Codelet {
    Codelet::new("nw", APP, vec![AccessMode::Read, AccessMode::Write])
        .with_native("omp", Arch::Cpu, native(nw_omp))
        .with_native("seq", Arch::Cpu, native(nw_seq))
        .with_artifact("cuda", Arch::Cuda, "pallas")
        .with_hint("cuda")
}

pub fn paper_variants() -> &'static [&'static str] {
    &["omp", "cuda"]
}

/// Random substitution-score matrix (integers in [-10, 10], like BLOSUM
/// lookups in Rodinia). Returned flat, (n+1)^2.
pub fn generate(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let n1 = n + 1;
    (0..n1 * n1)
        .map(|_| (rng.below(21) as f32) - 10.0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn omp_matches_seq() {
        let n = 127;
        let r = generate(8, n);
        let n1 = n + 1;
        let mut o1 = vec![0.0; n1 * n1];
        let mut o2 = vec![0.0; n1 * n1];
        nw_seq(&r, &mut o1, n1, PENALTY);
        nw_omp(&r, &mut o2, n1, PENALTY);
        assert_eq!(o1, o2);
    }

    #[test]
    fn borders_are_gap_penalties() {
        let n = 16;
        let r = generate(9, n);
        let n1 = n + 1;
        let mut o = vec![0.0; n1 * n1];
        nw_seq(&r, &mut o, n1, PENALTY);
        for i in 0..n1 {
            assert_eq!(o[i * n1], -(i as f32) * PENALTY);
            assert_eq!(o[i], -(i as f32) * PENALTY);
        }
    }

    #[test]
    fn known_small_case() {
        // 1x1 alignment: M[1][1] = max(0 + sub, -p - p twice)
        let n1 = 2;
        let mut r = vec![0.0; 4];
        r[3] = 5.0; // sub score at (1,1)
        let mut o = vec![0.0; 4];
        nw_seq(&r, &mut o, n1, 10.0);
        assert_eq!(o[3], 5.0);
        r[3] = -50.0;
        nw_seq(&r, &mut o, n1, 10.0);
        assert_eq!(o[3], -20.0); // two gaps beat the bad substitution
    }
}
