//! Rodinia LUD (LU decomposition, no pivoting, packed form) — Fig 1c.
//! Matches `python/compile/kernels/ref.py::lud`: U on/above the diagonal,
//! unit-lower L (without the 1s) below.

use std::sync::Arc;

use anyhow::Result;

use super::common::{omp_threads, par_chunks_mut};
use crate::taskrt::{AccessMode, Arch, Codelet, ExecBuffers};

pub const APP: &str = "lud";

/// Sequential right-looking Doolittle LU, in place.
pub fn lud_seq(m: &mut [f32], n: usize) {
    for k in 0..n {
        let pivot = m[k * n + k];
        for i in (k + 1)..n {
            m[i * n + k] /= pivot;
        }
        for i in (k + 1)..n {
            let lik = m[i * n + k];
            let (urow, irow) = {
                // split borrows: row k (read) and row i (write)
                let (a, b) = m.split_at_mut(i * n);
                (&a[k * n..k * n + n], &mut b[..n])
            };
            for j in (k + 1)..n {
                irow[j] -= lik * urow[j];
            }
        }
    }
}

/// Parallel LU: the trailing update of each panel step is row-parallel
/// (the dominant O(n^3) part), the panel scaling stays sequential.
pub fn lud_omp(m: &mut [f32], n: usize) {
    let threads = omp_threads();
    for k in 0..n {
        let pivot = m[k * n + k];
        for i in (k + 1)..n {
            m[i * n + k] /= pivot;
        }
        if k + 1 >= n {
            break;
        }
        let urow: Vec<f32> = m[k * n..k * n + n].to_vec();
        let lcol: Vec<f32> = ((k + 1)..n).map(|i| m[i * n + k]).collect();
        let tail = &mut m[(k + 1) * n..];
        par_chunks_mut(tail, n, threads, |off, rows| {
            let r0 = off / n;
            for (lr, row) in rows.chunks_mut(n).enumerate() {
                let lik = lcol[r0 + lr];
                for j in (k + 1)..n {
                    row[j] -= lik * urow[j];
                }
            }
        });
    }
}

fn native(f: fn(&mut [f32], usize)) -> crate::taskrt::NativeFn {
    Arc::new(move |bufs: &ExecBuffers| -> Result<()> {
        let n = bufs.size;
        let mut m = bufs.write(0);
        f(m.data_mut(), n);
        Ok(())
    })
}

pub fn codelet() -> Codelet {
    Codelet::new("lud", APP, vec![AccessMode::ReadWrite])
        .with_native("omp", Arch::Cpu, native(lud_omp))
        .with_native("seq", Arch::Cpu, native(lud_seq))
        .with_artifact("cuda", Arch::Cuda, "pallas")
        .with_hint("cuda")
}

pub fn paper_variants() -> &'static [&'static str] {
    &["omp", "cuda"]
}

/// Diagonally-dominant instance (safe without pivoting), like ref.py.
pub fn generate(seed: u64, n: usize) -> Vec<f32> {
    let mut m = crate::util::rng::Rng::new(seed).vec_f32(n * n, -1.0, 1.0);
    for i in 0..n {
        m[i * n + i] += n as f32;
    }
    m
}

/// Reconstruct A from the packed LU and return max |A - LU|.
pub fn residual(packed: &[f32], original: &[f32], n: usize) -> f32 {
    let mut max = 0.0f32;
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0f64;
            let kmax = i.min(j);
            // L has unit diagonal: A[i][j] = sum_{k<min(i,j)} L[i][k] U[k][j] (+ U[i][j] if i<=j ...)
            for k in 0..kmax {
                s += packed[i * n + k] as f64 * packed[k * n + j] as f64;
            }
            if i <= j {
                s += packed[i * n + j] as f64; // L[i][i] = 1 times U[i][j]
            } else {
                s += packed[i * n + j] as f64 * packed[j * n + j] as f64; // L[i][j] * U[j][j]
            }
            max = max.max((s as f32 - original[i * n + j]).abs());
        }
    }
    max
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seq_factorization_reconstructs() {
        let n = 48;
        let a = generate(3, n);
        let mut m = a.clone();
        lud_seq(&mut m, n);
        assert!(residual(&m, &a, n) < 1e-2, "residual too large");
    }

    #[test]
    fn omp_matches_seq() {
        let n = 64;
        let a = generate(4, n);
        let mut m1 = a.clone();
        let mut m2 = a;
        lud_seq(&mut m1, n);
        lud_omp(&mut m2, n);
        for (x, y) in m1.iter().zip(&m2) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn identity_is_fixed_point() {
        let n = 8;
        let mut m = vec![0.0f32; n * n];
        for i in 0..n {
            m[i * n + i] = 1.0;
        }
        let want = m.clone();
        lud_seq(&mut m, n);
        assert_eq!(m, want);
    }
}
