//! Rodinia hotspot (2D thermal simulation) — Fig 1a.
//!
//! Native variants implement exactly the stencil of
//! `python/compile/kernels/ref.py::hotspot` (Rodinia coefficients, edge
//! clamp, f32) so artifact and native results agree to float tolerance.
//! The CUDA variant is the Pallas-banded artifact; OpenMP is the
//! row-parallel native loop.

use std::sync::Arc;

use anyhow::Result;

use super::common::{omp_threads, par_chunks_mut};
use crate::taskrt::{AccessMode, Arch, Codelet, ExecBuffers};

pub const APP: &str = "hotspot";
pub const AMB_TEMP: f32 = 80.0;
/// Iterations baked into the artifacts (model.py HOTSPOT_STEPS).
pub const STEPS: usize = 8;

/// Rodinia hotspot coefficients for an n x n grid (matches ref.py).
#[derive(Debug, Clone, Copy)]
pub struct Coeffs {
    pub step_div_cap: f32,
    pub rx1: f32,
    pub ry1: f32,
    pub rz1: f32,
}

pub fn coeffs(n: usize) -> Coeffs {
    let t_chip = 0.0005f64;
    let chip_height = 0.016f64;
    let chip_width = 0.016f64;
    let k_si = 100.0f64;
    let cap_factor = 0.5f64;
    let precision = 0.001f64;
    let max_pd = 3.0e6f64;
    let spec_heat_si = 1.75e6f64;

    let nf = n as f64;
    let grid_height = chip_height / nf;
    let grid_width = chip_width / nf;
    let cap = cap_factor * spec_heat_si * t_chip * grid_width * grid_height;
    let rx = grid_width / (2.0 * k_si * t_chip * grid_height);
    let ry = grid_height / (2.0 * k_si * t_chip * grid_width);
    let rz = t_chip / (k_si * grid_height * grid_width);
    let max_slope = max_pd / (spec_heat_si * t_chip);
    let step = precision / max_slope;
    Coeffs {
        step_div_cap: (step / cap) as f32,
        rx1: (1.0 / rx) as f32,
        ry1: (1.0 / ry) as f32,
        rz1: (1.0 / rz) as f32,
    }
}

#[inline]
fn stencil_row(
    out_row: &mut [f32],
    up: &[f32],
    center: &[f32],
    down: &[f32],
    power: &[f32],
    c: &Coeffs,
    n: usize,
) {
    for j in 0..n {
        let left = center[j.saturating_sub(1)];
        let right = center[(j + 1).min(n - 1)];
        let t = center[j];
        let delta = c.step_div_cap
            * (power[j]
                + (down[j] + up[j] - 2.0 * t) * c.ry1
                + (right + left - 2.0 * t) * c.rx1
                + (AMB_TEMP - t) * c.rz1);
        out_row[j] = t + delta;
    }
}

/// One Euler step, sequential.
pub fn step_seq(temp: &[f32], power: &[f32], out: &mut [f32], n: usize, c: &Coeffs) {
    for i in 0..n {
        let up = &temp[i.saturating_sub(1) * n..][..n];
        let down = &temp[(i + 1).min(n - 1) * n..][..n];
        let center = &temp[i * n..][..n];
        stencil_row(&mut out[i * n..i * n + n], up, center, down, &power[i * n..i * n + n], c, n);
    }
}

/// One Euler step, row-parallel (the OpenMP variant).
pub fn step_omp(temp: &[f32], power: &[f32], out: &mut [f32], n: usize, c: &Coeffs) {
    let threads = omp_threads();
    par_chunks_mut(out, n, threads, |off, rows| {
        let i0 = off / n;
        for (li, row) in rows.chunks_mut(n).enumerate() {
            let i = i0 + li;
            let up = &temp[i.saturating_sub(1) * n..][..n];
            let down = &temp[(i + 1).min(n - 1) * n..][..n];
            let center = &temp[i * n..][..n];
            stencil_row(row, up, center, down, &power[i * n..i * n + n], c, n);
        }
    });
}

/// Run `steps` iterations in place on `temp`.
pub fn simulate(
    temp: &mut Vec<f32>,
    power: &[f32],
    n: usize,
    steps: usize,
    step: fn(&[f32], &[f32], &mut [f32], usize, &Coeffs),
) {
    let c = coeffs(n);
    let mut next = vec![0.0f32; n * n];
    for _ in 0..steps {
        step(temp, power, &mut next, n, &c);
        std::mem::swap(temp, &mut next);
    }
}

fn native(step: fn(&[f32], &[f32], &mut [f32], usize, &Coeffs)) -> crate::taskrt::NativeFn {
    Arc::new(move |bufs: &ExecBuffers| -> Result<()> {
        let n = bufs.size;
        let power = bufs.read(1).data().to_vec();
        let mut t = bufs.write(0);
        let mut temp = t.data().to_vec();
        simulate(&mut temp, &power, n, STEPS, step);
        t.data_mut().copy_from_slice(&temp);
        Ok(())
    })
}

/// The `hotspot` codelet: OMP (cpu) + CUDA (Pallas artifact), plus a
/// sequential CPU variant for ablations.
pub fn codelet() -> Codelet {
    Codelet::new("hotspot", APP, vec![AccessMode::ReadWrite, AccessMode::Read])
        .with_native("omp", Arch::Cpu, native(step_omp))
        .with_native("seq", Arch::Cpu, native(step_seq))
        .with_artifact("cuda", Arch::Cuda, "pallas")
        .with_hint("cuda")
}

pub fn paper_variants() -> &'static [&'static str] {
    &["omp", "cuda"]
}

/// Deterministic problem instance: (temp, power) grids like Rodinia's.
pub fn generate(seed: u64, n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = crate::util::rng::Rng::new(seed);
    let temp = rng.vec_f32(n * n, AMB_TEMP - 5.0, AMB_TEMP + 5.0);
    let power = rng.vec_f32(n * n, 0.0, 1.0);
    (temp, power)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn omp_matches_seq() {
        let n = 64;
        let (mut t1, p) = generate(11, n);
        let mut t2 = t1.clone();
        simulate(&mut t1, &p, n, STEPS, step_seq);
        simulate(&mut t2, &p, n, STEPS, step_omp);
        assert_eq!(t1, t2);
    }

    #[test]
    fn heat_stays_bounded() {
        let n = 32;
        let (mut t, p) = generate(12, n);
        simulate(&mut t, &p, n, STEPS, step_seq);
        for &x in &t {
            assert!(x.is_finite() && (0.0..400.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn coeffs_scale_with_grid() {
        // finer grid -> smaller cells -> larger rz1 coupling to ambient
        let a = coeffs(64);
        let b = coeffs(128);
        assert!(b.rz1 < a.rz1);
        assert!(a.step_div_cap > 0.0 && b.step_div_cap > 0.0);
    }

    #[test]
    fn codelet_variant_set() {
        let c = codelet();
        assert!(c.impl_by_name("omp").is_some());
        assert!(c.impl_by_name("cuda").is_some());
        assert_eq!(c.modes.len(), 2);
    }
}
