//! Rodinia hotspot3D (7-point stencil thermal simulation) — Fig 1b.
//! Mirrors `python/compile/kernels/ref.py::hotspot3d` exactly.

use std::sync::Arc;

use anyhow::Result;

use super::common::{omp_threads, par_chunks_mut};
use crate::taskrt::{AccessMode, Arch, Codelet, ExecBuffers};

pub const APP: &str = "hotspot3d";
pub const AMB_TEMP: f32 = 80.0;
pub const STEPS: usize = 8;
/// Z layers baked into the artifacts (model.py HOTSPOT3D_LAYERS).
pub const LAYERS: usize = 8;

#[derive(Debug, Clone, Copy)]
pub struct Coeffs {
    pub cc: f32,
    pub cw: f32,
    pub ce: f32,
    pub cn: f32,
    pub cs: f32,
    pub ct: f32,
    pub cb: f32,
    pub step_div_cap: f32,
}

/// Rodinia 3D.c coefficient set for an (nz, ny, nx) grid.
pub fn coeffs(nx: usize, ny: usize, nz: usize) -> Coeffs {
    let t_chip = 0.0005f64;
    let chip_height = 0.016f64;
    let chip_width = 0.016f64;
    let k_si = 100.0f64;
    let cap_factor = 0.5f64;
    let precision = 0.001f64;
    let max_pd = 3.0e6f64;
    let spec_heat_si = 1.75e6f64;

    let dx = chip_height / nx as f64;
    let dy = chip_width / ny as f64;
    let dz = t_chip / nz as f64;
    let cap = cap_factor * spec_heat_si * t_chip * dx * dy;
    let rx = dy / (2.0 * k_si * t_chip * dx);
    let ry = dx / (2.0 * k_si * t_chip * dy);
    let rz = dz / (k_si * dx * dy);
    let max_slope = max_pd / (spec_heat_si * t_chip);
    let dt = precision / max_slope;
    let step_div_cap = dt / cap;
    let ce = step_div_cap / rx;
    let cn = step_div_cap / ry;
    let ct = step_div_cap / rz;
    let cc = 1.0 - (2.0 * ce + 2.0 * cn + 3.0 * ct);
    Coeffs {
        cc: cc as f32,
        cw: ce as f32,
        ce: ce as f32,
        cn: cn as f32,
        cs: cn as f32,
        ct: ct as f32,
        cb: ct as f32,
        step_div_cap: step_div_cap as f32,
    }
}

/// One step over the (nz, ny, nx) row-major grid, writing `out`.
/// Parallelized over z-planes when `threads > 1`.
pub fn step(
    temp: &[f32],
    power: &[f32],
    out: &mut [f32],
    (nz, ny, nx): (usize, usize, usize),
    c: &Coeffs,
    threads: usize,
) {
    let plane = ny * nx;
    par_chunks_mut(out, plane, threads, |off, planes| {
        let z0 = off / plane;
        for (lz, out_plane) in planes.chunks_mut(plane).enumerate() {
            let z = z0 + lz;
            let below = &temp[z.saturating_sub(1) * plane..][..plane];
            let above = &temp[(z + 1).min(nz - 1) * plane..][..plane];
            let cur = &temp[z * plane..][..plane];
            let pw = &power[z * plane..][..plane];
            for y in 0..ny {
                for x in 0..nx {
                    let i = y * nx + x;
                    let w = cur[y * nx + x.saturating_sub(1)];
                    let e = cur[y * nx + (x + 1).min(nx - 1)];
                    let n_ = cur[y.saturating_sub(1) * nx + x];
                    let s = cur[(y + 1).min(ny - 1) * nx + x];
                    out_plane[i] = c.cc * cur[i]
                        + c.cw * w
                        + c.ce * e
                        + c.cn * n_
                        + c.cs * s
                        + c.cb * below[i]
                        + c.ct * above[i]
                        + c.step_div_cap * pw[i]
                        + c.ct * AMB_TEMP;
                }
            }
        }
    });
}

/// Run `steps` iterations in place.
pub fn simulate(
    temp: &mut Vec<f32>,
    power: &[f32],
    dims: (usize, usize, usize),
    steps: usize,
    threads: usize,
) {
    let c = coeffs(dims.2, dims.1, dims.0);
    let mut next = vec![0.0f32; temp.len()];
    for _ in 0..steps {
        step(temp, power, &mut next, dims, &c, threads);
        std::mem::swap(temp, &mut next);
    }
}

fn native(threads_fn: fn() -> usize) -> crate::taskrt::NativeFn {
    Arc::new(move |bufs: &ExecBuffers| -> Result<()> {
        let n = bufs.size;
        let dims = (LAYERS, n, n);
        let power = bufs.read(1).data().to_vec();
        let mut t = bufs.write(0);
        let mut temp = t.data().to_vec();
        simulate(&mut temp, &power, dims, STEPS, threads_fn());
        t.data_mut().copy_from_slice(&temp);
        Ok(())
    })
}

pub fn codelet() -> Codelet {
    Codelet::new(
        "hotspot3d",
        APP,
        vec![AccessMode::ReadWrite, AccessMode::Read],
    )
    .with_native("omp", Arch::Cpu, native(omp_threads))
    .with_native("seq", Arch::Cpu, native(|| 1))
    .with_artifact("cuda", Arch::Cuda, "pallas")
    .with_hint("cuda")
}

pub fn paper_variants() -> &'static [&'static str] {
    &["omp", "cuda"]
}

/// Deterministic (temp, power) instance with `LAYERS` z-planes.
pub fn generate(seed: u64, n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = crate::util::rng::Rng::new(seed);
    let len = LAYERS * n * n;
    let temp = rng.vec_f32(len, AMB_TEMP - 5.0, AMB_TEMP + 5.0);
    let power = rng.vec_f32(len, 0.0, 1.0);
    (temp, power)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_matches_sequential() {
        let n = 24;
        let (mut t1, p) = generate(5, n);
        let mut t2 = t1.clone();
        simulate(&mut t1, &p, (LAYERS, n, n), STEPS, 1);
        simulate(&mut t2, &p, (LAYERS, n, n), STEPS, 4);
        assert_eq!(t1, t2);
    }

    #[test]
    fn coefficients_sum_near_one() {
        // cc + 2ce + 2cn + cb + ct == 1 - ct (energy balance with ambient)
        let c = coeffs(64, 64, 8);
        let sum = c.cc + c.cw + c.ce + c.cn + c.cs + c.cb + c.ct;
        assert!((sum - (1.0 - c.ct)).abs() < 1e-3, "sum {sum} ct {}", c.ct);
    }

    #[test]
    fn stays_finite() {
        let n = 16;
        let (mut t, p) = generate(6, n);
        simulate(&mut t, &p, (LAYERS, n, n), STEPS, 2);
        assert!(t.iter().all(|x| x.is_finite()));
    }
}
