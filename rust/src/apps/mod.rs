//! The paper's benchmark applications (Table 2), each exposing multiple
//! implementation variants through one codelet:
//!
//! | app       | variants (paper)            | input parameter        |
//! |-----------|-----------------------------|------------------------|
//! | hotspot   | CUDA, OMP                   | squared grid size      |
//! | hotspot3d | CUDA, OMP                   | rows/cols (x 8 layers) |
//! | lud       | CUDA, OMP                   | squared matrix size    |
//! | nw        | CUDA, OMP                   | max rows/cols          |
//! | matmul    | BLAS, OMP, CUDA, CUBLAS     | squared matrix size    |
//! | sort      | CUDA, OMP (Listing 1.3)     | vector length          |
//!
//! Every app provides: deterministic generators, native Rust variants
//! (bit-reproducible parallel vs sequential), the codelet wiring, and a
//! [`run_once`] driver that registers data, submits one task, waits, and
//! verifies the result against the native sequential reference.

pub mod common;
pub mod hotspot;
pub mod hotspot3d;
pub mod lud;
pub mod matmul;
pub mod nw;
pub mod sort;

use anyhow::{anyhow, bail, Result};

use crate::runtime::Tensor;
use crate::taskrt::{Codelet, HandleId, Runtime, TaskSpec};

/// All benchmark app names, in the paper's Table 2 order.
pub const ALL: &[&str] = &["hotspot", "hotspot3d", "lud", "nw", "matmul", "sort"];

/// Apps whose codelet is idempotent over its handles (output depends
/// only on the read-only inputs, or re-running is a fixed point). Only
/// these support verified task *chains* in the serving layer — the
/// stencils and lud transform their input in place, so running the
/// codelet k times computes something different from one application.
pub const IDEMPOTENT: &[&str] = &["matmul", "nw", "sort"];

/// Whether `app`'s codelet can be re-applied without changing the result.
pub fn idempotent(app: &str) -> bool {
    IDEMPOTENT.contains(&app)
}

/// Build the codelet for an app by name.
pub fn codelet(app: &str) -> Result<Codelet> {
    Ok(match app {
        "hotspot" => hotspot::codelet(),
        "hotspot3d" => hotspot3d::codelet(),
        "lud" => lud::codelet(),
        "nw" => nw::codelet(),
        "matmul" => matmul::codelet(),
        "sort" => sort::codelet(),
        _ => bail!("unknown app '{app}' (expected one of {ALL:?})"),
    })
}

/// Variant names the paper's figures sweep for an app.
pub fn paper_variants(app: &str) -> &'static [&'static str] {
    match app {
        "hotspot" => hotspot::paper_variants(),
        "hotspot3d" => hotspot3d::paper_variants(),
        "lud" => lud::paper_variants(),
        "nw" => nw::paper_variants(),
        "matmul" => matmul::paper_variants(),
        "sort" => sort::paper_variants(),
        _ => &[],
    }
}

/// Paper Table 2 input ranges (sweep grids for Fig 1).
pub fn paper_sizes(app: &str) -> Vec<usize> {
    match app {
        "hotspot" => vec![64, 128, 256, 512, 1024, 2048, 4096, 8192],
        "hotspot3d" => vec![64, 128, 256, 512],
        "lud" => vec![64, 128, 256, 512, 1024, 2048, 4096, 8192],
        "nw" => vec![64, 128, 256, 512, 1024, 2048, 4096, 8192],
        "matmul" => vec![8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192],
        "sort" => vec![256, 1024, 4096, 16384, 65536],
        _ => vec![],
    }
}

/// One prepared problem instance: registered handles + enough context to
/// verify the output.
pub struct Instance {
    pub handles: Vec<HandleId>,
    pub size: usize,
    app: String,
    seed: u64,
}

/// Register a fresh problem instance for (app, size) in the runtime.
pub fn prepare(rt: &Runtime, app: &str, size: usize, seed: u64) -> Result<Instance> {
    let handles = match app {
        "hotspot" => {
            let (t, p) = hotspot::generate(seed, size);
            vec![
                rt.register_data(Tensor::matrix(size, size, t)),
                rt.register_data(Tensor::matrix(size, size, p)),
            ]
        }
        "hotspot3d" => {
            let (t, p) = hotspot3d::generate(seed, size);
            let shape = vec![hotspot3d::LAYERS, size, size];
            vec![
                rt.register_data(Tensor::new(shape.clone(), t)),
                rt.register_data(Tensor::new(shape, p)),
            ]
        }
        "lud" => {
            let m = lud::generate(seed, size);
            vec![rt.register_data(Tensor::matrix(size, size, m))]
        }
        "nw" => {
            let r = nw::generate(seed, size);
            let n1 = size + 1;
            vec![
                rt.register_data(Tensor::matrix(n1, n1, r)),
                rt.register_data(Tensor::zeros(vec![n1, n1])),
            ]
        }
        "matmul" => {
            let a = common::gen_matrix(seed, size, -1.0, 1.0);
            let b = common::gen_matrix(seed ^ 0xb, size, -1.0, 1.0);
            vec![
                rt.register_data(Tensor::matrix(size, size, a)),
                rt.register_data(Tensor::matrix(size, size, b)),
                rt.register_data(Tensor::zeros(vec![size, size])),
            ]
        }
        "sort" => {
            let v = sort::generate(seed, size);
            vec![rt.register_data(Tensor::vector(v))]
        }
        _ => bail!("unknown app '{app}'"),
    };
    Ok(Instance {
        handles,
        size,
        app: app.to_string(),
        seed,
    })
}

/// Compute the expected output with the native sequential variant.
pub fn expected(inst: &Instance) -> Result<Tensor> {
    let (app, size, seed) = (inst.app.as_str(), inst.size, inst.seed);
    Ok(match app {
        "hotspot" => {
            let (mut t, p) = hotspot::generate(seed, size);
            hotspot::simulate(&mut t, &p, size, hotspot::STEPS, hotspot::step_seq);
            Tensor::matrix(size, size, t)
        }
        "hotspot3d" => {
            let (mut t, p) = hotspot3d::generate(seed, size);
            hotspot3d::simulate(
                &mut t,
                &p,
                (hotspot3d::LAYERS, size, size),
                hotspot3d::STEPS,
                1,
            );
            Tensor::new(vec![hotspot3d::LAYERS, size, size], t)
        }
        "lud" => {
            let mut m = lud::generate(seed, size);
            lud::lud_seq(&mut m, size);
            Tensor::matrix(size, size, m)
        }
        "nw" => {
            let r = nw::generate(seed, size);
            let n1 = size + 1;
            let mut o = vec![0.0; n1 * n1];
            nw::nw_seq(&r, &mut o, n1, nw::PENALTY);
            Tensor::matrix(n1, n1, o)
        }
        "matmul" => {
            let a = common::gen_matrix(seed, size, -1.0, 1.0);
            let b = common::gen_matrix(seed ^ 0xb, size, -1.0, 1.0);
            let mut c = vec![0.0; size * size];
            matmul::matmul_seq(&a, &b, &mut c, size);
            Tensor::matrix(size, size, c)
        }
        "sort" => {
            let mut v = sort::generate(seed, size);
            sort::sort_seq(&mut v);
            Tensor::vector(v)
        }
        _ => bail!("unknown app '{app}'"),
    })
}

/// The handle that carries the app's result.
pub fn output_handle(inst: &Instance) -> HandleId {
    match inst.app.as_str() {
        "nw" => inst.handles[1],
        "matmul" => inst.handles[2],
        _ => inst.handles[0],
    }
}

/// Relative-L2 verification tolerance per app (iterated stencils and
/// O(n^3) accumulations tolerate more float reassociation).
pub fn tolerance(app: &str) -> f32 {
    match app {
        "matmul" | "lud" => 5e-3,
        _ => 1e-3,
    }
}

/// Result of one driven task.
pub struct AppRun {
    pub task: crate::taskrt::TaskId,
    pub variant: String,
    pub modeled: f64,
    pub wall: f64,
    pub rel_err: f32,
}

/// Submit one task on a fresh instance, wait, verify, and report which
/// variant the runtime selected.
pub fn run_once(
    rt: &Runtime,
    app: &str,
    size: usize,
    seed: u64,
    force_variant: Option<&str>,
    verify: bool,
) -> Result<AppRun> {
    let name = app_codelet_name(app).to_string();
    let cl = match rt.codelet(&name) {
        Some(c) => c,
        None => rt.register_codelet(codelet(app)?),
    };
    let inst = prepare(rt, app, size, seed)?;
    let mut spec = TaskSpec::new(cl, inst.handles.clone(), size);
    if let Some(v) = force_variant {
        spec = spec.with_variant(v);
    }
    let task = rt.submit(spec)?;
    rt.wait_all()?;

    let result = rt
        .metrics()
        .results()
        .into_iter()
        .rev()
        .find(|r| r.task == task)
        .ok_or_else(|| anyhow!("no result recorded for task {task}"))?;

    let rel_err = if verify {
        let got = rt.snapshot(output_handle(&inst))?;
        let want = expected(&inst)?;
        let err = got.rel_l2_error(&want);
        if err > tolerance(app) {
            bail!(
                "{app} size {size} variant {}: rel L2 error {err} exceeds {}",
                result.variant,
                tolerance(app)
            );
        }
        err
    } else {
        0.0
    };

    Ok(AppRun {
        task,
        variant: result.variant.clone(),
        modeled: result.modeled_total(),
        wall: result.wall,
        rel_err,
    })
}

/// Codelet name for an app (hotspot -> "hotspot", matmul -> "mmul", ...).
pub fn app_codelet_name(app: &str) -> &str {
    match app {
        "matmul" => "mmul",
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_apps_have_codelets_and_sizes() {
        for app in ALL {
            let c = codelet(app).unwrap();
            assert!(!c.impls.is_empty(), "{app} has no variants");
            assert!(!paper_sizes(app).is_empty());
            assert!(!paper_variants(app).is_empty());
        }
    }

    #[test]
    fn unknown_app_is_error() {
        assert!(codelet("bfs").is_err());
    }

    #[test]
    fn codelet_names() {
        assert_eq!(app_codelet_name("matmul"), "mmul");
        assert_eq!(app_codelet_name("nw"), "nw");
    }
}
