//! The paper's benchmark applications (Table 2), each exposing multiple
//! implementation variants through one codelet:
//!
//! | app       | variants (paper)            | input parameter        |
//! |-----------|-----------------------------|------------------------|
//! | hotspot   | CUDA, OMP                   | squared grid size      |
//! | hotspot3d | CUDA, OMP                   | rows/cols (x 8 layers) |
//! | lud       | CUDA, OMP                   | squared matrix size    |
//! | nw        | CUDA, OMP                   | max rows/cols          |
//! | matmul    | BLAS, OMP, CUDA, CUBLAS     | squared matrix size    |
//! | sort      | CUDA, OMP (Listing 1.3)     | vector length          |
//!
//! Every app provides: deterministic generators, native Rust variants
//! (bit-reproducible parallel vs sequential), the codelet wiring, and a
//! [`run_once`] driver that registers data, submits one task, waits, and
//! verifies the result against the native sequential reference.

pub mod common;
pub mod hotspot;
pub mod hotspot3d;
pub mod lud;
pub mod matmul;
pub mod nw;
pub mod sort;

use anyhow::{anyhow, bail, Result};

use crate::runtime::Tensor;
use crate::taskrt::{Codelet, HandleId, Runtime, TaskSpec};

/// All benchmark app names, in the paper's Table 2 order.
pub const ALL: &[&str] = &["hotspot", "hotspot3d", "lud", "nw", "matmul", "sort"];

/// Apps whose codelet is idempotent over its handles (output depends
/// only on the read-only inputs, or re-running is a fixed point). Only
/// these support verified task *chains* in the serving layer — the
/// stencils and lud transform their input in place, so running the
/// codelet k times computes something different from one application.
pub const IDEMPOTENT: &[&str] = &["matmul", "nw", "sort"];

/// Whether `app`'s codelet can be re-applied without changing the result.
pub fn idempotent(app: &str) -> bool {
    IDEMPOTENT.contains(&app)
}

/// Build the codelet for an app by name.
pub fn codelet(app: &str) -> Result<Codelet> {
    Ok(match app {
        "hotspot" => hotspot::codelet(),
        "hotspot3d" => hotspot3d::codelet(),
        "lud" => lud::codelet(),
        "nw" => nw::codelet(),
        "matmul" => matmul::codelet(),
        "sort" => sort::codelet(),
        _ => bail!("unknown app '{app}' (expected one of {ALL:?})"),
    })
}

/// Variant names the paper's figures sweep for an app.
pub fn paper_variants(app: &str) -> &'static [&'static str] {
    match app {
        "hotspot" => hotspot::paper_variants(),
        "hotspot3d" => hotspot3d::paper_variants(),
        "lud" => lud::paper_variants(),
        "nw" => nw::paper_variants(),
        "matmul" => matmul::paper_variants(),
        "sort" => sort::paper_variants(),
        _ => &[],
    }
}

/// Paper Table 2 input ranges (sweep grids for Fig 1).
pub fn paper_sizes(app: &str) -> Vec<usize> {
    match app {
        "hotspot" => vec![64, 128, 256, 512, 1024, 2048, 4096, 8192],
        "hotspot3d" => vec![64, 128, 256, 512],
        "lud" => vec![64, 128, 256, 512, 1024, 2048, 4096, 8192],
        "nw" => vec![64, 128, 256, 512, 1024, 2048, 4096, 8192],
        "matmul" => vec![8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192],
        "sort" => vec![256, 1024, 4096, 16384, 65536],
        _ => vec![],
    }
}

/// One prepared problem instance: registered handles + enough context to
/// verify the output.
pub struct Instance {
    pub handles: Vec<HandleId>,
    pub size: usize,
    /// Which handles this instance registered itself (and whose cleanup
    /// it is responsible for). Handles shared with other identical
    /// instances (zero-copy batching) or donated to a batch group are
    /// not owned.
    owned: Vec<bool>,
    app: String,
    seed: u64,
}

impl Instance {
    /// The handles this instance must unregister when it is done.
    pub fn owned_handles(&self) -> Vec<HandleId> {
        self.handles
            .iter()
            .zip(&self.owned)
            .filter(|(_, &o)| o)
            .map(|(h, _)| *h)
            .collect()
    }

    /// Transfer ownership of the handles at `idx` to the caller —
    /// zero-copy batching: the batch group frees the shared read-only
    /// inputs only after every rider has completed. Returns the
    /// (index, handle) pairs now owned by the caller.
    pub fn donate_handles(&mut self, idx: &[usize]) -> Vec<(usize, HandleId)> {
        idx.iter()
            .filter(|&&i| i < self.handles.len())
            .map(|&i| {
                self.owned[i] = false;
                (i, self.handles[i])
            })
            .collect()
    }
}

/// Indices of `app`'s handles that its codelet only ever reads, so
/// identical (app, size, seed) instances may share one registration
/// (zero-copy batching in the serve layer). Apps whose kernels update
/// their input in place (the stencils, lud, sort) share nothing.
pub fn shared_input_indices(app: &str) -> &'static [usize] {
    match app {
        // a and b are Read-mode; only c is written
        "matmul" => &[0, 1],
        // the reference matrix is Read-mode; the score matrix is written
        "nw" => &[0],
        _ => &[],
    }
}

/// The tensors backing a fresh (app, size, seed) problem instance, in
/// handle order. Indices in `skip` come back as `None` without paying
/// for data generation — the zero-copy path reuses a donor's handle
/// there, so generating the tensor would be pure waste. Only the
/// shareable apps consult `skip` (the others are never shared).
fn instance_tensors(
    app: &str,
    size: usize,
    seed: u64,
    skip: &[usize],
) -> Result<Vec<Option<Tensor>>> {
    let want = |i: usize| !skip.contains(&i);
    Ok(match app {
        "hotspot" => {
            let (t, p) = hotspot::generate(seed, size);
            vec![
                Some(Tensor::matrix(size, size, t)),
                Some(Tensor::matrix(size, size, p)),
            ]
        }
        "hotspot3d" => {
            let (t, p) = hotspot3d::generate(seed, size);
            let shape = vec![hotspot3d::LAYERS, size, size];
            vec![
                Some(Tensor::new(shape.clone(), t)),
                Some(Tensor::new(shape, p)),
            ]
        }
        "lud" => {
            let m = lud::generate(seed, size);
            vec![Some(Tensor::matrix(size, size, m))]
        }
        "nw" => {
            let n1 = size + 1;
            let r = want(0).then(|| Tensor::matrix(n1, n1, nw::generate(seed, size)));
            vec![r, Some(Tensor::zeros(vec![n1, n1]))]
        }
        "matmul" => {
            let a = want(0)
                .then(|| Tensor::matrix(size, size, common::gen_matrix(seed, size, -1.0, 1.0)));
            let b = want(1).then(|| {
                Tensor::matrix(size, size, common::gen_matrix(seed ^ 0xb, size, -1.0, 1.0))
            });
            vec![a, b, Some(Tensor::zeros(vec![size, size]))]
        }
        "sort" => {
            let v = sort::generate(seed, size);
            vec![Some(Tensor::vector(v))]
        }
        _ => bail!("unknown app '{app}'"),
    })
}

/// Register a fresh problem instance for (app, size, seed) in the
/// runtime.
pub fn prepare(rt: &Runtime, app: &str, size: usize, seed: u64) -> Result<Instance> {
    prepare_with_inputs(rt, app, size, seed, &[])
}

/// Like [`prepare`], but reuse already-registered handles for the given
/// (index, handle) pairs instead of registering fresh copies — the
/// zero-copy batching path for identical (app, size, seed) requests.
/// Only indices from [`shared_input_indices`] are safe to share. Shared
/// handles are not owned by the returned instance (the donor group
/// frees them).
pub fn prepare_with_inputs(
    rt: &Runtime,
    app: &str,
    size: usize,
    seed: u64,
    shared: &[(usize, HandleId)],
) -> Result<Instance> {
    let skip: Vec<usize> = shared.iter().map(|(i, _)| *i).collect();
    let tensors = instance_tensors(app, size, seed, &skip)?;
    let mut handles = Vec::with_capacity(tensors.len());
    let mut owned = Vec::with_capacity(tensors.len());
    for (i, t) in tensors.into_iter().enumerate() {
        match shared.iter().copied().find(|&(j, _)| j == i) {
            Some((_, h)) => {
                handles.push(h);
                owned.push(false);
            }
            None => {
                let t = t.ok_or_else(|| {
                    anyhow!("internal: handle {i} of '{app}' not generated and not shared")
                })?;
                handles.push(rt.register_data(t));
                owned.push(true);
            }
        }
    }
    Ok(Instance {
        handles,
        size,
        owned,
        app: app.to_string(),
        seed,
    })
}

/// Compute the expected output with the native sequential variant.
pub fn expected(inst: &Instance) -> Result<Tensor> {
    let (app, size, seed) = (inst.app.as_str(), inst.size, inst.seed);
    Ok(match app {
        "hotspot" => {
            let (mut t, p) = hotspot::generate(seed, size);
            hotspot::simulate(&mut t, &p, size, hotspot::STEPS, hotspot::step_seq);
            Tensor::matrix(size, size, t)
        }
        "hotspot3d" => {
            let (mut t, p) = hotspot3d::generate(seed, size);
            hotspot3d::simulate(
                &mut t,
                &p,
                (hotspot3d::LAYERS, size, size),
                hotspot3d::STEPS,
                1,
            );
            Tensor::new(vec![hotspot3d::LAYERS, size, size], t)
        }
        "lud" => {
            let mut m = lud::generate(seed, size);
            lud::lud_seq(&mut m, size);
            Tensor::matrix(size, size, m)
        }
        "nw" => {
            let r = nw::generate(seed, size);
            let n1 = size + 1;
            let mut o = vec![0.0; n1 * n1];
            nw::nw_seq(&r, &mut o, n1, nw::PENALTY);
            Tensor::matrix(n1, n1, o)
        }
        "matmul" => {
            let a = common::gen_matrix(seed, size, -1.0, 1.0);
            let b = common::gen_matrix(seed ^ 0xb, size, -1.0, 1.0);
            let mut c = vec![0.0; size * size];
            matmul::matmul_seq(&a, &b, &mut c, size);
            Tensor::matrix(size, size, c)
        }
        "sort" => {
            let mut v = sort::generate(seed, size);
            sort::sort_seq(&mut v);
            Tensor::vector(v)
        }
        _ => bail!("unknown app '{app}'"),
    })
}

/// The handle that carries the app's result.
pub fn output_handle(inst: &Instance) -> HandleId {
    match inst.app.as_str() {
        "nw" => inst.handles[1],
        "matmul" => inst.handles[2],
        _ => inst.handles[0],
    }
}

/// Relative-L2 verification tolerance per app (iterated stencils and
/// O(n^3) accumulations tolerate more float reassociation).
pub fn tolerance(app: &str) -> f32 {
    match app {
        "matmul" | "lud" => 5e-3,
        _ => 1e-3,
    }
}

/// Result of one driven task.
pub struct AppRun {
    pub task: crate::taskrt::TaskId,
    pub variant: String,
    pub modeled: f64,
    pub wall: f64,
    pub rel_err: f32,
}

/// Submit one task on a fresh instance, wait, verify, and report which
/// variant the runtime selected.
pub fn run_once(
    rt: &Runtime,
    app: &str,
    size: usize,
    seed: u64,
    force_variant: Option<&str>,
    verify: bool,
) -> Result<AppRun> {
    let name = app_codelet_name(app).to_string();
    let cl = match rt.codelet(&name) {
        Some(c) => c,
        None => rt.register_codelet(codelet(app)?),
    };
    let inst = prepare(rt, app, size, seed)?;
    let mut spec = TaskSpec::new(cl, inst.handles.clone(), size);
    if let Some(v) = force_variant {
        spec = spec.with_variant(v);
    }
    let task = rt.submit(spec)?;
    rt.wait_all()?;

    let result = rt
        .metrics()
        .results()
        .into_iter()
        .rev()
        .find(|r| r.task == task)
        .ok_or_else(|| anyhow!("no result recorded for task {task}"))?;

    let rel_err = if verify {
        let got = rt.snapshot(output_handle(&inst))?;
        let want = expected(&inst)?;
        let err = got.rel_l2_error(&want);
        if err > tolerance(app) {
            bail!(
                "{app} size {size} variant {}: rel L2 error {err} exceeds {}",
                result.variant,
                tolerance(app)
            );
        }
        err
    } else {
        0.0
    };

    Ok(AppRun {
        task,
        variant: result.variant.clone(),
        modeled: result.modeled_total(),
        wall: result.wall,
        rel_err,
    })
}

/// Codelet name for an app (hotspot -> "hotspot", matmul -> "mmul", ...).
pub fn app_codelet_name(app: &str) -> &str {
    match app {
        "matmul" => "mmul",
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_apps_have_codelets_and_sizes() {
        for app in ALL {
            let c = codelet(app).unwrap();
            assert!(!c.impls.is_empty(), "{app} has no variants");
            assert!(!paper_sizes(app).is_empty());
            assert!(!paper_variants(app).is_empty());
        }
    }

    #[test]
    fn unknown_app_is_error() {
        assert!(codelet("bfs").is_err());
    }

    #[test]
    fn codelet_names() {
        assert_eq!(app_codelet_name("matmul"), "mmul");
        assert_eq!(app_codelet_name("nw"), "nw");
    }
}
