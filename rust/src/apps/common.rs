//! Shared helpers for the benchmark applications: chunked parallel-for
//! (the "OpenMP" analog on the CPU device) and workload generators.

use crate::util::rng::Rng;

/// Parallel-for over row chunks using scoped threads — the native-Rust
//  stand-in for `#pragma omp parallel for`.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], chunk: usize, nthreads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    if nthreads <= 1 || data.len() <= chunk {
        f(0, data);
        return;
    }
    let per = data.len().div_ceil(nthreads).max(chunk);
    // round up to a whole number of chunks so rows are never split
    let per = per.div_ceil(chunk) * chunk;
    std::thread::scope(|s| {
        for (i, piece) in data.chunks_mut(per).enumerate() {
            let f = &f;
            s.spawn(move || f(i * per, piece));
        }
    });
}

/// Number of CPU threads the native "omp" variants use.
pub fn omp_threads() -> usize {
    std::env::var("COMPAR_OMP_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        })
}

/// Deterministic f32 matrix in [lo, hi).
pub fn gen_matrix(seed: u64, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    Rng::new(seed).vec_f32(n * n, lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_chunks_covers_everything() {
        let mut v = vec![0u32; 1000];
        par_chunks_mut(&mut v, 10, 4, |_, piece| {
            for x in piece {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn par_chunks_offsets_are_correct() {
        let mut v = vec![0usize; 64];
        par_chunks_mut(&mut v, 8, 4, |off, piece| {
            for (i, x) in piece.iter_mut().enumerate() {
                *x = off + i;
            }
        });
        let want: Vec<usize> = (0..64).collect();
        assert_eq!(v, want);
    }

    #[test]
    fn single_thread_fallback() {
        let mut v = vec![1.0f32; 7];
        par_chunks_mut(&mut v, 100, 8, |off, piece| {
            assert_eq!(off, 0);
            for x in piece {
                *x *= 2.0;
            }
        });
        assert_eq!(v, vec![2.0f32; 7]);
    }
}
