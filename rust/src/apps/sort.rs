//! Sort — the quickstart interface from the paper's Listing 1.3
//! (`sort(arr, N)` with CUDA and OpenMP variants).

use std::sync::Arc;

use anyhow::Result;

use super::common::omp_threads;
use crate::taskrt::{AccessMode, Arch, Codelet, ExecBuffers};

pub const APP: &str = "sort";

/// Sequential sort (std's pdqsort — the "Seq" variant).
pub fn sort_seq(arr: &mut [f32]) {
    arr.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

/// Parallel merge sort: chunk-sort on scoped threads, then k-way merge
/// by repeated pairwise merging (the "OpenMP" variant).
pub fn sort_omp(arr: &mut [f32]) {
    let threads = omp_threads().min(arr.len().max(1));
    if threads <= 1 || arr.len() < 4096 {
        sort_seq(arr);
        return;
    }
    let chunk = arr.len().div_ceil(threads);
    std::thread::scope(|s| {
        for piece in arr.chunks_mut(chunk) {
            s.spawn(|| piece.sort_by(|a, b| a.partial_cmp(b).unwrap()));
        }
    });
    // pairwise merge passes
    let mut width = chunk;
    let mut buf = vec![0.0f32; arr.len()];
    while width < arr.len() {
        let mut lo = 0;
        while lo + width < arr.len() {
            let mid = lo + width;
            let hi = (lo + 2 * width).min(arr.len());
            merge(&arr[lo..mid], &arr[mid..hi], &mut buf[lo..hi]);
            arr[lo..hi].copy_from_slice(&buf[lo..hi]);
            lo = hi;
        }
        width *= 2;
    }
}

fn merge(a: &[f32], b: &[f32], out: &mut [f32]) {
    let (mut i, mut j, mut k) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        if a[i] <= b[j] {
            out[k] = a[i];
            i += 1;
        } else {
            out[k] = b[j];
            j += 1;
        }
        k += 1;
    }
    out[k..k + a.len() - i].copy_from_slice(&a[i..]);
    k += a.len() - i;
    out[k..k + b.len() - j].copy_from_slice(&b[j..]);
}

fn native(f: fn(&mut [f32])) -> crate::taskrt::NativeFn {
    Arc::new(move |bufs: &ExecBuffers| -> Result<()> {
        let mut arr = bufs.write(0);
        f(arr.data_mut());
        Ok(())
    })
}

/// The `sort` codelet of Listing 1.3: CUDA (bitonic Pallas artifact) and
/// OpenMP variants, plus Seq.
pub fn codelet() -> Codelet {
    Codelet::new("sort", APP, vec![AccessMode::ReadWrite])
        .with_native("omp", Arch::Cpu, native(sort_omp))
        .with_native("seq", Arch::Cpu, native(sort_seq))
        .with_artifact("cuda", Arch::Cuda, "pallas")
        .with_hint("cuda")
}

pub fn paper_variants() -> &'static [&'static str] {
    &["omp", "cuda"]
}

pub fn generate(seed: u64, n: usize) -> Vec<f32> {
    crate::util::rng::Rng::new(seed).vec_f32(n, -1e4, 1e4)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_sorted(v: &[f32]) -> bool {
        v.windows(2).all(|w| w[0] <= w[1])
    }

    #[test]
    fn seq_sorts() {
        let mut v = generate(1, 1000);
        sort_seq(&mut v);
        assert!(is_sorted(&v));
    }

    #[test]
    fn omp_matches_seq() {
        let mut a = generate(2, 100_000);
        let mut b = a.clone();
        sort_seq(&mut a);
        sort_omp(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn omp_small_input() {
        let mut v = generate(3, 17);
        sort_omp(&mut v);
        assert!(is_sorted(&v));
    }

    #[test]
    fn merge_is_stable_total() {
        let a = [1.0f32, 3.0, 5.0];
        let b = [2.0f32, 4.0, 6.0];
        let mut out = [0.0f32; 6];
        merge(&a, &b, &mut out);
        assert_eq!(out, [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }
}
