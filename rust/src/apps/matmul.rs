//! Matrix multiplication — Fig 1e, the paper's richest variant set:
//! BLAS, OpenMP, CUDA and CUBLAS. Mapping (DESIGN.md §3):
//!
//! | paper variant | ours                                   | arch |
//! |---------------|----------------------------------------|------|
//! | BLAS          | XLA `jnp` artifact on the CPU device   | cpu  |
//! | OpenMP        | native blocked parallel loop           | cpu  |
//! | Seq (extra)   | native blocked triple loop             | cpu  |
//! | CUDA          | XLA `jnp` artifact on the CUDA device  | cuda |
//! | CUBLAS        | Pallas-tiled artifact on CUDA device   | cuda |

use std::sync::Arc;

use anyhow::Result;

use super::common::{omp_threads, par_chunks_mut};
use crate::taskrt::{AccessMode, Arch, Codelet, ExecBuffers};

pub const APP: &str = "matmul";

/// Cache-blocked sequential matmul: C = A @ B (f32, row-major, n x n).
pub fn matmul_seq(a: &[f32], b: &[f32], c: &mut [f32], n: usize) {
    const BK: usize = 64;
    c.fill(0.0);
    for kk in (0..n).step_by(BK) {
        let kmax = (kk + BK).min(n);
        for i in 0..n {
            for k in kk..kmax {
                let aik = a[i * n + k];
                let brow = &b[k * n..k * n + n];
                let crow = &mut c[i * n..i * n + n];
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
    }
}

/// Row-parallel blocked matmul (the OpenMP variant).
pub fn matmul_omp(a: &[f32], b: &[f32], c: &mut [f32], n: usize) {
    let threads = omp_threads();
    par_chunks_mut(c, n, threads, |off, rows| {
        let i0 = off / n;
        let nrows = rows.len() / n;
        const BK: usize = 64;
        rows.fill(0.0);
        for kk in (0..n).step_by(BK) {
            let kmax = (kk + BK).min(n);
            for li in 0..nrows {
                let i = i0 + li;
                for k in kk..kmax {
                    let aik = a[i * n + k];
                    let brow = &b[k * n..k * n + n];
                    let crow = &mut rows[li * n..li * n + n];
                    for j in 0..n {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
    });
}

fn native(f: fn(&[f32], &[f32], &mut [f32], usize)) -> crate::taskrt::NativeFn {
    Arc::new(move |bufs: &ExecBuffers| -> Result<()> {
        let n = bufs.size;
        let a = bufs.read(0).data().to_vec();
        let b = bufs.read(1).data().to_vec();
        let mut c = bufs.write(2);
        f(&a, &b, c.data_mut(), n);
        Ok(())
    })
}

/// The `mmul` codelet with the paper's full variant set.
pub fn codelet() -> Codelet {
    Codelet::new(
        "mmul",
        APP,
        vec![AccessMode::Read, AccessMode::Read, AccessMode::Write],
    )
    .with_artifact("blas", Arch::Cpu, "jnp")
    .with_native("omp", Arch::Cpu, native(matmul_omp))
    .with_native("seq", Arch::Cpu, native(matmul_seq))
    .with_artifact("cuda", Arch::Cuda, "jnp")
    .with_artifact("cublas", Arch::Cuda, "pallas")
    .with_hint("cuda")
}

/// Variants shown in Fig 1e.
pub fn paper_variants() -> &'static [&'static str] {
    &["blas", "omp", "cuda", "cublas"]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
        let mut c = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[i * n + k] * b[k * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    #[test]
    fn seq_matches_naive() {
        let n = 37; // non-multiple of block size
        let mut rng = Rng::new(1);
        let a = rng.vec_f32(n * n, -1.0, 1.0);
        let b = rng.vec_f32(n * n, -1.0, 1.0);
        let mut c = vec![0.0; n * n];
        matmul_seq(&a, &b, &mut c, n);
        let want = naive(&a, &b, n);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn omp_matches_seq() {
        let n = 96;
        let mut rng = Rng::new(2);
        let a = rng.vec_f32(n * n, -1.0, 1.0);
        let b = rng.vec_f32(n * n, -1.0, 1.0);
        let mut c1 = vec![0.0; n * n];
        let mut c2 = vec![0.0; n * n];
        matmul_seq(&a, &b, &mut c1, n);
        matmul_omp(&a, &b, &mut c2, n);
        assert_eq!(c1, c2, "parallel result must be bit-identical");
    }

    #[test]
    fn codelet_has_paper_variants() {
        let c = codelet();
        for v in paper_variants() {
            assert!(c.impl_by_name(v).is_some(), "missing variant {v}");
        }
        assert!(c.can_run_on(Arch::Cpu) && c.can_run_on(Arch::Cuda));
    }
}
