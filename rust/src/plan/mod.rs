//! `plan` — global lookahead variant composition over task DAGs
//! (Kessler & Dastgeer's *Optimized Composition*, PAPERS.md).
//!
//! Every other selection path in the repo decides one task at a time,
//! at ready time. This subsystem is the first component that reasons
//! about *more than one task jointly*: a client submits a whole
//! [`GraphSpec`] (named nodes + data-dependency edges over registry
//! handles), and the [`GraphPlanner`] assigns an implementation variant
//! to every node *before any task is released*, minimizing the modeled
//! makespan of the whole graph:
//!
//! * **Residency pricing** — candidate scores include the modeled PCIe
//!   cost of moving operand bytes ([`transfer_model`]): a dep edge
//!   whose producer landed on another architecture pays the transfer,
//!   a root node pays for its main-memory-resident inputs.
//! * **Transfer elision** — producer→consumer chains are co-scheduled
//!   on one architecture whenever that lowers (or ties) the makespan,
//!   so the bytes between them never cross the bus at all. Elided
//!   edges are reported per node ([`NodeAssignment::elided`]).
//! * **Span composition** — runs of consecutive same-arch nodes are
//!   grouped into batcher-friendly spans ([`NodeAssignment::span`]);
//!   the serve layer submits a span under one priority so same-codelet
//!   batching can coalesce it.
//! * **Contention degradation** — when the snapshot shows the machine
//!   contended (queue pressure beyond the partition's parallelism),
//!   the planner degrades to per-task greedy: the plan is still
//!   reported (mode [`PlanMode::Greedy`]) but tasks are released
//!   without priors. Planned assignments are always *prefer*-strength
//!   (the `planned` selector falls back when the variant is
//!   ineligible), never pins.
//!
//! The planner core is pure — it consumes a [`PlannerInput`] of
//! per-node candidate tables and edge byte counts, so it unit-tests
//! without a [`Runtime`](crate::taskrt::Runtime). The runtime glue
//! ([`crate::taskrt::Runtime::submit_graph`]) builds the input from
//! live perf models + residency state and releases the planned tasks
//! in dependency order.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::taskrt::device::transfer_model;
use crate::taskrt::{Arch, Codelet, HandleId, TaskId};

// ------------------------------------------------------- submission API

/// One node of a task graph: a codelet invocation over registry
/// handles, depending on earlier nodes.
pub struct GraphNode {
    /// Client-visible node name (report key; unique within the graph).
    pub name: String,
    pub codelet: Arc<Codelet>,
    /// Data handles in the codelet's declared parameter order.
    pub handles: Vec<HandleId>,
    /// Problem size (perf-model / artifact key).
    pub size: usize,
    /// Indices of *earlier* nodes this one depends on — the graph is
    /// acyclic by construction.
    pub deps: Vec<usize>,
    /// Optional per-node variant pin (overrides the planner).
    pub pinned: Option<String>,
}

/// A task DAG to be planned and submitted as one unit.
#[derive(Default)]
pub struct GraphSpec {
    pub nodes: Vec<GraphNode>,
    /// v9 observability: request trace id stamped onto every node task
    /// the graph releases (0 = untraced), so a whole DAG's execution
    /// spans share one id in the live trace ring.
    pub trace: u64,
}

impl GraphSpec {
    pub fn new() -> GraphSpec {
        GraphSpec::default()
    }

    /// Append a node depending on earlier nodes; returns its index.
    /// Dependency edges may only point backward (acyclic by
    /// construction), and node names must be unique (they key the
    /// per-node plan report).
    pub fn add_node(
        &mut self,
        name: &str,
        codelet: Arc<Codelet>,
        handles: Vec<HandleId>,
        size: usize,
        deps: &[usize],
    ) -> Result<usize> {
        let idx = self.nodes.len();
        if self.nodes.iter().any(|n| n.name == name) {
            bail!("graph node '{name}' already exists");
        }
        let mut deps = deps.to_vec();
        deps.sort_unstable();
        deps.dedup();
        if let Some(&bad) = deps.iter().find(|&&d| d >= idx) {
            bail!("graph node '{name}' depends on node {bad}, which is not an earlier node");
        }
        self.nodes.push(GraphNode {
            name: name.to_string(),
            codelet,
            handles,
            size,
            deps,
            pinned: None,
        });
        Ok(idx)
    }

    /// Pin the last-added node to one variant by name.
    pub fn pin_last(&mut self, variant: &str) {
        if let Some(n) = self.nodes.last_mut() {
            n.pinned = Some(variant.to_string());
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

// ------------------------------------------------------ planner input

/// One selectable implementation of a node, with its modeled execution
/// estimate (perf-model estimate, or the analytic device model while
/// the pair is uncalibrated).
#[derive(Debug, Clone)]
pub struct Candidate {
    pub variant: String,
    pub arch: Arch,
    /// Modeled execution seconds at the node's size.
    pub est: f64,
}

/// Planner view of one graph node: the candidate table plus the byte
/// counts residency pricing needs.
#[derive(Debug, Clone, Default)]
pub struct PlanNode {
    pub name: String,
    /// Indices of earlier nodes this one depends on.
    pub deps: Vec<usize>,
    /// Bytes crossing each dependency edge (parallel to `deps`): the
    /// handles this node shares with that producer.
    pub edge_bytes: Vec<usize>,
    /// Bytes of this node's inputs resident in main memory at plan
    /// time (what a device placement would have to move first).
    pub root_bytes: usize,
    pub candidates: Vec<Candidate>,
}

/// Everything the pure planner consumes — built by the runtime glue,
/// or directly by tests.
#[derive(Debug, Clone, Default)]
pub struct PlannerInput {
    pub nodes: Vec<PlanNode>,
    /// Modeled seconds already queued per architecture at plan time
    /// (the snapshot's `queued_secs`, per arch).
    pub arch_backlog: Vec<(Arch, f64)>,
    /// Queue pressure beyond the partition's parallelism: the planner
    /// degrades to per-task greedy rather than plan over stale state.
    pub contended: bool,
}

// ------------------------------------------------------------- output

/// Whether assignments were jointly optimized or chosen per-task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanMode {
    /// Joint lookahead assignment; released tasks carry prefer-strength
    /// priors (`planned` selector).
    Planned,
    /// Per-task greedy (forced, or contention degradation); tasks are
    /// released without priors.
    Greedy,
}

impl PlanMode {
    pub fn name(&self) -> &'static str {
        match self {
            PlanMode::Planned => "planned",
            PlanMode::Greedy => "greedy",
        }
    }
}

/// The planner's verdict for one node.
#[derive(Debug, Clone)]
pub struct NodeAssignment {
    pub node: usize,
    pub name: String,
    pub variant: String,
    pub arch: Arch,
    /// Modeled execution seconds behind the choice.
    pub est: f64,
    /// Modeled transfer seconds this placement pays (edges from
    /// foreign-arch producers + non-resident root inputs).
    pub transfer_secs: f64,
    /// At least one incoming data edge was kept on-arch with bytes on
    /// it — a transfer that per-edge pricing would otherwise pay.
    pub elided: bool,
    /// Batcher-friendly span index: consecutive same-arch nodes share
    /// a span and are submitted under one priority.
    pub span: usize,
}

/// A complete graph plan: per-node assignments + the modeled makespan
/// the joint schedule achieves.
#[derive(Debug, Clone)]
pub struct Plan {
    pub mode: PlanMode,
    pub assignments: Vec<NodeAssignment>,
    /// Modeled end-to-end seconds of the planned schedule.
    pub makespan: f64,
    /// Producer→consumer edges (with bytes on them) kept on one arch.
    pub elided_transfers: usize,
    /// Number of same-arch spans the graph composed into.
    pub spans: usize,
}

/// A planned graph after release: the submitted task ids (parallel to
/// the spec's nodes) and the plan that shaped their release.
pub struct GraphRun {
    pub tasks: Vec<TaskId>,
    pub plan: Plan,
}

// ------------------------------------------------------------ planner

/// The global lookahead planner (see the module docs).
#[derive(Default)]
pub struct GraphPlanner;

/// Modeled timing of one simulated schedule.
struct Sim {
    makespan: f64,
    /// Per-node (finish time, transfer secs, elided-edge count).
    per_node: Vec<(f64, f64, usize)>,
    elided: usize,
}

impl GraphPlanner {
    pub fn new() -> GraphPlanner {
        GraphPlanner
    }

    /// Plan the graph: joint lookahead assignment normally, per-task
    /// greedy when the input is contended. The planned makespan is
    /// never worse than greedy's by construction (the improvement
    /// sweep starts from the greedy assignment and only accepts
    /// non-worsening flips).
    pub fn plan(&self, input: &PlannerInput) -> Result<Plan> {
        if input.nodes.is_empty() {
            bail!("cannot plan an empty graph");
        }
        for n in &input.nodes {
            if n.candidates.is_empty() {
                bail!("graph node '{}' has no selectable implementation", n.name);
            }
        }
        let greedy = greedy_choices(input);
        if input.contended {
            return Ok(build_plan(input, &greedy, PlanMode::Greedy));
        }
        // Joint refinement: start from greedy, flip one node at a time
        // to any alternative candidate, re-simulate the whole schedule,
        // and keep the flip when it lowers the makespan — or ties it
        // while eliding more transfers (the co-scheduling move: pulling
        // a consumer onto its producer's arch is usually such a tie-
        // breaker win). Two sweeps are enough for chains to settle.
        let mut choices = greedy.clone();
        let mut best = simulate(input, &choices);
        for _ in 0..2 {
            let mut changed = false;
            for i in 0..input.nodes.len() {
                let mut kept = choices[i];
                for c in 0..input.nodes[i].candidates.len() {
                    if c == kept {
                        continue;
                    }
                    choices[i] = c;
                    let sim = simulate(input, &choices);
                    let wins = sim.makespan < best.makespan - 1e-12
                        || (sim.makespan <= best.makespan + 1e-12 && sim.elided > best.elided);
                    if wins {
                        best = sim;
                        kept = c;
                        changed = true;
                    } else {
                        choices[i] = kept;
                    }
                }
            }
            if !changed {
                break;
            }
        }
        Ok(build_plan(input, &choices, PlanMode::Planned))
    }
}

/// Per-task greedy assignment: in dependency order, each node picks the
/// candidate minimizing its own modeled finish (execution + transfers
/// given what earlier nodes already chose + the arch's backlog) — the
/// exact myopic decision ready-time selection makes.
fn greedy_choices(input: &PlannerInput) -> Vec<usize> {
    let mut choices: Vec<usize> = Vec::with_capacity(input.nodes.len());
    let mut free = backlog_map(input);
    let mut finish: Vec<f64> = Vec::with_capacity(input.nodes.len());
    let mut arch_of: Vec<Arch> = Vec::with_capacity(input.nodes.len());
    for n in &input.nodes {
        let mut best: Option<(usize, f64)> = None;
        for (c, cand) in n.candidates.iter().enumerate() {
            let f = node_finish(n, cand, &arch_of, &finish, &free);
            if best.map_or(true, |(_, bf)| f < bf) {
                best = Some((c, f));
            }
        }
        let (c, f) = best.expect("candidates checked non-empty");
        let cand = &n.candidates[c];
        set_free(&mut free, cand.arch, f);
        choices.push(c);
        finish.push(f);
        arch_of.push(cand.arch);
    }
    choices
}

/// Modeled finish time of `n` under candidate `cand`, given earlier
/// nodes' (arch, finish) and the per-arch free times.
fn node_finish(
    n: &PlanNode,
    cand: &Candidate,
    arch_of: &[Arch],
    finish: &[f64],
    free: &[(Arch, f64)],
) -> f64 {
    let (xfer, _) = placement_transfers(n, cand.arch, arch_of);
    let deps_done = n
        .deps
        .iter()
        .map(|&d| finish[d])
        .fold(0.0f64, f64::max);
    let ready = deps_done + xfer;
    let start = ready.max(get_free(free, cand.arch));
    start + cand.est
}

/// (transfer seconds, elided-edge count) of placing `n` on `arch`.
fn placement_transfers(n: &PlanNode, arch: Arch, arch_of: &[Arch]) -> (f64, usize) {
    let mut xfer = 0.0;
    let mut elided = 0;
    for (k, &d) in n.deps.iter().enumerate() {
        let bytes = n.edge_bytes.get(k).copied().unwrap_or(0);
        if bytes == 0 {
            continue;
        }
        if arch_of[d] == arch {
            elided += 1;
        } else {
            xfer += transfer_model(bytes);
        }
    }
    // root inputs live in main memory (the CPU's node)
    if n.root_bytes > 0 && arch != Arch::Cpu {
        xfer += transfer_model(n.root_bytes);
    }
    (xfer, elided)
}

fn backlog_map(input: &PlannerInput) -> Vec<(Arch, f64)> {
    input.arch_backlog.clone()
}

fn get_free(free: &[(Arch, f64)], arch: Arch) -> f64 {
    free.iter()
        .find(|(a, _)| *a == arch)
        .map(|&(_, t)| t)
        .unwrap_or(0.0)
}

fn set_free(free: &mut Vec<(Arch, f64)>, arch: Arch, t: f64) {
    match free.iter_mut().find(|(a, _)| *a == arch) {
        Some(slot) => slot.1 = t,
        None => free.push((arch, t)),
    }
}

/// Simulate the whole schedule under fixed choices (single modeled
/// lane per architecture — conservative, and what the backlog term
/// already assumes).
fn simulate(input: &PlannerInput, choices: &[usize]) -> Sim {
    let mut free = backlog_map(input);
    let mut finish: Vec<f64> = Vec::with_capacity(input.nodes.len());
    let mut arch_of: Vec<Arch> = Vec::with_capacity(input.nodes.len());
    let mut per_node = Vec::with_capacity(input.nodes.len());
    let mut elided_total = 0usize;
    let mut makespan = 0.0f64;
    for (i, n) in input.nodes.iter().enumerate() {
        let cand = &n.candidates[choices[i]];
        let (xfer, elided) = placement_transfers(n, cand.arch, &arch_of);
        let deps_done = n
            .deps
            .iter()
            .map(|&d| finish[d])
            .fold(0.0f64, f64::max);
        let start = (deps_done + xfer).max(get_free(&free, cand.arch));
        let f = start + cand.est;
        set_free(&mut free, cand.arch, f);
        finish.push(f);
        arch_of.push(cand.arch);
        per_node.push((f, xfer, elided));
        elided_total += elided;
        makespan = makespan.max(f);
    }
    Sim {
        makespan,
        per_node,
        elided: elided_total,
    }
}

/// Materialize the plan report: assignments, spans, makespan.
fn build_plan(input: &PlannerInput, choices: &[usize], mode: PlanMode) -> Plan {
    let sim = simulate(input, choices);
    let mut assignments = Vec::with_capacity(input.nodes.len());
    let mut span = 0usize;
    let mut prev_arch: Option<Arch> = None;
    for (i, n) in input.nodes.iter().enumerate() {
        let cand = &n.candidates[choices[i]];
        if prev_arch.is_some() && prev_arch != Some(cand.arch) {
            span += 1;
        }
        prev_arch = Some(cand.arch);
        let (_, xfer, elided) = sim.per_node[i];
        assignments.push(NodeAssignment {
            node: i,
            name: n.name.clone(),
            variant: cand.variant.clone(),
            arch: cand.arch,
            est: cand.est,
            transfer_secs: xfer,
            elided: elided > 0,
            span,
        });
    }
    Plan {
        mode,
        assignments,
        makespan: sim.makespan,
        elided_transfers: sim.elided,
        spans: span + 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cands(cpu: f64, cuda: f64) -> Vec<Candidate> {
        vec![
            Candidate {
                variant: "omp".into(),
                arch: Arch::Cpu,
                est: cpu,
            },
            Candidate {
                variant: "cuda".into(),
                arch: Arch::Cuda,
                est: cuda,
            },
        ]
    }

    /// A 4-stage pipeline moving 64 MB between stages: the device wins
    /// per stage, but only if the chain stays on-device.
    fn pipeline(contended: bool) -> PlannerInput {
        let mb64 = 64 * 1024 * 1024;
        let mut nodes = Vec::new();
        for i in 0..4 {
            nodes.push(PlanNode {
                name: format!("s{i}"),
                deps: if i == 0 { vec![] } else { vec![i - 1] },
                edge_bytes: if i == 0 { vec![] } else { vec![mb64] },
                root_bytes: if i == 0 { mb64 } else { 0 },
                candidates: cands(0.010, 0.004),
            });
        }
        PlannerInput {
            nodes,
            arch_backlog: vec![],
            contended,
        }
    }

    #[test]
    fn chain_stays_on_one_arch_and_elides_transfers() {
        let plan = GraphPlanner::new().plan(&pipeline(false)).unwrap();
        assert_eq!(plan.mode, PlanMode::Planned);
        // every consumer lands on its producer's arch: 3 elided edges
        assert_eq!(plan.elided_transfers, 3, "{plan:?}");
        assert!(plan.assignments[1..].iter().all(|a| a.elided));
        let archs: Vec<Arch> = plan.assignments.iter().map(|a| a.arch).collect();
        assert!(archs.windows(2).all(|w| w[0] == w[1]), "chain split: {archs:?}");
        assert_eq!(plan.spans, 1, "one same-arch span");
    }

    #[test]
    fn planned_never_worse_than_greedy() {
        // mixed graph: a fan-out with asymmetric costs and a join
        let kb256 = 256 * 1024;
        let input = PlannerInput {
            nodes: vec![
                PlanNode {
                    name: "src".into(),
                    deps: vec![],
                    edge_bytes: vec![],
                    root_bytes: kb256,
                    candidates: cands(0.002, 0.003),
                },
                PlanNode {
                    name: "a".into(),
                    deps: vec![0],
                    edge_bytes: vec![kb256],
                    root_bytes: 0,
                    candidates: cands(0.008, 0.001),
                },
                PlanNode {
                    name: "b".into(),
                    deps: vec![0],
                    edge_bytes: vec![kb256],
                    root_bytes: 0,
                    candidates: cands(0.003, 0.009),
                },
                PlanNode {
                    name: "join".into(),
                    deps: vec![1, 2],
                    edge_bytes: vec![kb256, kb256],
                    root_bytes: 0,
                    candidates: cands(0.004, 0.004),
                },
            ],
            arch_backlog: vec![(Arch::Cuda, 0.002)],
            contended: false,
        };
        let planner = GraphPlanner::new();
        let planned = planner.plan(&input).unwrap();
        let degraded = planner
            .plan(&PlannerInput {
                contended: true,
                ..input.clone()
            })
            .unwrap();
        assert_eq!(degraded.mode, PlanMode::Greedy);
        assert!(
            planned.makespan <= degraded.makespan + 1e-12,
            "planned {} > greedy {}",
            planned.makespan,
            degraded.makespan
        );
    }

    #[test]
    fn contention_degrades_to_greedy() {
        let plan = GraphPlanner::new().plan(&pipeline(true)).unwrap();
        assert_eq!(plan.mode, PlanMode::Greedy);
        assert_eq!(plan.assignments.len(), 4);
        assert!(plan.makespan > 0.0);
    }

    #[test]
    fn backlog_steers_placement_off_the_contended_arch() {
        // one independent node, device nominally faster — but 100 ms of
        // device backlog makes the CPU candidate finish first
        let input = PlannerInput {
            nodes: vec![PlanNode {
                name: "n".into(),
                deps: vec![],
                edge_bytes: vec![],
                root_bytes: 0,
                candidates: cands(0.010, 0.004),
            }],
            arch_backlog: vec![(Arch::Cuda, 0.100)],
            contended: false,
        };
        let plan = GraphPlanner::new().plan(&input).unwrap();
        assert_eq!(plan.assignments[0].arch, Arch::Cpu);
    }

    #[test]
    fn spans_group_consecutive_same_arch_nodes() {
        // costs force cpu, cpu, cuda, cuda -> 2 spans
        let input = PlannerInput {
            nodes: vec![
                PlanNode {
                    name: "a".into(),
                    candidates: cands(0.001, 0.5),
                    ..PlanNode::default()
                },
                PlanNode {
                    name: "b".into(),
                    candidates: cands(0.001, 0.5),
                    ..PlanNode::default()
                },
                PlanNode {
                    name: "c".into(),
                    candidates: cands(0.5, 0.001),
                    ..PlanNode::default()
                },
                PlanNode {
                    name: "d".into(),
                    candidates: cands(0.5, 0.001),
                    ..PlanNode::default()
                },
            ],
            arch_backlog: vec![],
            contended: false,
        };
        let plan = GraphPlanner::new().plan(&input).unwrap();
        assert_eq!(plan.spans, 2);
        assert_eq!(plan.assignments[0].span, plan.assignments[1].span);
        assert_eq!(plan.assignments[2].span, plan.assignments[3].span);
        assert_ne!(plan.assignments[0].span, plan.assignments[3].span);
    }

    #[test]
    fn graph_spec_rejects_forward_and_duplicate_nodes() {
        let cl = Arc::new(
            Codelet::new("c", "sort", vec![]), // zero-parameter codelet
        );
        let mut g = GraphSpec::new();
        let a = g.add_node("a", cl.clone(), vec![], 8, &[]).unwrap();
        assert_eq!(a, 0);
        assert!(g.add_node("a", cl.clone(), vec![], 8, &[]).is_err());
        assert!(g.add_node("b", cl.clone(), vec![], 8, &[5]).is_err());
        let b = g.add_node("b", cl, vec![], 8, &[a]).unwrap();
        assert_eq!(b, 1);
        assert_eq!(g.len(), 2);
    }

    #[test]
    fn empty_graph_and_empty_candidates_are_errors() {
        let planner = GraphPlanner::new();
        assert!(planner.plan(&PlannerInput::default()).is_err());
        let input = PlannerInput {
            nodes: vec![PlanNode {
                name: "n".into(),
                ..PlanNode::default()
            }],
            arch_backlog: vec![],
            contended: false,
        };
        assert!(planner.plan(&input).is_err());
    }
}
