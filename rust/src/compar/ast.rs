//! Abstract syntax tree of the COMPAR directive language (parser output,
//! paper's Bison phase result).

use super::token::Span;

/// One clause: `name(arg, arg, ...)`.
#[derive(Debug, Clone, PartialEq)]
pub struct Clause {
    pub name: String,
    pub args: Vec<ClauseArg>,
    pub span: Span,
}

/// Clause argument values.
#[derive(Debug, Clone, PartialEq)]
pub enum ClauseArg {
    /// Identifier (variable name, target name, interface name, ...).
    Ident(String),
    /// Integer literal.
    Number(i64),
    /// A C type: base identifier + pointer depth, e.g. float* = ("float", 1).
    Type { base: String, stars: usize },
}

impl ClauseArg {
    pub fn as_text(&self) -> String {
        match self {
            ClauseArg::Ident(s) => s.clone(),
            ClauseArg::Number(n) => n.to_string(),
            ClauseArg::Type { base, stars } => format!("{base}{}", "*".repeat(*stars)),
        }
    }
}

/// A parsed directive.
#[derive(Debug, Clone, PartialEq)]
pub enum Directive {
    /// `#pragma compar include`
    Include { span: Span },
    /// `#pragma compar initialize`
    Initialize { span: Span },
    /// `#pragma compar terminate`
    Terminate { span: Span },
    /// `#pragma compar method_declare interface(..) target(..) name(..)`
    MethodDeclare { clauses: Vec<Clause>, span: Span },
    /// `#pragma compar parameter name(..) type(..) size(..) access_mode(..)`
    Parameter { clauses: Vec<Clause>, span: Span },
}

impl Directive {
    pub fn span(&self) -> Span {
        match self {
            Directive::Include { span }
            | Directive::Initialize { span }
            | Directive::Terminate { span }
            | Directive::MethodDeclare { span, .. }
            | Directive::Parameter { span, .. } => *span,
        }
    }

    pub fn keyword(&self) -> &'static str {
        match self {
            Directive::Include { .. } => "include",
            Directive::Initialize { .. } => "initialize",
            Directive::Terminate { .. } => "terminate",
            Directive::MethodDeclare { .. } => "method_declare",
            Directive::Parameter { .. } => "parameter",
        }
    }

    pub fn clauses(&self) -> &[Clause] {
        match self {
            Directive::MethodDeclare { clauses, .. } | Directive::Parameter { clauses, .. } => {
                clauses
            }
            _ => &[],
        }
    }

    /// First clause with the given name.
    pub fn clause(&self, name: &str) -> Option<&Clause> {
        self.clauses().iter().find(|c| c.name == name)
    }
}

/// The parsed program: directive list in source order.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub directives: Vec<Directive>,
}
