//! Syntax analysis (the paper's Bison phase): token stream -> AST.
//!
//! Grammar:
//! ```text
//! program   := directive*
//! directive := PRAGMA keyword clause* EOL
//! keyword   := include | initialize | terminate
//!            | method_declare | parameter
//! clause    := IDENT '(' args? ')'
//! args      := arg (',' arg)*
//! arg       := IDENT '*'* | NUMBER
//! ```

use anyhow::{bail, Result};

use super::ast::{Clause, ClauseArg, Directive, Program};
use super::token::{Span, Token, TokenKind};

pub fn parse(tokens: &[Token], _source: &str, filename: &str) -> Result<Program> {
    let mut p = Parser {
        toks: tokens,
        i: 0,
        filename,
    };
    let mut program = Program::default();
    while !p.done() {
        program.directives.push(p.directive()?);
    }
    Ok(program)
}

struct Parser<'a> {
    toks: &'a [Token],
    i: usize,
    filename: &'a str,
}

impl<'a> Parser<'a> {
    fn done(&self) -> bool {
        self.i >= self.toks.len()
    }

    fn peek(&self) -> Option<&Token> {
        self.toks.get(self.i)
    }

    fn next(&mut self) -> Option<&Token> {
        let t = self.toks.get(self.i);
        self.i += 1;
        t
    }

    fn err<T>(&self, span: Span, msg: impl std::fmt::Display) -> Result<T> {
        bail!("{}:{}:{}: {msg}", self.filename, span.line, span.col)
    }

    fn directive(&mut self) -> Result<Directive> {
        let intro = self.next().cloned().expect("non-empty");
        if intro.kind != TokenKind::PragmaCompar {
            return self.err(intro.span, format!("expected #pragma compar, got {}", intro.kind));
        }
        let kw = match self.next().cloned() {
            Some(Token {
                kind: TokenKind::Ident(name),
                span,
            }) => (name, span),
            Some(t) => return self.err(t.span, format!("expected a directive name, got {}", t.kind)),
            None => return self.err(intro.span, "directive name missing"),
        };
        let span = intro.span;
        let d = match kw.0.as_str() {
            "include" => Directive::Include { span },
            "initialize" => Directive::Initialize { span },
            "terminate" => Directive::Terminate { span },
            "method_declare" => Directive::MethodDeclare {
                clauses: self.clauses()?,
                span,
            },
            "parameter" => Directive::Parameter {
                clauses: self.clauses()?,
                span,
            },
            other => {
                return self.err(
                    kw.1,
                    format!(
                        "unknown COMPAR directive '{other}' (expected include, initialize, \
                         terminate, method_declare or parameter)"
                    ),
                )
            }
        };
        // consume EOL
        match self.next() {
            Some(t) if t.kind == TokenKind::Eol => Ok(d),
            Some(t) => {
                let (k, s) = (t.kind.clone(), t.span);
                self.err(s, format!("unexpected {k} after directive"))
            }
            None => Ok(d),
        }
    }

    fn clauses(&mut self) -> Result<Vec<Clause>> {
        let mut out = Vec::new();
        while let Some(t) = self.peek() {
            match &t.kind {
                TokenKind::Eol => break,
                TokenKind::Ident(_) => out.push(self.clause()?),
                other => {
                    let (k, s) = (other.clone(), t.span);
                    return self.err(s, format!("expected a clause name, got {k}"));
                }
            }
        }
        Ok(out)
    }

    fn clause(&mut self) -> Result<Clause> {
        let (name, span) = match self.next().cloned() {
            Some(Token {
                kind: TokenKind::Ident(n),
                span,
            }) => (n, span),
            _ => unreachable!("guarded by peek"),
        };
        match self.next() {
            Some(t) if t.kind == TokenKind::LParen => {}
            Some(t) => {
                let s = t.span;
                return self.err(s, format!("clause '{name}' needs '('"));
            }
            None => return self.err(span, format!("clause '{name}' needs '('")),
        }
        let mut args = Vec::new();
        loop {
            match self.peek().map(|t| (t.kind.clone(), t.span)) {
                Some((TokenKind::RParen, _)) => {
                    self.next();
                    break;
                }
                Some((TokenKind::Comma, _)) => {
                    self.next();
                }
                Some((TokenKind::Ident(id), _)) => {
                    self.next();
                    // fold pointer stars into a type argument
                    let mut stars = 0;
                    while matches!(self.peek().map(|t| &t.kind), Some(TokenKind::Star)) {
                        self.next();
                        stars += 1;
                    }
                    if stars > 0 {
                        args.push(ClauseArg::Type { base: id, stars });
                    } else {
                        args.push(ClauseArg::Ident(id));
                    }
                }
                Some((TokenKind::Number(n), _)) => {
                    self.next();
                    args.push(ClauseArg::Number(n));
                }
                Some((k, s)) => return self.err(s, format!("unexpected {k} in clause '{name}'")),
                None => return self.err(span, format!("clause '{name}' not closed")),
            }
        }
        Ok(Clause { name, args, span })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compar::lexer::lex;

    fn parse_src(src: &str) -> Result<Program> {
        parse(&lex(src, "t.c").unwrap(), src, "t.c")
    }

    #[test]
    fn parses_listing_1_3_shapes() {
        let src = "\
#pragma compar include
#pragma compar method_declare interface(sort) target(cuda) name(sort_cuda)
#pragma compar parameter name(arr) type(float*) size(N) access_mode(readwrite)
#pragma compar parameter name(N) type(int)
#pragma compar initialize
#pragma compar terminate
";
        let p = parse_src(src).unwrap();
        assert_eq!(p.directives.len(), 6);
        assert_eq!(p.directives[0].keyword(), "include");
        let md = &p.directives[1];
        assert_eq!(md.clause("interface").unwrap().args[0].as_text(), "sort");
        assert_eq!(md.clause("target").unwrap().args[0].as_text(), "cuda");
        let param = &p.directives[2];
        assert_eq!(param.clause("type").unwrap().args[0].as_text(), "float*");
        assert_eq!(
            param.clause("access_mode").unwrap().args[0].as_text(),
            "readwrite"
        );
    }

    #[test]
    fn multi_arg_size_clause() {
        let p = parse_src("#pragma compar parameter name(A) type(float*) size(N, M)\n").unwrap();
        let sz = p.directives[0].clause("size").unwrap();
        assert_eq!(sz.args.len(), 2);
    }

    #[test]
    fn unknown_directive_rejected() {
        assert!(parse_src("#pragma compar frobnicate\n").is_err());
    }

    #[test]
    fn unclosed_clause_rejected() {
        assert!(parse_src("#pragma compar parameter name(arr\n").is_err());
    }

    #[test]
    fn missing_paren_rejected() {
        assert!(parse_src("#pragma compar method_declare interface sort\n").is_err());
    }

    #[test]
    fn numeric_size_args() {
        let p = parse_src("#pragma compar parameter name(x) type(int) size(4096)\n").unwrap();
        assert_eq!(
            p.directives[0].clause("size").unwrap().args[0],
            ClauseArg::Number(4096)
        );
    }

    #[test]
    fn double_pointer_type() {
        let p = parse_src("#pragma compar parameter name(x) type(float**)\n").unwrap();
        assert_eq!(
            p.directives[0].clause("type").unwrap().args[0],
            ClauseArg::Type {
                base: "float".into(),
                stars: 2
            }
        );
    }
}
