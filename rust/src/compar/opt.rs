//! Compile-time optimization (the paper's §5 future work, implemented):
//! "optimization techniques could be applied during compilation to
//! reduce the set of implementation variants based on benchmarking
//! results or other criteria."
//!
//! The pass evaluates every variant of every interface against the
//! calibrated device model over the app's input-size range and removes
//! *dominated* variants — those never within `keep_margin` of the best
//! variant at any size. The runtime then has fewer codelets to
//! calibrate, shortening the cold phase that §3.2 blames for StarPU's
//! early sub-optimal selections.

use crate::bench_harness::fig1::variant_time;
use crate::compar::codegen::rust_glue::variant_label;
use crate::compar::ir::{ComparProgram, Interface};
use crate::taskrt::device::Arch;

/// Result of pruning one interface.
#[derive(Debug, Clone)]
pub struct PruneReport {
    pub interface: String,
    pub kept: Vec<String>,
    pub removed: Vec<(String, String)>, // (variant func, reason)
}

/// The device-model app key for an interface (interface names follow the
/// benchmark apps; unknown interfaces fall back to their own name, which
/// hits the device model's generic path).
fn app_key(iface: &Interface) -> &str {
    match iface.name.as_str() {
        "mmul" => "matmul",
        other => other,
    }
}

/// Sizes to evaluate during pruning.
fn probe_sizes(app: &str) -> Vec<usize> {
    let s = crate::apps::paper_sizes(app);
    if s.is_empty() {
        vec![64, 256, 1024, 4096]
    } else {
        s
    }
}

/// Prune dominated variants. `keep_margin` = 1.25 keeps any variant that
/// comes within 25% of the best somewhere in the size range.
pub fn prune_variants(program: &mut ComparProgram, keep_margin: f64) -> Vec<PruneReport> {
    let mut reports = Vec::new();
    for iface in &mut program.interfaces {
        let app = app_key(iface).to_string();
        let sizes = probe_sizes(&app);
        // time matrix: variant x size
        let times: Vec<Vec<f64>> = iface
            .variants
            .iter()
            .map(|v| {
                let label = variant_label(&v.target);
                let arch = v.arch();
                sizes
                    .iter()
                    .map(|&n| variant_time(&app, label, arch, n))
                    .collect()
            })
            .collect();
        let best_per_size: Vec<f64> = (0..sizes.len())
            .map(|j| {
                times
                    .iter()
                    .map(|row| row[j])
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let mut kept = Vec::new();
        let mut removed = Vec::new();
        let keep_flags: Vec<bool> = times
            .iter()
            .map(|row| {
                row.iter()
                    .zip(&best_per_size)
                    .any(|(t, b)| *t <= b * keep_margin)
            })
            .collect();
        // never remove everything, and always keep at least one variant
        // per architecture that has one (the runtime needs a fallback
        // when a device class is absent)
        let mut keep_flags = keep_flags;
        for arch in [Arch::Cpu, Arch::Cuda] {
            let has_arch: Vec<usize> = iface
                .variants
                .iter()
                .enumerate()
                .filter(|(_, v)| v.arch() == arch)
                .map(|(i, _)| i)
                .collect();
            if !has_arch.is_empty() && !has_arch.iter().any(|&i| keep_flags[i]) {
                // keep the best-at-largest-size variant of this arch
                let best = has_arch
                    .into_iter()
                    .min_by(|&a, &b| {
                        times[a].last().unwrap().partial_cmp(times[b].last().unwrap()).unwrap()
                    })
                    .unwrap();
                keep_flags[best] = true;
            }
        }
        let old = std::mem::take(&mut iface.variants);
        for (i, v) in old.into_iter().enumerate() {
            if keep_flags[i] {
                kept.push(v.func.clone());
                iface.variants.push(v);
            } else {
                removed.push((
                    v.func.clone(),
                    format!(
                        "dominated: never within {:.0}% of the best variant over sizes {:?}",
                        (keep_margin - 1.0) * 100.0,
                        sizes
                    ),
                ));
            }
        }
        reports.push(PruneReport {
            interface: iface.name.clone(),
            kept,
            removed,
        });
    }
    reports
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compar::analyze;

    const SRC: &str = "\
#pragma compar method_declare interface(mmul) target(blas) name(mmul_blas)
#pragma compar parameter name(A) type(float*) size(N, N) access_mode(read)
#pragma compar parameter name(B) type(float*) size(N, N) access_mode(read)
#pragma compar parameter name(C) type(float*) size(N, N) access_mode(write)
#pragma compar parameter name(N) type(int)
#pragma compar method_declare interface(mmul) target(seq) name(mmul_seq)
#pragma compar method_declare interface(mmul) target(openmp) name(mmul_omp)
#pragma compar method_declare interface(mmul) target(cuda) name(mmul_cuda)
#pragma compar method_declare interface(mmul) target(cublas) name(mmul_cublas)
#pragma compar initialize
#pragma compar terminate
";

    #[test]
    fn dominated_variant_is_pruned_for_mmul() {
        let mut p = analyze(SRC, "t.c").unwrap();
        let reports = prune_variants(&mut p, 1.25);
        let r = &reports[0];
        // the naive OpenMP triple loop is dominated everywhere: seq wins
        // tiny sizes (lower overhead), blas wins small-mid, cuda/cublas
        // win large — omp is never within 25% of any of them
        assert!(
            r.removed.iter().any(|(f, _)| f == "mmul_omp"),
            "omp not pruned: {r:?}"
        );
        // the contested variants all survive (blas small, cuda mid,
        // cublas large)
        for keep in ["mmul_blas", "mmul_cuda", "mmul_cublas"] {
            assert!(r.kept.iter().any(|k| k == keep), "{keep} wrongly pruned");
        }
        // program was actually rewritten
        assert_eq!(
            p.interface("mmul").unwrap().variants.len(),
            r.kept.len()
        );
    }

    #[test]
    fn every_arch_keeps_a_fallback() {
        let mut p = analyze(SRC, "t.c").unwrap();
        // absurd margin would prune all but one; arch fallback must hold
        prune_variants(&mut p, 1.0);
        let iface = p.interface("mmul").unwrap();
        assert!(iface.variants.iter().any(|v| v.arch() == Arch::Cpu));
        assert!(iface.variants.iter().any(|v| v.arch() == Arch::Cuda));
    }
}
