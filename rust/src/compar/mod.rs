//! The COMPAR pre-compiler (paper §2): lexer -> parser -> semantic
//! analysis -> IR -> code generation.
//!
//! The surface language is the paper's `#pragma compar` directive set
//! embedded in C/C++-like sources; everything that is not a COMPAR
//! directive passes through untouched (backward compatibility, §2.1).

pub mod ast;
pub mod codegen;
pub mod diagnostics;
pub mod ir;
pub mod lexer;
pub mod opt;
pub mod parser;
pub mod sema;
pub mod token;

use anyhow::{bail, Result};

/// Run the full front-end: source text -> validated IR.
pub fn analyze(source: &str, filename: &str) -> Result<ir::ComparProgram> {
    let tokens = lexer::lex(source, filename)?;
    let program = parser::parse(&tokens, source, filename)?;
    let diags = sema::check(&program);
    if diags.iter().any(|d| d.is_error()) {
        let mut msg = String::new();
        for d in &diags {
            msg.push_str(&d.render(source, filename));
            msg.push('\n');
        }
        bail!("semantic errors:\n{msg}");
    }
    Ok(ir::lower(&program))
}

/// Full pipeline: source -> generated artifacts (paper §2.2).
pub struct CompileOutput {
    /// StarPU-style C glue, one unit per interface (paper Listing 1.4).
    pub c_units: Vec<(String, String)>,
    /// `compar.h` contents.
    pub header: String,
    /// Rust glue targeting our `taskrt` runtime.
    pub rust_glue: String,
    /// The transformed application source (directives -> plain C).
    pub transformed: String,
    pub program: ir::ComparProgram,
}

/// Compile COMPAR-annotated source to all glue outputs.
pub fn compile(source: &str, filename: &str) -> Result<CompileOutput> {
    let program = analyze(source, filename)?;
    Ok(CompileOutput {
        c_units: codegen::c_glue::generate_units(&program),
        header: codegen::header::generate(&program),
        rust_glue: codegen::rust_glue::generate(&program),
        transformed: codegen::c_glue::transform_source(source),
        program,
    })
}
