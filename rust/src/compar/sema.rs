//! Semantic analysis (paper §2.2): verifies directive usage in context.
//!
//! Checks implemented (superset of the paper's list):
//! * method_declare has exactly one interface/name/target clause each,
//!   with exactly one argument;
//! * target is a known programming model (cuda, openmp/omp, seq, opencl,
//!   blas, cublas);
//! * no duplicate variant name, and no duplicate target per interface;
//! * parameter directives appear only after a method_declare;
//! * the FIRST variant of an interface declares every parameter's type;
//!   later variants may re-declare parameters only with an identical
//!   signature (same name/type/size arity/access mode);
//! * parameter types come from the supported C scalar set; size clauses
//!   have 1..=4 dimensions (vector/matrix/3D/4D — paper §2.1);
//! * access_mode is read/write/readwrite (default read);
//! * duplicate include/initialize/terminate warnings, missing
//!   initialize/terminate warnings.

use std::collections::HashMap;

use super::ast::{Clause, ClauseArg, Directive, Program};
use super::diagnostics::Diagnostic;

pub const KNOWN_TARGETS: &[&str] = &["cuda", "openmp", "omp", "seq", "opencl", "blas", "cublas"];
pub const KNOWN_TYPES: &[&str] = &[
    "int", "float", "double", "char", "wchar_t", "long", "short", "unsigned", "size_t",
];
pub const KNOWN_MODES: &[&str] = &["read", "write", "readwrite"];

/// Run all checks; returns diagnostics (errors + warnings).
pub fn check(program: &Program) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let mut seen_include = false;
    let mut seen_init = false;
    let mut seen_term = false;

    // interface -> (variant names, targets, signature)
    #[derive(Default)]
    struct IfaceInfo {
        variants: Vec<String>,
        targets: Vec<String>,
        /// variants carrying a `prefer()` selection hint
        preferred: usize,
        /// (name, type text, size arity, mode) per parameter
        signature: Vec<(String, String, usize, String)>,
        signature_fixed: bool,
    }
    let mut ifaces: HashMap<String, IfaceInfo> = HashMap::new();
    // parameters of the method_declare currently being collected
    let mut current: Option<(String, Vec<(String, String, usize, String)>, bool)> = None;

    let flush_current =
        |current: &mut Option<(String, Vec<(String, String, usize, String)>, bool)>,
         ifaces: &mut HashMap<String, IfaceInfo>,
         diags: &mut Vec<Diagnostic>,
         span| {
            if let Some((iface, params, first)) = current.take() {
                let info = ifaces.entry(iface.clone()).or_default();
                if first {
                    info.signature = params;
                    info.signature_fixed = true;
                } else if !params.is_empty() && params != info.signature {
                    diags.push(Diagnostic::error(
                        format!(
                            "variant of interface '{iface}' re-declares parameters with a \
                             different signature (variants must share the method signature)"
                        ),
                        span,
                    ));
                }
            }
        };

    for d in &program.directives {
        match d {
            Directive::Include { span } => {
                if seen_include {
                    diags.push(Diagnostic::warning("duplicate include directive", *span));
                }
                seen_include = true;
            }
            Directive::Initialize { span } => {
                if seen_init {
                    diags.push(Diagnostic::error("duplicate initialize directive", *span));
                }
                seen_init = true;
            }
            Directive::Terminate { span } => {
                if seen_term {
                    diags.push(Diagnostic::error("duplicate terminate directive", *span));
                }
                seen_term = true;
            }
            Directive::MethodDeclare { clauses, span } => {
                flush_current(&mut current, &mut ifaces, &mut diags, *span);
                let iface = require_single(clauses, "interface", *span, &mut diags);
                let name = require_single(clauses, "name", *span, &mut diags);
                let target = require_single(clauses, "target", *span, &mut diags);
                check_unknown_clauses(
                    clauses,
                    &["interface", "name", "target", "prefer"],
                    &mut diags,
                );
                if let Some(c) = d.clause("prefer") {
                    if !c.args.is_empty() {
                        diags.push(Diagnostic::error(
                            "prefer clause takes no arguments (it marks this variant as \
                             the selection-policy prior)",
                            c.span,
                        ));
                    }
                }
                let (Some(iface), Some(name), Some(target)) = (iface, name, target) else {
                    continue;
                };
                if !KNOWN_TARGETS.contains(&target.to_ascii_lowercase().as_str()) {
                    diags.push(Diagnostic::error(
                        format!(
                            "unknown target '{target}' (supported: {})",
                            KNOWN_TARGETS.join(", ")
                        ),
                        d.clause("target").unwrap().span,
                    ));
                }
                let info = ifaces.entry(iface.clone()).or_default();
                if info.variants.contains(&name) {
                    diags.push(Diagnostic::error(
                        format!("duplicate variant '{name}' for interface '{iface}'"),
                        d.clause("name").unwrap().span,
                    ));
                }
                let tgt = target.to_ascii_lowercase();
                let tgt_norm = if tgt == "omp" { "openmp".to_string() } else { tgt };
                if info.targets.contains(&tgt_norm) {
                    diags.push(Diagnostic::warning(
                        format!(
                            "interface '{iface}' already has a variant for target '{target}'; \
                             the runtime will treat them as alternatives"
                        ),
                        d.clause("target").unwrap().span,
                    ));
                }
                if let Some(c) = d.clause("prefer") {
                    if info.preferred > 0 {
                        diags.push(Diagnostic::warning(
                            format!(
                                "interface '{iface}' already has a preferred variant; \
                                 only the first prefer() seeds the selection prior"
                            ),
                            c.span,
                        ));
                    }
                    info.preferred += 1;
                }
                info.variants.push(name);
                info.targets.push(tgt_norm);
                let first = !info.signature_fixed;
                current = Some((iface, Vec::new(), first));
            }
            Directive::Parameter { clauses, span } => {
                let Some((iface, params, first)) = current.as_mut() else {
                    diags.push(Diagnostic::error(
                        "parameter directive outside a method_declare context",
                        *span,
                    ));
                    continue;
                };
                check_unknown_clauses(
                    clauses,
                    &["name", "type", "size", "access_mode"],
                    &mut diags,
                );
                let Some(pname) = require_single(clauses, "name", *span, &mut diags) else {
                    continue;
                };
                if params.iter().any(|(n, _, _, _)| n == &pname) {
                    diags.push(Diagnostic::error(
                        format!("duplicate parameter '{pname}' for interface '{iface}'"),
                        *span,
                    ));
                    continue;
                }
                // type: required on the first variant
                let ptype = match d.clause("type") {
                    Some(c) if c.args.len() == 1 => {
                        let text = c.args[0].as_text();
                        let base = match &c.args[0] {
                            ClauseArg::Type { base, .. } => base.clone(),
                            ClauseArg::Ident(s) => s.clone(),
                            ClauseArg::Number(_) => String::new(),
                        };
                        if !KNOWN_TYPES.contains(&base.as_str()) {
                            diags.push(Diagnostic::error(
                                format!(
                                    "unsupported parameter type '{text}' (supported bases: {})",
                                    KNOWN_TYPES.join(", ")
                                ),
                                c.span,
                            ));
                        }
                        text
                    }
                    Some(c) => {
                        diags.push(Diagnostic::error(
                            "type clause takes exactly one argument",
                            c.span,
                        ));
                        String::new()
                    }
                    None => {
                        if *first {
                            diags.push(Diagnostic::error(
                                format!(
                                    "parameter '{pname}' of the first variant of '{iface}' \
                                     must declare a type"
                                ),
                                *span,
                            ));
                        }
                        String::new()
                    }
                };
                // size: 0 (scalar) or 1..=4 dims
                let arity = match d.clause("size") {
                    Some(c) => {
                        if c.args.is_empty() || c.args.len() > 4 {
                            diags.push(Diagnostic::error(
                                format!(
                                    "size clause takes 1 to 4 dimensions (vector, matrix, 3D, \
                                     4D), got {}",
                                    c.args.len()
                                ),
                                c.span,
                            ));
                        }
                        c.args.len()
                    }
                    None => 0,
                };
                if arity == 0 && ptype.contains('*') {
                    diags.push(Diagnostic::warning(
                        format!(
                            "pointer parameter '{pname}' has no size clause; treating as scalar"
                        ),
                        *span,
                    ));
                }
                // access_mode
                let mode = match d.clause("access_mode") {
                    Some(c) if c.args.len() == 1 => {
                        let m = c.args[0].as_text().to_ascii_lowercase();
                        if !KNOWN_MODES.contains(&m.as_str()) {
                            diags.push(Diagnostic::error(
                                format!(
                                    "unknown access_mode '{m}' (expected read, write or readwrite)"
                                ),
                                c.span,
                            ));
                        }
                        m
                    }
                    Some(c) => {
                        diags.push(Diagnostic::error(
                            "access_mode takes exactly one argument",
                            c.span,
                        ));
                        "read".into()
                    }
                    None => "read".into(),
                };
                params.push((pname, ptype, arity, mode));
            }
        }
    }
    let last_span = program
        .directives
        .last()
        .map(|d| d.span())
        .unwrap_or(super::token::Span::new(1, 1, 0, 1));
    flush_current(&mut current, &mut ifaces, &mut diags, last_span);

    if !ifaces.is_empty() {
        if !seen_init {
            diags.push(Diagnostic::warning(
                "no initialize directive: the runtime must be initialized manually",
                last_span,
            ));
        }
        if !seen_term {
            diags.push(Diagnostic::warning(
                "no terminate directive: the runtime will not be shut down cleanly",
                last_span,
            ));
        }
    }
    diags
}

fn require_single(
    clauses: &[Clause],
    name: &str,
    span: super::token::Span,
    diags: &mut Vec<Diagnostic>,
) -> Option<String> {
    let found: Vec<&Clause> = clauses.iter().filter(|c| c.name == name).collect();
    match found.as_slice() {
        [] => {
            diags.push(Diagnostic::error(
                format!("missing required clause '{name}'"),
                span,
            ));
            None
        }
        [c] => {
            if c.args.len() != 1 {
                diags.push(Diagnostic::error(
                    format!("clause '{name}' takes exactly one argument"),
                    c.span,
                ));
                None
            } else {
                Some(c.args[0].as_text())
            }
        }
        [_, dup, ..] => {
            diags.push(Diagnostic::error(
                format!("duplicate clause '{name}'"),
                dup.span,
            ));
            None
        }
    }
}

fn check_unknown_clauses(clauses: &[Clause], known: &[&str], diags: &mut Vec<Diagnostic>) {
    for c in clauses {
        if !known.contains(&c.name.as_str()) {
            diags.push(Diagnostic::error(
                format!("unknown clause '{}' (expected one of {})", c.name, known.join(", ")),
                c.span,
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compar::{lexer::lex, parser::parse};

    fn diags_for(src: &str) -> Vec<Diagnostic> {
        let p = parse(&lex(src, "t.c").unwrap(), src, "t.c").unwrap();
        check(&p)
    }

    fn errors(src: &str) -> Vec<String> {
        diags_for(src)
            .into_iter()
            .filter(|d| d.is_error())
            .map(|d| d.message)
            .collect()
    }

    const VALID: &str = "\
#pragma compar include
#pragma compar method_declare interface(sort) target(cuda) name(sort_cuda)
#pragma compar parameter name(arr) type(float*) size(N) access_mode(readwrite)
#pragma compar parameter name(N) type(int)
#pragma compar method_declare interface(sort) target(openmp) name(sort_omp)
#pragma compar initialize
#pragma compar terminate
";

    #[test]
    fn valid_program_clean() {
        assert!(errors(VALID).is_empty(), "{:?}", errors(VALID));
    }

    #[test]
    fn unknown_target() {
        let e = errors(
            "#pragma compar method_declare interface(f) target(fpga) name(f1)\n",
        );
        assert!(e.iter().any(|m| m.contains("unknown target 'fpga'")));
    }

    #[test]
    fn duplicate_variant_name() {
        let src = "\
#pragma compar method_declare interface(f) target(cuda) name(f1)
#pragma compar parameter name(x) type(int)
#pragma compar method_declare interface(f) target(openmp) name(f1)
";
        assert!(errors(src).iter().any(|m| m.contains("duplicate variant 'f1'")));
    }

    #[test]
    fn parameter_outside_method() {
        let e = errors("#pragma compar parameter name(x) type(int)\n");
        assert!(e.iter().any(|m| m.contains("outside a method_declare")));
    }

    #[test]
    fn missing_type_on_first_variant() {
        let src = "\
#pragma compar method_declare interface(f) target(cuda) name(f1)
#pragma compar parameter name(x)
";
        assert!(errors(src).iter().any(|m| m.contains("must declare a type")));
    }

    #[test]
    fn mismatched_redeclaration() {
        let src = "\
#pragma compar method_declare interface(f) target(cuda) name(f1)
#pragma compar parameter name(x) type(int)
#pragma compar method_declare interface(f) target(openmp) name(f2)
#pragma compar parameter name(x) type(float)
";
        assert!(errors(src).iter().any(|m| m.contains("different signature")));
    }

    #[test]
    fn matching_redeclaration_ok() {
        let src = "\
#pragma compar method_declare interface(f) target(cuda) name(f1)
#pragma compar parameter name(x) type(int)
#pragma compar method_declare interface(f) target(openmp) name(f2)
#pragma compar parameter name(x) type(int)
";
        assert!(errors(src).is_empty());
    }

    #[test]
    fn size_arity_limit() {
        let src = "\
#pragma compar method_declare interface(f) target(cuda) name(f1)
#pragma compar parameter name(x) type(float*) size(A, B, C, D, E)
";
        assert!(errors(src).iter().any(|m| m.contains("1 to 4 dimensions")));
    }

    #[test]
    fn bad_access_mode() {
        let src = "\
#pragma compar method_declare interface(f) target(cuda) name(f1)
#pragma compar parameter name(x) type(int) access_mode(scan)
";
        assert!(errors(src).iter().any(|m| m.contains("unknown access_mode")));
    }

    #[test]
    fn duplicate_parameter() {
        let src = "\
#pragma compar method_declare interface(f) target(cuda) name(f1)
#pragma compar parameter name(x) type(int)
#pragma compar parameter name(x) type(int)
";
        assert!(errors(src).iter().any(|m| m.contains("duplicate parameter 'x'")));
    }

    #[test]
    fn duplicate_initialize_is_error() {
        let src = "#pragma compar initialize\n#pragma compar initialize\n";
        assert!(errors(src).iter().any(|m| m.contains("duplicate initialize")));
    }

    #[test]
    fn missing_init_warns() {
        let src = "\
#pragma compar method_declare interface(f) target(cuda) name(f1)
#pragma compar parameter name(x) type(int)
";
        let w: Vec<_> = diags_for(src).into_iter().filter(|d| !d.is_error()).collect();
        assert!(w.iter().any(|d| d.message.contains("no initialize")));
    }

    #[test]
    fn unknown_clause_rejected() {
        let e = errors("#pragma compar method_declare interface(f) target(cuda) name(f1) speed(fast)\n");
        assert!(e.iter().any(|m| m.contains("unknown clause 'speed'")));
    }

    #[test]
    fn prefer_clause_accepted_without_args() {
        let src = "\
#pragma compar method_declare interface(f) target(cuda) name(f1) prefer()
#pragma compar parameter name(x) type(int)
#pragma compar initialize
#pragma compar terminate
";
        assert!(errors(src).is_empty(), "{:?}", errors(src));
    }

    #[test]
    fn prefer_clause_rejects_args() {
        let src =
            "#pragma compar method_declare interface(f) target(cuda) name(f1) prefer(fast)\n";
        assert!(errors(src).iter().any(|m| m.contains("prefer clause takes no arguments")));
    }

    #[test]
    fn duplicate_prefer_warns() {
        let src = "\
#pragma compar method_declare interface(f) target(cuda) name(f1) prefer()
#pragma compar parameter name(x) type(int)
#pragma compar method_declare interface(f) target(openmp) name(f2) prefer()
#pragma compar initialize
#pragma compar terminate
";
        let w: Vec<_> = diags_for(src).into_iter().filter(|d| !d.is_error()).collect();
        assert!(w.iter().any(|d| d.message.contains("already has a preferred variant")));
    }
}
