//! Lexical analysis (the paper's Flex phase).
//!
//! Scans the whole source, but produces tokens only for lines starting
//! with `#pragma compar` (after whitespace). Supports `\` line
//! continuations. Everything else is passthrough text the code
//! generator preserves verbatim.

use anyhow::{bail, Result};

use super::token::{Span, Token, TokenKind};

/// Tokenize all COMPAR directive lines in `source`.
///
/// The token stream is flat; each directive ends with an `Eol` token.
pub fn lex(source: &str, filename: &str) -> Result<Vec<Token>> {
    let mut tokens = Vec::new();
    let mut offset = 0usize;
    let mut lines = source.lines().enumerate().peekable();
    while let Some((lineno, raw)) = lines.next() {
        let line_start = offset;
        offset += raw.len() + 1; // + newline
        let trimmed = raw.trim_start();
        let indent = raw.len() - trimmed.len();
        if !is_compar_pragma(trimmed) {
            continue;
        }
        // assemble continuations
        let mut text = trimmed.to_string();
        let mut extent = raw.len();
        while text.ends_with('\\') {
            text.pop();
            match lines.next() {
                Some((_, cont)) => {
                    text.push(' ');
                    text.push_str(cont.trim());
                    extent += cont.len() + 1;
                    offset += cont.len() + 1;
                }
                None => break,
            }
        }
        let _ = extent;
        lex_directive_line(
            &text,
            lineno + 1,
            indent + 1,
            line_start + indent,
            &mut tokens,
            filename,
        )?;
    }
    Ok(tokens)
}

/// Does a (trimmed) line start a COMPAR directive?
pub fn is_compar_pragma(trimmed: &str) -> bool {
    let Some(rest) = trimmed.strip_prefix("#pragma") else {
        return false;
    };
    rest.trim_start().starts_with("compar")
        && rest
            .trim_start()
            .strip_prefix("compar")
            .map(|r| r.is_empty() || r.starts_with(char::is_whitespace))
            .unwrap_or(false)
}

fn lex_directive_line(
    text: &str,
    line: usize,
    col0: usize,
    offset0: usize,
    out: &mut Vec<Token>,
    filename: &str,
) -> Result<()> {
    // strip "#pragma" then "compar"
    let after_pragma = text.strip_prefix("#pragma").unwrap();
    let ws1 = after_pragma.len() - after_pragma.trim_start().len();
    let after = after_pragma.trim_start().strip_prefix("compar").unwrap();
    let intro_len = "#pragma".len() + ws1 + "compar".len();
    out.push(Token::new(
        TokenKind::PragmaCompar,
        Span::new(line, col0, offset0, intro_len),
    ));

    let bytes = after.as_bytes();
    let base_col = col0 + intro_len;
    let base_off = offset0 + intro_len;
    let mut i = 0usize;
    while i < bytes.len() {
        let c = bytes[i] as char;
        let span1 = |i: usize| Span::new(line, base_col + i, base_off + i, 1);
        match c {
            ' ' | '\t' => i += 1,
            '(' => {
                out.push(Token::new(TokenKind::LParen, span1(i)));
                i += 1;
            }
            ')' => {
                out.push(Token::new(TokenKind::RParen, span1(i)));
                i += 1;
            }
            ',' => {
                out.push(Token::new(TokenKind::Comma, span1(i)));
                i += 1;
            }
            '*' => {
                out.push(Token::new(TokenKind::Star, span1(i)));
                i += 1;
            }
            '/' if bytes.get(i + 1) == Some(&b'/') => break, // trailing comment
            c if c.is_ascii_digit() => {
                let start = i;
                while i < bytes.len() && (bytes[i] as char).is_ascii_digit() {
                    i += 1;
                }
                let n: i64 = after[start..i].parse().unwrap();
                out.push(Token::new(
                    TokenKind::Number(n),
                    Span::new(line, base_col + start, base_off + start, i - start),
                ));
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                while i < bytes.len() && {
                    let c = bytes[i] as char;
                    c.is_ascii_alphanumeric() || c == '_'
                } {
                    i += 1;
                }
                out.push(Token::new(
                    TokenKind::Ident(after[start..i].to_string()),
                    Span::new(line, base_col + start, base_off + start, i - start),
                ));
            }
            other => bail!(
                "{filename}:{line}:{}: unexpected character '{other}' in COMPAR directive",
                base_col + i
            ),
        }
    }
    out.push(Token::new(
        TokenKind::Eol,
        Span::new(line, base_col + bytes.len(), base_off + bytes.len(), 1),
    ));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<TokenKind> {
        lex(src, "t.c").unwrap().into_iter().map(|t| t.kind).collect()
    }

    #[test]
    fn ignores_plain_source() {
        assert!(lex("int main() { return 0; }\n// #pragma omp\n", "t.c")
            .unwrap()
            .is_empty());
    }

    #[test]
    fn detects_pragma_variants() {
        assert!(is_compar_pragma("#pragma compar include"));
        assert!(is_compar_pragma("#pragma  compar initialize"));
        assert!(!is_compar_pragma("#pragma omp parallel"));
        assert!(!is_compar_pragma("#pragma comparx"));
    }

    #[test]
    fn lexes_method_declare() {
        let k = kinds("#pragma compar method_declare interface(sort) target(cuda) name(sort_cuda)\n");
        use TokenKind::*;
        assert_eq!(
            k,
            vec![
                PragmaCompar,
                Ident("method_declare".into()),
                Ident("interface".into()),
                LParen,
                Ident("sort".into()),
                RParen,
                Ident("target".into()),
                LParen,
                Ident("cuda".into()),
                RParen,
                Ident("name".into()),
                LParen,
                Ident("sort_cuda".into()),
                RParen,
                Eol,
            ]
        );
    }

    #[test]
    fn lexes_pointer_type_and_sizes() {
        let k = kinds("#pragma compar parameter name(A) type(float*) size(N, M)\n");
        assert!(k.contains(&TokenKind::Star));
        assert!(k.contains(&TokenKind::Comma));
    }

    #[test]
    fn numbers_and_continuations() {
        let k = kinds("#pragma compar parameter name(x) \\\n  type(int) size(128)\n");
        assert!(k.contains(&TokenKind::Number(128)));
    }

    #[test]
    fn trailing_comment_ignored() {
        let k = kinds("#pragma compar initialize // boot the runtime\n");
        assert_eq!(
            k,
            vec![
                TokenKind::PragmaCompar,
                TokenKind::Ident("initialize".into()),
                TokenKind::Eol
            ]
        );
    }

    #[test]
    fn bad_character_is_error() {
        assert!(lex("#pragma compar parameter name(a$b)\n", "t.c").is_err());
    }

    #[test]
    fn spans_point_into_line() {
        let toks = lex("  #pragma compar include\n", "t.c").unwrap();
        assert_eq!(toks[0].span.line, 1);
        assert_eq!(toks[0].span.col, 3);
    }
}
