//! Token definitions for the COMPAR directive language.
//!
//! Only `#pragma compar ...` lines are tokenized (the pre-compiler's
//! Flex specification in the paper is equally narrow); all other source
//! text flows through untouched.

use std::fmt;

/// Source location (1-based line/column, byte offset + length).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub line: usize,
    pub col: usize,
    pub offset: usize,
    pub len: usize,
}

impl Span {
    pub fn new(line: usize, col: usize, offset: usize, len: usize) -> Span {
        Span {
            line,
            col,
            offset,
            len,
        }
    }
}

/// Token kinds of the directive grammar.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokenKind {
    /// The `#pragma compar` introducer (one per directive line).
    PragmaCompar,
    /// Identifier or keyword: directive names, clause names, values.
    Ident(String),
    /// Integer literal (e.g. in size clauses).
    Number(i64),
    /// Pointer star inside type(...) clauses.
    Star,
    LParen,
    RParen,
    Comma,
    /// End of one directive line.
    Eol,
}

impl fmt::Display for TokenKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TokenKind::PragmaCompar => write!(f, "#pragma compar"),
            TokenKind::Ident(s) => write!(f, "'{s}'"),
            TokenKind::Number(n) => write!(f, "{n}"),
            TokenKind::Star => write!(f, "'*'"),
            TokenKind::LParen => write!(f, "'('"),
            TokenKind::RParen => write!(f, "')'"),
            TokenKind::Eol => write!(f, "end of directive"),
            TokenKind::Comma => write!(f, "','"),
        }
    }
}

/// A token with its source span.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub kind: TokenKind,
    pub span: Span,
}

impl Token {
    pub fn new(kind: TokenKind, span: Span) -> Token {
        Token { kind, span }
    }
}
