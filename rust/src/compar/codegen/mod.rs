//! Code generation (paper §2.2, template-based): the IR is rendered to
//! * StarPU-style C glue, one translation unit per interface, matching
//!   the paper's Listing 1.4 ([`c_glue`]);
//! * the `compar.h` support header ([`header`]);
//! * Rust glue that registers the same interfaces with our `taskrt`
//!   runtime ([`rust_glue`]) — the back-end target is swappable, as the
//!   paper notes StarPU could be replaced by StarSs.

pub mod c_glue;
pub mod header;
pub mod rust_glue;
