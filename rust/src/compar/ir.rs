//! Intermediate representation (paper §2.2): the validated, lowered form
//! the code generators consume.

use super::ast::{ClauseArg, Directive, Program};
use crate::taskrt::{AccessMode, Arch};

/// One parameter of an interface.
#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    pub name: String,
    /// C type text, e.g. "float*".
    pub ctype: String,
    /// Size expressions (variable names or literals); empty = scalar.
    pub dims: Vec<String>,
    pub mode: AccessMode,
}

impl Param {
    pub fn is_buffer(&self) -> bool {
        !self.dims.is_empty()
    }

    /// Element C type (pointer stars stripped).
    pub fn elem_type(&self) -> String {
        self.ctype.trim_end_matches('*').to_string()
    }

    /// StarPU data interface for this parameter's rank.
    pub fn starpu_interface(&self) -> &'static str {
        match self.dims.len() {
            1 => "vector",
            2 => "matrix",
            3 => "block",
            4 => "tensor",
            _ => "variable",
        }
    }
}

/// One implementation variant of an interface.
#[derive(Debug, Clone, PartialEq)]
pub struct Variant {
    /// Function name, e.g. "sort_cuda".
    pub func: String,
    /// Normalized target ("cuda", "openmp", "seq", "opencl", "blas",
    /// "cublas").
    pub target: String,
    /// Component-author selection hint (`prefer()` clause): seed the
    /// runtime's selection-policy priors with this variant.
    pub preferred: bool,
}

impl Variant {
    /// Architecture the target maps onto.
    pub fn arch(&self) -> Arch {
        Arch::parse(&self.target).unwrap_or(Arch::Cpu)
    }
}

/// One interface (codelet) with its variants.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Interface {
    pub name: String,
    pub params: Vec<Param>,
    pub variants: Vec<Variant>,
}

impl Interface {
    /// The size expression used as the task scale parameter: the first
    /// dimension of the first buffer parameter (paper: "input size").
    pub fn size_expr(&self) -> Option<&str> {
        self.params
            .iter()
            .find(|p| p.is_buffer())
            .and_then(|p| p.dims.first())
            .map(String::as_str)
    }

    /// The variant carrying the `prefer()` selection hint, if any.
    pub fn preferred_variant(&self) -> Option<&Variant> {
        self.variants.iter().find(|v| v.preferred)
    }
}

/// The lowered program.
#[derive(Debug, Clone, Default)]
pub struct ComparProgram {
    pub interfaces: Vec<Interface>,
    pub has_include: bool,
    pub has_initialize: bool,
    pub has_terminate: bool,
}

impl ComparProgram {
    pub fn interface(&self, name: &str) -> Option<&Interface> {
        self.interfaces.iter().find(|i| i.name == name)
    }
}

/// Lower a validated AST into the IR. Assumes `sema::check` passed
/// (malformed clauses are skipped defensively rather than panicking).
pub fn lower(program: &Program) -> ComparProgram {
    let mut out = ComparProgram::default();
    let mut current: Option<usize> = None; // index into out.interfaces
    let mut current_first = false;

    for d in &program.directives {
        match d {
            Directive::Include { .. } => out.has_include = true,
            Directive::Initialize { .. } => out.has_initialize = true,
            Directive::Terminate { .. } => out.has_terminate = true,
            Directive::MethodDeclare { .. } => {
                let (Some(iface), Some(name), Some(target)) = (
                    d.clause("interface").and_then(|c| c.args.first()).map(ClauseArg::as_text),
                    d.clause("name").and_then(|c| c.args.first()).map(ClauseArg::as_text),
                    d.clause("target").and_then(|c| c.args.first()).map(ClauseArg::as_text),
                ) else {
                    current = None;
                    continue;
                };
                let mut target = target.to_ascii_lowercase();
                if target == "omp" {
                    target = "openmp".into();
                }
                let idx = match out.interfaces.iter().position(|i| i.name == iface) {
                    Some(i) => i,
                    None => {
                        out.interfaces.push(Interface {
                            name: iface,
                            ..Default::default()
                        });
                        out.interfaces.len() - 1
                    }
                };
                current_first = out.interfaces[idx].params.is_empty();
                let preferred = d.clause("prefer").is_some();
                out.interfaces[idx].variants.push(Variant {
                    func: name,
                    target,
                    preferred,
                });
                current = Some(idx);
            }
            Directive::Parameter { .. } => {
                let Some(idx) = current else { continue };
                if !current_first {
                    continue; // signature already fixed by the first variant
                }
                let Some(name) = d
                    .clause("name")
                    .and_then(|c| c.args.first())
                    .map(ClauseArg::as_text)
                else {
                    continue;
                };
                let ctype = d
                    .clause("type")
                    .and_then(|c| c.args.first())
                    .map(ClauseArg::as_text)
                    .unwrap_or_default();
                let dims = d
                    .clause("size")
                    .map(|c| c.args.iter().map(ClauseArg::as_text).collect())
                    .unwrap_or_default();
                let mode = d
                    .clause("access_mode")
                    .and_then(|c| c.args.first())
                    .and_then(|a| AccessMode::parse(&a.as_text()))
                    .unwrap_or(AccessMode::Read);
                out.interfaces[idx].params.push(Param {
                    name,
                    ctype,
                    dims,
                    mode,
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compar::{lexer::lex, parser::parse};

    fn lower_src(src: &str) -> ComparProgram {
        lower(&parse(&lex(src, "t.c").unwrap(), src, "t.c").unwrap())
    }

    const LISTING_1_3: &str = "\
#pragma compar include
#pragma compar method_declare interface(sort) target(cuda) name(sort_cuda)
#pragma compar parameter name(arr) type(float*) size(N) access_mode(readwrite)
#pragma compar parameter name(N) type(int)
#pragma compar method_declare interface(sort) target(openmp) name(sort_omp)
#pragma compar method_declare interface(mmul) target(cuda) name(mmul_cuda)
#pragma compar parameter name(A) type(float*) size(N, M) access_mode(read)
#pragma compar parameter name(B) type(float*) size(N, M) access_mode(read)
#pragma compar parameter name(N) type(int)
#pragma compar parameter name(M) type(int)
#pragma compar method_declare interface(mmul) target(openmp) name(mmul_omp)
#pragma compar initialize
#pragma compar terminate
";

    #[test]
    fn lowers_listing_1_3() {
        let p = lower_src(LISTING_1_3);
        assert!(p.has_include && p.has_initialize && p.has_terminate);
        assert_eq!(p.interfaces.len(), 2);
        let sort = p.interface("sort").unwrap();
        assert_eq!(sort.variants.len(), 2);
        assert_eq!(sort.variants[0].func, "sort_cuda");
        assert_eq!(sort.variants[1].target, "openmp");
        assert_eq!(sort.params.len(), 2);
        assert!(sort.params[0].is_buffer());
        assert_eq!(sort.params[0].mode, AccessMode::ReadWrite);
        assert!(!sort.params[1].is_buffer());
        assert_eq!(sort.size_expr(), Some("N"));

        let mmul = p.interface("mmul").unwrap();
        assert_eq!(mmul.params.len(), 4);
        assert_eq!(mmul.params[0].dims, vec!["N", "M"]);
        assert_eq!(mmul.params[0].starpu_interface(), "matrix");
    }

    #[test]
    fn variant_arch_mapping() {
        let v = Variant {
            func: "f".into(),
            target: "cublas".into(),
            preferred: false,
        };
        assert_eq!(v.arch(), Arch::Cuda);
        let v2 = Variant {
            func: "g".into(),
            target: "openmp".into(),
            preferred: false,
        };
        assert_eq!(v2.arch(), Arch::Cpu);
    }

    #[test]
    fn prefer_clause_marks_variant() {
        let src = "\
#pragma compar method_declare interface(f) target(cuda) name(f1) prefer()
#pragma compar parameter name(x) type(float*) size(N) access_mode(read)
#pragma compar parameter name(N) type(int)
#pragma compar method_declare interface(f) target(openmp) name(f2)
";
        let p = lower_src(src);
        let f = p.interface("f").unwrap();
        assert!(f.variants[0].preferred);
        assert!(!f.variants[1].preferred);
        assert_eq!(f.preferred_variant().unwrap().func, "f1");
    }

    #[test]
    fn elem_type_strips_stars() {
        let p = Param {
            name: "a".into(),
            ctype: "float*".into(),
            dims: vec!["N".into()],
            mode: AccessMode::Read,
        };
        assert_eq!(p.elem_type(), "float");
        assert_eq!(p.starpu_interface(), "vector");
    }

    #[test]
    fn later_variant_params_do_not_override() {
        let src = "\
#pragma compar method_declare interface(f) target(cuda) name(f1)
#pragma compar parameter name(x) type(int)
#pragma compar method_declare interface(f) target(openmp) name(f2)
#pragma compar parameter name(x) type(int)
";
        let p = lower_src(src);
        assert_eq!(p.interface("f").unwrap().params.len(), 1);
    }
}
