//! Diagnostics with source spans — rendered like a compiler error:
//!
//! ```text
//! error: duplicate variant 'sort_cuda' for interface 'sort'
//!   --> app.compar.c:12:44
//!    |
//! 12 | #pragma compar method_declare interface(sort) ...
//!    |                                          ^^^^
//! ```

use super::token::Span;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub severity: Severity,
    pub message: String,
    pub span: Span,
}

impl Diagnostic {
    pub fn error(message: impl Into<String>, span: Span) -> Diagnostic {
        Diagnostic {
            severity: Severity::Error,
            message: message.into(),
            span,
        }
    }

    pub fn warning(message: impl Into<String>, span: Span) -> Diagnostic {
        Diagnostic {
            severity: Severity::Warning,
            message: message.into(),
            span,
        }
    }

    pub fn is_error(&self) -> bool {
        self.severity == Severity::Error
    }

    /// Render with the offending source line and a caret underline.
    pub fn render(&self, source: &str, filename: &str) -> String {
        let sev = match self.severity {
            Severity::Error => "error",
            Severity::Warning => "warning",
        };
        let line_text = source.lines().nth(self.span.line.saturating_sub(1)).unwrap_or("");
        let gutter = format!("{}", self.span.line);
        let pad = " ".repeat(gutter.len());
        let caret_pad = " ".repeat(self.span.col.saturating_sub(1));
        let carets = "^".repeat(self.span.len.max(1));
        format!(
            "{sev}: {}\n {pad}--> {filename}:{}:{}\n {pad}|\n {gutter} | {line_text}\n {pad}| {caret_pad}{carets}",
            self.message, self.span.line, self.span.col
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_with_caret() {
        let src = "int x;\n#pragma compar bogus\n";
        let d = Diagnostic::error("unknown directive 'bogus'", Span::new(2, 16, 22, 5));
        let out = d.render(src, "t.c");
        assert!(out.contains("error: unknown directive 'bogus'"));
        assert!(out.contains("t.c:2:16"));
        assert!(out.contains("#pragma compar bogus"));
        assert!(out.contains("^^^^^"));
    }

    #[test]
    fn severity_flags() {
        let d = Diagnostic::warning("w", Span::new(1, 1, 0, 1));
        assert!(!d.is_error());
    }
}
