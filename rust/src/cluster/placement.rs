//! Shard-placement policies: which backend shard serves a submit.
//!
//! Three policies ship, mirroring the selection engine's shape (a small
//! closed set, picked by config, swappable per router):
//!
//! | policy         | behaviour                                             |
//! |----------------|-------------------------------------------------------|
//! | `round-robin`  | rotate over the available shards                      |
//! | `least-loaded` | lowest load (in-flight requests + runtime queue       |
//! |                | depth) at the last health poll                        |
//! | `calibrated`   | selection-aware: the shard whose perf models hold the |
//! |                | most samples for the request's (codelet, size) — so a |
//! |                | request lands where variant selection is already      |
//! |                | converged; equally-calibrated shards (and cold keys)  |
//! |                | are split by load, then round-robin                   |
//!
//! "Available" always means healthy (last stats probe succeeded) and not
//! drained out of the rotation. "Load" is the same runtime-snapshot
//! feature set the selection layer's `RuntimeSnapshot` uses inside one
//! process (queue depth + in-flight work), reported per shard through
//! the v4 `stats` fields and cached by the health poll.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::router::ShardState;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementKind {
    RoundRobin,
    LeastLoaded,
    /// Route to the shard best-calibrated for the request's
    /// (codelet, size), per the last gossip pull.
    Calibrated,
}

impl PlacementKind {
    pub fn parse(s: &str) -> Option<PlacementKind> {
        match s.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => Some(PlacementKind::RoundRobin),
            "least-loaded" | "leastloaded" | "load" => Some(PlacementKind::LeastLoaded),
            "calibrated" | "selection-aware" | "selection" => Some(PlacementKind::Calibrated),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlacementKind::RoundRobin => "round-robin",
            PlacementKind::LeastLoaded => "least-loaded",
            PlacementKind::Calibrated => "calibrated",
        }
    }
}

/// Pick a shard index for a submit of `app` at `size`, skipping
/// unavailable shards and the indices in `exclude` (prior failed
/// attempts of this request). `rr` is the router-wide rotation cursor.
pub fn pick(
    kind: PlacementKind,
    shards: &[Arc<ShardState>],
    app: &str,
    size: usize,
    exclude: &[usize],
    rr: &AtomicUsize,
) -> Option<usize> {
    let cands: Vec<usize> = (0..shards.len())
        .filter(|i| !exclude.contains(i))
        .filter(|&i| shards[i].available())
        .collect();
    if cands.is_empty() {
        return None;
    }
    match kind {
        PlacementKind::RoundRobin => {
            Some(cands[rr.fetch_add(1, Ordering::Relaxed) % cands.len()])
        }
        PlacementKind::LeastLoaded => cands
            .iter()
            .copied()
            .min_by_key(|&i| (shards[i].load(), i)),
        PlacementKind::Calibrated => {
            let codelet = crate::apps::app_codelet_name(app);
            let scored: Vec<(usize, usize)> = cands
                .iter()
                .map(|&i| (i, shards[i].calibration_samples(codelet, size)))
                .collect();
            let best = scored.iter().map(|&(_, s)| s).max().unwrap_or(0);
            if best == 0 {
                // nobody has seen this (codelet, size) yet: spread the
                // calibration work toward the least-loaded shards
                return Some(least_loaded_rr(shards, &cands, rr));
            }
            // among the equally-best-calibrated shards, prefer the one
            // with capacity to spare (same snapshot features the
            // in-process selection layer keys on), rotating over load
            // ties so a steady workload never pins the lowest index
            let best_set: Vec<usize> = scored
                .into_iter()
                .filter(|&(_, s)| s == best)
                .map(|(i, _)| i)
                .collect();
            Some(least_loaded_rr(shards, &best_set, rr))
        }
    }
}

/// Least-loaded member of `set`, breaking load ties round-robin. Loads
/// are read once into a snapshot: the health poll updates them
/// concurrently, and re-reading between the min pass and the filter
/// pass could leave the tie set empty.
fn least_loaded_rr(shards: &[Arc<ShardState>], set: &[usize], rr: &AtomicUsize) -> usize {
    let loads: Vec<(usize, u64)> = set.iter().map(|&i| (i, shards[i].load())).collect();
    let min_load = loads
        .iter()
        .map(|&(_, l)| l)
        .min()
        .expect("set is non-empty");
    let idle: Vec<usize> = loads
        .into_iter()
        .filter(|&(_, l)| l == min_load)
        .map(|(i, _)| i)
        .collect();
    idle[rr.fetch_add(1, Ordering::Relaxed) % idle.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shards(n: usize) -> Vec<Arc<ShardState>> {
        (0..n)
            .map(|i| Arc::new(ShardState::new(format!("127.0.0.1:{}", 7400 + i))))
            .collect()
    }

    #[test]
    fn parse_and_name_roundtrip() {
        for k in [
            PlacementKind::RoundRobin,
            PlacementKind::LeastLoaded,
            PlacementKind::Calibrated,
        ] {
            assert_eq!(PlacementKind::parse(k.name()), Some(k));
        }
        assert_eq!(PlacementKind::parse("rr"), Some(PlacementKind::RoundRobin));
        assert_eq!(PlacementKind::parse("nope"), None);
    }

    #[test]
    fn round_robin_rotates_over_available() {
        let s = shards(3);
        s[1].set_healthy(false);
        let rr = AtomicUsize::new(0);
        let picks: Vec<usize> = (0..4)
            .map(|_| pick(PlacementKind::RoundRobin, &s, "matmul", 64, &[], &rr).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn exclusion_and_exhaustion() {
        let s = shards(2);
        let rr = AtomicUsize::new(0);
        let p = pick(PlacementKind::RoundRobin, &s, "matmul", 64, &[0], &rr).unwrap();
        assert_eq!(p, 1);
        assert_eq!(
            pick(PlacementKind::RoundRobin, &s, "matmul", 64, &[0, 1], &rr),
            None
        );
        s[0].set_healthy(false);
        s[1].set_draining(true);
        assert_eq!(pick(PlacementKind::RoundRobin, &s, "matmul", 64, &[], &rr), None);
    }

    #[test]
    fn least_loaded_prefers_idle_shard() {
        let s = shards(3);
        s[0].set_inflight(5);
        s[1].set_inflight(1);
        s[2].set_inflight(9);
        let rr = AtomicUsize::new(0);
        let p = pick(PlacementKind::LeastLoaded, &s, "matmul", 64, &[], &rr).unwrap();
        assert_eq!(p, 1);
    }

    #[test]
    fn least_loaded_counts_queue_depth_not_just_inflight() {
        let s = shards(2);
        s[0].set_inflight(1);
        s[1].set_inflight(2);
        // shard 0 has fewer in flight but a deep runtime queue behind
        // them: the v4 snapshot field flips the decision
        s[0].set_queue_depth(10);
        let rr = AtomicUsize::new(0);
        let p = pick(PlacementKind::LeastLoaded, &s, "matmul", 64, &[], &rr).unwrap();
        assert_eq!(p, 1);
    }

    #[test]
    fn calibrated_splits_equally_calibrated_shards_by_load() {
        use crate::taskrt::perfmodel::VariantModel;
        use std::collections::BTreeMap;
        let s = shards(2);
        let mut models: BTreeMap<String, VariantModel> = BTreeMap::new();
        let m = models.entry("mmul:omp".into()).or_default();
        for _ in 0..4 {
            m.record(64, 0.01);
        }
        // both shards equally calibrated; shard 0 is swamped
        s[0].set_calib(models.clone());
        s[1].set_calib(models);
        s[0].set_inflight(6);
        s[0].set_queue_depth(4);
        let rr = AtomicUsize::new(0);
        for _ in 0..3 {
            let p = pick(PlacementKind::Calibrated, &s, "matmul", 64, &[], &rr).unwrap();
            assert_eq!(p, 1, "equally calibrated: load decides");
        }
    }

    #[test]
    fn calibrated_routes_to_the_shard_that_knows_the_size() {
        use crate::taskrt::perfmodel::VariantModel;
        use std::collections::BTreeMap;
        let s = shards(2);
        let mut models: BTreeMap<String, VariantModel> = BTreeMap::new();
        let m = models.entry("mmul:omp".into()).or_default();
        for _ in 0..4 {
            m.record(64, 0.01);
        }
        s[1].set_calib(models);
        let rr = AtomicUsize::new(0);
        // calibrated size goes to shard 1 every time
        for _ in 0..3 {
            let p = pick(PlacementKind::Calibrated, &s, "matmul", 64, &[], &rr).unwrap();
            assert_eq!(p, 1);
        }
        // an unseen size falls back to round-robin over both shards
        let picks: Vec<usize> = (0..4)
            .map(|_| pick(PlacementKind::Calibrated, &s, "matmul", 999, &[], &rr).unwrap())
            .collect();
        assert!(picks.contains(&0) && picks.contains(&1), "{picks:?}");
    }
}
