//! Shard-level elastic scaling: spawn a new `compar serve` process (or
//! retire the least-loaded one) when the cluster's aggregate load
//! crosses the policy bands — the cross-process twin of the in-process
//! worker migration in [`crate::autoscale`].
//!
//! The router's scale loop (see [`super::router`]) owns the decisions;
//! this module supplies its configuration and the [`ShardLauncher`]
//! abstraction over *how* shards come and go: a real child process
//! (`compar serve` via [`ProcessLauncher`], the production path) or an
//! in-process [`crate::serve::Server`] ([`InProcessLauncher`], tests
//! and `loadgen --shards`). A spawned shard is gossip-seeded with the
//! merged perf models of the existing shards *before* it enters the
//! rotation, so it serves its first request already calibrated.

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::serve::{Client, ServeOptions, Server};

/// Shard-scaling configuration (`compar route --autoscale ...`).
#[derive(Debug, Clone)]
pub struct ClusterScaleOptions {
    /// Never retire below this many live shards.
    pub min_shards: usize,
    /// Never spawn above this many live shards.
    pub max_shards: usize,
    /// Per-available-shard load (in-flight + runtime queue depth, the
    /// health poll's snapshot features) at which the cluster wants a
    /// new shard.
    pub up_load: u64,
    /// Per-shard load at or below which the cluster retires one.
    pub down_load: u64,
    /// Consecutive pressured (or idle) rounds before acting.
    pub sustain: usize,
    /// Token-bucket refill window between scale actions.
    pub cooldown: Duration,
    /// Scale-loop sampling period.
    pub period: Duration,
    /// Worker count passed to process-spawned shards (`--spawn-ncpu`).
    pub spawn_ncpu: usize,
    /// Extra `compar serve` flags for process-spawned shards
    /// (`--spawn-args "--contexts hot:2,pool:2 --selector contextual"`).
    /// Spawned shards must match the existing shards' topology: a
    /// request naming a scheduling context fails on a shard that does
    /// not have it.
    pub spawn_args: Vec<String>,
}

impl Default for ClusterScaleOptions {
    fn default() -> ClusterScaleOptions {
        ClusterScaleOptions {
            min_shards: 1,
            max_shards: 4,
            up_load: 8,
            down_load: 1,
            sustain: 2,
            cooldown: Duration::from_millis(1000),
            period: Duration::from_millis(200),
            spawn_ncpu: 2,
            spawn_args: Vec::new(),
        }
    }
}

/// How the router brings shards up and down.
pub trait ShardLauncher: Send + Sync {
    /// Bring up a shard and return its address once it accepts
    /// connections.
    fn spawn(&self) -> Result<String>;
    /// Gracefully stop the shard at `addr` (it drains first).
    fn stop(&self, addr: &str) -> Result<()>;
}

/// Wait until `addr` accepts a TCP connection (readiness probe).
fn wait_ready(addr: &str, timeout: Duration) -> Result<()> {
    let deadline = Instant::now() + timeout;
    loop {
        if TcpStream::connect(addr).is_ok() {
            return Ok(());
        }
        if Instant::now() >= deadline {
            bail!("shard {addr} never came up within {timeout:?}");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Spawns real `compar serve` child processes — the production path of
/// `compar route --autoscale`.
pub struct ProcessLauncher {
    exe: PathBuf,
    ncpu: usize,
    /// Extra `serve` flags so spawned shards match the existing shards'
    /// topology (contexts, selector, scheduler, cap).
    extra_args: Vec<String>,
    children: Mutex<HashMap<String, Child>>,
}

impl ProcessLauncher {
    /// Launch shards with this binary (`current_exe`) itself, passing
    /// `extra_args` through to every spawned `compar serve`.
    pub fn from_current_exe(ncpu: usize, extra_args: Vec<String>) -> Result<ProcessLauncher> {
        Ok(ProcessLauncher {
            exe: std::env::current_exe().context("resolving current executable")?,
            ncpu: ncpu.max(1),
            extra_args,
            children: Mutex::new(HashMap::new()),
        })
    }
}

impl ShardLauncher for ProcessLauncher {
    fn spawn(&self) -> Result<String> {
        // reserve an ephemeral port, then hand it to the child. The
        // small window between drop and the child's bind is racy in
        // principle; a lost race fails the readiness probe and the
        // scale loop simply retries on a later round.
        let port = {
            let probe = TcpListener::bind("127.0.0.1:0").context("probing for a free port")?;
            probe.local_addr()?.port()
        };
        let addr = format!("127.0.0.1:{port}");
        let child = Command::new(&self.exe)
            .arg("serve")
            .arg("--addr")
            .arg(&addr)
            .arg("--ncpu")
            .arg(self.ncpu.to_string())
            .args(&self.extra_args)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .with_context(|| format!("spawning {} serve", self.exe.display()))?;
        if let Err(e) = wait_ready(&addr, Duration::from_secs(10)) {
            let mut child = child;
            let _ = child.kill();
            let _ = child.wait();
            return Err(e);
        }
        self.children.lock().unwrap().insert(addr.clone(), child);
        Ok(addr)
    }

    fn stop(&self, addr: &str) -> Result<()> {
        let child = self.children.lock().unwrap().remove(addr);
        // graceful: the serve process drains in-flight work on shutdown
        let sent = Client::connect_with_deadline(addr, Duration::from_secs(2))
            .and_then(|mut c| c.shutdown_server());
        if let Some(mut child) = child {
            if sent.is_err() {
                let _ = child.kill();
            }
            let _ = child.wait();
        }
        sent
    }
}

impl Drop for ProcessLauncher {
    fn drop(&mut self) {
        for (_, mut child) in self.children.lock().unwrap().drain() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

/// Boots in-process [`Server`]s on ephemeral ports — tests, the bench
/// harness and `loadgen --shards` with autoscaling.
pub struct InProcessLauncher {
    serve: ServeOptions,
    servers: Mutex<HashMap<String, Server>>,
}

impl InProcessLauncher {
    pub fn new(serve: ServeOptions) -> InProcessLauncher {
        let mut serve = serve;
        serve.addr = "127.0.0.1:0".into();
        InProcessLauncher {
            serve,
            servers: Mutex::new(HashMap::new()),
        }
    }

    /// Drain every shard this launcher still owns (end-of-run cleanup).
    pub fn shutdown_all(&self) {
        for (_, server) in self.servers.lock().unwrap().drain() {
            let _ = server.shutdown();
        }
    }
}

impl ShardLauncher for InProcessLauncher {
    fn spawn(&self) -> Result<String> {
        let server = Server::start(self.serve.clone())?;
        let addr = server.local_addr().to_string();
        self.servers.lock().unwrap().insert(addr.clone(), server);
        Ok(addr)
    }

    fn stop(&self, addr: &str) -> Result<()> {
        match self.servers.lock().unwrap().remove(addr) {
            Some(server) => {
                server.shutdown()?;
                Ok(())
            }
            // not ours (one of the router's initial shards): drain it
            // over the wire like the process launcher would
            None => Client::connect_with_deadline(addr, Duration::from_secs(2))
                .and_then(|mut c| c.shutdown_server()),
        }
    }
}
