//! `cluster` — sharded multi-process serving (`compar route`).
//!
//! The serve layer scales one process; this layer scales across
//! processes while keeping the programmer-facing surface a single
//! endpoint (HSTREAM's "unified API, distributed runtime" shape): a
//! **router** speaks the exact same NDJSON protocol as `compar serve`,
//! so existing clients and the load generator work unchanged, and fans
//! submits out over N backend shards.
//!
//! ```text
//!                        ┌────────────────────────────────────────────┐
//!                        │              compar route                  │
//! clients ──NDJSON/TCP──▶│ sessions ─▶ placement (rr / least-loaded / │
//!  (unchanged protocol)  │             calibrated) ─▶ shard backends  │
//!                        │ health probe ─ drain ─ retry-on-failure    │
//!                        │ gossip: perf_pull* ─▶ merge ─▶ perf_push   │
//!                        └──────┬──────────────────┬──────────────────┘
//!                               ▼                  ▼
//!                      compar serve shard A   compar serve shard B
//!                      (scheduling contexts,  (scheduling contexts,
//!                       selection policies,    selection policies,
//!                       local PerfModels   ◀─gossip─▶  local PerfModels
//!                       + remote overlay)      + remote overlay)
//! ```
//!
//! What makes this more than a TCP proxy is the **perf-model gossip**
//! (see [`gossip`]): selection quality — the paper's core metric — stops
//! being a per-process property. A variant calibrated by traffic on one
//! shard seeds the selection priors of every other shard within a gossip
//! round, so a cold shard joins the cluster already knowing the variant
//! ranking. The `calibrated` placement policy closes the loop from the
//! other side: requests are routed toward the shard that already knows
//! their (codelet, size).
//!
//! Layers (each its own module):
//! * [`placement`] — pluggable shard-placement policies.
//! * [`router`] — sessions, fan-out, health, drain, retry, shutdown,
//!   and the shard-scaling control loop.
//! * [`gossip`] — the pull/merge/push round over protocol v3 (also
//!   seeds autoscale-spawned shards before they enter the rotation).
//! * [`autoscale`] — shard-scaling configuration and the
//!   [`autoscale::ShardLauncher`] process/in-process backends.

pub mod autoscale;
pub mod gossip;
pub mod placement;
pub mod router;

pub use autoscale::{ClusterScaleOptions, InProcessLauncher, ProcessLauncher, ShardLauncher};
pub use placement::PlacementKind;
pub use router::{Router, RouterOptions, ShardState};

use anyhow::{bail, Result};

use crate::serve::protocol::StatsResp;
use crate::serve::{ServeOptions, Server};

/// An in-process cluster: N serve shards on ephemeral loopback ports
/// behind one router — tests, `compar loadgen --shards N`, and the
/// cluster bench.
pub struct LocalCluster {
    pub shards: Vec<Server>,
    pub router: Router,
}

impl LocalCluster {
    /// Boot `n` shards (each a full [`Server`] with `serve`'s
    /// configuration, bound to an ephemeral port) and a router over
    /// them. `ropts.shards` is filled in; `ropts.listen` is honoured.
    pub fn start(n: usize, serve: &ServeOptions, mut ropts: RouterOptions) -> Result<LocalCluster> {
        if n == 0 {
            bail!("need at least one shard");
        }
        let mut shards = Vec::with_capacity(n);
        let mut addrs = Vec::with_capacity(n);
        for _ in 0..n {
            let mut so = serve.clone();
            so.addr = "127.0.0.1:0".into();
            let s = Server::start(so)?;
            addrs.push(s.local_addr().to_string());
            shards.push(s);
        }
        ropts.shards = addrs;
        let router = Router::start(ropts)?;
        Ok(LocalCluster { shards, router })
    }

    /// Boot an *elastic* in-process cluster: like [`LocalCluster::start`]
    /// but with the shard scaler enabled, spawning additional in-process
    /// shards through the returned [`InProcessLauncher`] (drain it with
    /// `shutdown_all` after [`LocalCluster::shutdown`]).
    pub fn start_elastic(
        n: usize,
        serve: &ServeOptions,
        mut ropts: RouterOptions,
        scale: autoscale::ClusterScaleOptions,
    ) -> Result<(LocalCluster, std::sync::Arc<InProcessLauncher>)> {
        if n == 0 {
            bail!("need at least one shard");
        }
        let mut shards = Vec::with_capacity(n);
        let mut addrs = Vec::with_capacity(n);
        for _ in 0..n {
            let mut so = serve.clone();
            so.addr = "127.0.0.1:0".into();
            let s = Server::start(so)?;
            addrs.push(s.local_addr().to_string());
            shards.push(s);
        }
        ropts.shards = addrs;
        ropts.autoscale = Some(scale);
        let launcher = std::sync::Arc::new(InProcessLauncher::new(serve.clone()));
        let router = Router::start_with_launcher(ropts, Some(launcher.clone()))?;
        Ok((LocalCluster { shards, router }, launcher))
    }

    /// The router's client-facing address.
    pub fn addr(&self) -> String {
        self.router.local_addr().to_string()
    }

    /// Drain the router, then every shard; returns per-shard stats.
    pub fn shutdown(self) -> Result<Vec<StatsResp>> {
        self.router.shutdown()?;
        let mut out = Vec::with_capacity(self.shards.len());
        for s in self.shards {
            out.push(s.shutdown()?);
        }
        Ok(out)
    }
}
