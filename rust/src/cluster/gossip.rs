//! Router-mediated perf-model gossip.
//!
//! Every round the router **pulls** each live shard's *locally observed*
//! perf-model bucket summaries (`perf_pull` — a `{count, mean, m2,
//! ewma, updated}` record per (codelet:variant, size)), then **pushes**
//! to each shard the combined summary of every *other* shard
//! (`perf_push`): means/variances Welford-combine exactly, decayed
//! means merge by recency (the shard with the fresher `updated` stamp
//! wins, so a drifting shard's observations dominate stale ones). The
//! receiving shard installs the payload as a replaceable remote overlay
//! ([`crate::taskrt::PerfModels::set_remote_json`]), so:
//!
//! * a variant calibrated on shard A is calibrated on shard B one round
//!   later — B's Calibrating/Greedy/EpsilonGreedy policies skip the
//!   cold-start exploration entirely (the Optimized-Composition
//!   "transferable performance data" property, across processes);
//! * no sample is ever counted twice: a shard only ever ships what it
//!   measured itself, and the overlay is replaced, not accumulated;
//! * the payload is bounded by the number of distinct (codelet,
//!   variant, size) triples, independent of traffic volume.
//!
//! Pulls run even when pushing is disabled (`compar route --no-gossip`):
//! the pulled summaries also feed the `calibrated` placement policy.
//!
//! **Deployment caveat:** the no-double-counting argument assumes each
//! shard's *local* layer holds only its own measurements. Shards that
//! share one persisted `COMPAR_PERFMODEL_DIR` all load the same
//! `models.json` into their local layer at startup and would each ship
//! those samples as their own — give clustered shards distinct
//! perf-model directories (or none).
//!
//! v8: each pull also carries the shard's **banded selection summary**
//! ([`crate::taskrt::Runtime::export_selection_bands`] — the contextual
//! policy's (size band, load band) EWMA buckets), and pushes ship every
//! *other* shard's bands alongside the models. The receiving policy
//! merges count-monotonically (a remote bucket wins only with strictly
//! more observations), so re-delivery is idempotent and stale gossip
//! never regresses local learning — and a graph planner on shard B
//! prices variants with interference evidence observed on shard A.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use super::router::ShardState;
use crate::serve::Client;
use crate::taskrt::perfmodel::{merge_models, models_to_json, parse_models, VariantModel};
use crate::util::json::Json;

/// Outcome of one gossip round (diagnostics).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RoundStats {
    /// Shards whose models were pulled this round.
    pub pulled: usize,
    /// Shards that accepted a pushed overlay this round.
    pub pushed: usize,
}

/// Pull every live shard's local models; when `push` is set, push each
/// shard the combined summary of the *others*.
pub fn run_round(shards: &[Arc<ShardState>], push: bool) -> RoundStats {
    let mut stats = RoundStats::default();
    for shard in shards {
        if !shard.healthy() {
            continue;
        }
        if let Ok((models, bands)) = pull(&shard.addr) {
            shard.set_calib(models);
            shard.set_bands(bands);
            stats.pulled += 1;
        }
    }
    if !push {
        return stats;
    }
    for (i, shard) in shards.iter().enumerate() {
        if !shard.healthy() {
            continue;
        }
        let mut merged: BTreeMap<String, VariantModel> = BTreeMap::new();
        let mut bands: Vec<Json> = Vec::new();
        for (j, other) in shards.iter().enumerate() {
            if i == j {
                continue; // never send a shard its own samples back
            }
            merge_models(&mut merged, &other.calib_clone());
            if let Some(Json::Arr(mut a)) = other.bands_clone() {
                bands.append(&mut a);
            }
        }
        if merged.is_empty() && bands.is_empty() {
            continue;
        }
        let bands = if bands.is_empty() {
            None
        } else {
            Some(Json::Arr(bands))
        };
        if push_models(&shard.addr, &models_to_json(&merged), bands.as_ref()).is_ok() {
            stats.pushed += 1;
        }
    }
    stats
}

/// Seed a freshly spawned shard with the combined models of the
/// existing shards (shard autoscaling): pushed *before* the newcomer
/// enters the routing rotation, so it serves its first request already
/// calibrated — no per-shard recalibration window. Returns the number
/// of buckets the newcomer accepted (0 when the cluster holds no
/// models yet).
pub fn seed_newcomer(addr: &str, existing: &[Arc<ShardState>]) -> Result<u64> {
    let mut merged: BTreeMap<String, VariantModel> = BTreeMap::new();
    let mut bands: Vec<Json> = Vec::new();
    for shard in existing {
        if shard.healthy() {
            merge_models(&mut merged, &shard.calib_clone());
            if let Some(Json::Arr(mut a)) = shard.bands_clone() {
                bands.append(&mut a);
            }
        }
    }
    if merged.is_empty() && bands.is_empty() {
        return Ok(0);
    }
    let bands = if bands.is_empty() {
        None
    } else {
        Some(Json::Arr(bands))
    };
    push_models(addr, &models_to_json(&merged), bands.as_ref())
}

fn pull(addr: &str) -> Result<(BTreeMap<String, VariantModel>, Option<Json>)> {
    let mut c = Client::connect_with_deadline(addr, super::router::ADMIN_TIMEOUT)?;
    let (models, bands) = c.perf_pull_full()?;
    let _ = c.quit();
    Ok((parse_models(&models), bands))
}

fn push_models(addr: &str, models: &Json, bands: Option<&Json>) -> Result<u64> {
    let mut c = Client::connect_with_deadline(addr, super::router::ADMIN_TIMEOUT)?;
    let merged = c.perf_push_full(models, bands)?;
    let _ = c.quit();
    Ok(merged)
}
