//! The cluster router (`compar route`): speaks the serve protocol to
//! clients and fans submits out over N backend `compar serve` shards.
//!
//! v7 framing: each client session negotiates its wire framing (ndjson
//! or binary) in `hello`, and the router forwards that choice to the
//! backend connections it opens for the session — a binary client gets
//! binary hops end to end. Admin traffic (health probes, gossip,
//! shutdown fan-out) stays on default-framing [`Client`] connections:
//! it is low-rate and worth keeping trivially debuggable.
//!
//! ```text
//! client ──TCP──▶ router session ──placement──▶ shard A (compar serve)
//!                  │   ▲                   └──▶ shard B (compar serve)
//!                  │   └── backend readers forward tagged results
//!                  ├── health thread: stats probe, mark ±healthy
//!                  └── gossip thread: perf_pull* → merge → perf_push
//! ```
//!
//! Lifecycle guarantees:
//!
//! * **health** — a background thread polls every shard's `stats`; a
//!   failed probe (or a failed submit write) marks the shard unhealthy
//!   and placement skips it until a probe succeeds again.
//! * **drain** — `drain_shard` takes a shard out of the rotation without
//!   killing it: in-flight requests on it complete normally, new submits
//!   go elsewhere.
//! * **retry-on-other-shard** — a submit whose shard connection fails
//!   (on write, or while the reply is pending when the connection dies)
//!   is transparently resubmitted to the next available shard; the
//!   client just sees its result. Requests are idempotent by
//!   construction (a fresh problem instance per request), so a
//!   duplicated execution on a shard that died mid-flight is wasted
//!   work, never a wrong answer.
//! * **shutdown** — a client `shutdown` is forwarded to every shard
//!   (each drains gracefully), then the router itself drains.

use std::collections::{BTreeMap, HashMap};
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::autoscale::{ClusterScaleOptions, ProcessLauncher, ShardLauncher};
use super::gossip;
use super::placement::{self, PlacementKind};
use crate::autoscale::TokenBucket;
use crate::serve::protocol::{
    self, AutoscaleResp, DecisionsResp, MetricsResp, Request, Response, ShardDesc, StatsResp,
    StreamOpenReq, SubmitGraphReq, SubmitReq, TraceResp, PROTOCOL_VERSION,
};
use crate::serve::transport::codec::{encode_frame, FrameDecoder, Framing};
use crate::serve::Client;
use crate::taskrt::perfmodel::VariantModel;
use crate::taskrt::{SelectorKind, VALID_SELECTORS};
use crate::util::json::Json;

// ---------------------------------------------------------- configuration

/// Router configuration (`compar route` flags).
#[derive(Debug, Clone)]
pub struct RouterOptions {
    /// Bind address; port 0 for an ephemeral port (tests).
    pub listen: String,
    /// Backend `compar serve` addresses.
    pub shards: Vec<String>,
    pub placement: PlacementKind,
    /// Health-probe period (a `stats` round trip per shard).
    pub health_period: Duration,
    /// Gossip period (perf-model pull round, plus a push when enabled).
    pub gossip_period: Duration,
    /// Push merged perf models back to the shards. Pulls always run —
    /// they also feed the `calibrated` placement policy.
    pub gossip: bool,
    /// Shard-level elastic scaling (`--autoscale`): spawn/retire
    /// `compar serve` processes as aggregate load crosses the bands.
    /// `None` = the shard set is static.
    pub autoscale: Option<ClusterScaleOptions>,
}

impl Default for RouterOptions {
    fn default() -> RouterOptions {
        RouterOptions {
            listen: "127.0.0.1:7190".into(),
            shards: Vec::new(),
            placement: PlacementKind::RoundRobin,
            health_period: Duration::from_millis(300),
            gossip_period: Duration::from_millis(500),
            gossip: true,
            autoscale: None,
        }
    }
}

// ------------------------------------------------------------ shard state

/// The router's live view of one backend shard.
pub struct ShardState {
    pub addr: String,
    healthy: AtomicBool,
    draining: AtomicBool,
    /// Permanently out of the cluster (stopped by the shard scaler).
    /// Entries are never removed from the table — session `Pending`
    /// records and placement results index into it — so retirement is
    /// a terminal flag, not a removal.
    retired: AtomicBool,
    inflight: AtomicU64,
    requests_ok: AtomicU64,
    /// Tasks queued inside the shard's runtime at the last health poll
    /// (the v4 stats `queue_depth` snapshot field; placement reuses it
    /// as a load signal alongside `inflight`).
    queue_depth: AtomicU64,
    /// Open stream sessions on the shard at the last health poll (the
    /// v6 stats `streams` gauge). A stream is a standing commitment of
    /// shard capacity, so placement counts each one as load even
    /// between chunks.
    streams: AtomicU64,
    /// The shard's locally observed perf models, from the last gossip
    /// pull (feeds the `calibrated` placement policy and the push merge).
    calib: Mutex<BTreeMap<String, VariantModel>>,
    /// The shard's banded selection summary from the last gossip pull
    /// (v8); pushed to the *other* shards so their graph planners price
    /// variants with this shard's interference evidence.
    bands: Mutex<Option<Json>>,
}

impl ShardState {
    pub(crate) fn new(addr: String) -> ShardState {
        ShardState {
            addr,
            // optimistic start: the first failed probe or submit marks
            // the shard down
            healthy: AtomicBool::new(true),
            draining: AtomicBool::new(false),
            retired: AtomicBool::new(false),
            inflight: AtomicU64::new(0),
            requests_ok: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            streams: AtomicU64::new(0),
            calib: Mutex::new(BTreeMap::new()),
            bands: Mutex::new(None),
        }
    }

    /// In the routing rotation: healthy, not drained, not retired.
    pub fn available(&self) -> bool {
        self.healthy.load(Ordering::Relaxed)
            && !self.draining.load(Ordering::Relaxed)
            && !self.retired.load(Ordering::Relaxed)
    }

    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::Relaxed)
    }

    pub fn retired(&self) -> bool {
        self.retired.load(Ordering::Relaxed)
    }

    pub fn healthy(&self) -> bool {
        self.healthy.load(Ordering::Relaxed)
    }

    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Runtime queue depth reported by the last health poll.
    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// Open streams reported by the last health poll (v6).
    pub fn streams(&self) -> u64 {
        self.streams.load(Ordering::Relaxed)
    }

    /// Combined load signal for placement: requests in flight plus
    /// tasks queued inside the shard's runtime (the snapshot features
    /// the selection layer uses, reused at the cluster level), plus
    /// one unit per open stream — a quiet stream still claims credit
    /// and will burst again, so new work prefers stream-free shards.
    pub fn load(&self) -> u64 {
        self.inflight() + self.queue_depth() + self.streams()
    }

    pub(crate) fn set_healthy(&self, v: bool) {
        self.healthy.store(v, Ordering::Relaxed);
    }

    pub(crate) fn set_draining(&self, v: bool) {
        self.draining.store(v, Ordering::Relaxed);
    }

    pub(crate) fn set_retired(&self) {
        self.retired.store(true, Ordering::Relaxed);
        self.healthy.store(false, Ordering::Relaxed);
    }

    // Not #[cfg(test)]: the verification model (`crate::model::shard`)
    // drives real ShardState values through placement with synthetic
    // loads, exactly like the placement unit tests do.
    pub(crate) fn set_inflight(&self, v: u64) {
        self.inflight.store(v, Ordering::Relaxed);
    }

    pub(crate) fn set_queue_depth(&self, v: u64) {
        self.queue_depth.store(v, Ordering::Relaxed);
    }

    pub(crate) fn set_calib(&self, models: BTreeMap<String, VariantModel>) {
        *self.calib.lock().unwrap() = models;
    }

    pub(crate) fn calib_clone(&self) -> BTreeMap<String, VariantModel> {
        self.calib.lock().unwrap().clone()
    }

    pub(crate) fn set_bands(&self, bands: Option<Json>) {
        *self.bands.lock().unwrap() = bands;
    }

    pub(crate) fn bands_clone(&self) -> Option<Json> {
        self.bands.lock().unwrap().clone()
    }

    /// Samples this shard holds for `codelet` at exactly `size`, summed
    /// over variants (the `calibrated` placement score). Key format is
    /// the perf-model store's "codelet:variant".
    pub fn calibration_samples(&self, codelet: &str, size: usize) -> usize {
        let prefix = format!("{codelet}:");
        self.calib
            .lock()
            .unwrap()
            .iter()
            .filter(|(k, _)| k.starts_with(&prefix))
            .filter_map(|(_, m)| m.buckets.get(&size))
            .map(|b| b.count)
            .sum()
    }

    fn desc(&self) -> ShardDesc {
        ShardDesc {
            addr: self.addr.clone(),
            healthy: self.healthy.load(Ordering::Relaxed),
            // a retired shard reads as permanently draining on the wire
            draining: self.draining.load(Ordering::Relaxed) || self.retired.load(Ordering::Relaxed),
            inflight: self.inflight.load(Ordering::Relaxed),
            requests_ok: self.requests_ok.load(Ordering::Relaxed),
        }
    }
}

// ------------------------------------------------------------- the router

struct RouterShared {
    placement: PlacementKind,
    /// The shard table. Append-only: the shard scaler adds spawned
    /// shards at the tail and marks retired ones rather than removing
    /// them, so a shard *index* (used by session pending-maps and
    /// result tags) stays valid for the router's lifetime.
    shards: RwLock<Vec<Arc<ShardState>>>,
    /// Placement rotation cursor (shared by every session).
    rr: AtomicUsize,
    draining: AtomicBool,
    stop: Mutex<bool>,
    stop_cv: Condvar,
    next_session: AtomicU64,
    sessions: Mutex<Vec<JoinHandle<()>>>,
    /// Submits forwarded to a shard.
    routed: AtomicU64,
    /// Submits re-routed to another shard after a failure.
    retried: AtomicU64,
    /// Shard scaling state (v5 `autoscale_status`).
    autoscale_on: AtomicBool,
    shards_spawned: AtomicU64,
    shards_retired: AtomicU64,
    /// v9 observability: trace ids the router mints for requests that
    /// arrive untraced, so the id rides client → router → shard. Seeded
    /// past the 32-bit range so router-minted ids cannot collide with
    /// ids a shard mints for its own direct clients.
    next_trace: AtomicU64,
    started: Instant,
}

impl RouterShared {
    /// Snapshot of the shard table. Indices in the returned vector are
    /// the global shard indices (the table is append-only).
    fn shard_list(&self) -> Vec<Arc<ShardState>> {
        self.shards.read().unwrap().clone()
    }

    fn shard(&self, i: usize) -> Option<Arc<ShardState>> {
        self.shards.read().unwrap().get(i).cloned()
    }

    /// Append a freshly spawned shard to the table (already seeded with
    /// gossip models; enters the rotation immediately).
    fn add_shard(&self, addr: String) -> usize {
        let mut shards = self.shards.write().unwrap();
        shards.push(Arc::new(ShardState::new(addr)));
        shards.len() - 1
    }

    /// Shards neither retired nor draining (the scaler's population).
    fn live_shards(&self) -> Vec<Arc<ShardState>> {
        self.shard_list()
            .into_iter()
            .filter(|s| !s.retired() && !s.draining())
            .collect()
    }
}

/// The routing front-end. `start` binds and returns immediately;
/// `serve_forever` blocks until a client sends `shutdown`.
pub struct Router {
    local_addr: SocketAddr,
    shared: Arc<RouterShared>,
    accept: Option<JoinHandle<()>>,
    health: Option<JoinHandle<()>>,
    gossip: Option<JoinHandle<()>>,
    scaler: Option<JoinHandle<()>>,
}

impl Router {
    /// Start with the default shard launcher: when `opts.autoscale` is
    /// set, spawned shards are real `compar serve` child processes of
    /// this binary.
    pub fn start(opts: RouterOptions) -> Result<Router> {
        let launcher: Option<Arc<dyn ShardLauncher>> = match &opts.autoscale {
            Some(a) => Some(Arc::new(ProcessLauncher::from_current_exe(
                a.spawn_ncpu,
                a.spawn_args.clone(),
            )?)),
            None => None,
        };
        Router::start_with_launcher(opts, launcher)
    }

    /// Start with an explicit [`ShardLauncher`] (tests and the bench
    /// harness use [`super::autoscale::InProcessLauncher`]).
    pub fn start_with_launcher(
        opts: RouterOptions,
        launcher: Option<Arc<dyn ShardLauncher>>,
    ) -> Result<Router> {
        if opts.shards.is_empty() {
            bail!("router needs at least one backend shard (--shards host:port,...)");
        }
        // validate the autoscale/launcher pairing *before* binding the
        // listener and spawning threads: bailing later would leak them
        if opts.autoscale.is_some() && launcher.is_none() {
            bail!("autoscale enabled without a shard launcher");
        }
        let listener = TcpListener::bind(&opts.listen)
            .with_context(|| format!("binding {}", opts.listen))?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(RouterShared {
            placement: opts.placement,
            shards: RwLock::new(
                opts.shards
                    .iter()
                    .map(|a| Arc::new(ShardState::new(a.clone())))
                    .collect(),
            ),
            rr: AtomicUsize::new(0),
            draining: AtomicBool::new(false),
            stop: Mutex::new(false),
            stop_cv: Condvar::new(),
            next_session: AtomicU64::new(1),
            sessions: Mutex::new(Vec::new()),
            routed: AtomicU64::new(0),
            retried: AtomicU64::new(0),
            autoscale_on: AtomicBool::new(opts.autoscale.is_some()),
            shards_spawned: AtomicU64::new(0),
            shards_retired: AtomicU64::new(0),
            next_trace: AtomicU64::new(1 << 32),
            started: Instant::now(),
        });
        let accept = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("route-accept".into())
                .spawn(move || accept_loop(shared, listener))
                .expect("spawning accept thread")
        };
        let health = {
            let shared = shared.clone();
            let period = opts.health_period;
            std::thread::Builder::new()
                .name("route-health".into())
                .spawn(move || health_loop(shared, period))
                .expect("spawning health thread")
        };
        let gossip = {
            let shared = shared.clone();
            let period = opts.gossip_period;
            let push = opts.gossip;
            std::thread::Builder::new()
                .name("route-gossip".into())
                .spawn(move || gossip_loop(shared, period, push))
                .expect("spawning gossip thread")
        };
        let scaler = match (opts.autoscale, launcher) {
            (Some(sopts), Some(launcher)) => {
                let shared = shared.clone();
                Some(
                    std::thread::Builder::new()
                        .name("route-scale".into())
                        .spawn(move || scale_loop(shared, sopts, launcher))
                        .expect("spawning shard-scale thread"),
                )
            }
            _ => None,
        };
        Ok(Router {
            local_addr,
            shared,
            accept: Some(accept),
            health: Some(health),
            gossip: Some(gossip),
            scaler,
        })
    }

    /// The actual bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The shard table, as `{"op":"shards"}` would report it.
    pub fn shards(&self) -> Vec<ShardDesc> {
        self.shared.shard_list().iter().map(|s| s.desc()).collect()
    }

    /// (shards spawned, shards retired) by the shard scaler.
    pub fn scale_counters(&self) -> (u64, u64) {
        (
            self.shared.shards_spawned.load(Ordering::Relaxed),
            self.shared.shards_retired.load(Ordering::Relaxed),
        )
    }

    /// (submits routed, submits retried on another shard).
    pub fn routing_counters(&self) -> (u64, u64) {
        (
            self.shared.routed.load(Ordering::Relaxed),
            self.shared.retried.load(Ordering::Relaxed),
        )
    }

    /// Block until a client sends `shutdown` (which is also forwarded to
    /// every shard), then drain the router.
    pub fn serve_forever(self) -> Result<()> {
        {
            let mut stop = self.shared.stop.lock().unwrap();
            while !*stop {
                stop = self.shared.stop_cv.wait(stop).unwrap();
            }
        }
        self.shutdown()
    }

    /// Drain the router: stop accepting, let sessions finish, join the
    /// background threads. The shards are left running (drain them
    /// separately, or send `shutdown` through a client, which forwards).
    pub fn shutdown(mut self) -> Result<()> {
        self.shared.draining.store(true, Ordering::SeqCst);
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        loop {
            let handles: Vec<JoinHandle<()>> =
                std::mem::take(&mut *self.shared.sessions.lock().unwrap());
            if handles.is_empty() {
                break;
            }
            for h in handles {
                let _ = h.join();
            }
        }
        if let Some(j) = self.health.take() {
            let _ = j.join();
        }
        if let Some(j) = self.gossip.take() {
            let _ = j.join();
        }
        if let Some(j) = self.scaler.take() {
            let _ = j.join();
        }
        Ok(())
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        if let Some(j) = self.accept.take() {
            let _ = j.join();
        }
        if let Some(j) = self.health.take() {
            let _ = j.join();
        }
        if let Some(j) = self.gossip.take() {
            let _ = j.join();
        }
        if let Some(j) = self.scaler.take() {
            let _ = j.join();
        }
    }
}

// ------------------------------------------------------ background threads

/// Sleep `period` in small slices so drain is observed promptly.
fn drain_aware_sleep(shared: &Arc<RouterShared>, period: Duration) {
    let deadline = Instant::now() + period;
    while Instant::now() < deadline && !shared.draining.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(20).min(period));
    }
}

fn accept_loop(shared: Arc<RouterShared>, listener: TcpListener) {
    while !shared.draining.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let sid = shared.next_session.fetch_add(1, Ordering::Relaxed);
                let shared2 = shared.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("route-session-{sid}"))
                    .spawn(move || session_loop(shared2, stream, sid))
                    .expect("spawning router session thread");
                let mut sessions = shared.sessions.lock().unwrap();
                // reap finished sessions so the list stays bounded
                crate::util::threads::reap_finished(&mut sessions);
                sessions.push(handle);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Probe every shard's `stats`; update health/load. A shard flapping
/// back up is re-admitted to the rotation here. Probes run concurrently
/// so one hung shard (bounded by [`ADMIN_TIMEOUT`]) delays the round by
/// the max probe time, not the sum.
fn health_loop(shared: Arc<RouterShared>, period: Duration) {
    while !shared.draining.load(Ordering::SeqCst) {
        let shards = shared.shard_list();
        std::thread::scope(|scope| {
            for shard in &shards {
                if shard.retired() {
                    continue; // the process is gone; don't probe-spam it
                }
                scope.spawn(move || match shard_stats(&shard.addr) {
                    Ok(stats) => {
                        shard.healthy.store(true, Ordering::Relaxed);
                        shard.inflight.store(stats.inflight, Ordering::Relaxed);
                        shard.requests_ok.store(stats.requests_ok, Ordering::Relaxed);
                        shard.queue_depth.store(stats.queue_depth, Ordering::Relaxed);
                        shard.streams.store(stats.streams, Ordering::Relaxed);
                    }
                    Err(_) => shard.healthy.store(false, Ordering::Relaxed),
                });
            }
        });
        drain_aware_sleep(&shared, period);
    }
}

fn gossip_loop(shared: Arc<RouterShared>, period: Duration, push: bool) {
    while !shared.draining.load(Ordering::SeqCst) {
        let live: Vec<Arc<ShardState>> = shared
            .shard_list()
            .into_iter()
            .filter(|s| !s.retired())
            .collect();
        gossip::run_round(&live, push);
        drain_aware_sleep(&shared, period);
    }
}

// ------------------------------------------------------- shard scaling

/// The shard-level elastic control loop (`compar route --autoscale`):
/// spawn a shard when the per-shard load stays above the high band,
/// retire the least-loaded one when it stays at the low band — same
/// hysteresis + token-bucket shape as the in-process worker scaler.
fn scale_loop(
    shared: Arc<RouterShared>,
    opts: ClusterScaleOptions,
    launcher: Arc<dyn ShardLauncher>,
) {
    let mut bucket = TokenBucket::new(1, opts.cooldown);
    let mut hot = 0usize;
    let mut cold = 0usize;
    // a scale-down is a *return* from pressure: an idle (or lightly
    // loaded) cluster keeps the shard count the operator configured.
    // `spawn_debt` counts scaler-spawned shards not yet reclaimed (a
    // burst that spawned two shards drains both back); `seen_load`
    // additionally allows one operator-shard retire per observed
    // pressure episode.
    let mut spawn_debt = 0usize;
    let mut seen_load = false;
    let mut last = Instant::now();
    while !shared.draining.load(Ordering::SeqCst) {
        let now = Instant::now();
        bucket.advance(now.duration_since(last));
        last = now;
        let live = shared.live_shards();
        let avail: Vec<&Arc<ShardState>> = live.iter().filter(|s| s.available()).collect();
        if !avail.is_empty() {
            let total: u64 = avail.iter().map(|s| s.load()).sum();
            let per_shard = total / avail.len() as u64;
            if per_shard >= opts.up_load {
                seen_load = true;
            }
            // min/max bound the *available* population, not the table:
            // a crashed (unhealthy) shard must neither block spawning
            // its replacement at max_shards nor count toward the floor
            // when retiring (retiring the last healthy shard would
            // leave the rotation empty)
            if per_shard >= opts.up_load && avail.len() < opts.max_shards {
                hot += 1;
            } else {
                hot = 0;
            }
            if (seen_load || spawn_debt > 0)
                && per_shard <= opts.down_load
                && avail.len() > opts.min_shards
            {
                cold += 1;
            } else {
                cold = 0;
            }
            if hot >= opts.sustain && bucket.try_take() {
                hot = 0;
                match spawn_shard(&shared, &*launcher) {
                    Ok(addr) => {
                        spawn_debt += 1;
                        eprintln!("route: scaled up, spawned shard {addr}");
                    }
                    Err(e) => eprintln!("route: shard spawn failed: {e:#}"),
                }
            } else if cold >= opts.sustain && bucket.try_take() {
                cold = 0;
                if spawn_debt > 0 {
                    spawn_debt -= 1;
                } else {
                    seen_load = false;
                }
                // retire the least-loaded available shard
                if let Some(victim) = avail
                    .iter()
                    .min_by_key(|s| (s.load(), s.addr.clone()))
                    .map(|s| (*s).clone())
                {
                    retire_shard(&shared, &victim, &*launcher);
                    eprintln!("route: scaled down, retired shard {}", victim.addr);
                }
            }
        }
        drain_aware_sleep(&shared, opts.period);
    }
}

/// Spawn a shard, gossip-seed it with the merged perf models of the
/// existing shards (it serves its first request already calibrated),
/// then add it to the routing rotation.
fn spawn_shard(shared: &Arc<RouterShared>, launcher: &dyn ShardLauncher) -> Result<String> {
    let addr = launcher.spawn()?;
    let existing = shared.live_shards();
    if let Err(e) = gossip::seed_newcomer(&addr, &existing) {
        // non-fatal: the shard still works, it just recalibrates
        eprintln!("route: gossip-seeding {addr} failed: {e:#}");
    }
    shared.add_shard(addr.clone());
    shared.shards_spawned.fetch_add(1, Ordering::Relaxed);
    Ok(addr)
}

/// Drain `victim` out of the rotation, wait (bounded) for its in-flight
/// requests to finish, then stop the process and mark it retired.
fn retire_shard(
    shared: &Arc<RouterShared>,
    victim: &Arc<ShardState>,
    launcher: &dyn ShardLauncher,
) {
    victim.set_draining(true);
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline && !shared.draining.load(Ordering::SeqCst) {
        match shard_stats(&victim.addr) {
            Ok(stats) if stats.inflight == 0 => break,
            Ok(_) => {}
            Err(_) => break, // unreachable — nothing left to wait for
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    // graceful stop: the serve process itself drains before exiting, so
    // a straggler request still completes and its reply is delivered
    // before the connection closes
    if let Err(e) = launcher.stop(&victim.addr) {
        eprintln!("route: stopping shard {} failed: {e:#}", victim.addr);
    }
    victim.set_retired();
    shared.shards_retired.fetch_add(1, Ordering::Relaxed);
}

/// Deadline on every periodic/admin connection to a shard (probe,
/// gossip, aggregation, shutdown forwarding): a hung shard counts as
/// down instead of blocking the caller forever.
pub(crate) const ADMIN_TIMEOUT: Duration = Duration::from_secs(2);

fn shard_stats(addr: &str) -> Result<StatsResp> {
    let mut c = Client::connect_with_deadline(addr, ADMIN_TIMEOUT)?;
    let stats = c.stats()?;
    let _ = c.quit();
    Ok(stats)
}

// ------------------------------------------------------------- sessions

/// Client-side write deadline: a client that stops reading must not
/// wedge the session (or its backend readers) inside a blocking send.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// The session's reply channel back to the client, shared between the
/// session thread and its backend readers; carries the wire framing the
/// session negotiated in hello.
struct ReplySink {
    stream: Mutex<TcpStream>,
    framing: Mutex<Framing>,
}

type ReplyLane = Arc<ReplySink>;

/// Send one response; returns false when the client is gone. A failed
/// reply write closes the socket loudly so the session's reader side
/// tears everything down instead of silently forwarding into the void.
fn send_line(lane: &ReplyLane, resp: &Response) -> bool {
    let f = *lane.framing.lock().unwrap();
    let mut buf = Vec::with_capacity(128);
    encode_frame(f, &protocol::response_value(resp), &mut buf);
    let mut w = lane.stream.lock().unwrap();
    match w.write_all(&buf).and_then(|_| w.flush()) {
        Ok(()) => true,
        Err(e) => {
            eprintln!("route: closing session, reply write failed: {e}");
            let _ = w.shutdown(Shutdown::Both);
            false
        }
    }
}

/// A submit forwarded to a shard whose reply has not come back yet. Kept
/// so the request can be replayed on another shard if the connection
/// dies under it.
struct Pending {
    req: SubmitReq,
    shard: usize,
}

/// A graph submission awaiting its `graph_done` (v8). Graphs are
/// forwarded *whole* to one shard — a plan is only meaningful over one
/// runtime's snapshot — and replayed whole on another shard when the
/// connection dies (fresh instances per replay, so duplicated execution
/// is wasted work, never a wrong answer — same as scalar submits).
struct PendingGraph {
    req: SubmitGraphReq,
    shard: usize,
}

/// One live backend connection of a session.
struct Backend {
    stream: Mutex<TcpStream>,
    /// Wire framing negotiated with the shard for this connection (the
    /// session's framing, if the shard confirmed it).
    framing: Framing,
}

impl Backend {
    /// Encode `req` in this connection's framing and write it out.
    fn write_request(&self, req: &Request) -> std::io::Result<()> {
        let mut buf = Vec::with_capacity(128);
        encode_frame(self.framing, &protocol::request_value(req), &mut buf);
        let mut s = self.stream.lock().unwrap();
        s.write_all(&buf).and_then(|_| s.flush())
    }
}

/// Per-client-session state shared between the session thread and its
/// backend reader threads.
struct Session {
    sid: u64,
    router: Arc<RouterShared>,
    reply: ReplyLane,
    /// Selection policy from the client's hello, forwarded to shards.
    policy: Mutex<Option<String>>,
    /// v5: latency SLO from the client's hello, forwarded to shards.
    slo_ms: Mutex<Option<f64>>,
    backends: Mutex<HashMap<usize, Arc<Backend>>>,
    pending: Mutex<HashMap<u64, Pending>>,
    /// Graph submissions in flight, keyed by request id (a separate map
    /// from `pending`: scalar and graph ids are independent client-side
    /// id spaces).
    graphs: Mutex<HashMap<u64, PendingGraph>>,
    /// v6: stream id → the shard index the stream is pinned to. A
    /// stream's chunk ordering, window accumulator and credit state
    /// all live inside one shard's runtime, so streams are
    /// shard-sticky: every chunk follows the pin, and the stream dies
    /// with its backend instead of being replayed elsewhere.
    streams: Mutex<HashMap<u64, usize>>,
    readers: Mutex<Vec<JoinHandle<()>>>,
    closing: AtomicBool,
}

fn session_loop(shared: Arc<RouterShared>, stream: TcpStream, sid: u64) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let reply: ReplyLane = match stream.try_clone() {
        Ok(w) => Arc::new(ReplySink {
            stream: Mutex::new(w),
            framing: Mutex::new(Framing::Ndjson),
        }),
        Err(_) => return,
    };
    let sess = Arc::new(Session {
        sid,
        router: shared.clone(),
        reply,
        policy: Mutex::new(None),
        slo_ms: Mutex::new(None),
        backends: Mutex::new(HashMap::new()),
        pending: Mutex::new(HashMap::new()),
        graphs: Mutex::new(HashMap::new()),
        streams: Mutex::new(HashMap::new()),
        readers: Mutex::new(Vec::new()),
        closing: AtomicBool::new(false),
    });
    let mut stream = stream;
    let mut dec = FrameDecoder::new(Framing::Ndjson);
    'session: loop {
        loop {
            match dec.next() {
                Ok(Some(v)) => {
                    let keep = handle_frame(&sess, &v);
                    // the hello arm may have renegotiated the framing
                    let f = *sess.reply.framing.lock().unwrap();
                    if f != dec.framing() {
                        dec.set_framing(f);
                    }
                    if !keep || shared.draining.load(Ordering::SeqCst) {
                        break 'session;
                    }
                }
                Ok(None) => break,
                Err(e) => {
                    send_line(
                        &sess.reply,
                        &Response::Error {
                            id: None,
                            error: format!("{e:#}"),
                        },
                    );
                    break 'session;
                }
            }
        }
        match dec.fill_from(&mut stream) {
            Ok(0) => break,
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shared.draining.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    close_session(&sess);
}

fn close_session(sess: &Arc<Session>) {
    sess.closing.store(true, Ordering::SeqCst);
    let backends: Vec<Arc<Backend>> = sess
        .backends
        .lock()
        .unwrap()
        .drain()
        .map(|(_, b)| b)
        .collect();
    for b in backends {
        let _ = b.write_request(&Request::Quit);
        let s = b.stream.lock().unwrap();
        let _ = s.shutdown(Shutdown::Both);
    }
    let readers: Vec<JoinHandle<()>> = std::mem::take(&mut *sess.readers.lock().unwrap());
    for r in readers {
        let _ = r.join();
    }
}

/// Decode one framed client request and dispatch it; returns false to
/// close the session.
fn handle_frame(sess: &Arc<Session>, value: &Json) -> bool {
    let req = match protocol::request_from_value(value) {
        Ok(r) => r,
        Err(e) => {
            send_line(
                &sess.reply,
                &Response::Error {
                    id: None,
                    error: format!("{e:#}"),
                },
            );
            return true;
        }
    };
    let router = &sess.router;
    match req {
        Request::Hello {
            client: _,
            policy,
            slo_ms,
            framing,
        } => {
            // v7: negotiate the session's wire framing; backend
            // connections opened for this session forward the choice
            let accepted = match framing.as_deref().map(Framing::parse) {
                None => None,
                Some(Ok(f)) => Some(f),
                Some(Err(e)) => {
                    send_line(
                        &sess.reply,
                        &Response::Error {
                            id: None,
                            error: format!("{e:#}"),
                        },
                    );
                    return true;
                }
            };
            if let Some(p) = &policy {
                if SelectorKind::parse(p).is_none() {
                    send_line(
                        &sess.reply,
                        &Response::Error {
                            id: None,
                            error: format!(
                                "unknown selection policy '{p}' (want {VALID_SELECTORS})"
                            ),
                        },
                    );
                    return true;
                }
            }
            *sess.policy.lock().unwrap() = policy;
            *sess.slo_ms.lock().unwrap() = slo_ms;
            send_line(
                &sess.reply,
                &Response::Hello {
                    session: sess.sid,
                    version: PROTOCOL_VERSION,
                    // the router has no context table of its own, so it
                    // cannot report an *effective* target here; shards
                    // apply the declared value when the hello is
                    // forwarded on each backend connection
                    slo_ms: None,
                    framing: accepted.map(|f| f.name().to_string()),
                },
            );
            // switch after the (always pre-switch-framing) hello reply
            if let Some(f) = accepted {
                *sess.reply.framing.lock().unwrap() = f;
            }
            true
        }
        Request::Submit(req) => {
            if router.draining.load(Ordering::SeqCst) {
                send_line(
                    &sess.reply,
                    &Response::Error {
                        id: Some(req.id),
                        error: "router is draining".into(),
                    },
                );
                return true;
            }
            let id = req.id;
            let mut exclude = Vec::new();
            if let Err(e) = route_submit(sess, req, &mut exclude) {
                send_line(
                    &sess.reply,
                    &Response::Error {
                        id: Some(id),
                        error: format!("{e:#}"),
                    },
                );
            }
            true
        }
        Request::SubmitGraph(req) => {
            if router.draining.load(Ordering::SeqCst) {
                send_line(
                    &sess.reply,
                    &Response::Error {
                        id: Some(req.id),
                        error: "router is draining".into(),
                    },
                );
                return true;
            }
            let id = req.id;
            let mut exclude = Vec::new();
            if let Err(e) = route_graph(sess, req, &mut exclude) {
                send_line(
                    &sess.reply,
                    &Response::Error {
                        id: Some(id),
                        error: format!("{e:#}"),
                    },
                );
            }
            true
        }
        Request::StreamOpen(req) => {
            if router.draining.load(Ordering::SeqCst) {
                send_line(
                    &sess.reply,
                    &Response::Error {
                        id: None,
                        error: "router is draining".into(),
                    },
                );
                return true;
            }
            if let Err(e) = route_stream_open(sess, req) {
                send_line(
                    &sess.reply,
                    &Response::Error {
                        id: None,
                        error: format!("{e:#}"),
                    },
                );
            }
            true
        }
        Request::StreamChunk { stream, seq, seed } => {
            if let Err(e) = forward_stream(sess, stream, &Request::StreamChunk { stream, seq, seed })
            {
                send_line(
                    &sess.reply,
                    &Response::Error {
                        id: None,
                        error: format!("stream {stream} chunk {seq}: {e:#}"),
                    },
                );
            }
            true
        }
        Request::StreamClose { stream } => {
            if let Err(e) = forward_stream(sess, stream, &Request::StreamClose { stream }) {
                // the pin is useless once the close cannot reach the
                // shard; the reader's death sweep may already have
                // dropped it, so ignore a missing entry
                sess.streams.lock().unwrap().remove(&stream);
                send_line(
                    &sess.reply,
                    &Response::Error {
                        id: None,
                        error: format!("stream {stream} close: {e:#}"),
                    },
                );
            }
            true
        }
        Request::Stats => {
            send_line(&sess.reply, &Response::Stats(cluster_stats(router)));
            true
        }
        Request::Metrics { format } => {
            // v9: aggregate every reachable shard's registry scrape,
            // namespacing each instrument as `shardN/<name>` — the
            // Prometheus renderer turns that prefix into a shard label
            let text = match format.as_deref() {
                None | Some("json") => false,
                Some("prometheus") | Some("text") => true,
                Some(other) => {
                    send_line(
                        &sess.reply,
                        &Response::Error {
                            id: None,
                            error: format!(
                                "unknown metrics format '{other}' (want json | prometheus)"
                            ),
                        },
                    );
                    return true;
                }
            };
            send_line(&sess.reply, &Response::Metrics(cluster_metrics(router, text)));
            true
        }
        Request::Decisions { limit, codelet } => {
            send_line(
                &sess.reply,
                &Response::Decisions(cluster_decisions(router, limit, codelet.as_deref())),
            );
            true
        }
        Request::DumpTrace => {
            send_line(&sess.reply, &Response::DumpTrace(cluster_trace(router)));
            true
        }
        Request::Contexts => {
            send_line(
                &sess.reply,
                &Response::Contexts {
                    contexts: cluster_contexts(router),
                },
            );
            true
        }
        Request::Shards => {
            send_line(
                &sess.reply,
                &Response::Shards {
                    shards: router.shard_list().iter().map(|s| s.desc()).collect(),
                },
            );
            true
        }
        Request::AutoscaleStatus => {
            let live = router.live_shards();
            send_line(
                &sess.reply,
                &Response::Autoscale(AutoscaleResp {
                    enabled: router.autoscale_on.load(Ordering::Relaxed),
                    policy: if router.autoscale_on.load(Ordering::Relaxed) {
                        "shard-threshold".into()
                    } else {
                        String::new()
                    },
                    shards: live.len() as u64,
                    shards_spawned: router.shards_spawned.load(Ordering::Relaxed),
                    shards_retired: router.shards_retired.load(Ordering::Relaxed),
                    ..AutoscaleResp::default()
                }),
            );
            true
        }
        Request::DrainShard { shard } => {
            match resolve_shard(router, &shard) {
                Some(i) => {
                    let target = router.shard(i).expect("resolved index is in the table");
                    target.set_draining(true);
                    send_line(
                        &sess.reply,
                        &Response::Drained {
                            shard: target.addr.clone(),
                        },
                    );
                }
                None => {
                    send_line(
                        &sess.reply,
                        &Response::Error {
                            id: None,
                            error: format!(
                                "unknown shard '{shard}' (have: {})",
                                router
                                    .shard_list()
                                    .iter()
                                    .map(|s| s.addr.clone())
                                    .collect::<Vec<_>>()
                                    .join(", ")
                            ),
                        },
                    );
                }
            }
            true
        }
        Request::PerfPull | Request::PerfPush { .. } => {
            send_line(
                &sess.reply,
                &Response::Error {
                    id: None,
                    error: "shard-level operation (the router gossips perf models \
                            on your behalf; send perf ops to a shard)"
                        .into(),
                },
            );
            true
        }
        Request::Shutdown => {
            // forward to every shard (each drains gracefully), then stop
            for shard in router.shard_list() {
                if shard.retired() {
                    continue; // already stopped by the scaler
                }
                if let Ok(mut c) = Client::connect_with_deadline(&shard.addr, ADMIN_TIMEOUT) {
                    let _ = c.shutdown_server();
                }
            }
            send_line(&sess.reply, &Response::Shutdown);
            let mut stop = router.stop.lock().unwrap();
            *stop = true;
            router.stop_cv.notify_all();
            true
        }
        Request::Quit => {
            send_line(&sess.reply, &Response::Bye);
            false
        }
    }
}

/// Resolve a shard by address, `shardN`, or bare index.
fn resolve_shard(router: &Arc<RouterShared>, name: &str) -> Option<usize> {
    let shards = router.shard_list();
    if let Some(i) = shards.iter().position(|s| s.addr == name) {
        return Some(i);
    }
    name.strip_prefix("shard")
        .unwrap_or(name)
        .parse::<usize>()
        .ok()
        .filter(|&i| i < shards.len())
}

// ------------------------------------------------------------- routing

/// Route one submit to a shard, retrying on the next available shard
/// when the chosen one cannot be reached or written to. Errors only when
/// every shard has been excluded.
fn route_submit(sess: &Arc<Session>, mut req: SubmitReq, exclude: &mut Vec<usize>) -> Result<()> {
    // v9: mint the trace id at the first hop so the shard (and its
    // tasks) inherit it rather than minting a shard-local one
    if req.trace == 0 {
        req.trace = sess.router.next_trace.fetch_add(1, Ordering::Relaxed);
    }
    loop {
        if sess.closing.load(Ordering::SeqCst) {
            bail!("session is closing");
        }
        // snapshot of the append-only shard table: indices returned by
        // placement are global shard indices
        let shards = sess.router.shard_list();
        let Some(si) = placement::pick(
            sess.router.placement,
            &shards,
            &req.app,
            req.size,
            exclude,
            &sess.router.rr,
        ) else {
            bail!(
                "no available shard for request {} ({} shard(s), {} excluded)",
                req.id,
                shards.len(),
                exclude.len()
            );
        };
        let backend = match ensure_backend(sess, si) {
            Ok(b) => b,
            Err(_) => {
                shards[si].set_healthy(false);
                exclude.push(si);
                continue;
            }
        };
        sess.pending.lock().unwrap().insert(
            req.id,
            Pending {
                req: req.clone(),
                shard: si,
            },
        );
        let wrote = backend.write_request(&Request::Submit(req.clone()));
        if wrote.is_err() {
            // reclaim the pending entry before retrying: if it is
            // already gone, the backend reader observed this connection
            // die first and is replaying the request itself — retrying
            // here too would submit it twice and send the client two
            // replies for one id
            let still_ours = sess.pending.lock().unwrap().remove(&req.id).is_some();
            {
                // evict only OUR dead connection: a reader may already
                // have replaced backends[si] with a fresh healthy one
                let mut backends = sess.backends.lock().unwrap();
                if backends
                    .get(&si)
                    .map(|b| Arc::ptr_eq(b, &backend))
                    .unwrap_or(false)
                {
                    backends.remove(&si);
                }
            }
            shards[si].set_healthy(false);
            if !still_ours {
                return Ok(());
            }
            sess.router.retried.fetch_add(1, Ordering::Relaxed);
            exclude.push(si);
            continue;
        }
        // a write into a freshly closed socket can still report success
        // (the bytes land in the kernel buffer; the RST arrives later).
        // If the reader swept this connection dead between our map
        // lookup and the write, nobody will ever read a reply for this
        // entry — re-check the backend is still the registered one and
        // replay if not. Lock order (reader: remove backend, then sweep
        // pending) guarantees that when we still see our backend
        // registered here, a later sweep will see our pending entry.
        let still_registered = sess
            .backends
            .lock()
            .unwrap()
            .get(&si)
            .map(|b| Arc::ptr_eq(b, &backend))
            .unwrap_or(false);
        if !still_registered {
            let still_ours = sess.pending.lock().unwrap().remove(&req.id).is_some();
            if !still_ours {
                return Ok(()); // the reader's sweep already replayed it
            }
            sess.router.retried.fetch_add(1, Ordering::Relaxed);
            exclude.push(si);
            continue;
        }
        sess.router.routed.fetch_add(1, Ordering::Relaxed);
        return Ok(());
    }
}

/// Route one graph submission, whole, to a single shard (v8). A graph
/// plan is computed over one runtime's snapshot — splitting nodes
/// across shards would plan each fragment blind to the others and pay
/// network hops on every internal edge — so the router never splits a
/// DAG. Uses the first node's (app, size) as the placement key and the
/// node count as the load hint. Retry mirrors [`route_submit`],
/// including the post-write registration re-check.
fn route_graph(
    sess: &Arc<Session>,
    mut req: SubmitGraphReq,
    exclude: &mut Vec<usize>,
) -> Result<()> {
    if req.trace == 0 {
        req.trace = sess.router.next_trace.fetch_add(1, Ordering::Relaxed);
    }
    loop {
        if sess.closing.load(Ordering::SeqCst) {
            bail!("session is closing");
        }
        let shards = sess.router.shard_list();
        let (app, size) = req
            .nodes
            .first()
            .map(|n| (n.app.as_str(), n.size))
            .unwrap_or(("", 0));
        let Some(si) = placement::pick(
            sess.router.placement,
            &shards,
            app,
            size,
            exclude,
            &sess.router.rr,
        ) else {
            bail!(
                "no available shard for graph {} ({} shard(s), {} excluded)",
                req.id,
                shards.len(),
                exclude.len()
            );
        };
        let backend = match ensure_backend(sess, si) {
            Ok(b) => b,
            Err(_) => {
                shards[si].set_healthy(false);
                exclude.push(si);
                continue;
            }
        };
        sess.graphs.lock().unwrap().insert(
            req.id,
            PendingGraph {
                req: req.clone(),
                shard: si,
            },
        );
        let wrote = backend.write_request(&Request::SubmitGraph(req.clone()));
        if wrote.is_err() {
            let still_ours = sess.graphs.lock().unwrap().remove(&req.id).is_some();
            {
                let mut backends = sess.backends.lock().unwrap();
                if backends
                    .get(&si)
                    .map(|b| Arc::ptr_eq(b, &backend))
                    .unwrap_or(false)
                {
                    backends.remove(&si);
                }
            }
            shards[si].set_healthy(false);
            if !still_ours {
                return Ok(()); // the reader's death sweep is replaying it
            }
            sess.router.retried.fetch_add(1, Ordering::Relaxed);
            exclude.push(si);
            continue;
        }
        let still_registered = sess
            .backends
            .lock()
            .unwrap()
            .get(&si)
            .map(|b| Arc::ptr_eq(b, &backend))
            .unwrap_or(false);
        if !still_registered {
            let still_ours = sess.graphs.lock().unwrap().remove(&req.id).is_some();
            if !still_ours {
                return Ok(());
            }
            sess.router.retried.fetch_add(1, Ordering::Relaxed);
            exclude.push(si);
            continue;
        }
        sess.router.routed.fetch_add(1, Ordering::Relaxed);
        return Ok(());
    }
}

/// Place a new stream on a shard and forward its open (v6). Placement
/// retries other shards only while the *open* cannot be written; after
/// the grant the stream is pinned and lives or dies with that backend
/// — its window and credit state cannot be replayed elsewhere.
fn route_stream_open(sess: &Arc<Session>, mut req: StreamOpenReq) -> Result<()> {
    if req.trace == 0 {
        req.trace = sess.router.next_trace.fetch_add(1, Ordering::Relaxed);
    }
    let mut exclude: Vec<usize> = Vec::new();
    loop {
        if sess.closing.load(Ordering::SeqCst) {
            bail!("session is closing");
        }
        let shards = sess.router.shard_list();
        let Some(si) = placement::pick(
            sess.router.placement,
            &shards,
            &req.app,
            req.size,
            &exclude,
            &sess.router.rr,
        ) else {
            bail!(
                "no available shard for stream {} ({} shard(s), {} excluded)",
                req.id,
                shards.len(),
                exclude.len()
            );
        };
        let backend = match ensure_backend(sess, si) {
            Ok(b) => b,
            Err(_) => {
                shards[si].set_healthy(false);
                exclude.push(si);
                continue;
            }
        };
        // pin before writing: the grant (or an immediate shard-side
        // rejection) races back through the backend reader, which
        // routes stream events by pin
        sess.streams.lock().unwrap().insert(req.id, si);
        let wrote = backend.write_request(&Request::StreamOpen(req.clone()));
        if wrote.is_err() {
            sess.streams.lock().unwrap().remove(&req.id);
            shards[si].set_healthy(false);
            exclude.push(si);
            continue;
        }
        sess.router.routed.fetch_add(1, Ordering::Relaxed);
        return Ok(());
    }
}

/// Forward a chunk or close to the shard its stream is pinned to. No
/// retry-on-other-shard here by design (see [`route_stream_open`]).
fn forward_stream(sess: &Arc<Session>, stream: u64, req: &Request) -> Result<()> {
    let si = *sess
        .streams
        .lock()
        .unwrap()
        .get(&stream)
        .ok_or_else(|| anyhow::anyhow!("unknown stream {stream} (open it first)"))?;
    let backend = sess
        .backends
        .lock()
        .unwrap()
        .get(&si)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("shard{si} connection is gone"))?;
    backend
        .write_request(req)
        .with_context(|| format!("writing to shard{si}"))?;
    Ok(())
}

/// Get (or open) this session's connection to shard `si`, performing the
/// hello handshake (forwarding the session's selection policy) and
/// spawning the reply-forwarding reader thread.
fn ensure_backend(sess: &Arc<Session>, si: usize) -> Result<Arc<Backend>> {
    let mut backends = sess.backends.lock().unwrap();
    if let Some(b) = backends.get(&si) {
        return Ok(b.clone());
    }
    let addr = sess
        .router
        .shard(si)
        .ok_or_else(|| anyhow::anyhow!("shard index {si} out of range"))?
        .addr
        .clone();
    let addr = addr.as_str();
    // deadline on connect AND handshake: this runs with the session's
    // backends mutex held, so a hung shard must fail fast here instead
    // of wedging the session (and with it, router shutdown)
    let sa = {
        use std::net::ToSocketAddrs;
        addr.to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow::anyhow!("cannot resolve shard '{addr}'"))?
    };
    let stream = TcpStream::connect_timeout(&sa, ADMIN_TIMEOUT)
        .with_context(|| format!("connecting shard {addr}"))?;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(ADMIN_TIMEOUT));
    let _ = stream.set_write_timeout(Some(ADMIN_TIMEOUT));
    let mut read_side = stream.try_clone()?;
    // forward the session's negotiated framing: a binary client gets a
    // binary hop to the shard too (the shard's echo confirms it)
    let want = *sess.reply.framing.lock().unwrap();
    let hello = Request::Hello {
        client: format!("compar-route-{}", sess.sid),
        policy: sess.policy.lock().unwrap().clone(),
        slo_ms: *sess.slo_ms.lock().unwrap(),
        framing: match want {
            Framing::Ndjson => None,
            f => Some(f.name().to_string()),
        },
    };
    let mut buf = Vec::with_capacity(128);
    encode_frame(Framing::Ndjson, &protocol::request_value(&hello), &mut buf);
    (&stream).write_all(&buf)?;
    (&stream).flush()?;
    let mut dec = FrameDecoder::new(Framing::Ndjson);
    let hello_value = loop {
        if let Some(v) = dec.next()? {
            break v;
        }
        if dec.fill_from(&mut read_side)? == 0 {
            bail!("shard {addr} closed during handshake");
        }
    };
    let framing = match protocol::response_from_value(&hello_value)? {
        Response::Hello {
            version, framing, ..
        } => {
            if version != PROTOCOL_VERSION {
                bail!("shard {addr} speaks protocol v{version}, router v{PROTOCOL_VERSION}");
            }
            match framing.as_deref() {
                Some(f) => Framing::parse(f)?,
                None => Framing::Ndjson,
            }
        }
        Response::Error { error, .. } => bail!("shard {addr} rejected hello: {error}"),
        other => bail!("shard {addr}: expected hello, got {other:?}"),
    };
    dec.set_framing(framing);
    // short read timeout so the reader thread can observe session close
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let backend = Arc::new(Backend {
        stream: Mutex::new(stream),
        framing,
    });
    backends.insert(si, backend.clone());
    drop(backends);
    let sess2 = sess.clone();
    let handle = std::thread::Builder::new()
        .name(format!("route-be-{}-{}", sess.sid, si))
        .spawn(move || backend_reader(sess2, si, read_side, dec))
        .expect("spawning backend reader");
    sess.readers.lock().unwrap().push(handle);
    Ok(backend)
}

/// Forward one shard's replies to the client, tagging results with the
/// shard index; when the connection dies with replies still pending,
/// replay those submits on another shard.
fn backend_reader(sess: Arc<Session>, shard: usize, mut stream: TcpStream, mut dec: FrameDecoder) {
    'read: loop {
        loop {
            match dec.next() {
                Ok(Some(v)) => forward_backend_value(&sess, shard, &v),
                Ok(None) => break,
                Err(_) => break 'read,
            }
        }
        match dec.fill_from(&mut stream) {
            Ok(0) => break,
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if sess.closing.load(Ordering::SeqCst)
                    || sess.router.draining.load(Ordering::SeqCst)
                {
                    return;
                }
            }
            Err(_) => break,
        }
    }
    if sess.closing.load(Ordering::SeqCst) || sess.router.draining.load(Ordering::SeqCst) {
        return;
    }
    // the shard connection died under us
    if let Some(s) = sess.router.shard(shard) {
        s.set_healthy(false);
    }
    sess.backends.lock().unwrap().remove(&shard);
    let orphans: Vec<SubmitReq> = {
        let mut pending = sess.pending.lock().unwrap();
        let ids: Vec<u64> = pending
            .iter()
            .filter(|(_, p)| p.shard == shard)
            .map(|(id, _)| *id)
            .collect();
        ids.into_iter()
            .filter_map(|id| pending.remove(&id))
            .map(|p| p.req)
            .collect()
    };
    for req in orphans {
        sess.router.retried.fetch_add(1, Ordering::Relaxed);
        let id = req.id;
        let mut exclude = vec![shard];
        if let Err(e) = route_submit(&sess, req, &mut exclude) {
            send_line(
                &sess.reply,
                &Response::Error {
                    id: Some(id),
                    error: format!("{e:#}"),
                },
            );
        }
    }
    // graphs pending on the dead shard are replayed whole elsewhere
    let graph_orphans: Vec<SubmitGraphReq> = {
        let mut graphs = sess.graphs.lock().unwrap();
        let ids: Vec<u64> = graphs
            .iter()
            .filter(|(_, p)| p.shard == shard)
            .map(|(id, _)| *id)
            .collect();
        ids.into_iter()
            .filter_map(|id| graphs.remove(&id))
            .map(|p| p.req)
            .collect()
    };
    for req in graph_orphans {
        sess.router.retried.fetch_add(1, Ordering::Relaxed);
        let id = req.id;
        let mut exclude = vec![shard];
        if let Err(e) = route_graph(&sess, req, &mut exclude) {
            send_line(
                &sess.reply,
                &Response::Error {
                    id: Some(id),
                    error: format!("{e:#}"),
                },
            );
        }
    }
    // streams pinned here die with the shard: their window accumulator
    // and credit controller lived inside its runtime, so there is
    // nothing to replay — surface the loss instead of going silent
    let lost: Vec<u64> = {
        let mut pins = sess.streams.lock().unwrap();
        let ids: Vec<u64> = pins
            .iter()
            .filter(|(_, s)| **s == shard)
            .map(|(id, _)| *id)
            .collect();
        for id in &ids {
            pins.remove(id);
        }
        ids
    };
    for id in lost {
        send_line(
            &sess.reply,
            &Response::Error {
                id: None,
                error: format!("stream {id} lost: shard{shard} connection died"),
            },
        );
    }
}

fn forward_backend_value(sess: &Arc<Session>, shard: usize, value: &Json) {
    let Ok(resp) = protocol::response_from_value(value) else {
        return;
    };
    match resp {
        Response::Result(mut r) => {
            sess.pending.lock().unwrap().remove(&r.id);
            // tag the context with the shard so clients (and the
            // loadgen per-context histogram) see the cluster spread
            r.ctx = format!("shard{shard}/{}", r.ctx);
            send_line(&sess.reply, &Response::Result(r));
        }
        // v8 graph reports follow the same shape as results: untrack,
        // tag the context with the shard, forward
        Response::GraphDone(mut g) => {
            sess.graphs.lock().unwrap().remove(&g.id);
            g.ctx = format!("shard{shard}/{}", g.ctx);
            send_line(&sess.reply, &Response::GraphDone(g));
        }
        Response::Error { id, error } => {
            if let Some(id) = id {
                sess.pending.lock().unwrap().remove(&id);
                sess.graphs.lock().unwrap().remove(&id);
            }
            // a per-request error from the shard (bad app, bad variant,
            // failed verification) is a real answer — forward, no retry
            send_line(&sess.reply, &Response::Error { id, error });
        }
        // v6 stream events ride the pinned stream's backend connection;
        // forward them, tagging acks with the shard like submit results
        Response::StreamOpened(o) => {
            send_line(&sess.reply, &Response::StreamOpened(o));
        }
        Response::StreamAck(mut a) => {
            a.ctx = format!("shard{shard}/{}", a.ctx);
            send_line(&sess.reply, &Response::StreamAck(a));
        }
        Response::StreamCredit(c) => {
            send_line(&sess.reply, &Response::StreamCredit(c));
        }
        Response::StreamClosed(c) => {
            sess.streams.lock().unwrap().remove(&c.stream);
            send_line(&sess.reply, &Response::StreamClosed(c));
        }
        // hello is consumed during the handshake; nothing else rides on
        // a submit connection
        _ => {}
    }
}

// -------------------------------------------------------- admin aggregates

/// Cluster-wide stats: sum of every reachable shard's counters, with
/// per-context tables prefixed by shard index. Deliberately fetched
/// live (not from the health cache, which lags a probe period): a
/// client asking for stats right after its submits completed must see
/// them counted.
fn cluster_stats(router: &Arc<RouterShared>) -> StatsResp {
    let mut agg = StatsResp {
        uptime: router.started.elapsed().as_secs_f64(),
        requests_ok: 0,
        requests_err: 0,
        inflight: 0,
        tasks_executed: 0,
        queue_depth: 0,
        busy_workers: 0,
        total_workers: 0,
        sessions: 0,
        streams: 0,
        plans: 0,
        planned_tasks: 0,
        tasks_completed: 0,
        bytes_transferred: 0,
        batches_fused: 0,
        decisions: 0,
        slo_ms: 0.0,
        ctx_tasks: BTreeMap::new(),
        ctx_variants: BTreeMap::new(),
    };
    for (i, shard) in router.shard_list().iter().enumerate() {
        if shard.retired() || !shard.healthy.load(Ordering::Relaxed) {
            continue;
        }
        let Ok(stats) = shard_stats(&shard.addr) else {
            continue;
        };
        agg.requests_ok += stats.requests_ok;
        agg.requests_err += stats.requests_err;
        agg.inflight += stats.inflight;
        agg.tasks_executed += stats.tasks_executed;
        agg.queue_depth += stats.queue_depth;
        agg.busy_workers += stats.busy_workers;
        agg.total_workers += stats.total_workers;
        agg.sessions += stats.sessions;
        agg.streams += stats.streams;
        agg.plans += stats.plans;
        agg.planned_tasks += stats.planned_tasks;
        agg.tasks_completed += stats.tasks_completed;
        agg.bytes_transferred += stats.bytes_transferred;
        agg.batches_fused += stats.batches_fused;
        agg.decisions += stats.decisions;
        // the cluster-wide effective SLO is the tightest one any shard
        // is currently enforcing (0 = no shard has a target)
        if stats.slo_ms > 0.0 && (agg.slo_ms == 0.0 || stats.slo_ms < agg.slo_ms) {
            agg.slo_ms = stats.slo_ms;
        }
        for (k, v) in stats.ctx_tasks {
            agg.ctx_tasks.insert(format!("shard{i}/{k}"), v);
        }
        for (k, h) in stats.ctx_variants {
            agg.ctx_variants.insert(format!("shard{i}/{k}"), h);
        }
    }
    agg
}

fn cluster_contexts(router: &Arc<RouterShared>) -> Vec<protocol::CtxDesc> {
    let mut out = Vec::new();
    for (i, shard) in router.shard_list().iter().enumerate() {
        if shard.retired() || !shard.healthy.load(Ordering::Relaxed) {
            continue;
        }
        let Ok(mut c) = Client::connect_with_deadline(&shard.addr, ADMIN_TIMEOUT) else {
            continue;
        };
        if let Ok(contexts) = c.contexts() {
            for mut ctx in contexts {
                ctx.name = format!("shard{i}/{}", ctx.name);
                out.push(ctx);
            }
        }
        let _ = c.quit();
    }
    out
}

/// v9: cluster-wide metrics scrape. Every reachable shard's registry is
/// fetched live and merged into one document with each instrument
/// namespaced as `shardN/<name>`; the Prometheus text renderer turns
/// that prefix into a `shard="shardN"` label, so per-shard series stay
/// distinguishable after aggregation.
fn cluster_metrics(router: &Arc<RouterShared>, text: bool) -> MetricsResp {
    let mut counters: BTreeMap<String, Json> = BTreeMap::new();
    let mut gauges: BTreeMap<String, Json> = BTreeMap::new();
    let mut histograms: BTreeMap<String, Json> = BTreeMap::new();
    for (i, shard) in router.shard_list().iter().enumerate() {
        if shard.retired() || !shard.healthy.load(Ordering::Relaxed) {
            continue;
        }
        let Ok(mut c) = Client::connect_with_deadline(&shard.addr, ADMIN_TIMEOUT) else {
            continue;
        };
        if let Ok(m) = c.metrics(None) {
            if let Json::Obj(sections) = m.metrics {
                for (section, dst) in [
                    ("counters", &mut counters),
                    ("gauges", &mut gauges),
                    ("histograms", &mut histograms),
                ] {
                    if let Some(Json::Obj(entries)) = sections.get(section) {
                        for (name, v) in entries {
                            dst.insert(format!("shard{i}/{name}"), v.clone());
                        }
                    }
                }
            }
        }
        let _ = c.quit();
    }
    let mut root = BTreeMap::new();
    root.insert("counters".into(), Json::Obj(counters));
    root.insert("gauges".into(), Json::Obj(gauges));
    root.insert("histograms".into(), Json::Obj(histograms));
    let metrics = Json::Obj(root);
    MetricsResp {
        text: text.then(|| crate::obs::prometheus_from_json(&metrics)),
        metrics,
    }
}

/// v9: cluster-wide selection-decision audit. Each shard's recent slice
/// is fetched with the caller's limit/filter and concatenated, every
/// record tagged with the shard it came from; ring counters are summed.
fn cluster_decisions(
    router: &Arc<RouterShared>,
    limit: Option<u64>,
    codelet: Option<&str>,
) -> DecisionsResp {
    let mut total = 0u64;
    let mut dropped = 0u64;
    let mut evicted = 0u64;
    let mut all = Vec::new();
    for (i, shard) in router.shard_list().iter().enumerate() {
        if shard.retired() || !shard.healthy.load(Ordering::Relaxed) {
            continue;
        }
        let Ok(mut c) = Client::connect_with_deadline(&shard.addr, ADMIN_TIMEOUT) else {
            continue;
        };
        if let Ok(d) = c.decisions(limit, codelet) {
            total += d.total;
            dropped += d.dropped;
            evicted += d.evicted;
            if let Json::Arr(recs) = d.decisions {
                for mut rec in recs {
                    if let Json::Obj(m) = &mut rec {
                        m.insert("shard".into(), Json::Str(format!("shard{i}")));
                    }
                    all.push(rec);
                }
            }
        }
        let _ = c.quit();
    }
    DecisionsResp {
        total,
        dropped,
        evicted,
        decisions: Json::Arr(all),
    }
}

/// v9: cluster-wide trace dump. Shard span rings are concatenated into
/// one Chrome Trace document with each event's `pid` rewritten to the
/// shard index, so the viewer shows one process group per shard.
fn cluster_trace(router: &Arc<RouterShared>) -> TraceResp {
    let mut events = Vec::new();
    let mut count = 0u64;
    for (i, shard) in router.shard_list().iter().enumerate() {
        if shard.retired() || !shard.healthy.load(Ordering::Relaxed) {
            continue;
        }
        let Ok(mut c) = Client::connect_with_deadline(&shard.addr, ADMIN_TIMEOUT) else {
            continue;
        };
        if let Ok(t) = c.dump_trace() {
            count += t.events;
            if let Json::Obj(mut m) = t.trace {
                if let Some(Json::Arr(evs)) = m.remove("traceEvents") {
                    for mut ev in evs {
                        if let Json::Obj(em) = &mut ev {
                            em.insert("pid".into(), Json::Num(i as f64));
                        }
                        events.push(ev);
                    }
                }
            }
        }
        let _ = c.quit();
    }
    let mut root = BTreeMap::new();
    root.insert("traceEvents".into(), Json::Arr(events));
    TraceResp {
        events: count,
        trace: Json::Obj(root),
    }
}
