//! Tiny thread-bookkeeping helper shared by the serving layers.

use std::thread::JoinHandle;

/// Join and drop every finished handle in `handles`, keeping the live
/// ones — bounded bookkeeping for long-running accept/dispatch loops
/// that would otherwise accumulate one handle per connection forever.
pub fn reap_finished(handles: &mut Vec<JoinHandle<()>>) {
    let done: Vec<usize> = handles
        .iter()
        .enumerate()
        .filter(|(_, h)| h.is_finished())
        .map(|(i, _)| i)
        .collect();
    for i in done.into_iter().rev() {
        let _ = handles.swap_remove(i).join();
    }
}
