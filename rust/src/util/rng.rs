//! Deterministic PRNG for workload generation (xoshiro256**).
//!
//! Input data must be reproducible across runs and match between the
//! bench harness, the examples and the tests, so we avoid OS entropy
//! entirely: every generator is seeded explicitly.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 (including 0) is a good seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Vector of uniform f32 in [lo, hi).
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.range_f32(lo, hi)).collect()
    }
}

/// Environment variable that pins every generative harness (property
/// tests, the model explorer, the concrete proof harnesses) to one
/// exact seed for failure replay.
pub const MODEL_SEED_ENV: &str = "COMPAR_MODEL_SEED";

/// Seed override from `COMPAR_MODEL_SEED` (decimal or `0x`-prefixed hex).
pub fn env_seed() -> Option<u64> {
    let raw = std::env::var(MODEL_SEED_ENV).ok()?;
    let raw = raw.trim();
    if raw.is_empty() {
        return None;
    }
    let parsed = if let Some(hex) = raw.strip_prefix("0x").or_else(|| raw.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16)
    } else {
        raw.parse::<u64>()
    };
    match parsed {
        Ok(v) => Some(v),
        Err(_) => {
            eprintln!("{MODEL_SEED_ENV}={raw:?} is not a u64 (decimal or 0x-hex); ignoring");
            None
        }
    }
}

/// Per-case seed derived from a harness base seed: splitmix64 finalizer
/// over `base ^ case`, so neighbouring case indices land far apart and
/// any single case can be replayed in isolation.
pub fn derive_seed(base: u64, case: u64) -> u64 {
    let mut z = base
        .wrapping_add(case.wrapping_mul(0x9e3779b97f4a7c15))
        .wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Run a seeded property-test body over `cases` derived seeds.
///
/// Every generative harness in the repo goes through here so failures
/// are always reproducible: if the case panics, the exact seed is
/// printed with the `COMPAR_MODEL_SEED=<seed>` incantation that replays
/// it, then the panic is re-raised. When `COMPAR_MODEL_SEED` is set in
/// the environment, only that one seed runs (replay mode).
pub fn run_cases<F: FnMut(u64)>(default_base: u64, cases: usize, mut body: F) {
    use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
    let seeds: Vec<u64> = match env_seed() {
        Some(seed) => vec![seed],
        None => (0..cases as u64)
            .map(|case| derive_seed(default_base, case))
            .collect(),
    };
    for seed in seeds {
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| body(seed))) {
            eprintln!("generative case failed; replay with {MODEL_SEED_ENV}={seed}");
            resume_unwind(payload);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn range_respected() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            let v = r.range_f32(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
        }
    }

    #[test]
    fn below_bound() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn derive_seed_spreads_and_is_stable() {
        let a = derive_seed(0x1234, 0);
        let b = derive_seed(0x1234, 1);
        assert_ne!(a, b);
        assert_eq!(a, derive_seed(0x1234, 0));
        // different bases diverge too
        assert_ne!(derive_seed(1, 0), derive_seed(2, 0));
    }

    #[test]
    fn run_cases_visits_each_derived_seed_once() {
        // run_cases only consults the env override, which tests must not
        // mutate (process-global); assert the derived-seed path instead
        // when no override is active, and skip under replay mode.
        if env_seed().is_some() {
            return;
        }
        let mut seen = Vec::new();
        run_cases(0xabc, 5, |seed| seen.push(seed));
        let expect: Vec<u64> = (0..5).map(|c| derive_seed(0xabc, c)).collect();
        assert_eq!(seen, expect);
    }
}
