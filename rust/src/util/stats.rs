//! Timing statistics for the benchmark harness (criterion is not
//! available offline, so benches use this module: warmup + N samples,
//! mean / median / stddev / min, and a compact report line).

use std::time::{Duration, Instant};

/// Summary statistics over a set of samples (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn from_samples(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "no samples");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            mean,
            median,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile over **sorted** samples, `q` in
/// [0, 100] (the serving-latency p50/p95/p99 primitive).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "no samples");
    let q = q.clamp(0.0, 100.0);
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Human-friendly duration formatting (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Benchmark a closure: `warmup` runs discarded, then `iters` timed runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::from_samples(&samples)
}

/// Benchmark with a time budget: runs until `budget` elapsed (at least
/// `min_iters` runs), so fast and slow cases both get stable numbers.
pub fn bench_budget<F: FnMut()>(budget: Duration, min_iters: usize, mut f: F) -> Summary {
    // single warmup
    f();
    let start = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < min_iters || start.elapsed() < budget {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() > 10_000 {
            break;
        }
    }
    Summary::from_samples(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_odd_median() {
        let s = Summary::from_samples(&[5.0, 1.0, 3.0]);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn percentiles() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert!((percentile(&v, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&v, 95.0) - 95.05).abs() < 1e-9);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_time(2e-9).contains("ns"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2.0).ends_with("s"));
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }
}
