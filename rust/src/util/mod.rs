//! Support utilities: hand-rolled JSON (offline image has no serde),
//! deterministic RNG for workloads, timing statistics for benches, and
//! thread bookkeeping for the serving layers.

pub mod json;
pub mod rng;
pub mod stats;
pub mod threads;
