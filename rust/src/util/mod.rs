//! Support utilities: hand-rolled JSON (offline image has no serde),
//! deterministic RNG for workloads, and timing statistics for benches.

pub mod json;
pub mod rng;
pub mod stats;
