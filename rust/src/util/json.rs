//! Minimal JSON parser/serializer (no external crates are available in
//! this offline image beyond the `xla` dependency tree, so the manifest
//! and perf-model stores are parsed with this hand-rolled module).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP; numbers are parsed as f64 (the manifest only carries small ints).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(s: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        b: s.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.i,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.b[self.i..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("bad utf8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            map.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Serialize a `Json` value (compact form, stable key order).
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""A\t\\""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\t\\");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01x").is_err());
        assert!(parse("\"abc").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"n":null,"o":{"k":true}}"#;
        let v = parse(src).unwrap();
        assert_eq!(to_string(&v), src);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ∞\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ∞");
    }
}
