//! Windowed operators over chunk sequences: tumbling and sliding
//! windows, with load shedding expressed as *granularity* rather than
//! loss — under pressure the window fires less often (the slide
//! stretches), it never drops chunks.
//!
//! The assembler is pure bookkeeping: the serve layer owns the window
//! *state* (a persistent `DataRegistry` handle set, so residency
//! pricing applies to the windowed stage across firings) and asks this
//! module only *when* a window completes.

use std::collections::VecDeque;

/// Declared window shape of a stream (`stream_open`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowSpec {
    /// Chunks aggregated per window (>= 1).
    pub window: usize,
    /// Chunks between firings: `slide == window` is a tumbling window,
    /// `slide < window` a sliding one.
    pub slide: usize,
}

impl WindowSpec {
    /// Normalize a wire-level declaration: `window == 0` means the
    /// stream runs no windowed operator; `slide == 0` means tumbling
    /// (slide = window); a slide wider than the window is clamped to it.
    pub fn new(window: usize, slide: usize) -> Option<WindowSpec> {
        if window == 0 {
            return None;
        }
        let slide = if slide == 0 { window } else { slide.min(window) };
        Some(WindowSpec { window, slide })
    }

    /// The slide at shed level `shed`: each level doubles the stride
    /// between firings (coarser granularity, less windowed work), capped
    /// at 4x the declared window so a shed stream still aggregates.
    pub fn effective_slide(&self, shed: u8) -> usize {
        let stretched = self.slide.saturating_shl(u32::from(shed.min(8)));
        stretched.min(self.window.saturating_mul(4)).max(self.slide)
    }
}

/// What a completed window firing covers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WindowFire {
    /// Chunk sequence numbers in the window extent, oldest first.
    pub seqs: Vec<u64>,
    /// Fired at reduced granularity (shed level > 0).
    pub shed: bool,
}

/// Assembles chunk sequences into window firings.
#[derive(Debug)]
pub struct Windower {
    spec: WindowSpec,
    /// The last `window` chunk seqs (the current window extent).
    buf: VecDeque<u64>,
    /// Chunks pushed since the last firing.
    since_fire: usize,
    /// Total windows fired.
    pub fired: u64,
    /// Firings emitted while shed (coarse granularity).
    pub shed_fired: u64,
}

impl Windower {
    pub fn new(spec: WindowSpec) -> Windower {
        Windower {
            spec,
            buf: VecDeque::with_capacity(spec.window),
            since_fire: 0,
            fired: 0,
            shed_fired: 0,
        }
    }

    pub fn spec(&self) -> WindowSpec {
        self.spec
    }

    /// Record one chunk; returns the window extent when a window
    /// completes at the current shed granularity.
    pub fn push(&mut self, seq: u64, shed: u8) -> Option<WindowFire> {
        self.buf.push_back(seq);
        while self.buf.len() > self.spec.window {
            self.buf.pop_front();
        }
        self.since_fire += 1;
        if self.buf.len() == self.spec.window && self.since_fire >= self.spec.effective_slide(shed)
        {
            self.since_fire = 0;
            self.fired += 1;
            if shed > 0 {
                self.shed_fired += 1;
            }
            return Some(WindowFire {
                seqs: self.buf.iter().copied().collect(),
                shed: shed > 0,
            });
        }
        None
    }
}

/// `usize::checked_shl` that saturates instead of wrapping (shift
/// counts here are tiny, but a hostile shed level must not overflow).
trait SaturatingShl {
    fn saturating_shl(self, n: u32) -> Self;
}

impl SaturatingShl for usize {
    fn saturating_shl(self, n: u32) -> usize {
        self.checked_shl(n).unwrap_or(usize::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_normalizes() {
        assert_eq!(WindowSpec::new(0, 0), None, "window 0 = no operator");
        let w = WindowSpec::new(4, 0).unwrap();
        assert_eq!(w.slide, 4, "slide 0 = tumbling");
        let w = WindowSpec::new(4, 9).unwrap();
        assert_eq!(w.slide, 4, "slide clamped to window");
        let w = WindowSpec::new(4, 2).unwrap();
        assert_eq!((w.window, w.slide), (4, 2));
    }

    #[test]
    fn shed_stretches_slide_with_cap() {
        let w = WindowSpec::new(4, 2).unwrap();
        assert_eq!(w.effective_slide(0), 2);
        assert_eq!(w.effective_slide(1), 4);
        assert_eq!(w.effective_slide(2), 8);
        // capped at 4x the window
        assert_eq!(w.effective_slide(3), 16);
        assert_eq!(w.effective_slide(4), 16);
        assert_eq!(w.effective_slide(8), 16);
    }

    #[test]
    fn tumbling_fires_disjoint_extents() {
        let mut w = Windower::new(WindowSpec::new(3, 0).unwrap());
        let mut fires = Vec::new();
        for seq in 1..=9 {
            if let Some(f) = w.push(seq, 0) {
                fires.push(f.seqs);
            }
        }
        assert_eq!(fires, vec![vec![1, 2, 3], vec![4, 5, 6], vec![7, 8, 9]]);
        assert_eq!(w.fired, 3);
        assert_eq!(w.shed_fired, 0);
    }

    #[test]
    fn sliding_fires_overlapping_extents() {
        let mut w = Windower::new(WindowSpec::new(4, 2).unwrap());
        let mut fires = Vec::new();
        for seq in 1..=8 {
            if let Some(f) = w.push(seq, 0) {
                fires.push(f.seqs);
            }
        }
        assert_eq!(
            fires,
            vec![vec![1, 2, 3, 4], vec![3, 4, 5, 6], vec![5, 6, 7, 8]]
        );
    }

    #[test]
    fn shed_level_coarsens_firing() {
        // same stream, shed level 1: the slide stretches 2 -> 4, so only
        // every other window fires — granularity shed, no chunk dropped
        let mut w = Windower::new(WindowSpec::new(4, 2).unwrap());
        let mut fired_at = Vec::new();
        for seq in 1..=12 {
            if let Some(f) = w.push(seq, 1) {
                assert!(f.shed);
                fired_at.push(seq);
            }
        }
        assert_eq!(fired_at, vec![4, 8, 12]);
        assert_eq!(w.shed_fired, 3);
    }

    #[test]
    fn recovery_restores_granularity() {
        let mut w = Windower::new(WindowSpec::new(2, 0).unwrap());
        assert!(w.push(1, 0).is_none());
        assert!(w.push(2, 0).is_some());
        // shed: window 2 slide 2 -> effective 4, fires every 4 chunks
        assert!(w.push(3, 1).is_none());
        assert!(w.push(4, 1).is_none());
        assert!(w.push(5, 1).is_none());
        assert!(w.push(6, 1).is_some());
        // recovered: back to every 2 chunks
        assert!(w.push(7, 0).is_none());
        assert!(w.push(8, 0).is_some());
    }
}
