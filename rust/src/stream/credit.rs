//! SLO-driven flow control for stream sessions.
//!
//! A stream never queues unboundedly: the client may only have
//! `credit` chunks outstanding, and the server re-prices that credit on
//! every chunk completion from the *measured* backlog in front of the
//! stream. When the modeled time-to-drain threatens the session's
//! `slo_ms`, the grant shrinks (and the window granularity sheds, see
//! [`super::window`]); when the backlog drains, it recovers. The grant
//! never reaches zero — backpressure slows the source, it never stalls
//! or drops an admitted chunk.

/// Default chunks-in-flight grant for a freshly opened stream.
pub const BASE_CREDIT: u64 = 8;

/// Highest shed level: credit 1, slide stretched 8x (capped by the
/// window spec).
pub const MAX_SHED: u8 = 3;

/// Outcome of one [`CreditController::assess`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CreditDecision {
    /// Chunks the client may now keep outstanding.
    pub credit: u64,
    /// Current shed level (0 = full granularity).
    pub shed: u8,
    /// The level moved this assessment — the server emits an
    /// unsolicited `stream_credit` signal exactly when this is set.
    pub changed: bool,
}

/// Per-stream credit state machine.
#[derive(Debug)]
pub struct CreditController {
    slo_ms: Option<f64>,
    base_credit: u64,
    shed: u8,
}

impl CreditController {
    pub fn new(slo_ms: Option<f64>, base_credit: u64) -> CreditController {
        CreditController {
            slo_ms: slo_ms.filter(|s| s.is_finite() && *s > 0.0),
            base_credit: base_credit.max(1),
            shed: 0,
        }
    }

    pub fn shed(&self) -> u8 {
        self.shed
    }

    /// Grant at the current shed level; halves per level, floor 1.
    pub fn credit(&self) -> u64 {
        (self.base_credit >> u32::from(self.shed)).max(1)
    }

    /// Re-price the grant against the estimated backlog (milliseconds
    /// of queued work in front of the stream's next chunk).
    ///
    /// Backpressure must engage *before* the SLO is violated, so
    /// pressure is measured against half the target: a backlog of
    /// `slo/2` is pressure 1.0 (shed level 1), and every further
    /// doubling sheds one more level up to [`MAX_SHED`]. Streams with
    /// no SLO are never shed.
    pub fn assess(&mut self, queued_ms: f64) -> CreditDecision {
        let next = match self.slo_ms {
            Some(slo) => {
                let mut pressure = queued_ms / (slo * 0.5);
                if pressure < 1.0 {
                    0
                } else {
                    let mut level: u8 = 1;
                    while pressure >= 2.0 && level < MAX_SHED {
                        pressure /= 2.0;
                        level += 1;
                    }
                    level
                }
            }
            None => 0,
        };
        let changed = next != self.shed;
        self.shed = next;
        CreditDecision {
            credit: self.credit(),
            shed: next,
            changed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_slo_never_sheds() {
        let mut c = CreditController::new(None, BASE_CREDIT);
        for backlog in [0.0, 10.0, 1e6] {
            let d = c.assess(backlog);
            assert_eq!((d.credit, d.shed, d.changed), (BASE_CREDIT, 0, false));
        }
    }

    #[test]
    fn invalid_slo_treated_as_none() {
        let mut c = CreditController::new(Some(f64::NAN), BASE_CREDIT);
        assert_eq!(c.assess(1e9).shed, 0);
        let mut c = CreditController::new(Some(0.0), BASE_CREDIT);
        assert_eq!(c.assess(1e9).shed, 0);
    }

    #[test]
    fn sheds_at_half_slo_and_escalates_per_doubling() {
        let mut c = CreditController::new(Some(20.0), 8);
        // idle: full grant
        let d = c.assess(0.0);
        assert_eq!((d.credit, d.shed, d.changed), (8, 0, false));
        // 12 ms backlog vs a 20 ms SLO: past the half-SLO engage point,
        // well before the SLO itself is violated
        let d = c.assess(12.0);
        assert_eq!((d.credit, d.shed, d.changed), (4, 1, true));
        // steady: same level, no new signal
        let d = c.assess(13.0);
        assert_eq!((d.credit, d.shed, d.changed), (4, 1, false));
        // 50 ms: pressure 5.0 -> two more doublings -> max shed
        let d = c.assess(50.0);
        assert_eq!((d.credit, d.shed, d.changed), (1, 3, true));
        // drained: full recovery, signalled once
        let d = c.assess(0.0);
        assert_eq!((d.credit, d.shed, d.changed), (8, 0, true));
    }

    #[test]
    fn credit_floor_is_one() {
        let mut c = CreditController::new(Some(1.0), 2);
        let d = c.assess(1e6);
        assert_eq!(d.shed, MAX_SHED);
        assert_eq!(d.credit, 1, "a shed stream still makes progress");
    }
}
