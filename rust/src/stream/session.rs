//! Shared per-stream state and the backlog estimator that ties
//! measured task walltime to the credit controller.
//!
//! The split between session thread and completion worker in the serve
//! layer is mediated through [`StreamShared`]: the session thread reads
//! the current shed level when assembling windows and bumps submission
//! counters; the completion worker (which sees task results) owns the
//! [`CreditController`](super::credit::CreditController) and publishes
//! its decisions here.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

use anyhow::{bail, Result};

use crate::apps;
use crate::util::stats;

use super::window::WindowSpec;

/// Validated shape of an open stream, as declared by `stream_open`.
#[derive(Debug, Clone)]
pub struct StreamSpec {
    /// Client-chosen stream id (unique within the session).
    pub id: u64,
    /// Application kernel each chunk runs (`apps::ALL`).
    pub app: String,
    /// Elements per chunk.
    pub size: usize,
    /// Pipeline depth: each chunk passes through `stages` chained
    /// applications of the codelet, each stage selecting its variant
    /// independently.
    pub stages: usize,
    /// Windowed operator, if declared.
    pub window: Option<WindowSpec>,
    /// Effective SLO driving backpressure (already merged with the
    /// session-level declaration).
    pub slo_ms: Option<f64>,
}

impl StreamSpec {
    /// Validate a wire-level declaration. Pipelines and windows re-apply
    /// the codelet to its own output, so any multi-stage or windowed
    /// stream requires an idempotent app.
    pub fn validate(
        id: u64,
        app: &str,
        size: usize,
        stages: usize,
        window: usize,
        slide: usize,
        slo_ms: Option<f64>,
    ) -> Result<StreamSpec> {
        if !apps::ALL.contains(&app) {
            bail!("unknown app '{app}' (expected one of {:?})", apps::ALL);
        }
        if size == 0 {
            bail!("stream chunk size must be >= 1");
        }
        let stages = stages.max(1);
        let window = WindowSpec::new(window, slide);
        if (stages > 1 || window.is_some()) && !apps::idempotent(app) {
            bail!(
                "app '{app}' is not idempotent: multi-stage pipelines and windowed \
                 operators re-apply the codelet (idempotent apps: {:?})",
                apps::IDEMPOTENT
            );
        }
        if let Some(ms) = slo_ms {
            if !ms.is_finite() || ms <= 0.0 {
                bail!("stream slo_ms must be a positive, finite number of milliseconds");
            }
        }
        Ok(StreamSpec {
            id,
            app: app.to_string(),
            size,
            stages,
            window,
            slo_ms,
        })
    }
}

/// Lock-free state shared between the session thread (submits chunks,
/// assembles windows) and the stream's completion worker (assesses
/// credit, acks chunks).
#[derive(Debug, Default)]
pub struct StreamShared {
    /// Current shed level, published by the completion worker and read
    /// by the session thread when pushing into the windower.
    pub shed: AtomicU8,
    /// Current credit grant (informational mirror of the last decision).
    pub credit: AtomicU64,
    /// Chunks acked.
    pub chunks: AtomicU64,
    /// Chunks that failed submit or execution (the credit loop keeps
    /// this at zero in healthy runs — backpressure sheds granularity,
    /// not chunks).
    pub dropped: AtomicU64,
    /// Windows fired.
    pub windows: AtomicU64,
    /// Windows fired at reduced granularity.
    pub shed_windows: AtomicU64,
    /// Unsolicited `stream_credit` signals emitted.
    pub credit_signals: AtomicU64,
}

impl StreamShared {
    pub fn new(initial_credit: u64) -> StreamShared {
        let s = StreamShared::default();
        s.credit.store(initial_credit, Ordering::Relaxed);
        s
    }
}

/// Estimates the wall-clock backlog in front of a stream from measured
/// per-task service times.
///
/// Modeled device times live in the microsecond domain of the analytic
/// model and are what the *selection* layer prices; an SLO is a promise
/// about wall milliseconds, so the credit loop must price the queue in
/// the same domain. An EWMA over observed task walltime, multiplied by
/// the runtime's current queue depth, is the modeled time-to-drain.
#[derive(Debug, Clone, Copy)]
pub struct BacklogModel {
    ewma_secs: f64,
    alpha: f64,
}

impl Default for BacklogModel {
    fn default() -> BacklogModel {
        BacklogModel {
            ewma_secs: 0.0,
            alpha: 0.3,
        }
    }
}

impl BacklogModel {
    /// Feed one measured per-task walltime (seconds).
    pub fn observe(&mut self, task_wall_secs: f64) {
        if !task_wall_secs.is_finite() || task_wall_secs < 0.0 {
            return;
        }
        if self.ewma_secs == 0.0 {
            self.ewma_secs = task_wall_secs;
        } else {
            self.ewma_secs += self.alpha * (task_wall_secs - self.ewma_secs);
        }
    }

    /// Modeled milliseconds of queued work at the given queue depth.
    pub fn queued_ms(&self, queue_depth: usize) -> f64 {
        self.ewma_secs * 1e3 * queue_depth as f64
    }
}

/// Per-chunk latency record kept by the completion worker for the
/// close-time summary.
#[derive(Debug, Default)]
pub struct LatencyTrack {
    samples: Vec<f64>,
}

impl LatencyTrack {
    pub fn record(&mut self, secs: f64) {
        self.samples.push(secs);
    }

    /// p95 chunk latency in milliseconds (0 when no chunk completed).
    pub fn p95_ms(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        stats::percentile(&sorted, 95.0) * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_normalizes_and_rejects() {
        let s = StreamSpec::validate(1, "sort", 4096, 0, 4, 0, Some(20.0)).unwrap();
        assert_eq!(s.stages, 1, "stages floor at 1");
        let w = s.window.unwrap();
        assert_eq!((w.window, w.slide), (4, 4), "slide 0 normalizes to tumbling");

        assert!(StreamSpec::validate(1, "nope", 64, 1, 0, 0, None).is_err());
        assert!(StreamSpec::validate(1, "sort", 0, 1, 0, 0, None).is_err());
        assert!(StreamSpec::validate(1, "sort", 64, 1, 0, 0, Some(-1.0)).is_err());
        // hotspot is not idempotent: fine single-stage, rejected piped
        assert!(StreamSpec::validate(1, "hotspot", 64, 1, 0, 0, None).is_ok());
        let err = StreamSpec::validate(1, "hotspot", 64, 2, 0, 0, None).unwrap_err();
        assert!(format!("{err:#}").contains("not idempotent"), "{err:#}");
        assert!(StreamSpec::validate(1, "hotspot", 64, 1, 4, 0, None).is_err());
    }

    #[test]
    fn backlog_tracks_measured_walltime() {
        let mut b = BacklogModel::default();
        assert_eq!(b.queued_ms(10), 0.0, "no observations yet");
        b.observe(0.002);
        assert!((b.queued_ms(10) - 20.0).abs() < 1e-9, "2 ms x 10 queued");
        // converges toward a new service time
        for _ in 0..64 {
            b.observe(0.001);
        }
        assert!((b.queued_ms(10) - 10.0).abs() < 0.5);
        // garbage observations are ignored
        b.observe(f64::NAN);
        b.observe(-1.0);
        assert!((b.queued_ms(10) - 10.0).abs() < 0.5);
    }

    #[test]
    fn latency_p95() {
        let mut l = LatencyTrack::default();
        assert_eq!(l.p95_ms(), 0.0);
        for i in 1..=100 {
            l.record(i as f64 / 1000.0);
        }
        assert!((l.p95_ms() - 95.05).abs() < 0.1);
    }
}
