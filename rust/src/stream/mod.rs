//! `stream` — heterogeneous stream computing on top of the serve
//! protocol (HSTREAM-style, Memeti & Pllana): a client opens a *stream
//! session* (protocol v6 `stream_open`), chunks flow continuously
//! through a declared codelet pipeline (`stream_chunk`), and every
//! chunk's stage picks its implementation variant per-chunk through the
//! runtime's selection engine — so device variants win while the
//! machine is idle and lose, chunk by chunk, when load-band pressure
//! builds.
//!
//! The module holds the pure core of the subsystem; the serve layer
//! wires it to sockets and the task runtime:
//!
//! - [`window`]: tumbling/sliding windows over chunk sequences. Under
//!   pressure the window *sheds granularity* (the slide stretches) —
//!   it never drops chunks. Window state lives in persistent
//!   `DataRegistry` handles owned by the serve layer, so residency
//!   pricing applies to the windowed stage across firings.
//! - [`credit`]: SLO-driven flow control. The client may only keep
//!   `credit` chunks outstanding; the grant is re-priced on every
//!   completion and an unsolicited `stream_credit` signal is pushed
//!   when it moves. Backpressure engages at *half* the SLO — before
//!   the target is violated, not after.
//! - [`session`]: the validated stream shape, the state shared between
//!   submission and completion threads, and the [`BacklogModel`] that
//!   prices the queue in wall milliseconds (the SLO's domain) from
//!   measured task service times.

pub mod credit;
pub mod session;
pub mod window;

pub use credit::{CreditController, CreditDecision, BASE_CREDIT, MAX_SHED};
pub use session::{BacklogModel, LatencyTrack, StreamShared, StreamSpec};
pub use window::{WindowFire, WindowSpec, Windower};

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::apps::sort::{sort_omp, sort_seq};
use crate::taskrt::{AccessMode, Arch, Codelet, ExecBuffers};

/// A `sort` codelet whose CUDA variant is a *native* device emulation:
/// it runs a real sort after sleeping `device_latency`, while modeled
/// time attribution still comes from the analytic device model.
///
/// The real app codelet's "cuda" variant is a Pallas artifact that
/// needs a compiled manifest and an XLA service; benches and tests run
/// on bare images where neither exists, yet the streaming story needs a
/// genuine device lane whose queue can be buried. Registering this
/// codelet under the app's name before serving makes the device lane
/// real (occupancy, backlog, per-chunk flips) without any artifact.
pub fn emulated_device_sort(device_latency: Duration) -> Codelet {
    let wrap = |f: fn(&mut [f32])| -> crate::taskrt::NativeFn {
        Arc::new(move |bufs: &ExecBuffers| -> Result<()> {
            let mut arr = bufs.write(0);
            f(arr.data_mut());
            Ok(())
        })
    };
    let device: crate::taskrt::NativeFn = Arc::new(move |bufs: &ExecBuffers| -> Result<()> {
        std::thread::sleep(device_latency);
        let mut arr = bufs.write(0);
        sort_seq(arr.data_mut());
        Ok(())
    });
    Codelet::new("sort", "sort", vec![AccessMode::ReadWrite])
        .with_native("omp", Arch::Cpu, wrap(sort_omp))
        .with_native("seq", Arch::Cpu, wrap(sort_seq))
        .with_native("cuda", Arch::Cuda, device)
        .with_hint("cuda")
}
