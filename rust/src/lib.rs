//! # COMPAR — component-based parallel programming with dynamic
//! implementation-variant selection
//!
//! Reproduction of Memeti, *"Enabling Dynamic Selection of Implementation
//! Variants in Component-Based Parallel Programming for Heterogeneous
//! Systems"* (2023), as a three-layer Rust + JAX + Pallas system:
//!
//! * [`compar`] — the paper's language extension and source-to-source
//!   pre-compiler (`#pragma compar ...` -> glue code).
//! * [`taskrt`] — the StarPU-analog heterogeneous task runtime: codelets,
//!   data handles, device workers, pluggable schedulers, history-based
//!   performance models.
//! * [`runtime`] — the PJRT bridge that executes the AOT-compiled JAX /
//!   Pallas artifacts (the "GPU library" implementation variants).
//! * [`apps`] — the paper's benchmark applications (Rodinia hotspot,
//!   hotspot3D, lud, nw, plus matmul and the sort quickstart), each with
//!   multiple implementation variants.
//! * [`serve`] — the multi-tenant component service: a persistent
//!   runtime partitioned into scheduling contexts, serving task-graph
//!   requests from concurrent clients (`compar serve` / `compar loadgen`).
//! * [`cluster`] — sharded multi-process serving: a routing front-end
//!   (`compar route`) speaking the same protocol over N serve shards,
//!   with perf-model gossip so variant selection learns cluster-wide.
//! * [`autoscale`] — the elastic control plane: a control loop that
//!   resizes scheduling contexts (live worker migration) and drives
//!   shard spawn/retire in the cluster, from the same runtime-snapshot
//!   features the selection layer keys on.
//! * [`stream`] — heterogeneous stream computing (HSTREAM-style):
//!   stream sessions over the serve protocol with per-chunk variant
//!   selection, windowed operators, and SLO-driven credit backpressure.
//! * [`plan`] — global lookahead composition: a `GraphPlanner` that
//!   assigns variants jointly over whole task DAGs before release,
//!   eliding producer→consumer transfers and composing same-arch spans
//!   (Kessler & Dastgeer's "Optimized Composition").
//! * [`obs`] — the live observability plane: a lock-cheap metrics
//!   registry (counters / gauges / latency histograms, JSON +
//!   Prometheus exposition), cross-layer request tracing with a live
//!   span ring (`dump_trace`), and the selection-decision audit log
//!   (`decisions`) — protocol v9, aggregated cluster-wide by the
//!   router.
//! * [`model`] — the verified concurrency core: a pure state-machine
//!   model of the runtime's contexts / migration / eviction / shard
//!   retirement, a deterministic generative explorer with shrinking,
//!   kani-ready bounded proof harnesses, and a differential mode
//!   against the real runtime (`compar verify model`).
//! * [`bench_harness`] — regenerates every table and figure of the
//!   paper's evaluation section.

pub mod apps;
pub mod autoscale;
pub mod bench_harness;
pub mod cluster;
pub mod compar;
pub mod model;
pub mod obs;
pub mod plan;
pub mod runtime;
pub mod serve;
pub mod stream;
pub mod taskrt;
pub mod util;
