//! The generative explorer: deterministic random op sequences over the
//! pure model, invariants checked after every step, failures shrunk to
//! a 1-minimal reproducing sequence by delta debugging.
//!
//! Determinism contract: a sequence is fully determined by its seed
//! (per-sequence seeds derive from the base via
//! [`derive_seed`](crate::util::rng::derive_seed)), and a recorded op
//! list replays to the identical state regardless of the seed — so a
//! shrunk counterexample is self-contained. Setting
//! `COMPAR_MODEL_SEED` replays exactly one seed.

use crate::util::rng::{derive_seed, env_seed, Rng};

use super::invariants;
use super::ops::{gen_op, Fault, Op};
use super::state::{ModelConfig, ModelState};

#[derive(Debug, Clone)]
pub struct ExploreOptions {
    /// Generated sequences to run (each gets its own derived seed).
    pub sequences: usize,
    /// Ops per sequence.
    pub ops_per_seq: usize,
    /// Base seed; per-sequence seeds derive from it.
    pub seed: u64,
    pub config: ModelConfig,
    /// Injected bug (self-test / `--fault`); `None` = verify.
    pub fault: Option<Fault>,
    /// Honor a `COMPAR_MODEL_SEED` override (replay mode). The
    /// self-test disables this: it must explore its own seeds.
    pub honor_env_seed: bool,
}

impl Default for ExploreOptions {
    fn default() -> Self {
        ExploreOptions {
            sequences: 10_000,
            ops_per_seq: 48,
            seed: 0x5eed_c0de,
            config: ModelConfig::default(),
            fault: None,
            honor_env_seed: true,
        }
    }
}

/// An invariant violation, shrunk and ready to report.
#[derive(Debug, Clone)]
pub struct Violation {
    /// The sequence seed — `COMPAR_MODEL_SEED=<seed>` replays it.
    pub seed: u64,
    /// Step index (into `ops`) at which the invariant first broke.
    pub step: usize,
    pub message: String,
    /// The full generated sequence up to (and including) the failure.
    pub ops: Vec<Op>,
    /// 1-minimal subsequence that still reproduces a violation.
    pub shrunk: Vec<Op>,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "invariant violated at step {} of seed {:#x}: {}",
            self.step, self.seed, self.message
        )?;
        writeln!(
            f,
            "shrunk to {} op(s) (from {}):",
            self.shrunk.len(),
            self.ops.len()
        )?;
        for (i, op) in self.shrunk.iter().enumerate() {
            writeln!(f, "  {i:>3}. {op:?}")?;
        }
        write!(
            f,
            "replay with COMPAR_MODEL_SEED={:#x} (or {})",
            self.seed, self.seed
        )
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct ExploreStats {
    pub sequences: usize,
    pub ops_applied: usize,
}

/// Run the explorer. `Ok` carries throughput stats; `Err` carries the
/// first violation, already shrunk.
pub fn explore(opts: &ExploreOptions) -> Result<ExploreStats, Box<Violation>> {
    let seeds: Vec<u64> = match env_seed().filter(|_| opts.honor_env_seed) {
        Some(seed) => vec![seed],
        None => (0..opts.sequences as u64)
            .map(|i| derive_seed(opts.seed, i))
            .collect(),
    };
    let mut stats = ExploreStats::default();
    for seed in seeds {
        let (ops, failure) = generate(seed, &opts.config, opts.fault, opts.ops_per_seq);
        stats.sequences += 1;
        stats.ops_applied += ops.len();
        if let Some((step, message)) = failure {
            let shrunk = shrink(&opts.config, opts.fault, &ops);
            return Err(Box::new(Violation {
                seed,
                step,
                message,
                ops,
                shrunk,
            }));
        }
    }
    Ok(stats)
}

/// Generate-and-check one sequence. Generation is state-aware (ops are
/// drawn against the live model), but the recorded list alone replays
/// to the same state — [`replay`] needs no RNG.
fn generate(
    seed: u64,
    cfg: &ModelConfig,
    fault: Option<Fault>,
    len: usize,
) -> (Vec<Op>, Option<(usize, String)>) {
    let mut rng = Rng::new(seed);
    let mut state = ModelState::new(cfg, fault);
    let mut ops = Vec::with_capacity(len);
    for step in 0..len {
        let op = gen_op(&mut rng, &state);
        ops.push(op.clone());
        let _ = state.apply(&op); // rejected ops are legal no-ops
        if let Err(msg) = invariants::check(&state) {
            return (ops, Some((step, msg)));
        }
    }
    (ops, None)
}

/// Replay a recorded op list from a fresh state; returns the first
/// violation, if any.
pub fn replay(cfg: &ModelConfig, fault: Option<Fault>, ops: &[Op]) -> Option<(usize, String)> {
    let mut state = ModelState::new(cfg, fault);
    for (step, op) in ops.iter().enumerate() {
        let _ = state.apply(op);
        if let Err(msg) = invariants::check(&state) {
            return Some((step, msg));
        }
    }
    None
}

/// Delta-debug the op list down to a 1-minimal subsequence that still
/// violates an invariant: remove chunks (halving the chunk size), then
/// single ops, until no single removal preserves the failure.
pub fn shrink(cfg: &ModelConfig, fault: Option<Fault>, ops: &[Op]) -> Vec<Op> {
    let mut cur: Vec<Op> = ops.to_vec();
    let mut chunk = (cur.len() / 2).max(1);
    loop {
        let mut removed_any = false;
        let mut i = 0;
        while i < cur.len() {
            let end = (i + chunk).min(cur.len());
            let mut candidate = cur.clone();
            candidate.drain(i..end);
            if replay(cfg, fault, &candidate).is_some() {
                cur = candidate;
                removed_any = true;
                // stay at i: the next chunk shifted into this position
            } else {
                i += chunk;
            }
        }
        if chunk > 1 {
            chunk = (chunk / 2).max(1);
        } else if !removed_any {
            return cur; // a full single-op pass removed nothing: 1-minimal
        }
    }
}

/// Prove the harness works: with an injected conservation bug the
/// explorer must find a violation, the shrunk sequence must still
/// reproduce it, and it must be 1-minimal. Returns the violation for
/// reporting, or an error describing how the harness failed.
pub fn self_test(cfg: &ModelConfig) -> Result<Box<Violation>, String> {
    let fault = Some(Fault::DropEvictedTask);
    let opts = ExploreOptions {
        sequences: 2_000,
        ops_per_seq: 32,
        seed: 0xfa017,
        config: *cfg,
        fault,
        honor_env_seed: false,
    };
    let violation = match explore(&opts) {
        Ok(stats) => {
            return Err(format!(
                "injected {} bug survived {} sequences ({} ops) undetected",
                Fault::DropEvictedTask.name(),
                stats.sequences,
                stats.ops_applied
            ))
        }
        Err(v) => v,
    };
    if violation.shrunk.is_empty() {
        return Err("shrinking produced an empty sequence".into());
    }
    if replay(cfg, fault, &violation.shrunk).is_none() {
        return Err("shrunk sequence no longer reproduces the violation".into());
    }
    // 1-minimality: removing any single op must make the failure vanish
    for skip in 0..violation.shrunk.len() {
        let mut candidate = violation.shrunk.clone();
        candidate.remove(skip);
        if replay(cfg, fault, &candidate).is_some() {
            return Err(format!(
                "shrunk sequence is not 1-minimal: op {skip} is removable"
            ));
        }
    }
    // the fault must not be observable without the injection — the
    // invariants hold on the same sequence against the correct model
    if let Some((step, msg)) = replay(cfg, None, &violation.shrunk) {
        return Err(format!(
            "counterexample fails even without the fault (step {step}: {msg}) — \
             the model itself is broken"
        ));
    }
    Ok(violation)
}
