//! Shard-table model: the router's append-only-with-retirement shard
//! set, driven through the **real** [`ShardState`] flags and the real
//! [`placement::pick`] — so the retirement invariants ("a retired
//! shard is never placed", "pending requests survive retirement")
//! are checked against the production placement code, not a
//! re-implementation of it.
//!
//! The model is single-threaded and deterministic: the round-robin
//! cursor is owned here, loads only change through explicit ops, and
//! calibration stays empty so `Calibrated` placement always takes its
//! least-loaded fallback.

use std::collections::BTreeMap;
use std::sync::atomic::AtomicUsize;
use std::sync::Arc;

use crate::cluster::placement::{self, PlacementKind};
use crate::cluster::router::ShardState;

/// A pending (routed, not yet completed) request: which shard it was
/// placed on. Keyed by request id, ascending = submission order.
pub type PendingMap = BTreeMap<u64, usize>;

pub struct ShardTableModel {
    shards: Vec<Arc<ShardState>>,
    rr: AtomicUsize,
    pending: PendingMap,
    next_req: u64,
    next_port: u16,
    /// First detected placement violation (a pick landed on an
    /// unavailable shard). Latched: once corrupt, always corrupt —
    /// the invariant checker reports it after the offending step.
    corrupt: Option<String>,
}

impl Default for ShardTableModel {
    fn default() -> Self {
        ShardTableModel::new()
    }
}

impl ShardTableModel {
    /// Start with one shard, like a freshly booted single-shard router.
    pub fn new() -> ShardTableModel {
        let mut m = ShardTableModel {
            shards: Vec::new(),
            rr: AtomicUsize::new(0),
            pending: BTreeMap::new(),
            next_req: 0,
            next_port: 7500,
            corrupt: None,
        };
        m.spawn();
        m
    }

    /// Append a shard (the table never shrinks); returns its index.
    pub fn spawn(&mut self) -> usize {
        let addr = format!("127.0.0.1:{}", self.next_port);
        self.next_port += 1;
        self.shards.push(Arc::new(ShardState::new(addr)));
        self.shards.len() - 1
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    pub fn available(&self, shard: usize) -> bool {
        self.shards.get(shard).is_some_and(|s| s.available())
    }

    pub fn retired(&self, shard: usize) -> bool {
        self.shards.get(shard).is_some_and(|s| s.retired())
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Retire a shard (terminal). Out-of-range ids are rejected like
    /// any other invalid op.
    pub fn retire(&mut self, shard: usize) -> Result<(), String> {
        match self.shards.get(shard) {
            Some(s) => {
                s.set_retired();
                Ok(())
            }
            None => Err(format!("unknown shard {shard}")),
        }
    }

    pub fn drain(&mut self, shard: usize, on: bool) -> Result<(), String> {
        match self.shards.get(shard) {
            Some(s) => {
                s.set_draining(on);
                Ok(())
            }
            None => Err(format!("unknown shard {shard}")),
        }
    }

    /// Health-poll overwrite of a shard's load signals.
    pub fn set_load(&mut self, shard: usize, inflight: u64, depth: u64) -> Result<(), String> {
        match self.shards.get(shard) {
            Some(s) => {
                s.set_inflight(inflight);
                s.set_queue_depth(depth);
                Ok(())
            }
            None => Err(format!("unknown shard {shard}")),
        }
    }

    /// Route one request through the real placement policy. Returns
    /// the request id, or an error when no shard is available (every
    /// shard down/draining/retired — the router's 503 path).
    pub fn place(&mut self, kind: PlacementKind, app: &str, size: usize) -> Result<u64, String> {
        let Some(i) = placement::pick(kind, &self.shards, app, size, &[], &self.rr) else {
            return Err("no shard available".into());
        };
        if !self.shards[i].available() && self.corrupt.is_none() {
            self.corrupt = Some(format!(
                "placement picked unavailable shard {i} (retired={}, draining={})",
                self.shards[i].retired(),
                self.shards[i].draining()
            ));
        }
        let req = self.next_req;
        self.next_req += 1;
        self.pending.insert(req, i);
        // the routed request counts toward the shard's load until it
        // completes (mirrors the router's in-flight accounting)
        let s = &self.shards[i];
        s.set_inflight(s.inflight() + 1);
        Ok(req)
    }

    /// Complete the `pick`-th oldest pending request. Retired shards
    /// still complete their in-flight work — retirement only removes
    /// them from the placement rotation.
    pub fn complete(&mut self, pick: usize) -> Result<u64, String> {
        let Some(&req) = self.pending.keys().nth(pick) else {
            return Err(format!("no pending request at position {pick}"));
        };
        let shard = self.pending.remove(&req).expect("key just listed");
        if let Some(s) = self.shards.get(shard) {
            s.set_inflight(s.inflight().saturating_sub(1));
        }
        Ok(req)
    }

    /// The shard-table invariants: no placement ever landed on an
    /// unavailable shard (latched at place() time, since the rotation
    /// state has moved on by check time), every pending request maps to
    /// a valid index (retirement never invalidates the pending map),
    /// and retirement is terminal (a retired shard is never available).
    pub fn check(&self) -> Result<(), String> {
        if let Some(msg) = &self.corrupt {
            return Err(msg.clone());
        }
        for (&req, &shard) in &self.pending {
            if shard >= self.shards.len() {
                return Err(format!(
                    "pending request {req} maps to shard {shard} but the table has {}",
                    self.shards.len()
                ));
            }
        }
        for (i, s) in self.shards.iter().enumerate() {
            if s.retired() && s.available() {
                return Err(format!("shard {i} is retired yet still available"));
            }
        }
        Ok(())
    }
}
