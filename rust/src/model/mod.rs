//! Verified concurrency core: a pure state-machine model of the
//! runtime's concurrency mechanics, a deterministic generative
//! explorer over it, bounded kani proof harnesses, and a differential
//! mode against the real [`Runtime`](crate::taskrt::Runtime).
//!
//! Dynamic variant selection (the paper's headline feature) rests on
//! genuinely intricate concurrency: live worker migration with
//! per-context gates, signed queue/occupancy counters, eviction and
//! re-placement, an append-only-with-retirement shard table. This
//! module is the machine-checked safety floor under all of it:
//!
//! - [`state`] — the pure model: contexts, members, lanes, in-flight
//!   charges, the shard table, the real autoscale policy;
//! - [`ops`] — the op alphabet + seeded generator + injectable faults;
//! - [`invariants`] — worker conservation, occupancy bounds (shared
//!   verbatim with the live runtime via
//!   [`validate_occupancy`](crate::taskrt::validate_occupancy)), task
//!   conservation, shard-retirement stability;
//! - [`explore`] — drive random op sequences, check after every step,
//!   shrink failures to 1-minimal counterexamples (ddmin), print the
//!   seed for `COMPAR_MODEL_SEED` replay;
//! - [`proofs`] — the same invariants as `#[cfg(kani)]` bounded proof
//!   harnesses, compiled and run concretely on images without kani;
//! - [`diff`] — replay structural sequences against a real `Runtime`
//!   and compare audited state, so model and implementation can't
//!   drift.
//!
//! Entry point: `compar verify model` (see `main.rs`), smoke-gated in
//! CI with ≥ 10k sequences plus the injected-fault self-test.

pub mod diff;
pub mod explore;
pub mod invariants;
pub mod ops;
pub mod proofs;
pub mod shard;
pub mod state;

pub use diff::{DiffOptions, DiffStats};
pub use explore::{explore, self_test, shrink, ExploreOptions, ExploreStats, Violation};
pub use ops::{Fault, Op, VALID_FAULTS};
pub use shard::ShardTableModel;
pub use state::{ModelConfig, ModelState};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::placement::PlacementKind;

    #[test]
    fn fresh_state_satisfies_invariants() {
        let st = ModelState::new(&ModelConfig::default(), None);
        assert!(invariants::check(&st).is_ok());
        assert!(st.is_quiescent());
        assert_eq!(st.total_workers(), 4);
        assert_eq!(st.contexts_len(), 1);
    }

    #[test]
    fn submit_pop_complete_lifecycle() {
        let mut st = ModelState::new(&ModelConfig::default(), None);
        let t = st.submit(0).unwrap();
        assert!(!st.is_quiescent());
        let ready = st.poppable_workers();
        assert_eq!(ready.len(), 1, "one lane holds the task");
        let w = ready[0];
        assert_eq!(st.pop(w).unwrap(), t);
        assert!(
            st.pop(w).is_err(),
            "a busy worker must not pop a second task"
        );
        assert_eq!(st.charged_workers(), vec![w]);
        assert_eq!(st.complete(w).unwrap(), t);
        assert!(st.is_quiescent());
        assert!(invariants::check(&st).is_ok());
    }

    #[test]
    fn create_context_requires_quiescence_and_range() {
        let mut st = ModelState::new(&ModelConfig::default(), None);
        assert!(st.create_context(&[]).is_err());
        assert!(st.create_context(&[9]).is_err());
        st.submit(0).unwrap();
        assert!(st.create_context(&[1]).is_err(), "not quiescent");
        st.drain();
        let id = st.create_context(&[1, 2]).unwrap();
        assert_eq!(id, 1);
        assert_eq!(st.memberships(), vec![vec![0, 3], vec![1, 2]]);
        assert!(invariants::check(&st).is_ok());
    }

    #[test]
    fn move_workers_respects_last_of_arch_floor() {
        // 3 cpu + 1 cuda: the cuda worker (id 3) is the default
        // context's last of its arch and must never leave it
        let mut st = ModelState::new(&ModelConfig::default(), None);
        let id = st.create_context(&[0]).unwrap();
        let moved = st.move_workers(0, id, 4).unwrap();
        assert_eq!(moved, 1, "two cpus: one must stay, cuda is pinned");
        assert_eq!(st.memberships()[0], vec![2, 3]);
        assert!(invariants::check(&st).is_ok());
        assert!(st.move_workers(id, id, 1).is_err(), "self-move rejected");
        assert!(st.move_workers(0, 7, 1).is_err(), "unknown context");
    }

    #[test]
    fn migration_evicts_and_replaces_queued_tasks() {
        let mut st = ModelState::new(&ModelConfig::default(), None);
        let id = st.create_context(&[0, 1]).unwrap();
        for _ in 0..6 {
            st.submit(id).unwrap();
        }
        // move one cpu out of the new context: its lane must re-place
        // onto the remaining member, losing nothing
        let moved = st.move_workers(id, 0, 1).unwrap();
        assert_eq!(moved, 1);
        assert!(invariants::check(&st).is_ok());
        assert_eq!(st.contexts[id].queued(), 6, "all six tasks survived");
        st.drain();
        assert!(st.is_quiescent());
        assert!(invariants::check(&st).is_ok());
    }

    #[test]
    fn migrated_workers_charge_stays_on_source() {
        let mut st = ModelState::new(&ModelConfig::default(), None);
        let id = st.create_context(&[0, 1]).unwrap();
        st.submit(id).unwrap();
        let w = st.poppable_workers()[0];
        st.pop(w).unwrap();
        // migrate the executing worker out: the charge stays on the
        // source context (the real Busy guard holds the source counter)
        let moved = st.move_workers(id, 0, 2).unwrap();
        assert!(moved >= 1);
        if !st.contexts[id].members.contains(&w) {
            assert!(
                st.contexts[id].running.contains_key(&w),
                "charge must stay on the source context"
            );
        }
        assert!(invariants::check(&st).is_ok());
        assert_eq!(st.complete(w).unwrap(), 0);
        assert!(st.is_quiescent());
    }

    #[test]
    fn injected_faults_violate_invariants() {
        // worker leak: a move drops the mover from the partition
        let mut st = ModelState::new(&ModelConfig::default(), Some(Fault::LeakWorkerOnMove));
        let id = st.create_context(&[0, 1]).unwrap();
        st.move_workers(id, 0, 1).unwrap();
        let err = invariants::check(&st).unwrap_err();
        assert!(err.contains("not a member of any context"), "{err}");

        // task drop: eviction loses a queued task
        let mut st = ModelState::new(&ModelConfig::default(), Some(Fault::DropEvictedTask));
        let id = st.create_context(&[0, 1]).unwrap();
        st.submit(id).unwrap();
        let w = *st.contexts[id].lanes.keys().next().unwrap();
        st.evict(id, w).unwrap();
        let err = invariants::check(&st).unwrap_err();
        assert!(err.contains("task conservation broken"), "{err}");
    }

    #[test]
    fn explorer_short_run_is_clean_and_deterministic() {
        let opts = ExploreOptions {
            sequences: 200,
            ops_per_seq: 40,
            honor_env_seed: false,
            ..ExploreOptions::default()
        };
        let a = explore(&opts).expect("no violation in the correct model");
        let b = explore(&opts).expect("deterministic re-run");
        assert_eq!(a.ops_applied, b.ops_applied, "same seeds, same ops");
        assert_eq!(a.sequences, 200);
    }

    #[test]
    fn self_test_catches_and_shrinks_the_injected_bug() {
        let v = self_test(&ModelConfig::default()).expect("harness must catch the fault");
        assert!(!v.shrunk.is_empty());
        assert!(
            v.shrunk.len() <= 8,
            "expected a tight counterexample, got {} ops: {:?}",
            v.shrunk.len(),
            v.shrunk
        );
    }

    #[test]
    fn shard_model_retirement_properties() {
        let mut sh = ShardTableModel::new();
        sh.spawn();
        sh.spawn();
        let req = sh.place(PlacementKind::RoundRobin, "matmul", 64).unwrap();
        sh.retire(1).unwrap();
        assert!(sh.retired(1) && !sh.available(1));
        // placement must keep avoiding the retired shard
        for _ in 0..8 {
            sh.place(PlacementKind::LeastLoaded, "matmul", 64).unwrap();
        }
        assert!(sh.check().is_ok(), "{:?}", sh.check());
        // the pre-retirement request is still resolvable
        assert_eq!(sh.complete(0).unwrap(), req);
        // retiring everything leaves no placement target
        sh.retire(0).unwrap();
        sh.retire(2).unwrap();
        assert!(sh.place(PlacementKind::RoundRobin, "matmul", 64).is_err());
        assert!(sh.check().is_ok());
    }

    #[test]
    fn proofs_run_concretely() {
        proofs::run_concrete(32);
    }
}
