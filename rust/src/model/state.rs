//! The pure state machine: an executable specification of the
//! runtime's concurrency core. No threads, no atomics, no clocks —
//! every transition is a plain function of the previous state, so the
//! explorer can replay, bisect and shrink op sequences byte-for-byte.
//!
//! The model mirrors the real semantics exactly where they matter for
//! the invariants:
//!
//! - `create_context` requires quiescence, shrinks donors in place and
//!   appends a slot (context ids are never reused);
//! - `move_workers` picks movers receiver-arch-first / idle-first /
//!   lowest-id, never moves a donor's last worker of an architecture,
//!   evicts the movers' lanes and re-places the tasks on the remaining
//!   members;
//! - a migrated worker's in-flight task stays **charged to the source
//!   context** until it completes (in the real runtime the Busy guard
//!   holds the source `SchedCtx`'s counter), so charges may legally
//!   sit on workers that are no longer members;
//! - the autoscaler step drives the real [`Threshold`] policy with
//!   samples derived from the modeled lanes and charges.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::autoscale::{CtxSample, ScaleAction, ScalePolicy, Threshold, ThresholdConfig};
use crate::taskrt::Arch;

use super::ops::{Fault, Op};
use super::shard::ShardTableModel;

/// Machine shape of a modeled runtime (the paper topology: `ncpu` CPU
/// workers on memory node 0, then `ncuda` device workers on node 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelConfig {
    pub ncpu: usize,
    pub ncuda: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig { ncpu: 3, ncuda: 1 }
    }
}

/// One scheduling context as the model sees it.
#[derive(Debug, Clone)]
pub struct ModelCtx {
    pub name: String,
    /// Sorted global worker ids of the partition.
    pub members: Vec<usize>,
    /// Worker count at creation — the autoscaler's rebalance target.
    pub home: usize,
    /// Per-member ready lanes (queued task ids, FIFO). Keys are always
    /// a subset of `members`; eviction maintains this on migration.
    pub lanes: BTreeMap<usize, Vec<u64>>,
    /// In-flight tasks *charged to this context*, by executing worker.
    /// A worker appears here from pop to complete; after a migration
    /// it may no longer be a member (the charge stays on the source).
    pub running: BTreeMap<usize, Vec<u64>>,
}

impl ModelCtx {
    pub fn queued(&self) -> usize {
        self.lanes.values().map(Vec::len).sum()
    }

    pub fn running_count(&self) -> usize {
        self.running.values().map(Vec::len).sum()
    }
}

/// The whole modeled system: worker partition, task lifecycle
/// counters, the shard table, and the real autoscale policy instance.
pub struct ModelState {
    /// Architecture of each global worker id (fixed topology).
    pub archs: Vec<Arch>,
    /// Current context of each worker (the `worker_ctx` table).
    pub worker_ctx: Vec<usize>,
    /// Context table: append-only, ids never reused.
    pub contexts: Vec<ModelCtx>,
    pub next_task: u64,
    pub submitted: u64,
    pub completed: u64,
    pub shards: ShardTableModel,
    scaler: Threshold,
    fault: Option<Fault>,
}

impl ModelState {
    pub fn new(cfg: &ModelConfig, fault: Option<Fault>) -> ModelState {
        let mut archs = vec![Arch::Cpu; cfg.ncpu];
        archs.resize(cfg.ncpu + cfg.ncuda, Arch::Cuda);
        let members: Vec<usize> = (0..archs.len()).collect();
        let default_ctx = ModelCtx {
            name: "default".into(),
            home: members.len(),
            members,
            lanes: BTreeMap::new(),
            running: BTreeMap::new(),
        };
        ModelState {
            worker_ctx: vec![0; archs.len()],
            archs,
            contexts: vec![default_ctx],
            next_task: 0,
            submitted: 0,
            completed: 0,
            shards: ShardTableModel::new(),
            scaler: Threshold::new(ThresholdConfig::default()),
            fault,
        }
    }

    // ------------------------------------------------------ introspection

    pub fn contexts_len(&self) -> usize {
        self.contexts.len()
    }

    pub fn total_workers(&self) -> usize {
        self.archs.len()
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn pending_routes(&self) -> usize {
        self.shards.pending_len()
    }

    /// No task submitted is still queued or in flight.
    pub fn is_quiescent(&self) -> bool {
        self.submitted == self.completed
    }

    /// Workers whose current context has something queued for them and
    /// that are not already executing (the legal Pop targets).
    pub fn poppable_workers(&self) -> Vec<usize> {
        (0..self.archs.len())
            .filter(|&w| {
                !self.worker_busy(w)
                    && self.contexts[self.worker_ctx[w]]
                        .lanes
                        .get(&w)
                        .is_some_and(|l| !l.is_empty())
            })
            .collect()
    }

    /// Workers currently charged with an in-flight task (in any
    /// context — migration can strand the charge on the source).
    pub fn charged_workers(&self) -> Vec<usize> {
        (0..self.archs.len())
            .filter(|&w| self.worker_busy(w))
            .collect()
    }

    fn worker_busy(&self, w: usize) -> bool {
        self.contexts
            .iter()
            .any(|c| c.running.get(&w).is_some_and(|v| !v.is_empty()))
    }

    /// Sorted member sets per context (the differential mode compares
    /// this against [`crate::taskrt::AuditedState`]).
    pub fn memberships(&self) -> Vec<Vec<usize>> {
        self.contexts.iter().map(|c| c.members.clone()).collect()
    }

    // ----------------------------------------------------------- stepping

    /// Apply one op. `Err` mirrors the runtime's `bail!` paths — the
    /// op was rejected and the state is unchanged. `Ok(Some(n))`
    /// carries the moved-worker count of `MoveWorkers`/`ResizeContext`
    /// (what the real calls return), `Ok(None)` for everything else.
    pub fn apply(&mut self, op: &Op) -> Result<Option<usize>, String> {
        match op {
            Op::CreateContext { workers } => self.create_context(workers).map(|_| None),
            Op::MoveWorkers { from, to, n } => self.move_workers(*from, *to, *n).map(Some),
            Op::ResizeContext { ctx, target } => self.resize_context(*ctx, *target).map(Some),
            Op::Submit { ctx } => self.submit(*ctx).map(|_| None),
            Op::Pop { worker } => self.pop(*worker).map(|_| None),
            Op::Complete { worker } => self.complete(*worker).map(|_| None),
            Op::Evict { ctx, worker } => self.evict(*ctx, *worker).map(|_| None),
            Op::ScaleTick { dt_ms } => self.scale_tick(*dt_ms).map(Some),
            Op::SpawnShard => {
                self.shards.spawn();
                Ok(None)
            }
            Op::RetireShard { shard } => self.shards.retire(*shard).map(|_| None),
            Op::DrainShard { shard, on } => self.shards.drain(*shard, *on).map(|_| None),
            Op::SetShardLoad {
                shard,
                inflight,
                depth,
            } => self.shards.set_load(*shard, *inflight, *depth).map(|_| None),
            Op::RouteSubmit { policy } => {
                self.shards.place(*policy, "matmul", 64).map(|_| None)
            }
            Op::RouteComplete { pick } => self.shards.complete(*pick).map(|_| None),
        }
    }

    /// Mirror of `Runtime::create_context_with` (same checks, same
    /// order): sort/dedup, non-empty, in-range, quiescent; donors
    /// shrink in place; the new context is appended.
    pub fn create_context(&mut self, workers: &[usize]) -> Result<usize, String> {
        let mut members = workers.to_vec();
        members.sort_unstable();
        members.dedup();
        let name = format!("m{}", self.contexts.len());
        if members.is_empty() {
            return Err(format!("context '{name}' needs at least one worker"));
        }
        if let Some(&bad) = members.iter().find(|&&w| w >= self.archs.len()) {
            return Err(format!(
                "context '{name}': worker {bad} out of range (topology has {})",
                self.archs.len()
            ));
        }
        if !self.is_quiescent() {
            return Err(format!(
                "create_context('{name}') requires a quiescent runtime"
            ));
        }
        let id = self.contexts.len();
        for ctx in self.contexts.iter_mut() {
            // quiescent: the removed members' lanes are empty, so the
            // donor loses only (idle) workers
            ctx.members.retain(|w| !members.contains(w));
            ctx.lanes.retain(|w, _| !members.contains(w));
        }
        for &w in &members {
            self.worker_ctx[w] = id;
        }
        self.contexts.push(ModelCtx {
            name,
            home: members.len(),
            members,
            lanes: BTreeMap::new(),
            running: BTreeMap::new(),
        });
        Ok(id)
    }

    /// Mirror of `Runtime::move_workers`: receiver-arch-first /
    /// idle-first / lowest-id mover choice, last-of-arch floor,
    /// eviction + re-placement of the movers' lanes.
    pub fn move_workers(&mut self, from: usize, to: usize, n: usize) -> Result<usize, String> {
        if from == to {
            return Err(format!(
                "move_workers: source and destination are both context {from}"
            ));
        }
        if from >= self.contexts.len() {
            return Err(format!("unknown scheduling context {from}"));
        }
        if to >= self.contexts.len() {
            return Err(format!("unknown scheduling context {to}"));
        }
        if n == 0 {
            return Ok(0);
        }
        let members = self.contexts[from].members.clone();
        let dst_archs: Vec<Arch> = {
            let mut v: Vec<Arch> = Vec::new();
            for &w in &self.contexts[to].members {
                if !v.contains(&self.archs[w]) {
                    v.push(self.archs[w]);
                }
            }
            v
        };
        let mut cands = members.clone();
        cands.sort_by_key(|&w| {
            (
                !dst_archs.is_empty() && !dst_archs.contains(&self.archs[w]),
                self.contexts[from]
                    .running
                    .get(&w)
                    .map_or(0, |v| v.len()),
                w,
            )
        });
        let mut remaining = members;
        let mut movers: Vec<usize> = Vec::new();
        for w in cands {
            if movers.len() == n {
                break;
            }
            let arch = self.archs[w];
            let same_arch = remaining
                .iter()
                .filter(|&&x| self.archs[x] == arch)
                .count();
            if same_arch <= 1 {
                continue; // last of its architecture stays
            }
            remaining.retain(|&x| x != w);
            movers.push(w);
        }
        if movers.is_empty() {
            return Ok(0);
        }
        self.contexts[from].members = remaining;
        for &w in &movers {
            let evicted = self.contexts[from].lanes.remove(&w).unwrap_or_default();
            self.replace_evicted(from, evicted, None);
        }
        for (i, &w) in movers.iter().enumerate() {
            if i == 0 && self.fault == Some(Fault::LeakWorkerOnMove) {
                // injected bug: the first mover never joins the
                // receiver — it vanishes from the partition
                continue;
            }
            self.contexts[to].members.push(w);
            self.worker_ctx[w] = to;
        }
        self.contexts[to].members.sort_unstable();
        Ok(movers.len())
    }

    /// Mirror of `Runtime::resize_context`: exchange with context 0.
    pub fn resize_context(&mut self, ctx: usize, target: usize) -> Result<usize, String> {
        if ctx == 0 {
            return Err("resize_context: context 0 is the elastic pool itself".into());
        }
        if ctx >= self.contexts.len() {
            return Err(format!("unknown scheduling context {ctx}"));
        }
        let cur = self.contexts[ctx].members.len();
        match target.cmp(&cur) {
            std::cmp::Ordering::Greater => {
                self.move_workers(0, ctx, target - cur)?;
            }
            std::cmp::Ordering::Less => {
                self.move_workers(ctx, 0, cur - target)?;
            }
            std::cmp::Ordering::Equal => {}
        }
        Ok(self.contexts[ctx].members.len())
    }

    /// Mirror of `Runtime::submit`: validates the context, then the
    /// task enters the least-loaded member's lane. (The real scheduler
    /// placement differs per policy; the invariants — conservation,
    /// occupancy — are placement-independent, and the differential
    /// mode compares outcomes at quiescent points only.)
    pub fn submit(&mut self, ctx: usize) -> Result<u64, String> {
        if ctx >= self.contexts.len() {
            return Err(format!("unknown scheduling context {ctx}"));
        }
        if self.contexts[ctx].members.is_empty() {
            // mirrors the "no selectable implementation" bail: a
            // memberless context has no executor of any architecture
            return Err(format!(
                "no selectable implementation in context {ctx} (no members)"
            ));
        }
        let task = self.next_task;
        self.next_task += 1;
        self.submitted += 1;
        self.place_task(ctx, task, None);
        Ok(task)
    }

    /// A worker pops the front task of its lane in its *current*
    /// context. Rejected while the worker is executing (the worker
    /// loop is serial: pop → execute → complete).
    pub fn pop(&mut self, worker: usize) -> Result<u64, String> {
        if worker >= self.archs.len() {
            return Err(format!("worker {worker} out of range"));
        }
        if self.worker_busy(worker) {
            return Err(format!("worker {worker} is executing a task"));
        }
        let ctx = self.worker_ctx[worker];
        let Some(lane) = self.contexts[ctx].lanes.get_mut(&worker) else {
            return Err(format!("worker {worker}: nothing queued"));
        };
        if lane.is_empty() {
            return Err(format!("worker {worker}: nothing queued"));
        }
        let task = lane.remove(0);
        self.contexts[ctx]
            .running
            .entry(worker)
            .or_default()
            .push(task);
        Ok(task)
    }

    /// The worker finishes its in-flight task; the charge is released
    /// in whichever context holds it (the source, after a migration).
    pub fn complete(&mut self, worker: usize) -> Result<u64, String> {
        for ctx in self.contexts.iter_mut() {
            if let Some(v) = ctx.running.get_mut(&worker) {
                if !v.is_empty() {
                    let task = v.remove(0);
                    if v.is_empty() {
                        ctx.running.remove(&worker);
                    }
                    self.completed += 1;
                    return Ok(task);
                }
            }
        }
        Err(format!("worker {worker} has nothing in flight"))
    }

    /// Mirror of `Scheduler::evict` + re-push: drain one member's lane
    /// and re-place the tasks on the context's *other* members (or back
    /// on the same worker when it is the only member).
    pub fn evict(&mut self, ctx: usize, worker: usize) -> Result<usize, String> {
        if ctx >= self.contexts.len() {
            return Err(format!("unknown scheduling context {ctx}"));
        }
        let evicted = self.contexts[ctx].lanes.remove(&worker).unwrap_or_default();
        let n = evicted.len();
        self.replace_evicted(ctx, evicted, Some(worker));
        Ok(n)
    }

    /// One autoscale control step: build [`CtxSample`]s from the
    /// modeled lanes/charges, run the real [`Threshold`] policy, apply
    /// its moves through the model's own `move_workers` (a move the
    /// floor rejects simply moves fewer workers, like the real call).
    pub fn scale_tick(&mut self, dt_ms: u64) -> Result<usize, String> {
        let total = self.archs.len();
        let samples: Vec<CtxSample> = self
            .contexts
            .iter()
            .enumerate()
            .map(|(id, c)| CtxSample {
                ctx: id,
                name: c.name.clone(),
                workers: c.members.len(),
                queue_depth: c.queued(),
                busy: c
                    .members
                    .iter()
                    .filter(|w| c.running.get(w).is_some_and(|v| !v.is_empty()))
                    .count(),
                queued_secs: 0.0,
                tenants: 0,
                home: c.home,
                min: 1,
                max: total,
                slo_ms: None,
            })
            .collect();
        let actions = self.scaler.decide(&samples, Duration::from_millis(dt_ms));
        let mut moved = 0;
        for ScaleAction::Move { from, to, n } in actions {
            moved += self.move_workers(from, to, n).unwrap_or(0);
        }
        Ok(moved)
    }

    /// Run every queued task to completion (pop + complete over all
    /// workers until quiescent) — the differential mode's sync point.
    /// Stops early if no worker can make progress (only possible with
    /// an injected fault; the invariants report the stranded task).
    pub fn drain(&mut self) {
        while !self.is_quiescent() {
            let mut progressed = false;
            for w in 0..self.archs.len() {
                if self.pop(w).is_ok() {
                    progressed = true;
                }
                if self.complete(w).is_ok() {
                    progressed = true;
                }
            }
            if !progressed {
                break;
            }
        }
    }

    // ------------------------------------------------------------ helpers

    /// Queue `task` on the context's least-loaded member (ties: lowest
    /// id), optionally excluding one worker (the eviction source).
    fn place_task(&mut self, ctx: usize, task: u64, exclude: Option<usize>) {
        let c = &mut self.contexts[ctx];
        let target = c
            .members
            .iter()
            .filter(|&&w| Some(w) != exclude)
            .min_by_key(|&&w| (c.lanes.get(&w).map_or(0, Vec::len), w))
            .copied()
            .or_else(|| c.members.iter().copied().find(|&w| Some(w) == exclude));
        let Some(w) = target else {
            // no member at all: the caller guarantees this cannot
            // happen for submit; eviction of a memberless context
            // drains nothing (lanes ⊆ members)
            return;
        };
        c.lanes.entry(w).or_default().push(task);
    }

    /// Re-place an evicted lane inside `ctx`, honoring the injected
    /// drop-task fault (the self-test's conservation bug).
    fn replace_evicted(&mut self, ctx: usize, evicted: Vec<u64>, exclude: Option<usize>) {
        let mut evicted = evicted;
        if self.fault == Some(Fault::DropEvictedTask) && !evicted.is_empty() {
            evicted.remove(0); // injected bug: the first task is lost
        }
        for t in evicted {
            self.place_task(ctx, t, exclude);
        }
    }
}
