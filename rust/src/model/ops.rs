//! The operation alphabet of the concurrency-core model, plus the
//! seeded generator that drives it and the injectable faults the
//! self-test uses to prove the harness catches real bugs.
//!
//! Every [`Op`] mirrors one observable transition of the live system:
//! the structural runtime calls (`create_context`, `move_workers`,
//! `resize_context`), the worker loop's task lifecycle (`submit` →
//! `pop` → `complete`), the migration-time `evict`, one autoscaler
//! control step, and the router-side shard-table transitions (spawn /
//! drain / retire / place / complete). An op may be *rejected* by
//! [`ModelState::apply`](super::state::ModelState::apply) — mirroring
//! the runtime's `bail!`s — which keeps generated sequences closed
//! under subsequence removal, the property delta-debug shrinking needs.

use crate::cluster::placement::PlacementKind;
use crate::util::rng::Rng;

use super::state::ModelState;

/// One transition of the modeled concurrency core.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Op {
    /// `Runtime::create_context_with`: carve `workers` out of their
    /// current contexts (quiescent runtimes only).
    CreateContext { workers: Vec<usize> },
    /// `Runtime::move_workers`: migrate up to `n` workers live.
    MoveWorkers { from: usize, to: usize, n: usize },
    /// `Runtime::resize_context`: exchange with the elastic pool.
    ResizeContext { ctx: usize, target: usize },
    /// `Runtime::submit` into `ctx` (task enters a member's lane).
    Submit { ctx: usize },
    /// A worker pops the next task from its current context's lane.
    Pop { worker: usize },
    /// The worker finishes its in-flight task (occupancy discharge).
    Complete { worker: usize },
    /// `Scheduler::evict`: drain one member's lane and re-place the
    /// tasks on the context's other members.
    Evict { ctx: usize, worker: usize },
    /// One `Threshold::decide` control step over the modeled loads;
    /// emitted moves are applied through the model's own `MoveWorkers`.
    ScaleTick { dt_ms: u64 },
    /// Router: append a new shard to the table.
    SpawnShard,
    /// Router: retire a shard (terminal; the slot is never reused).
    RetireShard { shard: usize },
    /// Router: toggle a shard's drain flag.
    DrainShard { shard: usize, on: bool },
    /// Health poll: overwrite a shard's load signals.
    SetShardLoad { shard: usize, inflight: u64, depth: u64 },
    /// Router: place one request via the real `placement::pick`.
    RouteSubmit { policy: PlacementKind },
    /// Router: complete the `pick`-th oldest pending request.
    RouteComplete { pick: usize },
}

/// A deliberately injected bug, used by the explorer's self-test to
/// prove the invariant harness actually catches (and shrinks) the
/// conservation violations it exists for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// `move_workers` forgets to add the first mover to the receiver:
    /// the worker vanishes from the partition (worker conservation).
    LeakWorkerOnMove,
    /// Eviction drops the first task of the drained lane instead of
    /// re-placing it (task conservation).
    DropEvictedTask,
}

impl Fault {
    pub fn parse(s: &str) -> Option<Fault> {
        match s.to_ascii_lowercase().as_str() {
            "leak-worker" | "leak-worker-on-move" => Some(Fault::LeakWorkerOnMove),
            "drop-task" | "drop-evicted-task" => Some(Fault::DropEvictedTask),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Fault::LeakWorkerOnMove => "leak-worker-on-move",
            Fault::DropEvictedTask => "drop-evicted-task",
        }
    }
}

/// Names accepted by `--fault` (kept next to the parser so the CLI
/// help cannot drift).
pub const VALID_FAULTS: &[&str] = &["leak-worker-on-move", "drop-evicted-task"];

/// Generate one weighted, state-aware op. Most draws target live ids
/// (so sequences exercise deep interleavings rather than bouncing off
/// validation), but roughly one draw in eight aims out of range on
/// purpose: rejected ops must stay harmless no-ops, and the error
/// paths are part of the modeled surface.
pub fn gen_op(rng: &mut Rng, state: &ModelState) -> Op {
    let nctx = state.contexts_len();
    let nworkers = state.total_workers();
    let nshards = state.shard_count();
    let spice = |rng: &mut Rng, bound: usize| -> usize {
        if rng.below(8) == 0 {
            bound + rng.below(3)
        } else {
            rng.below(bound.max(1))
        }
    };
    match rng.below(98) {
        0..=21 => Op::Submit {
            ctx: spice(rng, nctx),
        },
        22..=41 => {
            // prefer a worker that actually has something to pop
            let ready = state.poppable_workers();
            let worker = if !ready.is_empty() && rng.below(8) != 0 {
                ready[rng.below(ready.len())]
            } else {
                spice(rng, nworkers)
            };
            Op::Pop { worker }
        }
        42..=55 => {
            let busy = state.charged_workers();
            let worker = if !busy.is_empty() && rng.below(8) != 0 {
                busy[rng.below(busy.len())]
            } else {
                spice(rng, nworkers)
            };
            Op::Complete { worker }
        }
        56..=62 => Op::MoveWorkers {
            from: spice(rng, nctx),
            to: spice(rng, nctx),
            n: rng.below(4),
        },
        63..=65 => {
            let k = 1 + rng.below(nworkers.max(1));
            let workers: Vec<usize> = (0..k).map(|_| spice(rng, nworkers)).collect();
            Op::CreateContext { workers }
        }
        66..=70 => Op::ResizeContext {
            ctx: spice(rng, nctx),
            target: rng.below(nworkers + 2),
        },
        71..=75 => Op::Evict {
            ctx: spice(rng, nctx),
            worker: spice(rng, nworkers),
        },
        76..=79 => Op::ScaleTick {
            dt_ms: rng.below(400) as u64,
        },
        80..=81 => Op::SpawnShard,
        82..=83 => Op::RetireShard {
            shard: spice(rng, nshards),
        },
        84..=85 => Op::DrainShard {
            shard: spice(rng, nshards),
            on: rng.below(2) == 0,
        },
        86..=88 => Op::SetShardLoad {
            shard: spice(rng, nshards),
            inflight: rng.below(16) as u64,
            depth: rng.below(16) as u64,
        },
        89..=93 => Op::RouteSubmit {
            policy: match rng.below(3) {
                0 => PlacementKind::RoundRobin,
                1 => PlacementKind::LeastLoaded,
                _ => PlacementKind::Calibrated,
            },
        },
        _ => Op::RouteComplete {
            pick: rng.below(state.pending_routes().max(1) + 1),
        },
    }
}
