//! The global invariant set, checked after **every** op the explorer
//! applies. These are the properties the ROADMAP names as the safety
//! floor for the next wave of hot-path work:
//!
//! 1. **Worker conservation / partition** — every worker is a member
//!    of exactly one context, and the `worker_ctx` table agrees.
//! 2. **Occupancy** — per context, each member has at most one task in
//!    flight and per-arch in-flight ≤ per-arch members. Checked with
//!    the *same* [`validate_occupancy`] the live runtime's snapshot
//!    capture and `audited_state` use (single source of truth).
//! 3. **Task conservation** — `submitted = completed + queued +
//!    running`, with every live task id distinct: no task is ever lost
//!    (or duplicated) across eviction, migration or rebalancing.
//! 4. **Structural sanity** — lanes only exist on members; a worker
//!    never carries more than one in-flight charge across all contexts
//!    (the worker loop is serial).
//! 5. **Shard table** — placement never lands on an unavailable shard,
//!    pending requests stay resolvable across retirement, retirement
//!    is terminal ([`ShardTableModel::check`]).
//!
//! [`ShardTableModel::check`]: super::shard::ShardTableModel::check

use std::collections::BTreeSet;

use crate::taskrt::{validate_occupancy, WorkerOccupancy};

use super::state::ModelState;

/// Check every invariant; `Err` names the first violation.
pub fn check(state: &ModelState) -> Result<(), String> {
    partition(state)?;
    occupancy(state)?;
    conservation(state)?;
    structure(state)?;
    state.shards.check()
}

fn partition(state: &ModelState) -> Result<(), String> {
    let total = state.total_workers();
    let mut owner: Vec<Option<usize>> = vec![None; total];
    for (id, c) in state.contexts.iter().enumerate() {
        for &w in &c.members {
            if w >= total {
                return Err(format!(
                    "context {id} ('{}') lists worker {w} but the topology has {total}",
                    c.name
                ));
            }
            if let Some(prev) = owner[w] {
                return Err(format!(
                    "worker {w} is a member of both context {prev} and context {id}"
                ));
            }
            owner[w] = Some(id);
            if state.worker_ctx[w] != id {
                return Err(format!(
                    "worker {w} is a member of context {id} but worker_ctx says {}",
                    state.worker_ctx[w]
                ));
            }
        }
    }
    for (w, o) in owner.iter().enumerate() {
        if o.is_none() {
            return Err(format!(
                "worker {w} is not a member of any context (worker leaked)"
            ));
        }
    }
    Ok(())
}

fn occupancy(state: &ModelState) -> Result<(), String> {
    for (id, c) in state.contexts.iter().enumerate() {
        let occ: Vec<WorkerOccupancy> = c
            .members
            .iter()
            .map(|&w| {
                (
                    w,
                    state.archs[w],
                    c.running.get(&w).map_or(0, Vec::len),
                )
            })
            .collect();
        validate_occupancy(&occ)
            .map_err(|msg| format!("context {id} ('{}') counter audit: {msg}", c.name))?;
    }
    Ok(())
}

fn conservation(state: &ModelState) -> Result<(), String> {
    let queued: usize = state.contexts.iter().map(|c| c.queued()).sum();
    let running: usize = state.contexts.iter().map(|c| c.running_count()).sum();
    let live = state.submitted - state.completed;
    if live != (queued + running) as u64 {
        return Err(format!(
            "task conservation broken: submitted {} - completed {} = {live} live, \
             but {queued} queued + {running} running are accounted for",
            state.submitted, state.completed
        ));
    }
    let mut seen: BTreeSet<u64> = BTreeSet::new();
    for c in &state.contexts {
        for t in c.lanes.values().flatten().chain(c.running.values().flatten()) {
            if !seen.insert(*t) {
                return Err(format!("task {t} appears twice (duplicated in flight)"));
            }
        }
    }
    Ok(())
}

fn structure(state: &ModelState) -> Result<(), String> {
    for (id, c) in state.contexts.iter().enumerate() {
        for w in c.lanes.keys() {
            if !c.members.contains(w) {
                return Err(format!(
                    "context {id} ('{}') has a lane on worker {w}, not a member",
                    c.name
                ));
            }
        }
    }
    // the worker loop is serial: pop → execute → complete, so a worker
    // holds at most one charge across ALL contexts (after a migration
    // the charge legally sits on the source context)
    for w in 0..state.total_workers() {
        let charges: usize = state
            .contexts
            .iter()
            .map(|c| c.running.get(&w).map_or(0, Vec::len))
            .sum();
        if charges > 1 {
            return Err(format!(
                "worker {w} carries {charges} in-flight charges across contexts"
            ));
        }
    }
    Ok(())
}
