//! Differential mode: replay one structural op sequence against the
//! pure model **and** a real [`Runtime`], and compare the observable
//! state after every step — so the model and the implementation
//! cannot drift apart silently.
//!
//! The comparison runs at quiescent sync points (`wait_all` before
//! every structural op): with no task in flight, the runtime's mover
//! choice is deterministic (receiver-arch-first / idle-first /
//! lowest-id) and must match the model's exactly. Compared per step:
//!
//! - accept/reject agreement for every call (the `bail!` paths);
//! - moved-worker counts of `move_workers` / `resize_context`, and the
//!   context id of `create_context`;
//! - the full membership partition, read through
//!   [`Runtime::audited_state`] — which also re-validates the live
//!   occupancy counters on the spot.
//!
//! Task execution itself is compared only for submit accept/reject
//! agreement and completion (both sides drain) — per-task placement is
//! policy-dependent and deliberately outside the model.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::runtime::Tensor;
use crate::taskrt::{
    AccessMode, Arch, Codelet, Config, NativeFn, Runtime, SchedPolicy, SelectorKind, TaskSpec,
};
use crate::util::rng::{derive_seed, env_seed, Rng};

use super::invariants;
use super::state::{ModelConfig, ModelState};

#[derive(Debug, Clone)]
pub struct DiffOptions {
    pub sequences: usize,
    pub steps_per_seq: usize,
    pub seed: u64,
    pub config: ModelConfig,
}

impl Default for DiffOptions {
    fn default() -> Self {
        DiffOptions {
            sequences: 24,
            steps_per_seq: 12,
            seed: 0xd1ff,
            config: ModelConfig::default(),
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct DiffStats {
    pub sequences: usize,
    pub steps: usize,
    pub tasks_executed: usize,
}

fn diff_codelet() -> Codelet {
    let noop: NativeFn = Arc::new(|_| Ok(()));
    Codelet::new("diffcl", "sort", vec![AccessMode::Read])
        .with_native("omp", Arch::Cpu, noop.clone())
        .with_native("cuda", Arch::Cuda, noop)
}

/// Run the differential explorer. Any divergence (or audit failure on
/// the real side, or invariant violation on the model side) is an
/// error naming the seed and step for replay.
pub fn run(opts: &DiffOptions) -> Result<DiffStats> {
    let seeds: Vec<u64> = match env_seed() {
        Some(s) => vec![s],
        None => (0..opts.sequences as u64)
            .map(|i| derive_seed(opts.seed, i))
            .collect(),
    };
    let mut stats = DiffStats::default();
    for seed in seeds {
        run_sequence(opts, seed, &mut stats)
            .with_context(|| format!("differential sequence failed; replay with COMPAR_MODEL_SEED={seed:#x}"))?;
        stats.sequences += 1;
    }
    Ok(stats)
}

fn run_sequence(opts: &DiffOptions, seed: u64, stats: &mut DiffStats) -> Result<()> {
    let mut rng = Rng::new(seed);
    let cfg = opts.config;
    let total = cfg.ncpu + cfg.ncuda;
    let mut model = ModelState::new(&cfg, None);
    let rt = Runtime::new(
        Config {
            ncpu: cfg.ncpu,
            ncuda: cfg.ncuda,
            sched: SchedPolicy::Eager,
            ..Config::default()
        },
        None,
    )?;
    let cl = rt.register_codelet(diff_codelet());

    for step in 0..opts.steps_per_seq {
        // quiescent sync point: with zero in-flight tasks the real
        // mover choice is deterministic and create_context cannot
        // spuriously reject for quiescence
        rt.wait_all()?;
        match rng.below(10) {
            0..=1 => {
                // create_context over a random (occasionally invalid)
                // worker set — both sides must agree on accept/reject
                // and, when accepted, on the new context id
                let k = 1 + rng.below(total);
                let workers: Vec<usize> = (0..k)
                    .map(|_| {
                        if rng.below(8) == 0 {
                            total + rng.below(2)
                        } else {
                            rng.below(total)
                        }
                    })
                    .collect();
                let m = model.create_context(&workers);
                let r = rt.create_context_with(
                    &format!("d{step}"),
                    &workers,
                    SchedPolicy::Eager,
                    SelectorKind::Greedy,
                );
                match (m, r) {
                    (Ok(mid), Ok(rid)) if mid == rid => {}
                    (Err(_), Err(_)) => {}
                    (m, r) => bail!(
                        "step {step}: create_context({workers:?}) diverged: \
                         model {m:?}, runtime {:?}",
                        r.map_err(|e| e.to_string())
                    ),
                }
            }
            2..=4 => {
                let bound = model.contexts_len() + 1;
                let from = rng.below(bound);
                let to = rng.below(bound);
                let n = rng.below(4);
                let m = model.move_workers(from, to, n);
                let r = rt.move_workers(from, to, n);
                match (m, r) {
                    (Ok(mn), Ok(rn)) if mn == rn => {}
                    (Err(_), Err(_)) => {}
                    (m, r) => bail!(
                        "step {step}: move_workers({from}, {to}, {n}) diverged: \
                         model {m:?}, runtime {:?}",
                        r.map_err(|e| e.to_string())
                    ),
                }
            }
            5..=6 => {
                let ctx = rng.below(model.contexts_len() + 1);
                let target = rng.below(total + 2);
                let m = model.resize_context(ctx, target);
                let r = rt.resize_context(ctx, target);
                match (m, r) {
                    (Ok(mn), Ok(rn)) if mn == rn => {}
                    (Err(_), Err(_)) => {}
                    (m, r) => bail!(
                        "step {step}: resize_context({ctx}, {target}) diverged: \
                         model {m:?}, runtime {:?}",
                        r.map_err(|e| e.to_string())
                    ),
                }
            }
            _ => {
                // a burst of real task executions through a random
                // context; both sides must agree per-submit and drain
                // back to quiescence
                let ctx = rng.below(model.contexts_len());
                let count = 1 + rng.below(3);
                let mut ids = Vec::new();
                for _ in 0..count {
                    let m = model.submit(ctx);
                    let h = rt.register_data(Tensor::vector(vec![0.0; 4]));
                    let r = rt.submit(TaskSpec::new(cl.clone(), vec![h], 64).in_context(ctx));
                    match (m, r) {
                        (Ok(_), Ok(id)) => ids.push(id),
                        (Err(_), Err(_)) => {}
                        (m, r) => bail!(
                            "step {step}: submit(ctx {ctx}) diverged: model {m:?}, runtime {:?}",
                            r.map_err(|e| e.to_string())
                        ),
                    }
                }
                rt.wait_tasks(&ids)?;
                rt.reap_tasks(&ids);
                model.drain();
                stats.tasks_executed += ids.len();
            }
        }

        // structural comparison through the audited snapshot (which
        // also re-validates the runtime's live counters)
        let audited = rt
            .audited_state()
            .with_context(|| format!("step {step}: runtime failed its own audit"))?;
        if audited.contexts.len() != model.contexts_len() {
            bail!(
                "step {step}: context count diverged: model {}, runtime {}",
                model.contexts_len(),
                audited.contexts.len()
            );
        }
        let memberships = model.memberships();
        for (ca, mm) in audited.contexts.iter().zip(memberships.iter()) {
            if &ca.members != mm {
                bail!(
                    "step {step}: context {} membership diverged: \
                     model {mm:?}, runtime {:?}",
                    ca.id,
                    ca.members
                );
            }
        }
        if let Err(msg) = invariants::check(&model) {
            bail!("step {step}: model invariant violated: {msg}");
        }
        stats.steps += 1;
    }

    rt.wait_all()?;
    rt.shutdown()?;
    Ok(())
}
