//! Bounded proof harnesses over the model's invariant set.
//!
//! Each `proof_*` function is written against a tiny nondeterministic
//! value source and asserts the invariants afterwards. Under `cargo
//! kani` the source is `kani::any()` + `kani::assume`, the functions
//! carry `#[kani::proof]`, and the harness exhaustively covers the
//! bounded space. Under a plain build the *same bodies* compile with a
//! seeded RNG behind the source and run as concrete smoke cases
//! ([`run_concrete`], wired into `compar verify model --proofs` and
//! CI) — so the harnesses cannot rot on images without kani.
//!
//! Bounds are deliberately small (≤ 4 workers, ≤ 6 ops): the point is
//! exhaustive coverage of the structural transitions, not scale — the
//! generative explorer covers scale.

use crate::cluster::placement::PlacementKind;

use super::invariants;
use super::ops::Op;
use super::shard::ShardTableModel;
use super::state::{ModelConfig, ModelState};

#[cfg(kani)]
fn any_below(n: usize) -> usize {
    let v: usize = kani::any();
    kani::assume(v < n.max(1));
    v
}

#[cfg(not(kani))]
mod ambient {
    //! Concrete stand-in for `kani::any`: a thread-local seeded RNG,
    //! reseeded per case by [`super::run_concrete`].
    use std::cell::RefCell;

    use crate::util::rng::{env_seed, Rng};

    thread_local! {
        static AMBIENT: RefCell<Rng> =
            RefCell::new(Rng::new(env_seed().unwrap_or(0x0b5e55ed)));
    }

    pub fn reseed(seed: u64) {
        AMBIENT.with(|r| *r.borrow_mut() = Rng::new(seed));
    }

    pub fn below(n: usize) -> usize {
        AMBIENT.with(|r| r.borrow_mut().below(n.max(1)))
    }
}

#[cfg(not(kani))]
fn any_below(n: usize) -> usize {
    ambient::below(n)
}

fn check(state: &ModelState, harness: &str) {
    if let Err(msg) = invariants::check(state) {
        panic!("{harness}: {msg}");
    }
}

/// Any single live migration (any endpoints, any count) preserves the
/// worker partition and every occupancy bound — including the
/// rejected-op paths (self-move, unknown context, empty move).
#[cfg_attr(kani, kani::proof)]
pub fn proof_move_conserves_workers() {
    let cfg = ModelConfig { ncpu: 2, ncuda: 1 };
    let mut st = ModelState::new(&cfg, None);
    let _ = st.apply(&Op::CreateContext {
        workers: vec![any_below(3)],
    });
    let op = Op::MoveWorkers {
        from: any_below(3),
        to: any_below(3),
        n: any_below(4),
    };
    let _ = st.apply(&op);
    check(&st, "move_conserves_workers");
}

/// Eviction (and the re-placement behind migration) never loses or
/// duplicates a queued task, for any backlog shape and eviction
/// target — the conservation invariant the self-test's injected fault
/// breaks on purpose.
#[cfg_attr(kani, kani::proof)]
pub fn proof_eviction_conserves_tasks() {
    let cfg = ModelConfig { ncpu: 2, ncuda: 1 };
    let mut st = ModelState::new(&cfg, None);
    let backlog = any_below(4);
    for _ in 0..backlog {
        let _ = st.apply(&Op::Submit { ctx: 0 });
    }
    let _ = st.apply(&Op::Evict {
        ctx: any_below(2),
        worker: any_below(4),
    });
    check(&st, "eviction_conserves_tasks");
    let _ = st.apply(&Op::MoveWorkers {
        from: 0,
        to: any_below(2),
        n: 1 + any_below(2),
    });
    check(&st, "eviction_conserves_tasks(after move)");
}

/// The pop → complete lifecycle keeps every per-worker and per-arch
/// in-flight bound, under any interleaving of up to six steps.
#[cfg_attr(kani, kani::proof)]
pub fn proof_occupancy_bound() {
    let cfg = ModelConfig { ncpu: 2, ncuda: 1 };
    let mut st = ModelState::new(&cfg, None);
    for _ in 0..3 {
        let _ = st.apply(&Op::Submit { ctx: 0 });
    }
    for _ in 0..6 {
        let op = match any_below(3) {
            0 => Op::Pop {
                worker: any_below(3),
            },
            1 => Op::Complete {
                worker: any_below(3),
            },
            _ => Op::Submit { ctx: 0 },
        };
        let _ = st.apply(&op);
        check(&st, "occupancy_bound");
    }
}

/// Shard retirement keeps the pending map resolvable and never puts a
/// retired shard back into the placement rotation, for any retire /
/// route interleaving over a small table.
#[cfg_attr(kani, kani::proof)]
pub fn proof_retirement_keeps_pending_resolvable() {
    let mut shards = ShardTableModel::new();
    let extra = any_below(2);
    for _ in 0..extra {
        shards.spawn();
    }
    let _ = shards.place(PlacementKind::RoundRobin, "matmul", 64);
    let _ = shards.retire(any_below(shards.len() + 1));
    let _ = shards.place(PlacementKind::LeastLoaded, "matmul", 64);
    let _ = shards.complete(any_below(2));
    if let Err(msg) = shards.check() {
        panic!("retirement_keeps_pending_resolvable: {msg}");
    }
}

/// Base seed for the concrete (non-kani) runs of the proof bodies.
pub const CONCRETE_SEED: u64 = 0x0b5e55ed;

/// Run every proof body `cases` times with derived seeds — the
/// concrete lane that keeps the harnesses compiling and passing on
/// images without kani. Panics (with the seed printed by the caller's
/// `run_cases` wrapper) on any invariant violation.
#[cfg(not(kani))]
pub fn run_concrete(cases: usize) {
    use crate::util::rng::{derive_seed, env_seed};
    let seeds: Vec<u64> = match env_seed() {
        Some(s) => vec![s],
        None => (0..cases as u64)
            .map(|i| derive_seed(CONCRETE_SEED, i))
            .collect(),
    };
    for seed in seeds {
        ambient::reseed(seed);
        proof_move_conserves_workers();
        ambient::reseed(seed ^ 1);
        proof_eviction_conserves_tasks();
        ambient::reseed(seed ^ 2);
        proof_occupancy_bound();
        ambient::reseed(seed ^ 3);
        proof_retirement_keeps_pending_resolvable();
    }
}
