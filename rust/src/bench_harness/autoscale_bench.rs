//! Elastic-scaling bench (`compar bench autoscale`): the bursty-load
//! scenario behind the autoscale work, measured at both levels.
//!
//! **Context elasticity** — one server, a small `hot` context plus a
//! `pool` context, and a pipelined burst aimed exclusively at `hot`.
//! With `--autoscale` off the burst queues behind two workers; with it
//! on, the control loop migrates pool workers in (observed via the v5
//! `autoscale_status` request) and p95 drops. After the burst drains,
//! the borrowed workers return to their home context.
//!
//! **Shard elasticity** — a two-shard elastic cluster under burst load:
//! the router spawns a third shard (gossip-seeded, so it joins already
//! calibrated), then retires it once the load goes away — with zero
//! failed client requests throughout.

use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::autoscale::AutoscaleOptions;
use crate::cluster::{ClusterScaleOptions, LocalCluster, RouterOptions};
use crate::serve::protocol::AutoscaleResp;
use crate::serve::{loadgen, Client, LoadgenOptions, ServeOptions, Server};
use crate::util::stats::fmt_time;

/// Outcome of the context-elasticity scenario (one autoscale setting).
#[derive(Debug, Clone)]
pub struct ContextRun {
    pub autoscale: bool,
    pub p95: f64,
    pub rps: f64,
    pub errors: usize,
    /// Scale actions the control loop executed (0 when off).
    pub moves: u64,
    pub moved_workers: u64,
    /// `hot` context's worker count after the burst drained.
    pub hot_workers_after: usize,
    /// `hot`'s home (configured) worker count.
    pub hot_home: usize,
}

fn hot_pool_serve(autoscale: bool) -> Result<ServeOptions> {
    let mut so = ServeOptions {
        addr: "127.0.0.1:0".into(),
        contexts: crate::serve::parse_contexts("hot:2,pool:4")?,
        ..ServeOptions::default()
    };
    if autoscale {
        so.autoscale = Some(AutoscaleOptions {
            period: Duration::from_millis(20),
            cooldown: Duration::from_millis(100),
            sustain: 2,
            ..AutoscaleOptions::default()
        });
    }
    Ok(so)
}

/// Run the bursty one-context workload with autoscaling off or on.
pub fn context_run(autoscale: bool, smoke: bool) -> Result<ContextRun> {
    let server = Server::start(hot_pool_serve(autoscale)?)?;
    let addr = server.local_addr().to_string();
    let lg = LoadgenOptions {
        clients: 4,
        requests: if smoke { 20 } else { 60 },
        app: "matmul".into(),
        // heavy enough (a few ms per task) that the pipelined burst
        // builds a queue the control loop can observe and relieve
        size: 192,
        pipeline: 8,
        ctxs: vec!["hot".into()],
        verify: false,
        ..LoadgenOptions::default()
    };
    let report = loadgen::run(&addr, &lg)?;
    // the v5 status, over the wire (exercises the protocol path)
    let status: AutoscaleResp = {
        let mut c = Client::connect(&addr)?;
        let s = c.autoscale_status()?;
        let _ = c.quit();
        s
    };
    // after the drain, borrowed workers must return home
    let (hot_home, hot_after) = if autoscale {
        let deadline = Instant::now() + Duration::from_secs(15);
        loop {
            let hot = server
                .context_table()
                .into_iter()
                .find(|(name, _)| name == "hot")
                .map(|(_, w)| w.len())
                .unwrap_or(0);
            if hot == 2 || Instant::now() >= deadline {
                break (2, hot);
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    } else {
        (2, 2)
    };
    server.shutdown()?;
    Ok(ContextRun {
        autoscale,
        p95: report.p95,
        rps: report.rps,
        errors: report.errors,
        moves: status.moves,
        moved_workers: status.moved_workers,
        hot_workers_after: hot_after,
        hot_home,
    })
}

/// Outcome of the shard-elasticity scenario.
#[derive(Debug, Clone)]
pub struct ShardRun {
    pub spawned: u64,
    pub retired: u64,
    /// Shards in the table when the run ended (live, non-retired).
    pub final_shards: u64,
    /// Failed client requests across every load phase (must be 0).
    pub errors: usize,
}

/// Two-shard elastic cluster: burst load spawns a third shard, idleness
/// retires one again; every client request must succeed throughout.
pub fn shard_run(smoke: bool) -> Result<ShardRun> {
    let serve = ServeOptions {
        addr: "127.0.0.1:0".into(),
        ncpu: 2,
        ncuda: 0,
        ..ServeOptions::default()
    };
    let ropts = RouterOptions {
        listen: "127.0.0.1:0".into(),
        health_period: Duration::from_millis(100),
        gossip_period: Duration::from_millis(150),
        ..RouterOptions::default()
    };
    let scale = ClusterScaleOptions {
        min_shards: 1,
        max_shards: 3,
        up_load: 3,
        down_load: 0,
        sustain: 1,
        cooldown: Duration::from_millis(400),
        period: Duration::from_millis(100),
        ..ClusterScaleOptions::default()
    };
    let (cluster, launcher) = LocalCluster::start_elastic(2, &serve, ropts, scale)?;
    let addr = cluster.addr();
    let mut errors = 0usize;

    // phase 1: burst — enough sustained in-flight load to cross the
    // spawn band (load is polled from shard stats, so keep pressure on
    // until the router reacts)
    let lg = LoadgenOptions {
        clients: 6,
        requests: if smoke { 25 } else { 60 },
        app: "matmul".into(),
        // a couple of ms per request keeps the health poll's in-flight
        // gauge visibly above the spawn band for the whole burst
        size: 128,
        tasks: 2,
        pipeline: 8,
        verify: false,
        ..LoadgenOptions::default()
    };
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut spawned = 0u64;
    while Instant::now() < deadline {
        let report = loadgen::run(&addr, &lg)?;
        errors += report.errors;
        (spawned, _) = cluster.router.scale_counters();
        if spawned >= 1 {
            break;
        }
    }
    if spawned == 0 {
        launcher.shutdown_all();
        let _ = cluster.shutdown();
        return Err(anyhow!("burst load never triggered a shard spawn"));
    }

    // phase 2: idle — the scaler should retire back down
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut retired = 0u64;
    while Instant::now() < deadline {
        (_, retired) = cluster.router.scale_counters();
        if retired >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }

    // phase 3: the (possibly shrunk) cluster still serves flawlessly
    let tail = LoadgenOptions {
        clients: 2,
        requests: 6,
        app: "matmul".into(),
        size: 48,
        verify: true,
        ..LoadgenOptions::default()
    };
    let report = loadgen::run(&addr, &tail).context("post-retire load")?;
    errors += report.errors;

    let final_shards = cluster
        .router
        .shards()
        .iter()
        .filter(|d| !d.draining)
        .count() as u64;
    launcher.shutdown_all();
    cluster.shutdown()?;
    Ok(ShardRun {
        spawned,
        retired,
        final_shards,
        errors,
    })
}

pub fn render(off: &ContextRun, on: &ContextRun, shards: &ShardRun) -> String {
    let mut out = String::new();
    out.push_str("== compar bench autoscale ==\n");
    out.push_str("context elasticity (burst on 'hot:2', pool 4 workers):\n");
    for r in [off, on] {
        out.push_str(&format!(
            "  autoscale {:3}  p95 {:>9}  {:7.1} req/s  errors {}  moves {} ({} worker(s))\n",
            if r.autoscale { "on" } else { "off" },
            fmt_time(r.p95),
            r.rps,
            r.errors,
            r.moves,
            r.moved_workers,
        ));
    }
    out.push_str(&format!(
        "  p95 ratio on/off: {:.2}  (hot context after drain: {}/{} workers)\n",
        on.p95 / off.p95.max(1e-12),
        on.hot_workers_after,
        on.hot_home,
    ));
    out.push_str(&format!(
        "shard elasticity: spawned {}  retired {}  final shards {}  errors {}\n",
        shards.spawned, shards.retired, shards.final_shards, shards.errors
    ));
    out
}
