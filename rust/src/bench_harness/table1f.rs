//! Table 1f regenerator: programmability (lines of code a developer
//! writes) — COMPAR vs the PEPPHER composition tool [7] vs raw StarPU.
//!
//! The PEPPHER and StarPU numbers are the constants the paper cites from
//! Dastgeer et al. [7]; the COMPAR numbers are *measured* on the bundled
//! annotated sources (`examples/compar_src/*.compar.c`) by counting
//! directive lines, and the generated-glue size comes from actually
//! running our code generator on them — i.e. the effort COMPAR saves.

use anyhow::Result;

use super::report::Table;
use crate::compar;

/// Developer-written lines in a COMPAR source: directive lines only
/// (the variant bodies exist in every approach and are excluded, as in
/// the paper's comparison).
pub fn compar_loc(source: &str) -> usize {
    source
        .lines()
        .filter(|l| crate::compar::lexer::is_compar_pragma(l.trim_start()))
        .count()
}

/// Non-blank lines of generated glue (what a raw-StarPU user would have
/// written by hand).
pub fn generated_loc(source: &str, filename: &str) -> Result<usize> {
    let out = compar::compile(source, filename)?;
    let mut total = 0;
    for (_, unit) in &out.c_units {
        total += unit.lines().filter(|l| !l.trim().is_empty()).count();
    }
    Ok(total)
}

/// Literature constants from Dastgeer et al. [7] as cited by the paper
/// (hotspot3D was not evaluated there — the paper notes its absence).
/// (app, PEPPHER XML+code lines, hand-written StarPU lines)
pub const DASTGEER_LOC: &[(&str, usize, usize)] = &[
    ("hotspot", 104, 129),
    ("lud", 113, 152),
    ("nw", 106, 137),
    ("matmul", 124, 166),
];

/// One measured row.
#[derive(Debug, Clone)]
pub struct Row {
    pub app: String,
    pub compar_directives: usize,
    pub generated_glue: usize,
    pub pepper: Option<usize>,
    pub starpu: Option<usize>,
}

/// Measure all bundled sources. `sources` = (app, source text, filename).
pub fn measure(sources: &[(String, String, String)]) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for (app, src, file) in sources {
        let lit = DASTGEER_LOC.iter().find(|(a, _, _)| a == app);
        rows.push(Row {
            app: app.clone(),
            compar_directives: compar_loc(src),
            generated_glue: generated_loc(src, file)?,
            pepper: lit.map(|(_, p, _)| *p),
            starpu: lit.map(|(_, _, s)| *s),
        });
    }
    Ok(rows)
}

pub fn render(rows: &[Row]) -> String {
    let mut t = Table::new(
        "Table 1f: programmability (developer-written LoC; PEPPHER/StarPU from [7])",
        &["app", "COMPAR", "generated glue", "PEPPHER [7]", "StarPU [7]"],
    );
    for r in rows {
        t.row(vec![
            r.app.clone(),
            r.compar_directives.to_string(),
            r.generated_glue.to_string(),
            r.pepper.map(|v| v.to_string()).unwrap_or_else(|| "n/a".into()),
            r.starpu.map(|v| v.to_string()).unwrap_or_else(|| "n/a".into()),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = "\
#pragma compar include
#pragma compar method_declare interface(sort) target(cuda) name(sort_cuda)
#pragma compar parameter name(arr) type(float*) size(N) access_mode(readwrite)
#pragma compar parameter name(N) type(int)
void sort_cuda(float* arr, int N) {}
#pragma compar method_declare interface(sort) target(openmp) name(sort_omp)
void sort_omp(float* arr, int N) {}
int main() {
#pragma compar initialize
#pragma compar terminate
}
";

    #[test]
    fn counts_directives_only() {
        assert_eq!(compar_loc(SRC), 7);
    }

    #[test]
    fn generated_glue_is_larger() {
        let glue = generated_loc(SRC, "t.c").unwrap();
        let directives = compar_loc(SRC);
        assert!(
            glue > 3 * directives,
            "glue {glue} should dwarf directives {directives} (the paper's \
             programmability claim)"
        );
    }

    #[test]
    fn measure_attaches_literature_numbers() {
        let rows = measure(&[("sort".into(), SRC.into(), "t.c".into())]).unwrap();
        assert_eq!(rows[0].pepper, None); // sort not in [7]
        let rows = measure(&[("lud".into(), SRC.into(), "t.c".into())]).unwrap();
        assert_eq!(rows[0].pepper, Some(113));
        assert_eq!(rows[0].starpu, Some(152));
    }
}
