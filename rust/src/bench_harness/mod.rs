//! Regenerates every table and figure of the paper's evaluation (§3):
//! * [`fig1`] — Fig 1a-1e execution-time series (CPU-only / GPU-only /
//!   COMPAR) plus the matmul per-variant panel;
//! * [`table1f`] — the programmability (LoC) comparison;
//! * [`selection`] — the §3.2 selection-quality discussion, quantified;
//! * [`serve_bench`] — serving-path throughput/latency (BENCH_serve.json);
//! * [`cluster_bench`] — sharded serving: aggregate req/s + cross-shard
//!   selection regret, gossip off vs on;
//! * [`autoscale_bench`] — elastic scaling: bursty-load p95 with the
//!   autoscaler off vs on, plus shard spawn/retire under burst;
//! * [`stream_bench`] — v6 stream sessions: calibrated-rate vs
//!   overload, credit backpressure and window shedding counters;
//! * [`dag_bench`] — v8 graph planning: planned vs greedy makespan on
//!   a transfer-heavy pipeline, plus degradation under contention;
//! * [`report`] — the plain-text table renderer.

pub mod autoscale_bench;
pub mod cluster_bench;
pub mod dag_bench;
pub mod fig1;
pub mod report;
pub mod selection;
pub mod serve_bench;
pub mod stream_bench;
pub mod table1f;

/// The bundled COMPAR-annotated benchmark sources (compiled in, so the
/// harness works from any working directory).
pub fn bundled_sources() -> Vec<(String, String, String)> {
    [
        ("hotspot", include_str!("../../../examples/compar_src/hotspot.compar.c")),
        (
            "hotspot3d",
            include_str!("../../../examples/compar_src/hotspot3d.compar.c"),
        ),
        ("lud", include_str!("../../../examples/compar_src/lud.compar.c")),
        ("nw", include_str!("../../../examples/compar_src/nw.compar.c")),
        ("matmul", include_str!("../../../examples/compar_src/matmul.compar.c")),
        ("sort", include_str!("../../../examples/compar_src/sort.compar.c")),
    ]
    .into_iter()
    .map(|(app, src)| {
        (
            app.to_string(),
            src.to_string(),
            format!("{app}.compar.c"),
        )
    })
    .collect()
}

#[cfg(test)]
mod tests {
    #[test]
    fn bundled_sources_compile_cleanly() {
        for (app, src, file) in super::bundled_sources() {
            let out = crate::compar::compile(&src, &file)
                .unwrap_or_else(|e| panic!("{app}: {e:#}"));
            assert!(!out.c_units.is_empty(), "{app} produced no glue");
        }
    }
}
