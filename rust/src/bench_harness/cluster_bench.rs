//! Cluster serving bench: N in-process `compar serve` shards behind a
//! `compar route` router, driven by the load generator. Reports the
//! aggregate requests/s across the cluster and the **cross-shard
//! selection regret** — every task's selected variant scored against the
//! single-process oracle (the converged analytic device model over the
//! runnable variant pool), exactly as the single-process selection bench
//! does. Run with gossip off and on ([`compare`]) to see how much of the
//! per-shard cold-start regret the perf-model gossip removes: with
//! gossip, one shard's calibration seeds every other shard's priors, so
//! the cluster pays the exploration cost roughly once instead of once
//! per shard.

use std::time::Duration;

use anyhow::Result;

use super::fig1::variant_time;
use super::report::Table;
use super::selection::{oracle_among, runnable_variants};
use crate::cluster::{LocalCluster, PlacementKind, RouterOptions};
use crate::serve::loadgen::{self, LoadReport, LoadgenOptions};
use crate::serve::ServeOptions;
use crate::taskrt::device::Arch;
use crate::util::stats::fmt_time;

/// Outcome of one cluster run.
pub struct ClusterReport {
    pub shards: usize,
    pub gossip: bool,
    pub placement: &'static str,
    pub load: LoadReport,
    /// Selected-minus-oracle modeled seconds summed over every task.
    pub regret: f64,
    /// The oracle variant for (app, size) over the runnable pool.
    pub oracle: String,
    /// Tasks that selected the oracle variant / all tasks.
    pub oracle_hits: usize,
    pub tasks: usize,
}

/// Boot a cluster, drive it, score the selection histogram against the
/// single-process oracle, drain everything.
pub fn run(
    shards: usize,
    gossip: bool,
    placement: PlacementKind,
    serve: &ServeOptions,
    load: &LoadgenOptions,
) -> Result<ClusterReport> {
    let ropts = RouterOptions {
        listen: "127.0.0.1:0".into(),
        shards: Vec::new(),
        placement,
        health_period: Duration::from_millis(150),
        gossip_period: Duration::from_millis(150),
        gossip,
        autoscale: None,
    };
    let cluster = LocalCluster::start(shards, serve, ropts)?;
    let report = loadgen::run(&cluster.addr(), load)?;
    cluster.shutdown()?;

    // artifacts only count toward the oracle pool when the shards could
    // actually run them
    let with_artifacts = crate::runtime::Manifest::load(&crate::runtime::manifest::default_dir())
        .is_ok()
        && cfg!(feature = "xla");
    let pool = runnable_variants(&load.app, with_artifacts);
    let (oracle, oracle_t) =
        oracle_among(&load.app, load.size, &pool).unwrap_or_else(|| ("-".into(), 0.0));
    let mut regret = 0.0f64;
    let mut oracle_hits = 0usize;
    let mut tasks = 0usize;
    for (variant, count) in &report.variants {
        let arch = Arch::parse(variant).unwrap_or(Arch::Cpu);
        let t = variant_time(&load.app, variant, arch, load.size);
        regret += (*count as f64) * (t - oracle_t).max(0.0);
        tasks += count;
        if *variant == oracle {
            oracle_hits += count;
        }
    }
    Ok(ClusterReport {
        shards,
        gossip,
        placement: placement.name(),
        load: report,
        regret,
        oracle,
        oracle_hits,
        tasks,
    })
}

/// The gossip ablation: the same load with gossip off, then on.
pub fn compare(
    shards: usize,
    placement: PlacementKind,
    serve: &ServeOptions,
    load: &LoadgenOptions,
) -> Result<Vec<ClusterReport>> {
    Ok(vec![
        run(shards, false, placement, serve, load)?,
        run(shards, true, placement, serve, load)?,
    ])
}

pub fn render(reports: &[ClusterReport]) -> String {
    let mut t = Table::new(
        "Cluster bench (aggregate throughput + cross-shard selection regret vs oracle)",
        &[
            "shards",
            "gossip",
            "placement",
            "req/s",
            "p95",
            "errors",
            "oracle",
            "oracle hits",
            "regret",
        ],
    );
    for r in reports {
        t.row(vec![
            r.shards.to_string(),
            if r.gossip { "on" } else { "off" }.to_string(),
            r.placement.to_string(),
            format!("{:.1}", r.load.rps),
            fmt_time(r.load.p95),
            r.load.errors.to_string(),
            r.oracle.clone(),
            format!("{}/{}", r.oracle_hits, r.tasks),
            fmt_time(r.regret),
        ]);
    }
    t.render()
}
