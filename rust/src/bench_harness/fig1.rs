//! Fig. 1 regenerator: execution time per input size for three
//! configurations — CPU-only (STARPU_NCUDA=0), GPU-only (STARPU_NCPU=0)
//! and COMPAR (free dynamic selection, dmda) — for every benchmark app,
//! plus the per-variant series of Fig. 1e for matmul.
//!
//! Two row sources, marked in the output (DESIGN.md §3):
//! * `meas`  — the task really executed through the runtime (native Rust
//!   or XLA artifact); reported time is the modeled device time of the
//!   executed variant(s), exactly what the schedulers saw.
//! * `model` — sizes beyond the AOT artifact grid (up to the paper's
//!   8192) evaluated through the same calibrated device model the
//!   runtime's perf models learn; selection is simulated with trained
//!   models (best variant + transfer), i.e. the converged-dmda outcome.

use std::sync::Arc;

use anyhow::Result;

use super::report::{fmt_secs, Table};
use crate::apps;
use crate::runtime::Manifest;
use crate::taskrt::device::{exec_model, transfer_model, Arch};
use crate::taskrt::{Config, Runtime, SchedPolicy};

/// One Fig. 1 data point.
#[derive(Debug, Clone)]
pub struct Point {
    pub size: usize,
    /// configuration -> (seconds, winning variant, measured?)
    pub cpu_only: (f64, String, bool),
    pub gpu_only: (f64, String, bool),
    pub compar: (f64, String, bool),
}

/// Variant -> arch mapping for an app (paper variant names).
fn variants_with_arch(app: &str) -> Vec<(&'static str, Arch)> {
    apps::paper_variants(app)
        .iter()
        .map(|v| (*v, Arch::parse(v).unwrap_or(Arch::Cpu)))
        .collect()
}

/// Bytes an app's working set moves to the GPU on first touch.
fn workload_bytes(app: &str, n: usize) -> usize {
    match app {
        "hotspot" => 2 * 4 * n * n,
        "hotspot3d" => 2 * 4 * 8 * n * n,
        "lud" => 4 * n * n,
        "nw" => 2 * 4 * (n + 1) * (n + 1),
        "matmul" => 3 * 4 * n * n,
        "sort" => 4 * n,
        _ => 4 * n * n,
    }
}

/// Converged-model analytic time for one variant (exec + transfer if the
/// variant lives on the GPU).
pub fn variant_time(app: &str, variant: &str, arch: Arch, n: usize) -> f64 {
    let exec = exec_model(app, variant, n);
    match arch {
        Arch::Cpu => exec,
        Arch::Cuda => exec + transfer_model(workload_bytes(app, n)),
    }
}

/// Best variant restricted to an arch filter (analytic).
fn best_variant(app: &str, n: usize, allow: impl Fn(Arch) -> bool) -> (f64, String) {
    variants_with_arch(app)
        .into_iter()
        .filter(|(_, a)| allow(*a))
        .map(|(v, a)| (variant_time(app, v, a, n), v.to_string()))
        .min_by(|x, y| x.0.partial_cmp(&y.0).unwrap())
        .unwrap_or((f64::NAN, "-".into()))
}

/// Measured execution of one configuration through the real runtime:
/// calibration warmup, then the timed run; returns the modeled time of
/// the selected variant.
fn measured(
    app: &str,
    size: usize,
    manifest: &Arc<Manifest>,
    ncpu: usize,
    ncuda: usize,
    reps: usize,
) -> Result<(f64, String)> {
    let cfg = Config {
        ncpu,
        ncuda,
        sched: SchedPolicy::Dmda,
        ..Config::default()
    };
    let rt = Runtime::new(cfg, Some(manifest.clone()))?;
    // calibration phase (not timed): every variant of the codelet needs
    // MIN_SAMPLES observations before dmda trusts its estimate
    let nvariants = apps::codelet(app)?.impls.len();
    let warmup = (crate::taskrt::perfmodel::MIN_SAMPLES + 1) * nvariants;
    for i in 0..warmup {
        let _ = apps::run_once(&rt, app, size, 1000 + i as u64, None, false)?;
    }
    rt.drain_results();
    // timed: take the best (converged) selection over `reps`
    let mut best = f64::INFINITY;
    let mut variant = String::new();
    for i in 0..reps {
        let run = apps::run_once(&rt, app, size, 2000 + i as u64, None, false)?;
        if run.modeled < best {
            best = run.modeled;
            variant = run.variant;
        }
    }
    Ok((best, variant))
}

/// Is (app, size) fully executable (artifacts exist for the GPU variants)?
fn size_measurable(app: &str, size: usize, manifest: &Manifest) -> bool {
    // the pallas (cuda-analog) artifact must exist; native variants
    // always exist. matmul additionally needs jnp (blas/cuda).
    let need: &[&str] = if app == "matmul" {
        &["pallas", "jnp"]
    } else {
        &["pallas"]
    };
    need.iter().all(|f| manifest.find(app, f, size).is_some())
}

/// Generate the Fig. 1 series for one app.
pub fn series(
    app: &str,
    manifest: Option<&Arc<Manifest>>,
    reps: usize,
    max_measured_size: usize,
) -> Result<Vec<Point>> {
    let mut out = Vec::new();
    for size in apps::paper_sizes(app) {
        let measurable = manifest
            .map(|m| size_measurable(app, size, m) && size <= max_measured_size)
            .unwrap_or(false);
        let point = if let (true, Some(m)) = (measurable, manifest) {
            let cpu = measured(app, size, m, 4, 0, reps)?;
            let gpu = measured(app, size, m, 0, 1, reps)?;
            let both = measured(app, size, m, 4, 1, reps)?;
            Point {
                size,
                cpu_only: (cpu.0, cpu.1, true),
                gpu_only: (gpu.0, gpu.1, true),
                compar: (both.0, both.1, true),
            }
        } else {
            // converged-model extrapolation (same model family the
            // runtime's perf models learn)
            let cpu = best_variant(app, size, |a| a == Arch::Cpu);
            let gpu = best_variant(app, size, |a| a == Arch::Cuda);
            let free = best_variant(app, size, |_| true);
            // dmda decision overhead on the critical path (measured by
            // the taskrt_overhead bench; ~microseconds)
            let overhead = 5e-6;
            Point {
                size,
                cpu_only: (cpu.0, cpu.1, false),
                gpu_only: (gpu.0, gpu.1, false),
                compar: (free.0 + overhead, free.1, false),
            }
        };
        out.push(point);
    }
    Ok(out)
}

/// Render one app's Fig. 1 panel.
pub fn render(app: &str, points: &[Point]) -> String {
    let mut t = Table::new(
        &format!("Fig 1 ({app}): execution time, CPU-only vs GPU-only vs COMPAR"),
        &["size", "cpu-only", "gpu-only", "COMPAR", "selected", "src"],
    );
    for p in points {
        t.row(vec![
            p.size.to_string(),
            fmt_secs(p.cpu_only.0),
            fmt_secs(p.gpu_only.0),
            fmt_secs(p.compar.0),
            p.compar.1.clone(),
            if p.compar.2 { "meas" } else { "model" }.into(),
        ]);
    }
    t.render()
}

/// Fig 1e per-variant series for matmul (BLAS/OMP/CUDA/CUBLAS columns).
pub fn matmul_variant_table() -> String {
    let mut t = Table::new(
        "Fig 1e (matmul): per-variant execution time (converged models)",
        &["size", "blas", "omp", "cuda", "cublas", "best"],
    );
    for size in apps::paper_sizes("matmul") {
        let times: Vec<(f64, &str)> = [
            ("blas", Arch::Cpu),
            ("omp", Arch::Cpu),
            ("cuda", Arch::Cuda),
            ("cublas", Arch::Cuda),
        ]
        .iter()
        .map(|(v, a)| (variant_time("matmul", v, *a, size), *v))
        .collect();
        let best = times
            .iter()
            .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
            .unwrap()
            .1;
        t.row(vec![
            size.to_string(),
            fmt_secs(times[0].0),
            fmt_secs(times[1].0),
            fmt_secs(times[2].0),
            fmt_secs(times[3].0),
            best.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modeled_series_has_paper_shape_hotspot() {
        // GPU wins at large sizes (Fig 1a), CPU competitive at 64
        let pts = series("hotspot", None, 1, 0).unwrap();
        let large = pts.iter().find(|p| p.size == 4096).unwrap();
        assert!(large.gpu_only.0 < large.cpu_only.0);
        // COMPAR tracks the winner
        assert!(large.compar.0 <= large.cpu_only.0.min(large.gpu_only.0) * 1.1);
    }

    #[test]
    fn matmul_crossover_in_variant_table() {
        // Fig 1e shape: cuda beats cublas at 4096, loses at 8192
        let t4096 = variant_time("matmul", "cuda", Arch::Cuda, 4096);
        let b4096 = variant_time("matmul", "cublas", Arch::Cuda, 4096);
        let t8192 = variant_time("matmul", "cuda", Arch::Cuda, 8192);
        let b8192 = variant_time("matmul", "cublas", Arch::Cuda, 8192);
        assert!(t4096 < b4096);
        assert!(b8192 < t8192);
    }

    #[test]
    fn small_matmul_contested() {
        // 8..128: no single variant dominates by 10x (paper: "not always
        // clear which variant performs best")
        for size in [8usize, 32, 128] {
            let cpu = best_variant("matmul", size, |a| a == Arch::Cpu);
            let gpu = best_variant("matmul", size, |a| a == Arch::Cuda);
            assert!(cpu.0 < gpu.0, "CPU should win tiny matmul at {size}");
        }
    }

    #[test]
    fn render_contains_all_sizes() {
        let pts = series("nw", None, 1, 0).unwrap();
        let s = render("nw", &pts);
        for size in apps::paper_sizes("nw") {
            assert!(s.contains(&size.to_string()));
        }
    }
}
