//! Plain-text table rendering for the figure/table regenerators.

/// A simple aligned-column table printer.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Engineering-notation seconds for table cells.
pub fn fmt_secs(t: f64) -> String {
    crate::util::stats::fmt_time(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["size", "time"]);
        t.row(vec!["64".into(), "1.2 ms".into()]);
        t.row(vec!["8192".into(), "950 ms".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("size"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
