//! Stream-serving bench (`compar bench stream`): boots an in-process
//! server with an emulated device variant, drives v6 stream sessions
//! at a sustainable (calibrated) rate and then at overload, and
//! reports what the SLO-driven backpressure machinery did. The smoke
//! gates check the two sides of the contract: at the calibrated rate
//! every chunk lands inside the SLO with nothing dropped; at overload
//! the server engages credit backpressure (shedding window granularity
//! and shrinking the chunk window) instead of dropping chunks.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::Result;

use super::report::Table;
use super::serve_bench::BENCH_SCHEMA;
use crate::serve::loadgen::{self, LoadProfile, LoadReport, LoadgenOptions};
use crate::serve::protocol::StatsResp;
use crate::serve::{ServeOptions, Server};
use crate::stream;
use crate::taskrt::SelectorKind;
use crate::util::json::{self, Json};
use crate::util::stats::fmt_time;

/// The latency SLO every stream in this bench declares (ms). Credit
/// backpressure engages when the modeled backlog crosses half of it.
pub const SLO_MS: f64 = 40.0;

/// One sub-run: the offered profile plus both sides' numbers.
pub struct StreamRun {
    pub profile: String,
    pub report: LoadReport,
    pub stats: StatsResp,
}

/// The full bench: a calibrated run and an overload run.
pub struct StreamBenchRun {
    pub slo_ms: f64,
    pub calibrated: StreamRun,
    pub overload: StreamRun,
}

/// Boot a fresh server (2 CPU + 1 emulated-device worker, contextual
/// selection) and drive it with one stream profile.
fn one_run(
    profile: LoadProfile,
    clients: usize,
    requests: usize,
    window: usize,
    slide: usize,
) -> Result<StreamRun> {
    let server = Server::start(ServeOptions {
        addr: "127.0.0.1:0".into(),
        ncpu: 2,
        ncuda: 1,
        selector: Some(SelectorKind::Contextual),
        ..ServeOptions::default()
    })?;
    // the app's real cuda variant is a Pallas artifact (absent in CI);
    // a native device-emulating variant keeps the bench heterogeneous
    server.register_codelet(stream::emulated_device_sort(Duration::from_millis(4)));
    let addr = server.local_addr().to_string();
    let lg = LoadgenOptions {
        clients,
        requests,
        app: "sort".into(),
        profile: Some(profile),
        slo_ms: Some(SLO_MS),
        window,
        slide,
        verify: false,
        ..LoadgenOptions::default()
    };
    let report = loadgen::run(&addr, &lg)?;
    let stats = server.shutdown()?;
    Ok(StreamRun {
        profile: profile.name(),
        report,
        stats,
    })
}

/// Run both phases. `smoke` shortens the runs for CI.
pub fn run(smoke: bool) -> Result<StreamBenchRun> {
    // calibrated: well under what 3 workers sustain — the SLO should
    // never be threatened and no credit signal should be needed
    let calibrated = one_run(
        LoadProfile::Stream {
            rate: 60.0,
            chunk_kb: 16,
            stages: 1,
        },
        2,
        if smoke { 40 } else { 150 },
        4,
        2,
    )?;
    // overload: ~10x the sustainable chunk cost, many streams — the
    // credit controller must throttle the offered rate instead of
    // letting the queue (and the latency) grow without bound
    let overload = one_run(
        LoadProfile::Stream {
            rate: 400.0,
            chunk_kb: 64,
            stages: 2,
        },
        6,
        if smoke { 40 } else { 150 },
        4,
        2,
    )?;
    Ok(StreamBenchRun {
        slo_ms: SLO_MS,
        calibrated,
        overload,
    })
}

/// Plain-text report: one row per phase.
pub fn render(r: &StreamBenchRun) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "== compar stream bench (slo {} ms) ==\n",
        r.slo_ms
    ));
    let mut t = Table::new(
        "stream phases",
        &[
            "phase",
            "profile",
            "chunks/s",
            "p95",
            "errors",
            "credit signals",
            "windows (shed)",
        ],
    );
    for (name, run) in [("calibrated", &r.calibrated), ("overload", &r.overload)] {
        t.row(vec![
            name.to_string(),
            run.profile.clone(),
            format!("{:.1}", run.report.rps),
            fmt_time(run.report.p95),
            run.report.errors.to_string(),
            run.report.stream_credits.to_string(),
            format!("{} ({})", run.report.windows, run.report.shed_windows),
        ]);
    }
    out.push_str(&t.render());
    for (name, run) in [("calibrated", &r.calibrated), ("overload", &r.overload)] {
        if !run.report.variants.is_empty() {
            let cells: Vec<String> = run
                .report
                .variants
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect();
            out.push_str(&format!("variants[{name}]: {}\n", cells.join("  ")));
        }
    }
    out
}

/// The BENCH record (`compar bench stream --out FILE`), kind
/// "compar-stream": both phases' loadgen numbers plus server counters.
pub fn to_json(r: &StreamBenchRun) -> String {
    let mut m = BTreeMap::new();
    m.insert("bench".to_string(), Json::Str("compar-stream".into()));
    m.insert("schema".to_string(), Json::Num(BENCH_SCHEMA as f64));
    m.insert("status".to_string(), Json::Str("measured".into()));
    m.insert("slo_ms".to_string(), Json::Num(r.slo_ms));
    for (key, run) in [("calibrated", &r.calibrated), ("overload", &r.overload)] {
        let mut o = BTreeMap::new();
        o.insert("profile".into(), Json::Str(run.profile.clone()));
        o.insert("load".into(), loadgen::to_json(&run.report));
        let mut srv = BTreeMap::new();
        srv.insert(
            "requests_ok".into(),
            Json::Num(run.stats.requests_ok as f64),
        );
        srv.insert(
            "requests_err".into(),
            Json::Num(run.stats.requests_err as f64),
        );
        srv.insert(
            "tasks_executed".into(),
            Json::Num(run.stats.tasks_executed as f64),
        );
        o.insert("server".into(), Json::Obj(srv));
        m.insert(key.to_string(), Json::Obj(o));
    }
    json::to_string(&Json::Obj(m))
}
