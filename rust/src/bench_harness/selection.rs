//! Selection-quality experiments: the §3.2 discussion quantified, plus
//! the selection-policy shoot-out.
//!
//! The paper observes that StarPU's dmda (a) converges to the best
//! variant for the Rodinia apps, and (b) for matmul "frequently chose
//! sub-optimal options" while its models were cold. This module measures
//! both: run a task stream through the real runtime and score every
//! decision against the oracle (the converged device model). Since the
//! unified selection engine landed, it also compares the pluggable
//! [`SelectionPolicy`] implementations (Greedy / Calibrating /
//! EpsilonGreedy) head-to-head on selection regret — the measurement
//! behind "which policy should a long-running server run".
//!
//! [`SelectionPolicy`]: crate::taskrt::selection::SelectionPolicy

use std::sync::Arc;

use anyhow::Result;

use super::fig1::variant_time;
use super::report::Table;
use crate::apps;
use crate::runtime::Manifest;
use crate::taskrt::device::Arch;
use crate::taskrt::{Config, ImplKind, Runtime, SchedPolicy, SelectorKind};

/// Policies the comparison bench sweeps (Forced is excluded: its regret
/// is a property of the pinned variant, not of learning).
pub const POLICY_SET: &[SelectorKind] = &[
    SelectorKind::Greedy,
    SelectorKind::Calibrating,
    SelectorKind::EpsilonGreedy(0.1),
    SelectorKind::EpsilonDecayed(0.1),
];

/// Decision trace of one run.
#[derive(Debug, Clone)]
pub struct Trace {
    pub app: String,
    pub size: usize,
    /// Selection policy that produced the decisions.
    pub policy: String,
    /// (selected variant, oracle variant, regret seconds) per task.
    pub decisions: Vec<(String, String, f64)>,
}

impl Trace {
    /// Fraction of decisions matching the oracle.
    pub fn accuracy(&self) -> f64 {
        if self.decisions.is_empty() {
            return 0.0;
        }
        let hits = self
            .decisions
            .iter()
            .filter(|(sel, oracle, _)| sel == oracle)
            .count();
        hits as f64 / self.decisions.len() as f64
    }

    /// Total regret (selected modeled time - oracle time), seconds.
    pub fn regret(&self) -> f64 {
        self.decisions.iter().map(|(_, _, r)| r.max(0.0)).sum()
    }
}

/// Variants of `app` the runtime can actually execute: all of them when
/// artifacts are available, natives only otherwise (artifact variants
/// are ineligible without a manifest).
pub fn runnable_variants(app: &str, with_artifacts: bool) -> Vec<String> {
    match apps::codelet(app) {
        Ok(cl) => cl
            .impls
            .iter()
            .filter(|i| with_artifacts || matches!(i.kind, ImplKind::Native(_)))
            .map(|i| i.name.clone())
            .collect(),
        Err(_) => Vec::new(),
    }
}

/// Best variant (analytic device model, incl. transfer) within a pool.
pub fn oracle_among(app: &str, size: usize, variants: &[String]) -> Option<(String, f64)> {
    variants
        .iter()
        .map(|v| {
            let arch = Arch::parse(v).unwrap_or(Arch::Cpu);
            (v.clone(), variant_time(app, v, arch, size))
        })
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
}

/// Oracle over the paper's full variant set (incl. accelerator
/// variants, whether or not artifacts are installed).
pub fn oracle_variant(app: &str, size: usize) -> (String, f64) {
    let pool: Vec<String> = apps::paper_variants(app)
        .iter()
        .map(|v| v.to_string())
        .collect();
    oracle_among(app, size, &pool).unwrap()
}

/// Run `tasks` submissions of (app, size) under scheduler `sched` and
/// selection policy `selector`, tracing every selection. Fresh runtime
/// => cold models (the paper's scenario). Regret is scored against the
/// oracle over the *runnable* variants, so artifact-less environments
/// stay comparable.
pub fn trace(
    app: &str,
    size: usize,
    sched: SchedPolicy,
    selector: SelectorKind,
    tasks: usize,
    manifest: Option<&Arc<Manifest>>,
) -> Result<Trace> {
    let cfg = Config {
        ncpu: 2,
        ncuda: 1,
        sched,
        selector: selector.clone(),
        ..Config::default()
    };
    let rt = Runtime::new(cfg, manifest.cloned())?;
    let pool = runnable_variants(app, manifest.is_some());
    let (oracle, oracle_t) =
        oracle_among(app, size, &pool).unwrap_or_else(|| ("-".into(), 0.0));
    let mut decisions = Vec::new();
    for i in 0..tasks {
        let run = apps::run_once(&rt, app, size, 7000 + i as u64, None, false)?;
        let arch = Arch::parse(&run.variant).unwrap_or(Arch::Cpu);
        let sel_t = variant_time(app, &run.variant, arch, size);
        decisions.push((run.variant, oracle.clone(), sel_t - oracle_t));
    }
    Ok(Trace {
        app: app.to_string(),
        size,
        policy: selector.name(),
        decisions,
    })
}

/// Run every policy in [`POLICY_SET`] over the given (app, size) pairs.
pub fn compare_policies(
    pairs: &[(&str, usize)],
    tasks: usize,
    manifest: Option<&Arc<Manifest>>,
) -> Result<Vec<Trace>> {
    let mut out = Vec::new();
    for &(app, size) in pairs {
        for kind in POLICY_SET {
            out.push(trace(
                app,
                size,
                SchedPolicy::Dmda,
                kind.clone(),
                tasks,
                manifest,
            )?);
        }
    }
    Ok(out)
}

/// Accuracy-over-time table: cold phase vs converged phase.
pub fn render(traces: &[Trace]) -> String {
    let mut t = Table::new(
        "Selection quality (decisions vs oracle; paper §3.2)",
        &["app", "size", "policy", "tasks", "cold acc.", "warm acc.", "total regret"],
    );
    for tr in traces {
        let n = tr.decisions.len();
        let half = n / 2;
        let cold = Trace {
            app: tr.app.clone(),
            size: tr.size,
            policy: tr.policy.clone(),
            decisions: tr.decisions[..half].to_vec(),
        };
        let warm = Trace {
            app: tr.app.clone(),
            size: tr.size,
            policy: tr.policy.clone(),
            decisions: tr.decisions[half..].to_vec(),
        };
        t.row(vec![
            tr.app.clone(),
            tr.size.to_string(),
            tr.policy.clone(),
            n.to_string(),
            format!("{:.0}%", cold.accuracy() * 100.0),
            format!("{:.0}%", warm.accuracy() * 100.0),
            crate::util::stats::fmt_time(tr.regret()),
        ]);
    }
    t.render()
}

/// Policy shoot-out: one row per (app, size), regret per policy, winner
/// marked — the "which policy should the server run" report.
pub fn render_comparison(traces: &[Trace]) -> String {
    let mut headers = vec!["app".to_string(), "size".to_string()];
    for k in POLICY_SET {
        headers.push(format!("regret {}", k.name()));
    }
    headers.push("winner".into());
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(
        "Selection-policy comparison (total regret vs oracle; lower is better)",
        &hdr_refs,
    );
    // group by (app, size), preserving first-seen order
    let mut keys: Vec<(String, usize)> = Vec::new();
    for tr in traces {
        let key = (tr.app.clone(), tr.size);
        if !keys.contains(&key) {
            keys.push(key);
        }
    }
    for (app, size) in keys {
        let mut row = vec![app.clone(), size.to_string()];
        let mut best: Option<(String, f64)> = None;
        for k in POLICY_SET {
            let name = k.name();
            let regret = traces
                .iter()
                .find(|tr| tr.app == app && tr.size == size && tr.policy == name)
                .map(|tr| tr.regret());
            match regret {
                Some(r) => {
                    row.push(crate::util::stats::fmt_time(r));
                    if best.as_ref().map(|(_, b)| r < *b).unwrap_or(true) {
                        best = Some((name, r));
                    }
                }
                None => row.push("-".into()),
            }
        }
        row.push(best.map(|(n, _)| n).unwrap_or_else(|| "-".into()));
        t.row(row);
    }
    t.render()
}

/// The selection-regret record (`compar bench selection --out FILE`):
/// schema-versioned like `BENCH_serve.json`, one row per trace.
pub fn to_json(traces: &[Trace]) -> String {
    use crate::util::json::Json;
    let mut m = std::collections::BTreeMap::new();
    m.insert(
        "bench".to_string(),
        Json::Str("compar-selection".to_string()),
    );
    m.insert(
        "schema".to_string(),
        Json::Num(super::serve_bench::BENCH_SCHEMA as f64),
    );
    m.insert("status".to_string(), Json::Str("measured".to_string()));
    let rows: Vec<Json> = traces
        .iter()
        .map(|tr| {
            let mut row = std::collections::BTreeMap::new();
            row.insert("app".to_string(), Json::Str(tr.app.clone()));
            row.insert("size".to_string(), Json::Num(tr.size as f64));
            row.insert("policy".to_string(), Json::Str(tr.policy.clone()));
            row.insert(
                "tasks".to_string(),
                Json::Num(tr.decisions.len() as f64),
            );
            row.insert("accuracy".to_string(), Json::Num(tr.accuracy()));
            row.insert("regret_s".to_string(), Json::Num(tr.regret()));
            Json::Obj(row)
        })
        .collect();
    m.insert("rows".to_string(), Json::Arr(rows));
    crate::util::json::to_string(&Json::Obj(m))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_is_gpu_for_large_hotspot() {
        let (v, _) = oracle_variant("hotspot", 4096);
        assert_eq!(v, "cuda");
    }

    #[test]
    fn oracle_is_cpu_for_tiny_matmul() {
        let (v, _) = oracle_variant("matmul", 8);
        assert!(v == "blas" || v == "omp", "{v}");
    }

    #[test]
    fn native_only_pool_excludes_artifacts() {
        let v = runnable_variants("matmul", false);
        assert!(v.contains(&"omp".to_string()) && v.contains(&"seq".to_string()));
        assert!(!v.contains(&"cuda".to_string()), "{v:?}");
        let all = runnable_variants("matmul", true);
        assert!(all.contains(&"cuda".to_string()));
    }

    #[test]
    fn accuracy_and_regret_math() {
        let t = Trace {
            app: "x".into(),
            size: 1,
            policy: "greedy".into(),
            decisions: vec![
                ("a".into(), "a".into(), 0.0),
                ("b".into(), "a".into(), 0.5),
            ],
        };
        assert_eq!(t.accuracy(), 0.5);
        assert_eq!(t.regret(), 0.5);
    }
}
