//! Selection-quality experiments: the §3.2 discussion quantified, plus
//! the selection-policy shoot-out.
//!
//! The paper observes that StarPU's dmda (a) converges to the best
//! variant for the Rodinia apps, and (b) for matmul "frequently chose
//! sub-optimal options" while its models were cold. This module measures
//! both: run a task stream through the real runtime and score every
//! decision against the oracle (the converged device model). Since the
//! unified selection engine landed, it also compares the pluggable
//! [`SelectionPolicy`] implementations (Greedy / Calibrating /
//! EpsilonGreedy) head-to-head on selection regret — the measurement
//! behind "which policy should a long-running server run".
//!
//! [`SelectionPolicy`]: crate::taskrt::selection::SelectionPolicy

use std::sync::Arc;

use anyhow::Result;

use super::fig1::variant_time;
use super::report::Table;
use crate::apps;
use crate::runtime::Manifest;
use crate::taskrt::device::Arch;
use crate::taskrt::{Config, ImplKind, Runtime, SchedPolicy, SelectorKind};

/// Policies the comparison bench sweeps (Forced is excluded: its regret
/// is a property of the pinned variant, not of learning).
pub const POLICY_SET: &[SelectorKind] = &[
    SelectorKind::Greedy,
    SelectorKind::Calibrating,
    SelectorKind::EpsilonGreedy(0.1),
    SelectorKind::EpsilonDecayed(0.1),
    SelectorKind::Contextual,
];

/// Decision trace of one run.
#[derive(Debug, Clone)]
pub struct Trace {
    pub app: String,
    pub size: usize,
    /// Selection policy that produced the decisions.
    pub policy: String,
    /// (selected variant, oracle variant, regret seconds) per task.
    pub decisions: Vec<(String, String, f64)>,
}

impl Trace {
    /// Fraction of decisions matching the oracle.
    pub fn accuracy(&self) -> f64 {
        if self.decisions.is_empty() {
            return 0.0;
        }
        let hits = self
            .decisions
            .iter()
            .filter(|(sel, oracle, _)| sel == oracle)
            .count();
        hits as f64 / self.decisions.len() as f64
    }

    /// Total regret (selected modeled time - oracle time), seconds.
    pub fn regret(&self) -> f64 {
        self.decisions.iter().map(|(_, _, r)| r.max(0.0)).sum()
    }
}

/// Variants of `app` the runtime can actually execute: all of them when
/// artifacts are available, natives only otherwise (artifact variants
/// are ineligible without a manifest).
pub fn runnable_variants(app: &str, with_artifacts: bool) -> Vec<String> {
    match apps::codelet(app) {
        Ok(cl) => cl
            .impls
            .iter()
            .filter(|i| with_artifacts || matches!(i.kind, ImplKind::Native(_)))
            .map(|i| i.name.clone())
            .collect(),
        Err(_) => Vec::new(),
    }
}

/// Best variant (analytic device model, incl. transfer) within a pool.
pub fn oracle_among(app: &str, size: usize, variants: &[String]) -> Option<(String, f64)> {
    variants
        .iter()
        .map(|v| {
            let arch = Arch::parse(v).unwrap_or(Arch::Cpu);
            (v.clone(), variant_time(app, v, arch, size))
        })
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
}

/// Oracle over the paper's full variant set (incl. accelerator
/// variants, whether or not artifacts are installed).
pub fn oracle_variant(app: &str, size: usize) -> (String, f64) {
    let pool: Vec<String> = apps::paper_variants(app)
        .iter()
        .map(|v| v.to_string())
        .collect();
    oracle_among(app, size, &pool).unwrap()
}

/// Run `tasks` submissions of (app, size) under scheduler `sched` and
/// selection policy `selector`, tracing every selection. Fresh runtime
/// => cold models (the paper's scenario). Regret is scored against the
/// oracle over the *runnable* variants, so artifact-less environments
/// stay comparable.
pub fn trace(
    app: &str,
    size: usize,
    sched: SchedPolicy,
    selector: SelectorKind,
    tasks: usize,
    manifest: Option<&Arc<Manifest>>,
) -> Result<Trace> {
    let cfg = Config {
        ncpu: 2,
        ncuda: 1,
        sched,
        selector: selector.clone(),
        ..Config::default()
    };
    let rt = Runtime::new(cfg, manifest.cloned())?;
    let pool = runnable_variants(app, manifest.is_some());
    let (oracle, oracle_t) =
        oracle_among(app, size, &pool).unwrap_or_else(|| ("-".into(), 0.0));
    let mut decisions = Vec::new();
    for i in 0..tasks {
        let run = apps::run_once(&rt, app, size, 7000 + i as u64, None, false)?;
        let arch = Arch::parse(&run.variant).unwrap_or(Arch::Cpu);
        let sel_t = variant_time(app, &run.variant, arch, size);
        decisions.push((run.variant, oracle.clone(), sel_t - oracle_t));
    }
    Ok(Trace {
        app: app.to_string(),
        size,
        policy: selector.name(),
        decisions,
    })
}

/// Run every policy in [`POLICY_SET`] over the given (app, size) pairs.
pub fn compare_policies(
    pairs: &[(&str, usize)],
    tasks: usize,
    manifest: Option<&Arc<Manifest>>,
) -> Result<Vec<Trace>> {
    let mut out = Vec::new();
    for &(app, size) in pairs {
        for kind in POLICY_SET {
            out.push(trace(
                app,
                size,
                SchedPolicy::Dmda,
                kind.clone(),
                tasks,
                manifest,
            )?);
        }
    }
    Ok(out)
}

/// Accuracy-over-time table: cold phase vs converged phase.
pub fn render(traces: &[Trace]) -> String {
    let mut t = Table::new(
        "Selection quality (decisions vs oracle; paper §3.2)",
        &["app", "size", "policy", "tasks", "cold acc.", "warm acc.", "total regret"],
    );
    for tr in traces {
        let n = tr.decisions.len();
        let half = n / 2;
        let cold = Trace {
            app: tr.app.clone(),
            size: tr.size,
            policy: tr.policy.clone(),
            decisions: tr.decisions[..half].to_vec(),
        };
        let warm = Trace {
            app: tr.app.clone(),
            size: tr.size,
            policy: tr.policy.clone(),
            decisions: tr.decisions[half..].to_vec(),
        };
        t.row(vec![
            tr.app.clone(),
            tr.size.to_string(),
            tr.policy.clone(),
            n.to_string(),
            format!("{:.0}%", cold.accuracy() * 100.0),
            format!("{:.0}%", warm.accuracy() * 100.0),
            crate::util::stats::fmt_time(tr.regret()),
        ]);
    }
    t.render()
}

/// Policy shoot-out: one row per (app, size), regret per policy, winner
/// marked — the "which policy should the server run" report.
pub fn render_comparison(traces: &[Trace]) -> String {
    let mut headers = vec!["app".to_string(), "size".to_string()];
    for k in POLICY_SET {
        headers.push(format!("regret {}", k.name()));
    }
    headers.push("winner".into());
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(
        "Selection-policy comparison (total regret vs oracle; lower is better)",
        &hdr_refs,
    );
    // group by (app, size), preserving first-seen order
    let mut keys: Vec<(String, usize)> = Vec::new();
    for tr in traces {
        let key = (tr.app.clone(), tr.size);
        if !keys.contains(&key) {
            keys.push(key);
        }
    }
    for (app, size) in keys {
        let mut row = vec![app.clone(), size.to_string()];
        let mut best: Option<(String, f64)> = None;
        for k in POLICY_SET {
            let name = k.name();
            let regret = traces
                .iter()
                .find(|tr| tr.app == app && tr.size == size && tr.policy == name)
                .map(|tr| tr.regret());
            match regret {
                Some(r) => {
                    row.push(crate::util::stats::fmt_time(r));
                    if best.as_ref().map(|(_, b)| r < *b).unwrap_or(true) {
                        best = Some((name, r));
                    }
                }
                None => row.push("-".into()),
            }
        }
        row.push(best.map(|(n, _)| n).unwrap_or_else(|| "-".into()));
        t.row(row);
    }
    t.render()
}

// -------------------------------------------------- contended scenario

/// Outcome of one policy's run through the contended scenario.
#[derive(Debug, Clone)]
pub struct ContendedOutcome {
    pub policy: String,
    /// Total effective-time regret vs the phase-aware oracle (seconds).
    pub regret: f64,
    /// Decisions matching the phase-aware oracle.
    pub accuracy: f64,
}

/// Idle effective time of the device variant (seconds).
const CUDA_IDLE: f64 = 1e-3;
/// Effective time of the device variant while the device is contended:
/// the queue wait + interference the paper's global per-(codelet, size)
/// models cannot represent.
const CUDA_CONTENDED: f64 = 1e-2;
/// The CPU variant is load-insensitive in this scenario.
const OMP_TIME: f64 = 4e-3;

fn effective_time(variant: &str, contended: bool) -> f64 {
    match (variant, contended) {
        ("cuda", false) => CUDA_IDLE,
        ("cuda", true) => CUDA_CONTENDED,
        _ => OMP_TIME,
    }
}

/// The contended scenario: a deterministic decision-level simulation of
/// phase-alternating device pressure. A two-arch partition serves a
/// steady (codelet, size) stream whose device is periodically contended
/// (in-flight work + queue depth that only the selection layer's
/// [`RuntimeSnapshot`] exposes — dmda's deque model cannot see it, and
/// the perf models were warmed while idle). During contended phases the
/// device variant's *effective* time is [`CUDA_CONTENDED`]; the oracle
/// switches to the CPU variant there. A policy that keys on (codelet,
/// size) alone keeps choosing the device; a context-aware policy flips.
///
/// Decision-level on purpose: no threads, no sleeps, no wall-clock — the
/// regret ordering is stable enough for a CI gate (`--smoke` asserts
/// contextual ≤ greedy).
///
/// [`RuntimeSnapshot`]: crate::taskrt::selection::RuntimeSnapshot
pub fn contended_compare(steps: usize) -> Vec<ContendedOutcome> {
    [SelectorKind::Greedy, SelectorKind::Contextual]
        .iter()
        .map(|k| contended_run(k, steps))
        .collect()
}

fn contended_run(kind: &SelectorKind, steps: usize) -> ContendedOutcome {
    use std::sync::atomic::Ordering;

    use crate::taskrt::data::DataRegistry;
    use crate::taskrt::perfmodel::{PerfModels, MIN_SAMPLES};
    use crate::taskrt::scheduler::dmda::Dmda;
    use crate::taskrt::scheduler::{ReadyTask, SchedCtx, WorkerInfo};
    use crate::taskrt::{AccessMode, Codelet};

    let workers = vec![
        WorkerInfo {
            id: 0,
            arch: Arch::Cpu,
            mem_node: 0,
        },
        WorkerInfo {
            id: 1,
            arch: Arch::Cuda,
            mem_node: 1,
        },
    ];
    let perf = Arc::new(PerfModels::new());
    // warmed while idle: the global models rank the device first
    for _ in 0..MIN_SAMPLES {
        perf.record("mmul", "cuda", 64, CUDA_IDLE);
        perf.record("mmul", "omp", 64, OMP_TIME);
    }
    let ctx = SchedCtx::new(
        workers,
        perf,
        Arc::new(DataRegistry::new()),
        None,
        kind.build(7),
        7,
    );
    let codelet = Arc::new(
        Codelet::new("mmul", "matmul", Vec::<AccessMode>::new())
            .with_native("omp", Arch::Cpu, Arc::new(|_| Ok(())))
            .with_native("cuda", Arch::Cuda, Arc::new(|_| Ok(()))),
    );
    let task = ReadyTask {
        id: 0,
        codelet,
        size: 64,
        handles: vec![],
        selector: None,
        priority: 0,
        ctx: 0,
        chosen_impl: None,
        est_cost_ns: 0,
        tag: 0,
        trace: 0,
        enqueued_ns: 0,
    };

    let mut regret = 0.0;
    let mut hits = 0usize;
    let mut decided = 0usize;
    for step in 0..steps {
        // alternate 4-step idle / 4-step contended phases (at most one
        // in-flight task per worker — the occupancy invariant the
        // autoscale counter audit asserts; the queue depth carries the
        // contended band)
        let contended = (step / 4) % 2 == 1;
        let (inflight, depth): (usize, isize) = if contended { (1, 5) } else { (0, 0) };
        ctx.running[1].store(inflight, Ordering::Relaxed);
        ctx.pending.store(depth, Ordering::Relaxed);
        let Some((w, i, _)) = Dmda::place(&task, &ctx, |_, _, _| 0.0) else {
            continue;
        };
        let variant = task.codelet.impls[i].name.clone();
        let effective = effective_time(&variant, contended);
        let oracle_t = OMP_TIME.min(effective_time("cuda", contended));
        regret += (effective - oracle_t).max(0.0);
        if (effective - oracle_t).abs() < 1e-12 {
            hits += 1;
        }
        decided += 1;
        // close the online-learning loop with the *effective* time, so
        // context-aware policies can learn the interference
        let arch = ctx.workers[w].arch;
        ctx.feedback(&task, arch, &variant, effective);
    }
    ContendedOutcome {
        policy: kind.name(),
        regret,
        accuracy: if decided == 0 {
            0.0
        } else {
            hits as f64 / decided as f64
        },
    }
}

/// Render the contended-scenario shoot-out.
pub fn render_contended(outcomes: &[ContendedOutcome]) -> String {
    let mut t = Table::new(
        "Contended scenario (phase-alternating device pressure; lower regret is better)",
        &["policy", "oracle accuracy", "total regret"],
    );
    for o in outcomes {
        t.row(vec![
            o.policy.clone(),
            format!("{:.0}%", o.accuracy * 100.0),
            crate::util::stats::fmt_time(o.regret),
        ]);
    }
    t.render()
}

/// The selection-regret record (`compar bench selection --out FILE`):
/// schema-versioned like `BENCH_serve.json`, one row per trace.
pub fn to_json(traces: &[Trace]) -> String {
    use crate::util::json::Json;
    let mut m = std::collections::BTreeMap::new();
    m.insert(
        "bench".to_string(),
        Json::Str("compar-selection".to_string()),
    );
    m.insert(
        "schema".to_string(),
        Json::Num(super::serve_bench::BENCH_SCHEMA as f64),
    );
    m.insert("status".to_string(), Json::Str("measured".to_string()));
    let rows: Vec<Json> = traces
        .iter()
        .map(|tr| {
            let mut row = std::collections::BTreeMap::new();
            row.insert("app".to_string(), Json::Str(tr.app.clone()));
            row.insert("size".to_string(), Json::Num(tr.size as f64));
            row.insert("policy".to_string(), Json::Str(tr.policy.clone()));
            row.insert(
                "tasks".to_string(),
                Json::Num(tr.decisions.len() as f64),
            );
            row.insert("accuracy".to_string(), Json::Num(tr.accuracy()));
            row.insert("regret_s".to_string(), Json::Num(tr.regret()));
            Json::Obj(row)
        })
        .collect();
    m.insert("rows".to_string(), Json::Arr(rows));
    crate::util::json::to_string(&Json::Obj(m))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_is_gpu_for_large_hotspot() {
        let (v, _) = oracle_variant("hotspot", 4096);
        assert_eq!(v, "cuda");
    }

    #[test]
    fn oracle_is_cpu_for_tiny_matmul() {
        let (v, _) = oracle_variant("matmul", 8);
        assert!(v == "blas" || v == "omp", "{v}");
    }

    #[test]
    fn native_only_pool_excludes_artifacts() {
        let v = runnable_variants("matmul", false);
        assert!(v.contains(&"omp".to_string()) && v.contains(&"seq".to_string()));
        assert!(!v.contains(&"cuda".to_string()), "{v:?}");
        let all = runnable_variants("matmul", true);
        assert!(all.contains(&"cuda".to_string()));
    }

    #[test]
    fn contended_scenario_contextual_beats_greedy() {
        let out = contended_compare(40);
        let regret = |n: &str| out.iter().find(|o| o.policy == n).unwrap().regret;
        assert!(
            regret("contextual") < regret("greedy"),
            "context-aware selection must win under phased pressure: {out:?}"
        );
        // greedy pays for (nearly) every contended step; contextual only
        // for the first step of the first contended phase
        assert!(regret("greedy") > 10.0 * regret("contextual").max(1e-9), "{out:?}");
    }

    #[test]
    fn accuracy_and_regret_math() {
        let t = Trace {
            app: "x".into(),
            size: 1,
            policy: "greedy".into(),
            decisions: vec![
                ("a".into(), "a".into(), 0.0),
                ("b".into(), "a".into(), 0.5),
            ],
        };
        assert_eq!(t.accuracy(), 0.5);
        assert_eq!(t.regret(), 0.5);
    }
}
