//! Selection-quality experiments: the §3.2 discussion quantified.
//!
//! The paper observes that StarPU's dmda (a) converges to the best
//! variant for the Rodinia apps, and (b) for matmul "frequently chose
//! sub-optimal options" while its models were cold. This module measures
//! both: run a task stream through the real runtime and score every
//! decision against the oracle (the converged device model).

use std::sync::Arc;

use anyhow::Result;

use super::fig1::variant_time;
use super::report::Table;
use crate::apps;
use crate::runtime::Manifest;
use crate::taskrt::device::Arch;
use crate::taskrt::{Config, Runtime, SchedPolicy};

/// Decision trace of one run.
#[derive(Debug, Clone)]
pub struct Trace {
    pub app: String,
    pub size: usize,
    /// (selected variant, oracle variant, regret seconds) per task.
    pub decisions: Vec<(String, String, f64)>,
}

impl Trace {
    /// Fraction of decisions matching the oracle.
    pub fn accuracy(&self) -> f64 {
        if self.decisions.is_empty() {
            return 0.0;
        }
        let hits = self
            .decisions
            .iter()
            .filter(|(sel, oracle, _)| sel == oracle)
            .count();
        hits as f64 / self.decisions.len() as f64
    }

    /// Total regret (selected modeled time - oracle time), seconds.
    pub fn regret(&self) -> f64 {
        self.decisions.iter().map(|(_, _, r)| r.max(0.0)).sum()
    }
}

/// Oracle = variant with minimal converged-model time (incl. transfer).
pub fn oracle_variant(app: &str, size: usize) -> (String, f64) {
    apps::paper_variants(app)
        .iter()
        .map(|v| {
            let arch = Arch::parse(v).unwrap_or(Arch::Cpu);
            (v.to_string(), variant_time(app, v, arch, size))
        })
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap()
}

/// Run `tasks` submissions of (app, size) under `sched` and trace the
/// selections. Fresh runtime => cold models (the paper's scenario).
pub fn trace(
    app: &str,
    size: usize,
    sched: SchedPolicy,
    tasks: usize,
    manifest: &Arc<Manifest>,
) -> Result<Trace> {
    let cfg = Config {
        ncpu: 2,
        ncuda: 1,
        sched,
        ..Config::default()
    };
    let rt = Runtime::new(cfg, Some(manifest.clone()))?;
    let (oracle, oracle_t) = oracle_variant(app, size);
    let mut decisions = Vec::new();
    for i in 0..tasks {
        let run = apps::run_once(&rt, app, size, 7000 + i as u64, None, false)?;
        let arch = Arch::parse(&run.variant).unwrap_or(Arch::Cpu);
        let sel_t = variant_time(app, &run.variant, arch, size);
        decisions.push((run.variant, oracle.clone(), sel_t - oracle_t));
    }
    Ok(Trace {
        app: app.to_string(),
        size,
        decisions,
    })
}

/// Accuracy-over-time table: cold phase vs converged phase.
pub fn render(traces: &[Trace]) -> String {
    let mut t = Table::new(
        "Selection quality (dmda decisions vs oracle; paper §3.2)",
        &["app", "size", "tasks", "cold acc.", "warm acc.", "total regret"],
    );
    for tr in traces {
        let n = tr.decisions.len();
        let half = n / 2;
        let cold = Trace {
            app: tr.app.clone(),
            size: tr.size,
            decisions: tr.decisions[..half].to_vec(),
        };
        let warm = Trace {
            app: tr.app.clone(),
            size: tr.size,
            decisions: tr.decisions[half..].to_vec(),
        };
        t.row(vec![
            tr.app.clone(),
            tr.size.to_string(),
            n.to_string(),
            format!("{:.0}%", cold.accuracy() * 100.0),
            format!("{:.0}%", warm.accuracy() * 100.0),
            crate::util::stats::fmt_time(tr.regret()),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_is_gpu_for_large_hotspot() {
        let (v, _) = oracle_variant("hotspot", 4096);
        assert_eq!(v, "cuda");
    }

    #[test]
    fn oracle_is_cpu_for_tiny_matmul() {
        let (v, _) = oracle_variant("matmul", 8);
        assert!(v == "blas" || v == "omp", "{v}");
    }

    #[test]
    fn accuracy_and_regret_math() {
        let t = Trace {
            app: "x".into(),
            size: 1,
            decisions: vec![
                ("a".into(), "a".into(), 0.0),
                ("b".into(), "a".into(), 0.5),
            ],
        };
        assert_eq!(t.accuracy(), 0.5);
        assert_eq!(t.regret(), 0.5);
    }
}
