//! Graph-planning bench (`compar bench dag`): boots an in-process
//! server with an emulated device variant, ships a transfer-heavy
//! producer→consumer pipeline as one v8 `submit_graph` request, and
//! compares the [`crate::plan::GraphPlanner`]'s joint assignment
//! against per-task greedy on the same DAG. Three phases:
//!
//! * **planned** — the planner assigns variants to all nodes jointly;
//!   co-scheduling the chain on one arch elides the intermediate
//!   transfers the greedy baseline pays edge by edge.
//! * **greedy** — the same graph with `mode: "greedy"`, the per-task
//!   baseline the planner must never lose to (and cannot, by
//!   construction: the planner's sweep starts from the greedy
//!   assignment and only accepts improving flips).
//! * **contended** — the same graph submitted while scalar chains keep
//!   the context queue deeper than its worker count; the planner must
//!   *degrade* to per-task greedy (stale lookahead under contention is
//!   worse than no lookahead), observable as `mode: "greedy"` in the
//!   `graph_done` report.
//!
//! The smoke gates check exactly the planning contract: planned
//! makespan ≤ greedy makespan, at least one transfer elided, every
//! node reports a result, and the contended submit degrades.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{bail, Result};

use super::report::Table;
use super::serve_bench::BENCH_SCHEMA;
use crate::serve::protocol::{GraphDoneResp, GraphNodeReq, StatsResp, SubmitGraphReq, SubmitReq};
use crate::serve::{Client, ClientConfig, Framing, ServeOptions, Server, TransportKind};
use crate::stream;
use crate::taskrt::SelectorKind;
use crate::util::json::{self, Json};
use crate::util::stats::fmt_time;

/// Problem size of every pipeline node — large enough that the modeled
/// PCIe cost of an un-elided intermediate edge is visible next to the
/// modeled kernel times.
pub const NODE_SIZE: usize = 65536;

/// The full bench: one server, three graph submissions.
pub struct DagBenchRun {
    pub transport: TransportKind,
    pub framing: Framing,
    /// Pipeline length (nodes per graph).
    pub nodes: usize,
    pub planned: GraphDoneResp,
    pub greedy: GraphDoneResp,
    pub contended: GraphDoneResp,
    pub stats: StatsResp,
}

fn connect(addr: &str, framing: Framing) -> Result<Client> {
    Client::connect_cfg(
        addr,
        &ClientConfig {
            framing,
            ..ClientConfig::default()
        },
    )
}

/// A linear producer→consumer pipeline: node k reads/writes the data
/// node k-1 produced (the server shares the registry handles, so the
/// dependency carries real bytes the planner can price — and elide).
fn pipeline(id: u64, nodes: usize, mode: Option<&str>) -> SubmitGraphReq {
    let nodes = (0..nodes)
        .map(|k| GraphNodeReq {
            name: format!("stage{k}"),
            app: "sort".into(),
            size: NODE_SIZE,
            deps: if k == 0 {
                Vec::new()
            } else {
                vec![format!("stage{}", k - 1)]
            },
            variant: None,
        })
        .collect();
    SubmitGraphReq {
        id,
        nodes,
        ctx: None,
        mode: mode.map(str::to_string),
        trace: 0,
    }
}

/// Run all three phases against one server. `smoke` shortens the
/// pipeline and the contention burst for CI.
pub fn run(transport: TransportKind, framing: Framing, smoke: bool) -> Result<DagBenchRun> {
    let nodes = if smoke { 5 } else { 8 };
    let server = Server::start(ServeOptions {
        addr: "127.0.0.1:0".into(),
        ncpu: 2,
        ncuda: 1,
        selector: Some(SelectorKind::Contextual),
        transport,
        ..ServeOptions::default()
    })?;
    // the app's real cuda variant is a Pallas artifact (absent in CI);
    // a native device-emulating variant keeps the planner heterogeneous
    server.register_codelet(stream::emulated_device_sort(Duration::from_millis(4)));
    let addr = server.local_addr().to_string();

    let mut c = connect(&addr, framing)?;
    let planned = c.submit_graph(pipeline(1, nodes, None))?;
    let greedy = c.submit_graph(pipeline(2, nodes, Some("greedy")))?;

    // contention phase: scalar chains keep the default context's queue
    // deeper than its 3 workers while the graph arrives
    let (clients, chain) = if smoke { (6, 24) } else { (8, 48) };
    let mut burst = Vec::new();
    for i in 0..clients {
        let addr = addr.clone();
        burst.push(std::thread::spawn(move || -> Result<()> {
            let mut c = connect(&addr, framing)?;
            c.submit(SubmitReq {
                id: 100 + i as u64,
                app: "sort".into(),
                size: 32768,
                tasks: chain,
                ctx: None,
                seed: 7 + i as u64,
                variant: None,
                verify: false,
                trace: 0,
            })?;
            let _ = c.quit();
            Ok(())
        }));
    }
    // let the burst release its chains before the graph is submitted
    std::thread::sleep(Duration::from_millis(30));
    let contended = c.submit_graph(pipeline(3, nodes, None))?;
    for h in burst {
        h.join()
            .map_err(|_| anyhow::anyhow!("burst client panicked"))??;
    }
    let _ = c.quit();

    let stats = server.shutdown()?;
    Ok(DagBenchRun {
        transport,
        framing,
        nodes,
        planned,
        greedy,
        contended,
        stats,
    })
}

/// The CI gates (`compar bench dag --smoke`): the planning contract,
/// checked on the wire-visible report.
pub fn check_gates(r: &DagBenchRun) -> Result<()> {
    for (label, g) in [
        ("planned", &r.planned),
        ("greedy", &r.greedy),
        ("contended", &r.contended),
    ] {
        if g.nodes.len() != r.nodes {
            bail!(
                "gate: {label} run reported {}/{} nodes",
                g.nodes.len(),
                r.nodes
            );
        }
        for nd in &g.nodes {
            if nd.variant.is_empty() {
                bail!("gate: {label} node '{}' finished without a variant", nd.name);
            }
        }
    }
    if r.planned.mode != "planned" {
        bail!(
            "gate: uncontended submit ran mode '{}' (want planned)",
            r.planned.mode
        );
    }
    if r.greedy.mode != "greedy" {
        bail!(
            "gate: forced-greedy submit ran mode '{}' (want greedy)",
            r.greedy.mode
        );
    }
    if r.contended.mode != "greedy" {
        bail!(
            "gate: contended submit ran mode '{}' (want degradation to greedy)",
            r.contended.mode
        );
    }
    if r.planned.makespan > r.greedy.makespan * (1.0 + 1e-9) {
        bail!(
            "gate: planned makespan {:.6}s exceeds greedy {:.6}s",
            r.planned.makespan,
            r.greedy.makespan
        );
    }
    if r.planned.elided_transfers < 1 {
        bail!("gate: planned run elided no producer→consumer transfers");
    }
    Ok(())
}

/// Plain-text report: one row per phase plus the planned assignment.
pub fn render(r: &DagBenchRun) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "== compar dag bench ({} nodes, {} / {}) ==\n",
        r.nodes,
        r.transport.name(),
        r.framing.name()
    ));
    let mut t = Table::new(
        "graph phases",
        &[
            "phase",
            "mode",
            "modeled makespan",
            "wall",
            "elided",
            "nodes",
        ],
    );
    for (name, g) in [
        ("planned", &r.planned),
        ("greedy", &r.greedy),
        ("contended", &r.contended),
    ] {
        t.row(vec![
            name.to_string(),
            g.mode.clone(),
            fmt_time(g.makespan),
            fmt_time(g.wall),
            g.elided_transfers.to_string(),
            g.nodes.len().to_string(),
        ]);
    }
    out.push_str(&t.render());
    let cells: Vec<String> = r
        .planned
        .nodes
        .iter()
        .map(|nd| {
            format!(
                "{}={}/{}{}",
                nd.name,
                nd.variant,
                nd.arch,
                if nd.elided { "*" } else { "" }
            )
        })
        .collect();
    out.push_str(&format!(
        "planned assignment (*=incoming transfer elided): {}\n",
        cells.join("  ")
    ));
    out.push_str(&format!(
        "server: plans={} planned_tasks={}\n",
        r.stats.plans, r.stats.planned_tasks
    ));
    out
}

fn graph_json(g: &GraphDoneResp) -> Json {
    let mut o = BTreeMap::new();
    o.insert("mode".into(), Json::Str(g.mode.clone()));
    o.insert("makespan".into(), Json::Num(g.makespan));
    o.insert("wall".into(), Json::Num(g.wall));
    o.insert(
        "elided_transfers".into(),
        Json::Num(g.elided_transfers as f64),
    );
    let nodes = g
        .nodes
        .iter()
        .map(|nd| {
            let mut n = BTreeMap::new();
            n.insert("name".into(), Json::Str(nd.name.clone()));
            n.insert("variant".into(), Json::Str(nd.variant.clone()));
            n.insert("arch".into(), Json::Str(nd.arch.clone()));
            n.insert("planned".into(), Json::Bool(nd.planned));
            n.insert("est".into(), Json::Num(nd.est));
            n.insert("modeled".into(), Json::Num(nd.modeled));
            n.insert("wall".into(), Json::Num(nd.wall));
            n.insert("elided".into(), Json::Bool(nd.elided));
            Json::Obj(n)
        })
        .collect();
    o.insert("nodes".into(), Json::Arr(nodes));
    Json::Obj(o)
}

/// The BENCH record (`compar bench dag --out FILE`), kind "compar-dag":
/// all three phases' wire reports plus server plan counters.
pub fn to_json(r: &DagBenchRun) -> String {
    let mut m = BTreeMap::new();
    m.insert("bench".to_string(), Json::Str("compar-dag".into()));
    m.insert("schema".to_string(), Json::Num(BENCH_SCHEMA as f64));
    m.insert("status".to_string(), Json::Str("measured".into()));
    let mut knobs = BTreeMap::new();
    knobs.insert("nodes".into(), Json::Num(r.nodes as f64));
    knobs.insert("size".into(), Json::Num(NODE_SIZE as f64));
    knobs.insert("transport".into(), Json::Str(r.transport.name().into()));
    knobs.insert("framing".into(), Json::Str(r.framing.name().into()));
    m.insert("config".into(), Json::Obj(knobs));
    m.insert("planned".into(), graph_json(&r.planned));
    m.insert("greedy".into(), graph_json(&r.greedy));
    m.insert("contended".into(), graph_json(&r.contended));
    let mut srv = BTreeMap::new();
    srv.insert("plans".into(), Json::Num(r.stats.plans as f64));
    srv.insert(
        "planned_tasks".into(),
        Json::Num(r.stats.planned_tasks as f64),
    );
    srv.insert("requests_ok".into(), Json::Num(r.stats.requests_ok as f64));
    srv.insert(
        "requests_err".into(),
        Json::Num(r.stats.requests_err as f64),
    );
    m.insert("server".into(), Json::Obj(srv));
    json::to_string(&Json::Obj(m))
}
