//! Serving-path throughput/latency bench: boots an in-process
//! `compar serve` instance, drives it with the load generator, and
//! renders a report (requests/s + p50/p95/p99) — the measurement the
//! multi-tenant scaling story is tracked by (BENCH_serve.json).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::report::Table;
use crate::serve::loadgen::{self, LoadReport, LoadgenOptions};
use crate::serve::protocol::StatsResp;
use crate::serve::{ServeOptions, Server, TransportKind};
use crate::util::json::{self, Json};
use crate::util::stats::fmt_time;

/// Schema version of every bench JSON record (`BENCH_serve.json` and
/// the selection-regret and stream records). Bump on breaking shape
/// changes; the `compar bench validate` subcommand (and ci.sh) checks
/// it. v3: loadgen records grew stream counters (windows,
/// shed_windows, stream_credits) and the "compar-stream" kind landed.
/// v4: loadgen records carry the transport lane (config.transport,
/// config.framing) plus connection fan-out stats (load.connections,
/// load.connect_failures, load.connect_p50_s/p99_s), so threaded and
/// epoll measurements are never compared as if they were one lane.
/// v5: server counters gained the v9 monotonic totals
/// (tasks_completed, bytes_transferred, batches_fused, decisions) and
/// the "compar-obs" metrics-snapshot kind (`loadgen --metrics-out`)
/// landed.
pub const BENCH_SCHEMA: u64 = 5;

/// Write a bench record atomically (temp file + rename), so a reader —
/// or a crashed run — never observes a half-written record and the
/// `"pending"` placeholder is replaced in one step.
pub fn write_atomic(path: &str, contents: &str) -> Result<()> {
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, contents).with_context(|| format!("writing {tmp}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp} -> {path}"))?;
    Ok(())
}

/// Boot a server, run the load, drain, return both sides' numbers.
pub fn run_inprocess(
    serve: ServeOptions,
    load: &LoadgenOptions,
) -> Result<(LoadReport, StatsResp)> {
    let server = Server::start(serve)?;
    let addr = server.local_addr().to_string();
    let report = loadgen::run(&addr, load)?;
    let stats = server.shutdown()?;
    Ok((report, stats))
}

/// Render the combined report (loadgen render + a server-side table).
pub fn render(report: &LoadReport, stats: &StatsResp) -> String {
    let mut out = loadgen::render(report);
    let mut t = Table::new(
        "server-side counters",
        &["requests ok", "requests err", "tasks", "uptime"],
    );
    t.row(vec![
        stats.requests_ok.to_string(),
        stats.requests_err.to_string(),
        stats.tasks_executed.to_string(),
        fmt_time(stats.uptime),
    ]);
    out.push('\n');
    out.push_str(&t.render());
    if !stats.ctx_tasks.is_empty() {
        let cells: Vec<String> = stats
            .ctx_tasks
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        out.push_str(&format!("tasks per context: {}\n", cells.join("  ")));
    }
    for (ctx, hist) in &stats.ctx_variants {
        let cells: Vec<String> = hist.iter().map(|(k, v)| format!("{k}={v}")).collect();
        out.push_str(&format!("selection[{ctx}]: {}\n", cells.join("  ")));
    }
    out
}

/// The BENCH_serve.json record: loadgen numbers + server counters +
/// the knobs that produced them, so trajectories stay comparable.
pub fn to_json(
    report: &LoadReport,
    stats: &StatsResp,
    load: &LoadgenOptions,
    contexts: &str,
    transport: TransportKind,
) -> String {
    let mut m = BTreeMap::new();
    m.insert("bench".to_string(), Json::Str("compar-loadgen".into()));
    m.insert("schema".to_string(), Json::Num(BENCH_SCHEMA as f64));
    m.insert("status".to_string(), Json::Str("measured".into()));
    let mut knobs = BTreeMap::new();
    knobs.insert("app".into(), Json::Str(load.app.clone()));
    knobs.insert("size".into(), Json::Num(load.size as f64));
    knobs.insert("tasks".into(), Json::Num(load.tasks as f64));
    knobs.insert("pipeline".into(), Json::Num(load.pipeline.max(1) as f64));
    knobs.insert(
        "policy".into(),
        Json::Str(load.policy.clone().unwrap_or_else(|| "context".into())),
    );
    knobs.insert(
        "profile".into(),
        Json::Str(
            load.profile
                .map(|p| p.name())
                .unwrap_or_else(|| "closed-loop".into()),
        ),
    );
    knobs.insert("contexts".into(), Json::Str(contexts.to_string()));
    knobs.insert("transport".into(), Json::Str(transport.name().into()));
    knobs.insert("framing".into(), Json::Str(load.framing.name().into()));
    if load.connections > 0 {
        knobs.insert("connections".into(), Json::Num(load.connections as f64));
    }
    m.insert("config".into(), Json::Obj(knobs));
    m.insert("load".into(), loadgen::to_json(report));
    let mut srv = BTreeMap::new();
    srv.insert("requests_ok".into(), Json::Num(stats.requests_ok as f64));
    srv.insert("requests_err".into(), Json::Num(stats.requests_err as f64));
    srv.insert(
        "tasks_executed".into(),
        Json::Num(stats.tasks_executed as f64),
    );
    // v5: the monotonic totals (vs the point-in-time gauges above)
    srv.insert(
        "tasks_completed".into(),
        Json::Num(stats.tasks_completed as f64),
    );
    srv.insert(
        "bytes_transferred".into(),
        Json::Num(stats.bytes_transferred as f64),
    );
    srv.insert(
        "batches_fused".into(),
        Json::Num(stats.batches_fused as f64),
    );
    srv.insert("decisions".into(), Json::Num(stats.decisions as f64));
    let mut ctx_tasks = BTreeMap::new();
    for (k, v) in &stats.ctx_tasks {
        ctx_tasks.insert(k.clone(), Json::Num(*v as f64));
    }
    srv.insert("ctx_tasks".into(), Json::Obj(ctx_tasks));
    m.insert("server".into(), Json::Obj(srv));
    json::to_string(&Json::Obj(m))
}
