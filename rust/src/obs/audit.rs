//! The selection-decision audit log: a bounded ring of
//! [`DecisionRecord`]s, one per `SelectionPolicy::select` call, each
//! capturing the `SelectionQuery` snapshot the policy saw (size band,
//! load band, queue depth, residency penalty), the per-variant
//! candidate estimates, the chosen variant and a reason tag. The ring
//! answers the protocol-v9 `decisions` request; its totals feed
//! `stats` and the metrics scrape.
//!
//! The recording side sits on the selection hot path, so it must never
//! block it: `record` takes the ring lock with `try_lock` and counts a
//! *drop* instead of waiting when a reader holds it. Overflow evicts
//! the oldest record and counts an *eviction*; both counters are
//! exported as metrics so silent loss is visible to scrapers.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;

/// Canonical reason tags, in the order policies fall through them.
/// `reason_index` maps a tag to its slot in the per-reason counters;
/// unknown tags share the final overflow slot.
pub const REASON_NAMES: [&str; 7] = [
    "calibrating",
    "hint-prior",
    "explore",
    "exploit",
    "contextual-band",
    "planned-prefer",
    "forced",
];

pub fn reason_index(name: &str) -> usize {
    REASON_NAMES
        .iter()
        .position(|r| *r == name)
        .unwrap_or(REASON_NAMES.len())
}

/// One audited selection decision.
#[derive(Debug, Clone)]
pub struct DecisionRecord {
    /// Monotonic sequence number, assigned by the ring.
    pub seq: u64,
    /// Task id the decision was made for (0 for probe queries).
    pub task: u64,
    /// Trace id propagated from the request, 0 if untraced.
    pub trace: u64,
    pub codelet: String,
    /// Scheduling context the query ran under.
    pub ctx: u64,
    /// Operand size the policy bucketed.
    pub size: usize,
    pub size_band: u32,
    /// Snapshot load band (0 idle / 1 busy / 2 saturated).
    pub load_band: u8,
    /// Snapshot ready-queue depth for the querying context.
    pub queue_depth: usize,
    /// Target arch the query was scoped to.
    pub arch: String,
    /// Modeled residency/transfer penalty (seconds) the query priced.
    pub transfer_penalty_secs: f64,
    /// Per-variant candidate estimates at decision time
    /// (`None` = uncalibrated).
    pub candidates: Vec<(String, Option<f64>)>,
    /// Variant the policy chose.
    pub chosen: String,
    /// The chosen variant's estimate, if the policy had one.
    pub est: Option<f64>,
    /// Reason tag; one of [`REASON_NAMES`].
    pub reason: &'static str,
}

impl DecisionRecord {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("seq".into(), Json::Num(self.seq as f64));
        m.insert("task".into(), Json::Num(self.task as f64));
        m.insert("trace".into(), Json::Num(self.trace as f64));
        m.insert("codelet".into(), Json::Str(self.codelet.clone()));
        m.insert("ctx".into(), Json::Num(self.ctx as f64));
        m.insert("size".into(), Json::Num(self.size as f64));
        m.insert("size_band".into(), Json::Num(self.size_band as f64));
        m.insert("load_band".into(), Json::Num(self.load_band as f64));
        m.insert("queue_depth".into(), Json::Num(self.queue_depth as f64));
        m.insert("arch".into(), Json::Str(self.arch.clone()));
        m.insert(
            "transfer_penalty_secs".into(),
            Json::Num(self.transfer_penalty_secs),
        );
        m.insert(
            "candidates".into(),
            Json::Arr(
                self.candidates
                    .iter()
                    .map(|(name, est)| {
                        let mut c = std::collections::BTreeMap::new();
                        c.insert("variant".into(), Json::Str(name.clone()));
                        c.insert(
                            "est".into(),
                            est.map(Json::Num).unwrap_or(Json::Null),
                        );
                        Json::Obj(c)
                    })
                    .collect(),
            ),
        );
        m.insert("chosen".into(), Json::Str(self.chosen.clone()));
        m.insert("est".into(), self.est.map(Json::Num).unwrap_or(Json::Null));
        m.insert("reason".into(), Json::Str(self.reason.to_string()));
        Json::Obj(m)
    }
}

/// The bounded audit ring. Capacity is runtime-configurable
/// (`--audit-cap`); capacity 0 disables retention but keeps counting.
pub struct DecisionAudit {
    ring: Mutex<VecDeque<DecisionRecord>>,
    cap: AtomicUsize,
    next_seq: AtomicU64,
    recorded: AtomicU64,
    dropped: AtomicU64,
    evicted: AtomicU64,
    by_reason: [AtomicU64; REASON_NAMES.len() + 1],
}

pub const DEFAULT_AUDIT_CAP: usize = 512;

impl Default for DecisionAudit {
    fn default() -> Self {
        DecisionAudit::new(DEFAULT_AUDIT_CAP)
    }
}

impl DecisionAudit {
    pub fn new(cap: usize) -> DecisionAudit {
        DecisionAudit {
            ring: Mutex::new(VecDeque::new()),
            cap: AtomicUsize::new(cap),
            next_seq: AtomicU64::new(1),
            recorded: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            by_reason: Default::default(),
        }
    }

    pub fn set_capacity(&self, cap: usize) {
        self.cap.store(cap, Ordering::Relaxed);
        if let Ok(mut ring) = self.ring.try_lock() {
            while ring.len() > cap {
                ring.pop_front();
                self.evicted.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap.load(Ordering::Relaxed)
    }

    /// Record one decision. Never blocks: a contended ring counts a
    /// drop, a full ring evicts its oldest entry. Reason and total
    /// counters are bumped unconditionally so the metrics stay exact
    /// even when the record itself is shed.
    pub fn record(&self, mut rec: DecisionRecord) {
        self.recorded.fetch_add(1, Ordering::Relaxed);
        self.by_reason[reason_index(rec.reason)].fetch_add(1, Ordering::Relaxed);
        let cap = self.cap.load(Ordering::Relaxed);
        if cap == 0 {
            return;
        }
        match self.ring.try_lock() {
            Ok(mut ring) => {
                rec.seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
                ring.push_back(rec);
                while ring.len() > cap {
                    ring.pop_front();
                    self.evicted.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(_) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Newest-last slice of retained records, optionally filtered by
    /// codelet name, capped at `limit` (0 = no cap).
    pub fn recent(&self, limit: usize, codelet: &str) -> Vec<DecisionRecord> {
        let ring = self.ring.lock().unwrap();
        let filtered: Vec<DecisionRecord> = ring
            .iter()
            .filter(|r| codelet.is_empty() || r.codelet == codelet)
            .cloned()
            .collect();
        let skip = if limit > 0 && filtered.len() > limit {
            filtered.len() - limit
        } else {
            0
        };
        filtered.into_iter().skip(skip).collect()
    }

    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// Per-reason totals as `(tag, count)`, unknown-tag overflow last.
    pub fn reason_totals(&self) -> Vec<(&'static str, u64)> {
        let mut out: Vec<(&'static str, u64)> = REASON_NAMES
            .iter()
            .enumerate()
            .map(|(i, name)| (*name, self.by_reason[i].load(Ordering::Relaxed)))
            .collect();
        out.push((
            "other",
            self.by_reason[REASON_NAMES.len()].load(Ordering::Relaxed),
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn rec(codelet: &str, reason: &'static str) -> DecisionRecord {
        DecisionRecord {
            seq: 0,
            task: 1,
            trace: 42,
            codelet: codelet.to_string(),
            ctx: 0,
            size: 1024,
            size_band: 3,
            load_band: 1,
            queue_depth: 7,
            arch: "cuda".into(),
            transfer_penalty_secs: 1e-4,
            candidates: vec![("omp".into(), Some(2e-3)), ("cuda".into(), None)],
            chosen: "omp".into(),
            est: Some(2e-3),
            reason,
        }
    }

    #[test]
    fn overflow_evicts_oldest_and_counts() {
        let a = DecisionAudit::new(4);
        for i in 0..10 {
            a.record(rec(&format!("c{i}"), "exploit"));
        }
        assert_eq!(a.recorded(), 10);
        assert_eq!(a.evicted(), 6);
        let kept = a.recent(0, "");
        assert_eq!(kept.len(), 4);
        assert_eq!(kept[0].codelet, "c6", "oldest surviving record");
        assert_eq!(kept[3].codelet, "c9");
        // sequence numbers stay monotonic across eviction
        assert!(kept.windows(2).all(|w| w[0].seq < w[1].seq));
    }

    #[test]
    fn contended_ring_drops_instead_of_blocking() {
        let a = Arc::new(DecisionAudit::new(64));
        // Hold the ring lock from this thread, then record from
        // another: the recorder must return promptly with a drop.
        let guard = a.ring.lock().unwrap();
        let a2 = a.clone();
        let t = std::thread::spawn(move || {
            let t0 = std::time::Instant::now();
            for _ in 0..100 {
                a2.record(rec("sort", "exploit"));
            }
            t0.elapsed()
        });
        let took = t.join().unwrap();
        drop(guard);
        assert_eq!(a.dropped(), 100);
        assert_eq!(a.recorded(), 100, "totals still counted");
        assert!(
            took < std::time::Duration::from_millis(500),
            "recording under contention must not block ({took:?})"
        );
        assert!(a.recent(0, "").is_empty());
    }

    #[test]
    fn zero_capacity_disables_retention_not_counting() {
        let a = DecisionAudit::new(0);
        a.record(rec("sort", "forced"));
        assert_eq!(a.recorded(), 1);
        assert!(a.recent(0, "").is_empty());
        assert_eq!(a.evicted(), 0);
    }

    #[test]
    fn recent_filters_by_codelet_and_limits() {
        let a = DecisionAudit::new(32);
        for _ in 0..3 {
            a.record(rec("sort", "exploit"));
            a.record(rec("scale", "explore"));
        }
        assert_eq!(a.recent(0, "sort").len(), 3);
        assert_eq!(a.recent(2, "").len(), 2);
        let last = a.recent(1, "scale");
        assert_eq!(last.len(), 1);
        assert_eq!(last[0].codelet, "scale");
    }

    #[test]
    fn reason_totals_track_tags_and_overflow() {
        let a = DecisionAudit::new(8);
        a.record(rec("s", "exploit"));
        a.record(rec("s", "exploit"));
        a.record(rec("s", "calibrating"));
        a.record(rec("s", "mystery-tag"));
        let totals: std::collections::BTreeMap<_, _> =
            a.reason_totals().into_iter().collect();
        assert_eq!(totals["exploit"], 2);
        assert_eq!(totals["calibrating"], 1);
        assert_eq!(totals["other"], 1);
        assert_eq!(reason_index("contextual-band"), 4);
    }

    #[test]
    fn record_json_shape() {
        let r = rec("sort", "contextual-band");
        let j = r.to_json();
        assert_eq!(j.get("codelet").and_then(Json::as_str), Some("sort"));
        assert_eq!(j.get("load_band").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("reason").and_then(Json::as_str), Some("contextual-band"));
        let cands = j.get("candidates").and_then(Json::as_arr).unwrap();
        assert_eq!(cands.len(), 2);
        assert_eq!(cands[1].get("est"), Some(&Json::Null));
    }
}
