//! The metrics registry: named atomic counters, gauges, and
//! fixed-bucket histograms, cheap enough for every hot path in the
//! runtime to report through.
//!
//! Design constraints, in order:
//!
//! 1. **Recording never blocks recording.** Every instrument is a
//!    handful of relaxed atomics; the registry's maps are locked only
//!    on *registration* (get-or-create) and on *scrape*. Hot paths
//!    hold an `Arc` to their instrument and never touch the maps.
//! 2. **Counters are monotonic** by construction (`AtomicU64`
//!    increments); scrapers compute rates from two scrapes without
//!    races. Gauges are set-style (`AtomicI64`) point-in-time values.
//! 3. **Histograms are fixed-bucket**: observation is one bucket index
//!    scan over a short bounds slice plus three relaxed adds. The sum
//!    is kept in fixed-point nanounits so it can live in an atomic —
//!    `sum()`/`count()` always agree with the bucket counts, which is
//!    the consistency property ci.sh's selfcheck asserts.
//!
//! The scrape side renders the whole registry as a JSON object (the
//! protocol-v9 `metrics` response) and, via [`prometheus_from_json`],
//! as Prometheus-style text exposition. The router aggregates shard
//! scrapes by key prefix (`shard0/...`), which the text renderer turns
//! into a `shard` label — so the same renderer serves both a single
//! shard and a whole cluster.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Default latency buckets (seconds): spans sub-10µs selection calls
/// up to multi-second end-to-end tails. The final overflow bucket is
/// implicit (`counts` has one more slot than `le`).
pub const LATENCY_BUCKETS: [f64; 10] = [
    1e-5, 5e-5, 1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2, 0.1, 1.0,
];

/// A fixed-bucket histogram. `counts[i]` counts observations `<=
/// bounds[i]`; the last slot counts overflow. The running sum is held
/// in nanounits (`1e-9` resolution) so it fits an atomic and stays
/// exactly consistent with `count` under concurrency.
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<AtomicU64>,
    sum_nanos: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Histogram {
        let bounds: Vec<f64> = bounds.to_vec();
        let counts = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            bounds,
            counts,
            sum_nanos: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Record one observation. Negative / non-finite values clamp to
    /// zero rather than poisoning the sum.
    pub fn observe(&self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        let idx = self
            .bounds
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(self.bounds.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_nanos
            .fetch_add((v * 1e9).round() as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        self.sum_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// (bounds, per-bucket counts incl. overflow, sum, count).
    pub fn snapshot(&self) -> (Vec<f64>, Vec<u64>, f64, u64) {
        (
            self.bounds.clone(),
            self.counts
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
            self.sum(),
            self.count(),
        )
    }

    fn to_json(&self) -> Json {
        let (bounds, counts, sum, count) = self.snapshot();
        let mut m = BTreeMap::new();
        m.insert(
            "le".into(),
            Json::Arr(bounds.into_iter().map(Json::Num).collect()),
        );
        m.insert(
            "counts".into(),
            Json::Arr(counts.into_iter().map(|c| Json::Num(c as f64)).collect()),
        );
        m.insert("sum".into(), Json::Num(sum));
        m.insert("count".into(), Json::Num(count as f64));
        Json::Obj(m)
    }
}

/// The registry: three get-or-create instrument maps. Instruments are
/// `Arc`-shared, so registration cost is paid once and recording never
/// sees these mutexes.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicI64>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-create a monotonic counter.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Get-or-create a set-style gauge.
    pub fn gauge(&self, name: &str) -> Arc<AtomicI64> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Get-or-create a histogram with the default latency buckets.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.histogram_with(name, &LATENCY_BUCKETS)
    }

    /// Get-or-create a histogram with explicit bucket bounds (e.g.
    /// batch sizes). An existing instrument keeps its original bounds.
    pub fn histogram_with(&self, name: &str, bounds: &[f64]) -> Arc<Histogram> {
        self.hists
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new(bounds)))
            .clone()
    }

    /// Scrape: `{"counters":{..},"gauges":{..},"histograms":{..}}`.
    pub fn to_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            counters.insert(k.clone(), Json::Num(v.load(Ordering::Relaxed) as f64));
        }
        let mut gauges = BTreeMap::new();
        for (k, v) in self.gauges.lock().unwrap().iter() {
            gauges.insert(k.clone(), Json::Num(v.load(Ordering::Relaxed) as f64));
        }
        let mut hists = BTreeMap::new();
        for (k, v) in self.hists.lock().unwrap().iter() {
            hists.insert(k.clone(), v.to_json());
        }
        let mut m = BTreeMap::new();
        m.insert("counters".into(), Json::Obj(counters));
        m.insert("gauges".into(), Json::Obj(gauges));
        m.insert("histograms".into(), Json::Obj(hists));
        Json::Obj(m)
    }
}

/// Split an aggregated key: a `shard0/name` prefix (added by the
/// router) becomes a `shard` label on the bare metric name.
fn split_key(key: &str) -> (String, Option<String>) {
    match key.split_once('/') {
        Some((prefix, name)) => (sanitize(name), Some(prefix.to_string())),
        None => (sanitize(key), None),
    }
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

fn label_str(shard: &Option<String>, extra: Option<(&str, String)>) -> String {
    let mut parts = Vec::new();
    if let Some(s) = shard {
        parts.push(format!("shard=\"{s}\""));
    }
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

fn fmt_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Render a metrics JSON scrape (one shard's, or the router's
/// prefix-aggregated cluster view) as Prometheus-style text
/// exposition. Keys carrying a `prefix/` become `shard` labels, so
/// the same metric from N shards groups under one name.
pub fn prometheus_from_json(v: &Json) -> String {
    let mut out = String::new();
    // name -> [(labels, rendered value lines)]
    let mut counters: BTreeMap<String, Vec<(Option<String>, f64)>> = BTreeMap::new();
    let mut gauges: BTreeMap<String, Vec<(Option<String>, f64)>> = BTreeMap::new();
    for (section, dst) in [("counters", &mut counters), ("gauges", &mut gauges)] {
        if let Some(obj) = v.get(section).and_then(Json::as_obj) {
            for (k, val) in obj {
                let (name, shard) = split_key(k);
                dst.entry(name)
                    .or_default()
                    .push((shard, val.as_f64().unwrap_or(0.0)));
            }
        }
    }
    for (kind, map) in [("counter", &counters), ("gauge", &gauges)] {
        for (name, series) in map {
            out.push_str(&format!("# TYPE {name} {kind}\n"));
            for (shard, val) in series {
                out.push_str(&format!(
                    "{name}{} {}\n",
                    label_str(shard, None),
                    fmt_num(*val)
                ));
            }
        }
    }
    // histograms: cumulative buckets + _sum + _count per series
    let mut hists: BTreeMap<String, Vec<(Option<String>, &Json)>> = BTreeMap::new();
    if let Some(obj) = v.get("histograms").and_then(Json::as_obj) {
        for (k, val) in obj {
            let (name, shard) = split_key(k);
            hists.entry(name).or_default().push((shard, val));
        }
    }
    for (name, series) in &hists {
        out.push_str(&format!("# TYPE {name} histogram\n"));
        for (shard, h) in series {
            let le: Vec<f64> = h
                .get("le")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default();
            let counts: Vec<f64> = h
                .get("counts")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default();
            let mut cum = 0.0;
            for (i, c) in counts.iter().enumerate() {
                cum += c;
                let bound = le
                    .get(i)
                    .map(|b| format!("{b}"))
                    .unwrap_or_else(|| "+Inf".into());
                out.push_str(&format!(
                    "{name}_bucket{} {}\n",
                    label_str(shard, Some(("le", bound))),
                    fmt_num(cum)
                ));
            }
            let sum = h.get("sum").and_then(Json::as_f64).unwrap_or(0.0);
            let count = h.get("count").and_then(Json::as_f64).unwrap_or(0.0);
            out.push_str(&format!(
                "{name}_sum{} {sum}\n",
                label_str(shard, None)
            ));
            out.push_str(&format!(
                "{name}_count{} {}\n",
                label_str(shard, None),
                fmt_num(count)
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_shared_and_monotonic() {
        let r = Registry::new();
        let a = r.counter("x_total");
        let b = r.counter("x_total");
        a.fetch_add(2, Ordering::Relaxed);
        b.fetch_add(3, Ordering::Relaxed);
        assert_eq!(r.counter("x_total").load(Ordering::Relaxed), 5);
    }

    #[test]
    fn histogram_sum_and_count_match_buckets() {
        let r = Registry::new();
        let h = r.histogram_with("lat", &[0.001, 0.01, 0.1]);
        for v in [0.0005, 0.005, 0.05, 0.5, 5.0] {
            h.observe(v);
        }
        let (bounds, counts, sum, count) = h.snapshot();
        assert_eq!(bounds.len() + 1, counts.len());
        assert_eq!(counts, vec![1, 1, 1, 2]);
        assert_eq!(count, 5);
        assert_eq!(counts.iter().sum::<u64>(), count);
        assert!((sum - 5.5555).abs() < 1e-6, "sum {sum}");
    }

    #[test]
    fn histogram_clamps_junk_observations() {
        let h = Histogram::new(&[1.0]);
        h.observe(-3.0);
        h.observe(f64::NAN);
        h.observe(f64::INFINITY);
        assert_eq!(h.count(), 3);
        assert_eq!(h.sum(), 0.0);
        let (_, counts, _, _) = h.snapshot();
        assert_eq!(counts, vec![3, 0], "all clamp into the first bucket");
    }

    #[test]
    fn json_scrape_has_all_sections() {
        let r = Registry::new();
        r.counter("a_total").fetch_add(7, Ordering::Relaxed);
        r.gauge("g").store(-2, Ordering::Relaxed);
        r.histogram_with("h", &[1.0]).observe(0.5);
        let j = r.to_json();
        assert_eq!(j.get("counters").unwrap().get("a_total").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("gauges").unwrap().get("g").unwrap().as_f64(), Some(-2.0));
        let h = j.get("histograms").unwrap().get("h").unwrap();
        assert_eq!(h.get("count").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn prometheus_rendering_groups_shard_prefixes_as_labels() {
        let r = Registry::new();
        r.counter("req_total").fetch_add(4, Ordering::Relaxed);
        let mut j = r.to_json();
        // simulate the router's aggregation: prefix a second series
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(c)) = m.get_mut("counters") {
                c.insert("shard1/req_total".into(), Json::Num(9.0));
            }
        }
        let text = prometheus_from_json(&j);
        assert!(text.contains("# TYPE req_total counter\n"), "{text}");
        assert!(text.contains("req_total 4\n"), "{text}");
        assert!(text.contains("req_total{shard=\"shard1\"} 9\n"), "{text}");
        // one TYPE line for the grouped name
        assert_eq!(text.matches("# TYPE req_total").count(), 1);
    }

    #[test]
    fn prometheus_histogram_buckets_are_cumulative_with_inf() {
        let r = Registry::new();
        let h = r.histogram_with("lat_seconds", &[0.01, 0.1]);
        h.observe(0.005);
        h.observe(0.05);
        h.observe(7.0);
        let text = prometheus_from_json(&r.to_json());
        assert!(text.contains("lat_seconds_bucket{le=\"0.01\"} 1\n"), "{text}");
        assert!(text.contains("lat_seconds_bucket{le=\"0.1\"} 2\n"), "{text}");
        assert!(text.contains("lat_seconds_bucket{le=\"+Inf\"} 3\n"), "{text}");
        assert!(text.contains("lat_seconds_count 3\n"), "{text}");
    }
}
