//! Live observability plane: metrics registry, cross-layer request
//! tracing, and the selection-decision audit log.
//!
//! Everything the running system wants to prove about itself flows
//! through one [`Obs`] handle, owned by the `taskrt::Runtime` and
//! shared (via `Arc`) with the serve/stream/plan/cluster layers:
//!
//! - **Metrics** ([`registry`]): lock-cheap counters, gauges, and
//!   fixed-bucket latency histograms. Hot paths record through cached
//!   `Arc` handles ([`Obs::select_seconds`] and friends); scrapers get
//!   JSON or Prometheus-style text via the protocol-v9 `metrics`
//!   request, and the router aggregates shard scrapes under
//!   `shard{i}/` key prefixes that render as `shard` labels.
//! - **Tracing** ([`trace_ring`]): a trace id is minted per request
//!   (`submit` / `stream_open` / `submit_graph`), rides `TaskSpec` →
//!   `ReadyTask` → `TaskResult`, and every layer pushes completed
//!   spans (admission, batch fuse, task execution, router hop) into a
//!   bounded live ring served by `dump_trace` as Chrome Trace Event
//!   Format.
//! - **Decision audit** ([`audit`]): every `SelectionPolicy::select`
//!   records the query snapshot, candidate estimates, chosen variant
//!   and reason tag into a bounded ring served by `decisions`.
//!
//! All three recording paths are non-blocking by design: rings use
//! `try_lock` + drop counters, instruments are relaxed atomics. The
//! plane observes the hot path; it never becomes part of it.

pub mod audit;
pub mod registry;
pub mod trace_ring;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::util::json::Json;

pub use audit::{reason_index, DecisionAudit, DecisionRecord, DEFAULT_AUDIT_CAP, REASON_NAMES};
pub use registry::{prometheus_from_json, Histogram, Registry, LATENCY_BUCKETS};
pub use trace_ring::{SpanEvent, TraceRing, DEFAULT_TRACE_CAP};

/// The shared observability handle: one per `Runtime`, cloned into
/// every layer that reports.
pub struct Obs {
    /// Common time base: span timestamps and queue-wait stamps are
    /// seconds/nanos since this instant. The runtime copies it so the
    /// chrome exporter and the live ring agree on the timeline.
    epoch: Instant,
    pub registry: Registry,
    pub audit: DecisionAudit,
    pub trace: TraceRing,
    // Cached hot-path instruments (registered once, recorded lock-free).
    select_seconds: Arc<Histogram>,
    queue_wait_seconds: Arc<Histogram>,
    exec_seconds: Arc<Histogram>,
    transfer_seconds: Arc<Histogram>,
    e2e_seconds: Arc<Histogram>,
    decisions_total: Arc<AtomicU64>,
}

impl Default for Obs {
    fn default() -> Self {
        Obs::new()
    }
}

impl Obs {
    pub fn new() -> Obs {
        let registry = Registry::new();
        let select_seconds = registry.histogram("taskrt_select_seconds");
        let queue_wait_seconds = registry.histogram("taskrt_queue_wait_seconds");
        let exec_seconds = registry.histogram("taskrt_exec_seconds");
        let transfer_seconds = registry.histogram("taskrt_transfer_seconds");
        let e2e_seconds = registry.histogram("serve_e2e_seconds");
        let decisions_total = registry.counter("select_decisions_total");
        Obs {
            epoch: Instant::now(),
            registry,
            audit: DecisionAudit::default(),
            trace: TraceRing::default(),
            select_seconds,
            queue_wait_seconds,
            exec_seconds,
            transfer_seconds,
            e2e_seconds,
            decisions_total,
        }
    }

    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Seconds since the epoch — the span time base.
    pub fn now_secs(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Nanoseconds since the epoch — the queue-wait stamp base.
    pub fn now_nanos(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Policy-consult duration (`SelectionPolicy::select` call).
    pub fn select_seconds(&self) -> &Histogram {
        &self.select_seconds
    }

    /// Ready-queue wait: task enqueue → worker pop.
    pub fn queue_wait_seconds(&self) -> &Histogram {
        &self.queue_wait_seconds
    }

    /// Task execution wall time.
    pub fn exec_seconds(&self) -> &Histogram {
        &self.exec_seconds
    }

    /// Modeled operand-transfer time per task.
    pub fn transfer_seconds(&self) -> &Histogram {
        &self.transfer_seconds
    }

    /// Serve end-to-end latency: admission → reply. Its `count`
    /// reconciles with loadgen's successful-request count.
    pub fn e2e_seconds(&self) -> &Histogram {
        &self.e2e_seconds
    }

    /// Record one audited selection decision (ring + totals).
    pub fn record_decision(&self, rec: DecisionRecord) {
        self.decisions_total.fetch_add(1, Ordering::Relaxed);
        self.audit.record(rec);
    }

    /// Total decisions observed (survives ring eviction).
    pub fn decisions(&self) -> u64 {
        self.decisions_total.load(Ordering::Relaxed)
    }

    /// Full metrics scrape: the registry's sections plus the audit and
    /// trace rings' synthetic counters (per-reason decision totals,
    /// drop/evict visibility for both rings).
    pub fn metrics_json(&self) -> Json {
        let mut j = self.registry.to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(counters)) = m.get_mut("counters") {
                for (reason, n) in self.audit.reason_totals() {
                    counters.insert(
                        format!("select_reason_{}_total", reason.replace('-', "_")),
                        Json::Num(n as f64),
                    );
                }
                counters.insert(
                    "audit_dropped_total".into(),
                    Json::Num(self.audit.dropped() as f64),
                );
                counters.insert(
                    "audit_evicted_total".into(),
                    Json::Num(self.audit.evicted() as f64),
                );
                counters.insert(
                    "trace_spans_total".into(),
                    Json::Num(self.trace.recorded() as f64),
                );
                counters.insert(
                    "trace_dropped_total".into(),
                    Json::Num(self.trace.dropped() as f64),
                );
                counters.insert(
                    "trace_evicted_total".into(),
                    Json::Num(self.trace.evicted() as f64),
                );
            }
        }
        j
    }

    /// Prometheus-style text exposition of [`Obs::metrics_json`].
    pub fn render_prometheus(&self) -> String {
        prometheus_from_json(&self.metrics_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cached_instruments_appear_in_scrape() {
        let obs = Obs::new();
        obs.e2e_seconds().observe(0.002);
        obs.select_seconds().observe(1e-5);
        let j = obs.metrics_json();
        let hists = j.get("histograms").unwrap();
        assert_eq!(
            hists.get("serve_e2e_seconds").unwrap().get("count").and_then(Json::as_f64),
            Some(1.0)
        );
        assert_eq!(
            hists
                .get("taskrt_select_seconds")
                .unwrap()
                .get("count")
                .and_then(Json::as_f64),
            Some(1.0)
        );
    }

    #[test]
    fn decision_recording_feeds_counters_and_ring() {
        let obs = Obs::new();
        obs.record_decision(DecisionRecord {
            seq: 0,
            task: 1,
            trace: 9,
            codelet: "sort".into(),
            ctx: 0,
            size: 64,
            size_band: 2,
            load_band: 0,
            queue_depth: 0,
            arch: "cpu".into(),
            transfer_penalty_secs: 0.0,
            candidates: vec![("omp".into(), Some(1e-3))],
            chosen: "omp".into(),
            est: Some(1e-3),
            reason: "hint-prior",
        });
        assert_eq!(obs.decisions(), 1);
        assert_eq!(obs.audit.recent(0, "sort").len(), 1);
        let j = obs.metrics_json();
        let counters = j.get("counters").unwrap();
        assert_eq!(
            counters.get("select_decisions_total").and_then(Json::as_f64),
            Some(1.0)
        );
        assert_eq!(
            counters
                .get("select_reason_hint_prior_total")
                .and_then(Json::as_f64),
            Some(1.0)
        );
    }

    #[test]
    fn prometheus_render_covers_merged_counters() {
        let obs = Obs::new();
        obs.registry
            .counter("serve_requests_total")
            .fetch_add(3, Ordering::Relaxed);
        let text = obs.render_prometheus();
        assert!(text.contains("serve_requests_total 3\n"), "{text}");
        assert!(text.contains("# TYPE taskrt_select_seconds histogram"), "{text}");
        assert!(text.contains("audit_evicted_total 0\n"), "{text}");
    }

    #[test]
    fn epoch_time_bases_are_monotone() {
        let obs = Obs::new();
        let a = obs.now_nanos();
        let b = obs.now_nanos();
        assert!(b >= a);
        assert!(obs.now_secs() >= 0.0);
    }
}
