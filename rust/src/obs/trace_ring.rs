//! The live trace ring: a bounded buffer of completed spans that a
//! running server can flush as Chrome Trace Event Format JSON via the
//! protocol-v9 `dump_trace` admin request — the live counterpart to
//! `taskrt::trace::chrome_trace`, which only works post-hoc on a
//! finished batch run.
//!
//! Spans carry the cross-layer trace id (minted at `submit` /
//! `stream_open` / `submit_graph`, propagated through `TaskSpec` →
//! `TaskResult`), so one request's admission span, batch window and
//! per-stage task spans all correlate in the exported timeline. Like
//! the decision audit, pushing a span never blocks the producer: the
//! ring is `try_lock`-guarded with drop/evict counters exported as
//! metrics.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;

/// One completed span, times in seconds since the owning [`super::Obs`]
/// epoch. `lane` is the chrome-trace tid (worker id, session id, …);
/// `lane_name` labels it once in the export's thread metadata.
#[derive(Debug, Clone)]
pub struct SpanEvent {
    pub name: String,
    /// Category: "task", "serve", "route", …
    pub cat: &'static str,
    pub lane: u64,
    pub lane_name: String,
    /// Cross-layer trace id; 0 = untraced.
    pub trace: u64,
    pub t_start: f64,
    pub t_end: f64,
}

pub const DEFAULT_TRACE_CAP: usize = 4096;

/// Bounded span ring with non-blocking push.
pub struct TraceRing {
    ring: Mutex<VecDeque<SpanEvent>>,
    cap: AtomicUsize,
    recorded: AtomicU64,
    dropped: AtomicU64,
    evicted: AtomicU64,
}

impl Default for TraceRing {
    fn default() -> Self {
        TraceRing::new(DEFAULT_TRACE_CAP)
    }
}

impl TraceRing {
    pub fn new(cap: usize) -> TraceRing {
        TraceRing {
            ring: Mutex::new(VecDeque::new()),
            cap: AtomicUsize::new(cap),
            recorded: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
        }
    }

    pub fn set_capacity(&self, cap: usize) {
        self.cap.store(cap, Ordering::Relaxed);
        if let Ok(mut ring) = self.ring.try_lock() {
            while ring.len() > cap {
                ring.pop_front();
                self.evicted.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Push one completed span; never blocks (contention counts a
    /// drop, overflow evicts the oldest span).
    pub fn push(&self, ev: SpanEvent) {
        self.recorded.fetch_add(1, Ordering::Relaxed);
        let cap = self.cap.load(Ordering::Relaxed);
        if cap == 0 {
            return;
        }
        match self.ring.try_lock() {
            Ok(mut ring) => {
                ring.push_back(ev);
                while ring.len() > cap {
                    ring.pop_front();
                    self.evicted.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(_) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// Export the retained spans as Chrome Trace Event Format:
    /// `{"traceEvents":[…]}` with one `M` thread-name metadata event
    /// per lane and one `X` complete event per span (µs timestamps,
    /// trace id in `args.trace`). Load the output in
    /// `chrome://tracing` or Perfetto.
    pub fn chrome_json(&self, pid: u64) -> Json {
        let ring = self.ring.lock().unwrap();
        let mut events = Vec::new();
        let mut lanes: BTreeSet<(u64, String)> = BTreeSet::new();
        for ev in ring.iter() {
            lanes.insert((ev.lane, ev.lane_name.clone()));
        }
        for (lane, name) in &lanes {
            let mut args = BTreeMap::new();
            args.insert("name".into(), Json::Str(name.clone()));
            let mut m = BTreeMap::new();
            m.insert("ph".into(), Json::Str("M".into()));
            m.insert("name".into(), Json::Str("thread_name".into()));
            m.insert("pid".into(), Json::Num(pid as f64));
            m.insert("tid".into(), Json::Num(*lane as f64));
            m.insert("args".into(), Json::Obj(args));
            events.push(Json::Obj(m));
        }
        for ev in ring.iter() {
            let mut args = BTreeMap::new();
            args.insert("trace".into(), Json::Num(ev.trace as f64));
            let mut m = BTreeMap::new();
            m.insert("ph".into(), Json::Str("X".into()));
            m.insert("name".into(), Json::Str(ev.name.clone()));
            m.insert("cat".into(), Json::Str(ev.cat.to_string()));
            m.insert("pid".into(), Json::Num(pid as f64));
            m.insert("tid".into(), Json::Num(ev.lane as f64));
            m.insert("ts".into(), Json::Num(ev.t_start * 1e6));
            m.insert(
                "dur".into(),
                Json::Num(((ev.t_end - ev.t_start).max(0.0)) * 1e6),
            );
            m.insert("args".into(), Json::Obj(args));
            events.push(Json::Obj(m));
        }
        let mut m = BTreeMap::new();
        m.insert("traceEvents".into(), Json::Arr(events));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(name: &str, lane: u64, trace: u64, t0: f64, t1: f64) -> SpanEvent {
        SpanEvent {
            name: name.to_string(),
            cat: "task",
            lane,
            lane_name: format!("worker{lane}"),
            trace,
            t_start: t0,
            t_end: t1,
        }
    }

    #[test]
    fn ring_bounds_and_evicts() {
        let r = TraceRing::new(3);
        for i in 0..5 {
            r.push(span(&format!("s{i}"), 0, i, i as f64, i as f64 + 0.5));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.recorded(), 5);
        assert_eq!(r.evicted(), 2);
    }

    #[test]
    fn chrome_export_has_metadata_and_complete_events() {
        let r = TraceRing::new(16);
        r.push(span("sort", 2, 77, 0.001, 0.003));
        r.push(span("admission", 1_000_003, 77, 0.0005, 0.001));
        let j = r.chrome_json(0);
        let events = j.get("traceEvents").and_then(Json::as_arr).unwrap();
        // 2 lanes of metadata + 2 spans
        assert_eq!(events.len(), 4);
        let metas: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .collect();
        assert_eq!(metas.len(), 2);
        let xs: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .collect();
        assert_eq!(xs.len(), 2);
        let sort = xs
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("sort"))
            .unwrap();
        assert_eq!(sort.get("ts").and_then(Json::as_f64), Some(1000.0));
        assert_eq!(sort.get("dur").and_then(Json::as_f64), Some(2000.0));
        assert_eq!(
            sort.get("args").unwrap().get("trace").and_then(Json::as_f64),
            Some(77.0)
        );
    }

    #[test]
    fn capacity_shrink_trims_existing() {
        let r = TraceRing::new(10);
        for i in 0..10 {
            r.push(span("s", 0, i, 0.0, 1.0));
        }
        r.set_capacity(4);
        assert_eq!(r.len(), 4);
        assert_eq!(r.evicted(), 6);
    }
}
