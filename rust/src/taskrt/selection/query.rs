//! The first-class selection query: everything a [`SelectionPolicy`]
//! may condition a decision on, bundled into one value object.
//!
//! Before this type existed, `select(task, arch, ctx)` could only key
//! decisions on (codelet, size, arch) — the runtime state a
//! context-aware policy needs (queue depths, worker occupancy, operand
//! residency, co-tenancy) was either buried in the scheduler or not
//! observable at all. Kessler & Dastgeer's *Optimized Composition*
//! dispatch tables condition on call context (operand locality, problem
//! shape), and HSTREAM splits work by device load; both require the
//! selection *API*, not just the policies, to carry runtime state.
//!
//! A [`SelectionQuery`] is built per decision by
//! [`SchedCtx::query`](crate::taskrt::scheduler::SchedCtx::query). The
//! cheap scalar features (atomic counter reads) are captured eagerly
//! into a [`RuntimeSnapshot`]; the data-residency features walk the
//! data registry and are computed on demand
//! ([`SelectionQuery::pending_transfer_bytes`]), so policies that never
//! look at operand locality never pay for it.
//!
//! [`SelectionPolicy`]: super::SelectionPolicy

use std::sync::atomic::Ordering;

use crate::taskrt::device::{transfer_model, Arch};
use crate::taskrt::scheduler::{ReadyTask, SchedCtx};

/// One member worker's occupancy as seen by the counter audit:
/// `(worker id, architecture, in-flight count)`.
pub type WorkerOccupancy = (usize, Arch, usize);

/// The counter-audit invariants over one context's membership, as a
/// `Result` so the pure model, the runtime's audited snapshots and the
/// hot-path capture all share one source of truth:
///
/// - each member worker executes at most one task at a time (the Busy
///   guard / worker-migration accounting must never leak an increment);
/// - per architecture, in-flight tasks never exceed that architecture's
///   member count (each member contributes at most one in-flight task).
pub fn validate_occupancy(members: &[WorkerOccupancy]) -> Result<(), String> {
    let mut errors: Vec<String> = Vec::new();
    let mut per_arch: Vec<(Arch, usize, usize)> = Vec::new();
    for &(w, arch, running) in members {
        if running > 1 {
            errors.push(format!(
                "worker {w} in-flight count {running} > 1 (occupancy leak)"
            ));
        }
        match per_arch.iter_mut().find(|(a, _, _)| *a == arch) {
            Some(entry) => {
                entry.1 += 1;
                entry.2 += running;
            }
            None => per_arch.push((arch, 1, running)),
        }
    }
    for (arch, arch_workers, arch_inflight) in per_arch {
        if arch_inflight > arch_workers {
            errors.push(format!(
                "{arch_inflight} in-flight tasks on {arch_workers} {} member worker(s)",
                arch.name()
            ));
        }
    }
    if errors.is_empty() {
        Ok(())
    } else {
        Err(errors.join("; "))
    }
}

/// A cheap point-in-time view of the runtime state relevant to one
/// (task, arch) selection decision. Captured from atomic counters only
/// — building one costs a handful of relaxed loads, so it sits on the
/// per-decision hot path (including work-stealing eligibility scans).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RuntimeSnapshot {
    /// Tasks pushed to the submitting context's scheduler and not yet
    /// popped by a worker (context-wide queue depth).
    pub queue_depth: usize,
    /// Member workers of the queried architecture in this context.
    pub arch_workers: usize,
    /// Tasks currently *executing* on members of the queried
    /// architecture (in-flight count; schedulers' deque models do not
    /// see these, only policies do).
    pub arch_inflight: usize,
    /// Member workers of this context currently executing a task
    /// (occupancy, across all architectures).
    pub busy_workers: usize,
    /// Total member workers in this context's partition.
    pub partition_workers: usize,
    /// Modeled seconds of work already queued on the *least-loaded*
    /// member of the queried architecture (the dmda deque model, seen
    /// from the policy's side).
    pub queued_secs: f64,
    /// Serve-layer sessions currently sharing the runtime (co-tenant
    /// count; 0 outside `compar serve`).
    pub tenants: usize,
}

impl RuntimeSnapshot {
    /// Coarse load band for bucketing performance observations:
    /// 0 = idle (nothing queued or in flight on this arch),
    /// 1 = busy (backlog up to one task per member worker),
    /// 2 = contended (backlog beyond the partition's parallelism).
    pub fn load_band(&self) -> u8 {
        let pressure = self.queue_depth + self.arch_inflight;
        if pressure == 0 {
            0
        } else if pressure <= self.arch_workers.max(1) {
            1
        } else {
            2
        }
    }

    /// Nothing queued or running on the queried architecture.
    pub fn is_idle(&self) -> bool {
        self.load_band() == 0
    }
}

/// One variant-selection question: "which implementation of
/// [`SelectionQuery::task`]'s codelet should run on
/// [`SelectionQuery::arch`], given the runtime state in
/// [`SelectionQuery::snapshot`]?" — the sole argument of
/// [`SelectionPolicy::select`](super::SelectionPolicy::select) and
/// [`SelectionPolicy::feedback`](super::SelectionPolicy::feedback).
pub struct SelectionQuery<'a> {
    pub task: &'a ReadyTask,
    pub arch: Arch,
    pub ctx: &'a SchedCtx,
    pub snapshot: RuntimeSnapshot,
}

impl<'a> SelectionQuery<'a> {
    /// Build a query, capturing the runtime snapshot from the context's
    /// counters (relaxed atomic loads only).
    pub fn capture(task: &'a ReadyTask, arch: Arch, ctx: &'a SchedCtx) -> SelectionQuery<'a> {
        let mut arch_workers = 0usize;
        let mut arch_inflight = 0usize;
        let mut busy_workers = 0usize;
        let mut queued: Option<f64> = None;
        // counter audit (debug builds only): the Busy guard and worker
        // migration must keep each member's in-flight count ≤ 1 and each
        // arch's in-flight total ≤ its member count; the same
        // validate_occupancy is the model's invariant source of truth
        let mut audit: Vec<WorkerOccupancy> = Vec::new();
        let members = ctx.members_read();
        for &w in members.iter() {
            let running = ctx.running[w].load(Ordering::Relaxed);
            if cfg!(debug_assertions) {
                audit.push((w, ctx.workers[w].arch, running));
            }
            busy_workers += running.min(1);
            if ctx.workers[w].arch == arch {
                arch_workers += 1;
                arch_inflight += running;
                let backlog = ctx.queued_secs(w);
                queued = Some(match queued {
                    Some(v) if v <= backlog => v,
                    _ => backlog,
                });
            }
        }
        if cfg!(debug_assertions) {
            if let Err(msg) = validate_occupancy(&audit) {
                panic!("{msg}");
            }
        }
        let partition_workers = members.len();
        drop(members);
        let snapshot = RuntimeSnapshot {
            // clamped: the pop/push accounting may transiently be -1
            queue_depth: ctx.pending.load(Ordering::Relaxed).max(0) as usize,
            arch_workers,
            arch_inflight,
            busy_workers,
            partition_workers,
            queued_secs: queued.unwrap_or(0.0),
            tenants: ctx.tenants.load(Ordering::Relaxed),
        };
        SelectionQuery {
            task,
            arch,
            ctx,
            snapshot,
        }
    }

    /// Build a query with an explicit snapshot (tests and simulations).
    pub fn with_snapshot(
        task: &'a ReadyTask,
        arch: Arch,
        ctx: &'a SchedCtx,
        snapshot: RuntimeSnapshot,
    ) -> SelectionQuery<'a> {
        SelectionQuery {
            task,
            arch,
            ctx,
            snapshot,
        }
    }

    pub fn codelet_name(&self) -> &str {
        &self.task.codelet.name
    }

    pub fn size(&self) -> usize {
        self.task.size
    }

    /// Variant name of implementation `idx`.
    pub fn variant_name(&self, idx: usize) -> &str {
        &self.task.codelet.impls[idx].name
    }

    /// Indices of implementations executable on this query's arch right
    /// now (arch match + artifact availability).
    pub fn eligible(&self) -> Vec<usize> {
        self.ctx.eligible_impls(self.task, self.arch)
    }

    /// Perf-model estimate for implementation `idx`; `None` =
    /// uncalibrated.
    pub fn exec_estimate(&self, idx: usize) -> Option<f64> {
        self.ctx.exec_estimate(self.task, idx)
    }

    /// Exponentially-decayed estimate for implementation `idx` (what
    /// drift-tracking policies exploit).
    pub fn recent_estimate(&self, idx: usize) -> Option<f64> {
        self.ctx.recent_estimate(self.task, idx)
    }

    /// Measured-execution observations for implementation `idx`.
    pub fn samples(&self, idx: usize) -> usize {
        self.ctx
            .perf
            .samples(&self.task.codelet.name, &self.task.codelet.impls[idx].name)
    }

    /// Bytes of the task's handles *not* yet resident on the queried
    /// architecture's best member node — what a placement there would
    /// have to move. Walks the data registry, so it is computed on
    /// demand rather than captured in the snapshot.
    pub fn pending_transfer_bytes(&self) -> usize {
        let members = self.ctx.members_read();
        let mut best: Option<usize> = None;
        let mut seen_nodes: Vec<usize> = Vec::new();
        for &id in members.iter() {
            let w = &self.ctx.workers[id];
            if w.arch != self.arch || seen_nodes.contains(&w.mem_node) {
                continue;
            }
            seen_nodes.push(w.mem_node);
            let pending = self.ctx.transfer_bytes(self.task, id);
            best = Some(match best {
                Some(b) if b <= pending => b,
                _ => pending,
            });
        }
        best.unwrap_or(0)
    }

    /// Modeled seconds the pending (non-resident) operand bytes would
    /// take to move — the transfer-adjustment term of context-aware
    /// estimates. Zero when the context's data-aware term is disabled.
    pub fn transfer_penalty_secs(&self) -> f64 {
        if !self.ctx.data_aware {
            return 0.0;
        }
        let pending = self.pending_transfer_bytes();
        if pending == 0 {
            0.0
        } else {
            transfer_model(pending)
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::Ordering;
    use std::sync::Arc;

    use super::*;
    use crate::runtime::Tensor;
    use crate::taskrt::codelet::Codelet;
    use crate::taskrt::data::{AccessMode, DataRegistry};
    use crate::taskrt::perfmodel::PerfModels;
    use crate::taskrt::scheduler::WorkerInfo;
    use crate::taskrt::selection::Greedy;

    fn two_arch_ctx() -> (SchedCtx, crate::taskrt::HandleId) {
        let workers = vec![
            WorkerInfo {
                id: 0,
                arch: Arch::Cpu,
                mem_node: 0,
            },
            WorkerInfo {
                id: 1,
                arch: Arch::Cuda,
                mem_node: 1,
            },
        ];
        let data = Arc::new(DataRegistry::new());
        let h = data.register(Tensor::vector(vec![0.0; 256]));
        (
            SchedCtx::new(
                workers,
                Arc::new(PerfModels::new()),
                data,
                None,
                Arc::new(Greedy::new()),
                7,
            ),
            h,
        )
    }

    fn task(h: crate::taskrt::HandleId) -> ReadyTask {
        let cl = Codelet::new("c", "sort", vec![AccessMode::Read])
            .with_native("omp", Arch::Cpu, Arc::new(|_| Ok(())))
            .with_native("cuda", Arch::Cuda, Arc::new(|_| Ok(())));
        ReadyTask {
            id: 0,
            codelet: Arc::new(cl),
            size: 64,
            handles: vec![(h, AccessMode::Read)],
            selector: None,
            priority: 0,
            ctx: 0,
            chosen_impl: None,
            est_cost_ns: 0,
            tag: 0,
            trace: 0,
            enqueued_ns: 0,
        }
    }

    #[test]
    fn snapshot_captures_counters_per_arch() {
        let (ctx, h) = two_arch_ctx();
        let t = task(h);
        let q = ctx.query(&t, Arch::Cuda);
        assert!(q.snapshot.is_idle());
        assert_eq!(q.snapshot.arch_workers, 1);
        assert_eq!(q.snapshot.partition_workers, 2);

        ctx.pending.store(3, Ordering::Relaxed);
        // at most one in-flight task per worker — capture() debug-asserts
        // the invariant (the autoscale counter audit)
        ctx.running[1].store(1, Ordering::Relaxed);
        ctx.charge(1, 50_000_000); // 50 ms modeled backlog on the device
        let q = ctx.query(&t, Arch::Cuda);
        assert_eq!(q.snapshot.queue_depth, 3);
        assert_eq!(q.snapshot.arch_inflight, 1);
        assert_eq!(q.snapshot.busy_workers, 1);
        assert_eq!(q.snapshot.load_band(), 2, "4 pending > 1 worker");
        assert!((q.snapshot.queued_secs - 0.05).abs() < 1e-9);
        // the CPU-side view sees the context-wide queue but not the
        // device's in-flight work
        let q = ctx.query(&t, Arch::Cpu);
        assert_eq!(q.snapshot.arch_inflight, 0);
        assert_eq!(q.snapshot.queued_secs, 0.0);
        assert_eq!(q.snapshot.load_band(), 2);
    }

    #[test]
    fn load_band_thresholds() {
        let s = RuntimeSnapshot {
            arch_workers: 2,
            ..RuntimeSnapshot::default()
        };
        assert_eq!(s.load_band(), 0);
        let busy = RuntimeSnapshot {
            arch_workers: 2,
            queue_depth: 2,
            ..RuntimeSnapshot::default()
        };
        assert_eq!(busy.load_band(), 1);
        let contended = RuntimeSnapshot {
            arch_workers: 2,
            queue_depth: 2,
            arch_inflight: 2,
            ..RuntimeSnapshot::default()
        };
        assert_eq!(contended.load_band(), 2);
    }

    #[test]
    fn validate_occupancy_accepts_legal_states() {
        assert!(validate_occupancy(&[]).is_ok());
        assert!(validate_occupancy(&[(0, Arch::Cpu, 0)]).is_ok());
        assert!(validate_occupancy(&[
            (0, Arch::Cpu, 1),
            (1, Arch::Cpu, 0),
            (2, Arch::Cuda, 1),
        ])
        .is_ok());
    }

    #[test]
    fn validate_occupancy_flags_per_worker_leak() {
        let err = validate_occupancy(&[(3, Arch::Cpu, 2), (4, Arch::Cpu, 0)]).unwrap_err();
        assert!(err.contains("worker 3"), "{err}");
        assert!(err.contains("occupancy leak"), "{err}");
    }

    #[test]
    fn validate_occupancy_flags_per_arch_overflow() {
        // one cuda member carrying two in-flight tasks trips both the
        // per-worker bound and the per-arch aggregate; the report names
        // both so a migration leak is diagnosable from either side
        let err = validate_occupancy(&[(0, Arch::Cpu, 0), (1, Arch::Cuda, 2)]).unwrap_err();
        assert!(err.contains("occupancy leak"), "{err}");
        assert!(err.contains("in-flight tasks on 1 cuda member worker(s)"), "{err}");
    }

    #[test]
    fn pending_transfer_tracks_residency() {
        let (ctx, h) = two_arch_ctx();
        let t = task(h);
        // data starts in main memory: the device side would transfer,
        // the CPU side would not
        let q = ctx.query(&t, Arch::Cuda);
        assert_eq!(q.pending_transfer_bytes(), 1024);
        assert!(q.transfer_penalty_secs() > 0.0);
        let q = ctx.query(&t, Arch::Cpu);
        assert_eq!(q.pending_transfer_bytes(), 0);
        assert_eq!(q.transfer_penalty_secs(), 0.0);
        // move the data to the device: the penalty flips sides
        ctx.data.acquire(h, 1, AccessMode::ReadWrite).unwrap();
        let q = ctx.query(&t, Arch::Cuda);
        assert_eq!(q.pending_transfer_bytes(), 0);
        let q = ctx.query(&t, Arch::Cpu);
        assert_eq!(q.pending_transfer_bytes(), 1024);
    }
}
