//! The unified variant-selection engine (the paper's headline feature,
//! promoted to a first-class subsystem).
//!
//! Before this module existed, selection logic was scattered across
//! three layers: a greedy `SchedCtx::pick_impl` in the scheduler, raw
//! `PerfModels` lookups inside dmda, and a per-request variant override
//! special-cased in the serve layer. Kessler & Dastgeer's *Optimized
//! Composition* line of work argues selection deserves a dedicated
//! composition layer with trained dispatch tables; this module is that
//! layer. Every component of the stack now consults one
//! [`SelectionPolicy`], and every consultation goes through a
//! first-class [`SelectionQuery`]:
//!
//! * a query bundles the codelet, size and architecture *plus* a cheap
//!   [`RuntimeSnapshot`] of the runtime state (queue depth, per-arch
//!   in-flight counts and worker occupancy, operand residency,
//!   co-tenant sessions) — so policies can condition on call context,
//!   not just problem shape (the Optimized-Composition dispatch-table
//!   argument, and HSTREAM's load-dependent splitting);
//! * schedulers ask the policy which implementation to run per
//!   architecture (dmda then places the chosen variant cost-aware);
//! * workers report measured execution times back through
//!   [`SelectionPolicy::feedback`] with the same query shape, closing
//!   the online-learning loop *with* the load context attached;
//! * the COMPAR pre-compiler emits `prefer(...)` hints into generated
//!   glue ([`crate::taskrt::Codelet::with_hint`]) that seed exploration
//!   priors (per (size, load) band for the [`Contextual`] policy);
//! * scheduling contexts carry their own policy instance (configured at
//!   [`crate::taskrt::Runtime::create_context_with`] time) so different
//!   tenants can run different policies over the same machine;
//! * the serve layer maps per-session policy choices and per-request
//!   variant pins onto per-task policy overrides
//!   ([`crate::taskrt::TaskSpec::with_selector`]).
//!
//! Six policies ship:
//!
//! | policy                    | behaviour                                          |
//! |---------------------------|----------------------------------------------------|
//! | [`Greedy`]                | explore uncalibrated variants round-robin, then    |
//! |                           | always take the model minimum (trusts regression   |
//! |                           | extrapolation across sizes)                        |
//! | [`Calibrating`]           | round-robin until `needs_calibration` clears *at   |
//! |                           | this exact size*, then model minimum               |
//! | [`EpsilonGreedy`]         | Greedy exploitation + an ε-fraction of continuous  |
//! |                           | exploration (least-observed variant first) so      |
//! |                           | models keep tracking drift on a long-running server|
//! | `epsilon-decayed[:E]`     | [`EpsilonGreedy`] whose exploitation ranks variants|
//! |                           | by the *exponentially-decayed* mean                |
//! |                           | ([`crate::taskrt::perfmodel::Bucket::ewma`]), so a |
//! |                           | real performance shift flips the ranking within a  |
//! |                           | few observations instead of O(history)             |
//! | [`Contextual`]            | context-aware: buckets observations by (size band, |
//! |                           | load band) and ranks by the *transfer-adjusted*    |
//! |                           | estimate, so a device variant loses to a CPU       |
//! |                           | variant when the device queue is deep or the       |
//! |                           | inputs are CPU-resident                            |
//! | [`Forced`]                | pin one variant by name; replaces both the old     |
//! |                           | `force_variant` plumbing and the serve special case|
//! | [`Planned`]               | prefer-strength graph-plan prior: takes the variant|
//! |                           | the [`crate::plan::GraphPlanner`] assigned when it |
//! |                           | is eligible, degrades to greedy otherwise (a plan  |
//! |                           | is advice, not a pin)                              |

pub mod contextual;
pub mod query;

pub use contextual::Contextual;
pub use query::{validate_occupancy, RuntimeSnapshot, SelectionQuery, WorkerOccupancy};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::perfmodel::key;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Default exploration rate for [`EpsilonGreedy`].
pub const DEFAULT_EPSILON: f64 = 0.1;

/// The valid selector names, for uniform validation errors across the
/// CLI, `compar serve` and `compar route` (unknown names must be
/// rejected with this set, never silently defaulted).
pub const VALID_SELECTORS: &str =
    "greedy | calibrating | epsilon[:E] | epsilon-decayed[:E] | contextual | planned | forced:VARIANT";

/// Why a policy chose the variant it chose — the reason tag the
/// observability plane's decision audit records (`decisions` request).
/// [`SelectReason::as_str`] values match
/// [`crate::obs::REASON_NAMES`], so per-reason counters in the metrics
/// scrape need no mapping table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectReason {
    /// Cold-start round-robin over un-modeled variants.
    Calibrating,
    /// A pre-compiler `prefer()` hint seeded the first exploration.
    HintPrior,
    /// An ε-fraction (or similar) deliberate exploration pick.
    Explore,
    /// Model-minimum exploitation.
    Exploit,
    /// The contextual policy's banded, transfer/queue-adjusted ranking.
    ContextualBand,
    /// A graph plan's prefer-strength prior was honoured.
    PlannedPrefer,
    /// A `forced:VARIANT` pin.
    Forced,
}

impl SelectReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            SelectReason::Calibrating => "calibrating",
            SelectReason::HintPrior => "hint-prior",
            SelectReason::Explore => "explore",
            SelectReason::Exploit => "exploit",
            SelectReason::ContextualBand => "contextual-band",
            SelectReason::PlannedPrefer => "planned-prefer",
            SelectReason::Forced => "forced",
        }
    }
}

/// The outcome of one selection decision.
#[derive(Debug, Clone)]
pub struct VariantChoice {
    /// Index into the codelet's `impls`.
    pub impl_idx: usize,
    /// Modeled execution estimate behind the choice; `None` means the
    /// policy is exploring (schedulers fall back to calibration-style
    /// placement for such tasks). Context-aware policies may return a
    /// *context-adjusted* estimate (e.g. including pending-transfer
    /// cost), which cost-argmin schedulers compare directly.
    pub est: Option<f64>,
    /// Why this variant won (audit-log reason tag).
    pub reason: SelectReason,
}

impl VariantChoice {
    /// Tag (or re-tag) the choice's audit reason.
    pub fn with_reason(mut self, reason: SelectReason) -> VariantChoice {
        self.reason = reason;
        self
    }
}

/// A pluggable variant-selection policy. One instance lives per
/// scheduling context (shared by all its workers), and tasks may carry
/// a per-task override ([`crate::taskrt::TaskSpec::with_selector`]).
///
/// Every entry point takes a [`SelectionQuery`]: the (task, arch) pair
/// being decided plus a [`RuntimeSnapshot`] of queue depths, worker
/// occupancy, operand residency and co-tenancy. Policies that only care
/// about (codelet, size) simply ignore the snapshot.
pub trait SelectionPolicy: Send + Sync {
    /// Human-readable policy name (diagnostics / serve protocol).
    fn name(&self) -> String;

    /// Choose an implementation for the query's (task, arch), or `None`
    /// when the policy cannot serve this pair.
    fn select(&self, q: &SelectionQuery) -> Option<VariantChoice>;

    /// Side-effect-free eligibility probe: could [`Self::select`] return
    /// a choice for this query? Used for worker placement, stealing
    /// filters and submit-time validation — hot scan loops, so the
    /// probe query may carry an **empty snapshot**. Eligibility must
    /// therefore be load-independent: policies may steer *rankings* by
    /// the snapshot, never whether a (task, arch) pair is servable at
    /// all.
    fn can_serve(&self, q: &SelectionQuery) -> bool {
        !q.eligible().is_empty()
    }

    /// Online-learning hook: a worker measured `secs` of execution of
    /// `variant` for the query's (codelet, size) — the query's snapshot
    /// carries the load context the measurement was taken under. The
    /// shared [`super::PerfModels`] store is updated separately by the
    /// worker; policies use this to maintain their own state.
    fn feedback(&self, _q: &SelectionQuery, _variant: &str, _secs: f64) {}

    /// Serialize this policy's banded observation state for gossip, so
    /// a graph plan computed on one shard prices variants with the
    /// whole cluster's evidence. `None` (the default) means the policy
    /// has no banded state to ship.
    fn export_bands(&self) -> Option<Json> {
        None
    }

    /// Merge banded observation state received from a peer; returns
    /// the number of buckets accepted. Idempotent by construction —
    /// re-importing the same summary is a no-op. Default: ignore.
    fn import_bands(&self, _bands: &Json) -> usize {
        0
    }
}

/// Serializable policy selector: what configs, CLI flags and the serve
/// protocol name; [`SelectorKind::build`] instantiates the live policy.
#[derive(Debug, Clone, PartialEq)]
pub enum SelectorKind {
    Greedy,
    Calibrating,
    EpsilonGreedy(f64),
    /// Epsilon-greedy whose exploitation consults the exponentially-
    /// decayed estimates (fast drift recovery; see
    /// [`crate::taskrt::perfmodel::EWMA_ALPHA`]).
    EpsilonDecayed(f64),
    /// Context-aware selection over the full [`SelectionQuery`]
    /// (banded observations + transfer/queue-adjusted ranking).
    Contextual,
    /// Prefer-strength graph-plan priors ([`Planned`]): honour the
    /// variant a [`crate::plan::GraphPlanner`] assigned when eligible,
    /// greedy otherwise. Built bare (no prior) it behaves like greedy;
    /// the runtime attaches per-task priors at graph release.
    Planned,
    Forced(String),
}

impl SelectorKind {
    /// Parse `greedy`, `calibrating`, `epsilon`, `epsilon:0.2`,
    /// `epsilon-decayed[:E]`, `contextual`, `forced:VARIANT`.
    pub fn parse(s: &str) -> Option<SelectorKind> {
        let s = s.trim();
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "greedy" => return Some(SelectorKind::Greedy),
            "calibrating" | "calibrate" => return Some(SelectorKind::Calibrating),
            "epsilon" | "epsilon-greedy" | "egreedy" => {
                return Some(SelectorKind::EpsilonGreedy(DEFAULT_EPSILON))
            }
            "epsilon-decayed" | "edecay" => {
                return Some(SelectorKind::EpsilonDecayed(DEFAULT_EPSILON))
            }
            "contextual" | "context-aware" => return Some(SelectorKind::Contextual),
            "planned" => return Some(SelectorKind::Planned),
            _ => {}
        }
        if let Some(e) = lower.strip_prefix("epsilon-decayed:") {
            let eps: f64 = e.parse().ok()?;
            if (0.0..=1.0).contains(&eps) {
                return Some(SelectorKind::EpsilonDecayed(eps));
            }
            return None;
        }
        if let Some(e) = lower.strip_prefix("epsilon:") {
            let eps: f64 = e.parse().ok()?;
            if (0.0..=1.0).contains(&eps) {
                return Some(SelectorKind::EpsilonGreedy(eps));
            }
            return None;
        }
        // variant names are case-sensitive: strip the prefix from `s`
        if let Some(v) = s.strip_prefix("forced:") {
            if !v.is_empty() {
                return Some(SelectorKind::Forced(v.to_string()));
            }
        }
        None
    }

    pub fn name(&self) -> String {
        match self {
            SelectorKind::Greedy => "greedy".into(),
            SelectorKind::Calibrating => "calibrating".into(),
            SelectorKind::EpsilonGreedy(e) => format!("epsilon:{e}"),
            SelectorKind::EpsilonDecayed(e) => format!("epsilon-decayed:{e}"),
            SelectorKind::Contextual => "contextual".into(),
            SelectorKind::Planned => "planned".into(),
            SelectorKind::Forced(v) => format!("forced:{v}"),
        }
    }

    /// Instantiate a fresh policy (per scheduling context or session).
    pub fn build(&self, seed: u64) -> Arc<dyn SelectionPolicy> {
        match self {
            SelectorKind::Greedy => Arc::new(Greedy::new()),
            SelectorKind::Calibrating => Arc::new(Calibrating::new()),
            SelectorKind::EpsilonGreedy(e) => Arc::new(EpsilonGreedy::new(*e, seed)),
            SelectorKind::EpsilonDecayed(e) => Arc::new(EpsilonGreedy::new_decayed(*e, seed)),
            SelectorKind::Contextual => Arc::new(Contextual::new()),
            SelectorKind::Planned => Arc::new(Planned::new()),
            SelectorKind::Forced(v) => Arc::new(Forced::new(v)),
        }
    }
}

// ------------------------------------------------------------ shared bits

/// If the codelet carries a pre-compiler `prefer(...)` hint naming a
/// variant in `pool` that has never been observed, explore it first —
/// the hint seeds the policy's prior so the likely winner gets a model
/// before anything else.
fn hint_first(q: &SelectionQuery, pool: &[usize]) -> Option<usize> {
    let hint = q.task.codelet.hint.as_deref()?;
    let &idx = pool.iter().find(|&&i| q.variant_name(i) == hint)?;
    if q.ctx.perf.samples(q.codelet_name(), hint) == 0 {
        Some(idx)
    } else {
        None
    }
}

/// Cold-start exploration over `pool` (impl indices still lacking a
/// usable model): the unseen hinted variant first, then round-robin by
/// `cursor`. `None` when nothing needs exploring.
fn explore_pool(q: &SelectionQuery, pool: &[usize], cursor: &AtomicUsize) -> Option<VariantChoice> {
    if pool.is_empty() {
        return None;
    }
    if let Some(i) = hint_first(q, pool) {
        return Some(VariantChoice {
            impl_idx: i,
            est: None,
            reason: SelectReason::HintPrior,
        });
    }
    let k = cursor.fetch_add(1, Ordering::Relaxed);
    Some(VariantChoice {
        impl_idx: pool[k % pool.len()],
        est: None,
        reason: SelectReason::Calibrating,
    })
}

/// Model minimum over `pool` (assumes every entry has an estimate; a
/// missing one sorts last rather than panicking).
fn best_known(q: &SelectionQuery, pool: &[usize]) -> Option<VariantChoice> {
    best_by(pool, |i| q.exec_estimate(i))
}

/// Decayed-mean minimum over `pool` — the drift-tracking ranking
/// ([`crate::taskrt::perfmodel::Bucket::ewma`]).
fn best_recent(q: &SelectionQuery, pool: &[usize]) -> Option<VariantChoice> {
    best_by(pool, |i| q.recent_estimate(i))
}

fn best_by(pool: &[usize], est: impl Fn(usize) -> Option<f64>) -> Option<VariantChoice> {
    pool.iter()
        .copied()
        .map(|i| (i, est(i)))
        .min_by(|a, b| {
            let ta = a.1.unwrap_or(f64::MAX);
            let tb = b.1.unwrap_or(f64::MAX);
            ta.partial_cmp(&tb).unwrap_or(std::cmp::Ordering::Equal)
        })
        .map(|(i, est)| VariantChoice {
            impl_idx: i,
            est,
            reason: SelectReason::Exploit,
        })
}

// ----------------------------------------------------------------- greedy

/// Today's historical behaviour, extracted from `SchedCtx::pick_impl`:
/// round-robin over variants whose model has *no estimate at all* (no
/// trusted bucket and no regression), then always take the model
/// minimum. Trusts power-law regression to extrapolate across sizes, so
/// it stops exploring a size as soon as any fit exists.
pub struct Greedy {
    rr: AtomicUsize,
}

impl Greedy {
    pub fn new() -> Greedy {
        Greedy {
            rr: AtomicUsize::new(0),
        }
    }
}

impl Default for Greedy {
    fn default() -> Self {
        Self::new()
    }
}

impl SelectionPolicy for Greedy {
    fn name(&self) -> String {
        "greedy".into()
    }

    fn select(&self, q: &SelectionQuery) -> Option<VariantChoice> {
        let eligible = q.eligible();
        if eligible.is_empty() {
            return None;
        }
        let unknown: Vec<usize> = eligible
            .iter()
            .copied()
            .filter(|&i| q.exec_estimate(i).is_none())
            .collect();
        if let Some(c) = explore_pool(q, &unknown, &self.rr) {
            return Some(c);
        }
        best_known(q, &eligible)
    }
}

// ------------------------------------------------------------ calibrating

/// STARPU_CALIBRATE analog: round-robin over every variant that still
/// [`super::PerfModels::needs_calibration`] *at this exact size*, then
/// take the model minimum. Unlike [`Greedy`] it refuses to trust
/// regression extrapolation — a new problem size re-triggers
/// exploration until the per-size bucket is trusted.
pub struct Calibrating {
    rr: AtomicUsize,
}

impl Calibrating {
    pub fn new() -> Calibrating {
        Calibrating {
            rr: AtomicUsize::new(0),
        }
    }
}

impl Default for Calibrating {
    fn default() -> Self {
        Self::new()
    }
}

impl SelectionPolicy for Calibrating {
    fn name(&self) -> String {
        "calibrating".into()
    }

    fn select(&self, q: &SelectionQuery) -> Option<VariantChoice> {
        let eligible = q.eligible();
        if eligible.is_empty() {
            return None;
        }
        let need: Vec<usize> = eligible
            .iter()
            .copied()
            .filter(|&i| {
                q.ctx
                    .perf
                    .needs_calibration(q.codelet_name(), q.variant_name(i), q.size())
            })
            .collect();
        if let Some(c) = explore_pool(q, &need, &self.rr) {
            return Some(c);
        }
        best_known(q, &eligible)
    }
}

// ---------------------------------------------------------- epsilon-greedy

/// Greedy exploitation plus an ε-fraction of continuous exploration, so
/// a long-running server keeps sampling every variant and the shared
/// performance models track drift instead of freezing at the first
/// converged ranking. Exploration picks the *least-observed* eligible
/// variant (observation counts are maintained by the
/// [`SelectionPolicy::feedback`] loop from the workers).
pub struct EpsilonGreedy {
    epsilon: f64,
    /// Exploit via the exponentially-decayed estimates instead of the
    /// cumulative means (the `epsilon-decayed` policy): after a real
    /// performance shift the ranking flips in O(1/alpha) observations.
    decayed: bool,
    rr: AtomicUsize,
    rng: Mutex<Rng>,
    /// "codelet:variant" -> measured-execution observations (same key
    /// format as the [`super::PerfModels`] store, via [`key`]).
    seen: Mutex<BTreeMap<String, u64>>,
}

impl EpsilonGreedy {
    pub fn new(epsilon: f64, seed: u64) -> EpsilonGreedy {
        EpsilonGreedy {
            epsilon: epsilon.clamp(0.0, 1.0),
            decayed: false,
            rr: AtomicUsize::new(0),
            rng: Mutex::new(Rng::new(seed ^ 0xeb511e55)),
            seen: Mutex::new(BTreeMap::new()),
        }
    }

    /// The drift-tracking variant: exploitation ranks by decayed mean.
    pub fn new_decayed(epsilon: f64, seed: u64) -> EpsilonGreedy {
        EpsilonGreedy {
            decayed: true,
            ..EpsilonGreedy::new(epsilon, seed)
        }
    }

    /// Observation count for diagnostics/tests.
    pub fn observations(&self, codelet: &str, variant: &str) -> u64 {
        self.seen
            .lock()
            .unwrap()
            .get(&key(codelet, variant))
            .copied()
            .unwrap_or(0)
    }
}

impl SelectionPolicy for EpsilonGreedy {
    fn name(&self) -> String {
        if self.decayed {
            format!("epsilon-decayed:{}", self.epsilon)
        } else {
            format!("epsilon:{}", self.epsilon)
        }
    }

    fn select(&self, q: &SelectionQuery) -> Option<VariantChoice> {
        let eligible = q.eligible();
        if eligible.is_empty() {
            return None;
        }
        // cold start behaves exactly like Greedy
        let unknown: Vec<usize> = eligible
            .iter()
            .copied()
            .filter(|&i| q.exec_estimate(i).is_none())
            .collect();
        if let Some(c) = explore_pool(q, &unknown, &self.rr) {
            return Some(c);
        }
        let explore = (self.rng.lock().unwrap().next_f32() as f64) < self.epsilon;
        if explore {
            let pool: Vec<usize> = {
                let seen = self.seen.lock().unwrap();
                let counts: Vec<(usize, u64)> = eligible
                    .iter()
                    .map(|&i| {
                        let k = key(q.codelet_name(), q.variant_name(i));
                        (i, seen.get(&k).copied().unwrap_or(0))
                    })
                    .collect();
                let min = counts.iter().map(|&(_, c)| c).min().unwrap_or(0);
                counts
                    .into_iter()
                    .filter(|&(_, c)| c == min)
                    .map(|(i, _)| i)
                    .collect()
            };
            let k = self.rng.lock().unwrap().below(pool.len());
            // est None = "this is an exploration pick": cost-argmin
            // schedulers (dmda/heft) must execute it rather than let it
            // lose the completion-time comparison against the exploit
            // choice of another architecture — otherwise exploration
            // would starve on every arch that isn't the current winner.
            return Some(VariantChoice {
                impl_idx: pool[k],
                est: None,
                reason: SelectReason::Explore,
            });
        }
        if self.decayed {
            best_recent(q, &eligible)
        } else {
            best_known(q, &eligible)
        }
    }

    fn feedback(&self, q: &SelectionQuery, variant: &str, _secs: f64) {
        *self
            .seen
            .lock()
            .unwrap()
            .entry(key(q.codelet_name(), variant))
            .or_insert(0) += 1;
    }
}

// ---------------------------------------------------------------- planned

/// Prefer-strength graph-plan prior: the [`crate::plan::GraphPlanner`]
/// assigned this task a variant while optimizing the whole DAG's
/// makespan, and the runtime attached that assignment here at release
/// ([`Planned::with_prior`]). Unlike [`Forced`], a plan is advice: if
/// the planned variant is not eligible on the arch being asked (the
/// snapshot moved, workers migrated, the artifact is absent), selection
/// degrades to greedy over whatever *is* eligible — workers can always
/// bail. Built bare (`SelectorKind::Planned`) it carries no prior and
/// behaves exactly like [`Greedy`].
pub struct Planned {
    variant: Option<String>,
    /// The plan's modeled estimate behind the assignment (execution
    /// only; schedulers re-add transfer terms themselves).
    est: Option<f64>,
    rr: AtomicUsize,
}

impl Planned {
    /// No prior: greedy-like (what `SelectorKind::Planned` builds).
    pub fn new() -> Planned {
        Planned {
            variant: None,
            est: None,
            rr: AtomicUsize::new(0),
        }
    }

    /// A per-task prior from a graph plan.
    pub fn with_prior(variant: &str, est: f64) -> Planned {
        Planned {
            variant: Some(variant.to_string()),
            est: Some(est),
            rr: AtomicUsize::new(0),
        }
    }

    /// The planned variant, if any (diagnostics/tests).
    pub fn planned_variant(&self) -> Option<&str> {
        self.variant.as_deref()
    }
}

impl Default for Planned {
    fn default() -> Self {
        Self::new()
    }
}

impl SelectionPolicy for Planned {
    fn name(&self) -> String {
        match &self.variant {
            Some(v) => format!("planned:{v}"),
            None => "planned".into(),
        }
    }

    fn select(&self, q: &SelectionQuery) -> Option<VariantChoice> {
        let eligible = q.eligible();
        if eligible.is_empty() {
            return None;
        }
        if let Some(planned) = self.variant.as_deref() {
            if let Some(&i) = eligible.iter().find(|&&i| q.variant_name(i) == planned) {
                return Some(VariantChoice {
                    impl_idx: i,
                    est: self.est.or_else(|| q.exec_estimate(i)),
                    reason: SelectReason::PlannedPrefer,
                });
            }
        }
        // plan inapplicable here: greedy fallback
        let unknown: Vec<usize> = eligible
            .iter()
            .copied()
            .filter(|&i| q.exec_estimate(i).is_none())
            .collect();
        if let Some(c) = explore_pool(q, &unknown, &self.rr) {
            return Some(c);
        }
        best_known(q, &eligible)
    }
}

// ----------------------------------------------------------------- forced

/// Pin selection to one variant by name. Replaces both the old
/// `force_variant` plumbing through `ReadyTask` and the serve layer's
/// per-request override special case: a pinned request simply carries a
/// `Forced` policy as its per-task selector. A pin wins over any
/// snapshot state by construction — the override *replaces* the
/// context's policy, so no load signal can ever veto it.
pub struct Forced {
    variant: String,
}

impl Forced {
    pub fn new(variant: &str) -> Forced {
        Forced {
            variant: variant.to_string(),
        }
    }

    pub fn variant(&self) -> &str {
        &self.variant
    }
}

impl SelectionPolicy for Forced {
    fn name(&self) -> String {
        format!("forced:{}", self.variant)
    }

    fn select(&self, q: &SelectionQuery) -> Option<VariantChoice> {
        q.eligible()
            .into_iter()
            .find(|&i| q.variant_name(i) == self.variant)
            .map(|i| VariantChoice {
                impl_idx: i,
                est: q.exec_estimate(i),
                reason: SelectReason::Forced,
            })
    }

    fn can_serve(&self, q: &SelectionQuery) -> bool {
        q.eligible()
            .iter()
            .any(|&i| q.variant_name(i) == self.variant)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskrt::codelet::Codelet;
    use crate::taskrt::data::DataRegistry;
    use crate::taskrt::device::Arch;
    use crate::taskrt::perfmodel::{PerfModels, MIN_SAMPLES};
    use crate::taskrt::scheduler::{ReadyTask, SchedCtx, WorkerInfo};

    fn ctx_with(perf: Arc<PerfModels>) -> SchedCtx {
        let workers = vec![WorkerInfo {
            id: 0,
            arch: Arch::Cpu,
            mem_node: 0,
        }];
        let data = Arc::new(DataRegistry::new());
        SchedCtx::new(workers, perf, data, None, Arc::new(Greedy::new()), 7)
    }

    fn two_variant_task(hint: Option<&str>) -> ReadyTask {
        let mut cl = Codelet::new("c", "sort", vec![])
            .with_native("fast", Arch::Cpu, Arc::new(|_| Ok(())))
            .with_native("slow", Arch::Cpu, Arc::new(|_| Ok(())));
        if let Some(h) = hint {
            cl = cl.with_hint(h);
        }
        ReadyTask {
            id: 0,
            codelet: Arc::new(cl),
            size: 64,
            handles: vec![],
            selector: None,
            priority: 0,
            ctx: 0,
            chosen_impl: None,
            est_cost_ns: 0,
            tag: 0,
            trace: 0,
            enqueued_ns: 0,
        }
    }

    fn warm(perf: &PerfModels, variant: &str, t: f64) {
        for _ in 0..MIN_SAMPLES {
            perf.record("c", variant, 64, t);
        }
    }

    #[test]
    fn selector_kind_parse_roundtrip() {
        assert_eq!(SelectorKind::parse("greedy"), Some(SelectorKind::Greedy));
        assert_eq!(
            SelectorKind::parse("CALIBRATING"),
            Some(SelectorKind::Calibrating)
        );
        assert_eq!(
            SelectorKind::parse("epsilon"),
            Some(SelectorKind::EpsilonGreedy(DEFAULT_EPSILON))
        );
        assert_eq!(
            SelectorKind::parse("epsilon:0.25"),
            Some(SelectorKind::EpsilonGreedy(0.25))
        );
        assert_eq!(
            SelectorKind::parse("forced:cuda"),
            Some(SelectorKind::Forced("cuda".into()))
        );
        assert_eq!(
            SelectorKind::parse("epsilon-decayed"),
            Some(SelectorKind::EpsilonDecayed(DEFAULT_EPSILON))
        );
        assert_eq!(
            SelectorKind::parse("epsilon-decayed:0.3"),
            Some(SelectorKind::EpsilonDecayed(0.3))
        );
        assert_eq!(
            SelectorKind::parse("contextual"),
            Some(SelectorKind::Contextual)
        );
        assert_eq!(
            SelectorKind::parse("Context-Aware"),
            Some(SelectorKind::Contextual)
        );
        assert_eq!(SelectorKind::parse("planned"), Some(SelectorKind::Planned));
        assert_eq!(SelectorKind::parse("epsilon:7"), None);
        assert_eq!(SelectorKind::parse("epsilon-decayed:7"), None);
        assert_eq!(SelectorKind::parse("forced:"), None);
        assert_eq!(SelectorKind::parse("nope"), None);
        for k in [
            SelectorKind::Greedy,
            SelectorKind::Calibrating,
            SelectorKind::EpsilonGreedy(0.5),
            SelectorKind::EpsilonDecayed(0.25),
            SelectorKind::Contextual,
            SelectorKind::Planned,
            SelectorKind::Forced("omp".into()),
        ] {
            assert_eq!(SelectorKind::parse(&k.name()), Some(k.clone()), "{k:?}");
        }
    }

    #[test]
    fn valid_selector_set_names_every_policy() {
        for name in [
            "greedy",
            "calibrating",
            "epsilon",
            "contextual",
            "planned",
            "forced",
        ] {
            assert!(VALID_SELECTORS.contains(name), "{name} missing");
        }
    }

    #[test]
    fn greedy_explores_then_exploits() {
        let perf = Arc::new(PerfModels::new());
        let ctx = ctx_with(perf.clone());
        let task = two_variant_task(None);
        let g = Greedy::new();
        // cold: explores (est None)
        let c = g.select(&ctx.query(&task, Arch::Cpu)).unwrap();
        assert!(c.est.is_none());
        // warmed: exploits the minimum
        warm(&perf, "fast", 1e-3);
        warm(&perf, "slow", 1e-1);
        let c = g.select(&ctx.query(&task, Arch::Cpu)).unwrap();
        assert_eq!(task.codelet.impls[c.impl_idx].name, "fast");
        assert!(c.est.is_some());
    }

    #[test]
    fn calibrating_completes_then_exploits() {
        let perf = Arc::new(PerfModels::new());
        let ctx = ctx_with(perf.clone());
        let task = two_variant_task(None);
        let p = Calibrating::new();
        // drive the calibration loop exactly as a worker would
        for _ in 0..(2 * MIN_SAMPLES) {
            let c = p.select(&ctx.query(&task, Arch::Cpu)).unwrap();
            assert!(c.est.is_none(), "still calibrating");
            let name = &task.codelet.impls[c.impl_idx].name;
            let t = if name == "fast" { 1e-3 } else { 1e-1 };
            perf.record("c", name, 64, t);
            p.feedback(&ctx.query(&task, Arch::Cpu), name, t);
        }
        assert!(!perf.needs_calibration("c", "fast", 64));
        assert!(!perf.needs_calibration("c", "slow", 64));
        let c = p.select(&ctx.query(&task, Arch::Cpu)).unwrap();
        assert_eq!(task.codelet.impls[c.impl_idx].name, "fast");
        // a NEW size re-triggers calibration (unlike Greedy's regression)
        let mut big = two_variant_task(None);
        big.size = 4096;
        let c = p.select(&ctx.query(&big, Arch::Cpu)).unwrap();
        assert!(c.est.is_none(), "new size must recalibrate");
    }

    #[test]
    fn epsilon_greedy_converges_to_fastest_under_bimodal_costs() {
        let perf = Arc::new(PerfModels::new());
        warm(&perf, "fast", 1e-3);
        warm(&perf, "slow", 1e-1);
        let ctx = ctx_with(perf);
        let task = two_variant_task(None);
        let p = EpsilonGreedy::new(0.2, 11);
        let mut fast = 0usize;
        let n = 1000;
        for _ in 0..n {
            let c = p.select(&ctx.query(&task, Arch::Cpu)).unwrap();
            let name = task.codelet.impls[c.impl_idx].name.clone();
            if name == "fast" {
                fast += 1;
            }
            p.feedback(&ctx.query(&task, Arch::Cpu), &name, 0.0);
        }
        // expected fast fraction = (1 - eps) + eps * balance ≈ 0.9
        assert!(fast as f64 / n as f64 > 0.7, "converged to {fast}/{n}");
        // exploration keeps observing the slow variant too
        assert!(p.observations("c", "slow") > 0);
    }

    #[test]
    fn decayed_epsilon_recovers_from_drift_cumulative_does_not() {
        // long history: "fast" was the winner for 50 observations, then
        // drifted to 1.0 s for the last 5. The cumulative mean still
        // ranks it first; the decayed mean has already flipped.
        let perf = Arc::new(PerfModels::new());
        for _ in 0..50 {
            perf.record("c", "fast", 64, 1e-3);
        }
        warm(&perf, "slow", 1e-1);
        for _ in 0..5 {
            perf.record("c", "fast", 64, 1.0);
        }
        let ctx = ctx_with(perf);
        let task = two_variant_task(None);
        // epsilon 0.0: pure exploitation, no randomness
        let cumulative = EpsilonGreedy::new(0.0, 3);
        let c = cumulative.select(&ctx.query(&task, Arch::Cpu)).unwrap();
        assert_eq!(task.codelet.impls[c.impl_idx].name, "fast", "cumulative lags");
        let decayed = EpsilonGreedy::new_decayed(0.0, 3);
        assert_eq!(decayed.name(), "epsilon-decayed:0");
        let c = decayed.select(&ctx.query(&task, Arch::Cpu)).unwrap();
        assert_eq!(
            task.codelet.impls[c.impl_idx].name, "slow",
            "decayed ranking flips after the drift"
        );
        assert!(c.est.is_some());
    }

    #[test]
    fn forced_selects_only_its_variant() {
        let perf = Arc::new(PerfModels::new());
        warm(&perf, "fast", 1e-3);
        let ctx = ctx_with(perf);
        let task = two_variant_task(None);
        let p = Forced::new("slow");
        let c = p.select(&ctx.query(&task, Arch::Cpu)).unwrap();
        assert_eq!(task.codelet.impls[c.impl_idx].name, "slow");
        assert!(p.can_serve(&ctx.query(&task, Arch::Cpu)));
        // unknown variant: no selection, no eligibility
        let bogus = Forced::new("nope");
        assert!(bogus.select(&ctx.query(&task, Arch::Cpu)).is_none());
        assert!(!bogus.can_serve(&ctx.query(&task, Arch::Cpu)));
    }

    #[test]
    fn planned_prior_prefers_but_never_pins() {
        let perf = Arc::new(PerfModels::new());
        warm(&perf, "fast", 1e-3);
        warm(&perf, "slow", 1e-1);
        let ctx = ctx_with(perf);
        let task = two_variant_task(None);
        // planned prior names the *slower* variant: the plan wins
        // (joint makespan said so), carrying the plan's estimate
        let p = Planned::with_prior("slow", 0.05);
        assert_eq!(p.name(), "planned:slow");
        let c = p.select(&ctx.query(&task, Arch::Cpu)).unwrap();
        assert_eq!(task.codelet.impls[c.impl_idx].name, "slow");
        assert_eq!(c.est, Some(0.05));
        // prior naming an ineligible variant: greedy fallback, not None
        let stale = Planned::with_prior("gone", 0.05);
        let c = stale.select(&ctx.query(&task, Arch::Cpu)).unwrap();
        assert_eq!(task.codelet.impls[c.impl_idx].name, "fast");
        // bare Planned behaves like greedy
        let bare = Planned::new();
        assert_eq!(bare.name(), "planned");
        assert!(bare.planned_variant().is_none());
        let c = bare.select(&ctx.query(&task, Arch::Cpu)).unwrap();
        assert_eq!(task.codelet.impls[c.impl_idx].name, "fast");
    }

    #[test]
    fn hint_seeds_first_exploration() {
        let perf = Arc::new(PerfModels::new());
        let ctx = ctx_with(perf.clone());
        let task = two_variant_task(Some("slow"));
        let g = Greedy::new();
        let c = g.select(&ctx.query(&task, Arch::Cpu)).unwrap();
        assert_eq!(
            task.codelet.impls[c.impl_idx].name, "slow",
            "hinted variant is explored first"
        );
        // once observed, the hint no longer dominates exploration
        perf.record("c", "slow", 64, 1e-1);
        let mut names = std::collections::BTreeSet::new();
        for _ in 0..4 {
            let c = g.select(&ctx.query(&task, Arch::Cpu)).unwrap();
            names.insert(task.codelet.impls[c.impl_idx].name.clone());
        }
        assert!(names.contains("fast"), "round-robin resumes: {names:?}");
    }
}
