//! The `contextual` policy: context-aware selection over the full
//! [`SelectionQuery`], the capability the `SelectionQuery` redesign
//! exists to enable.
//!
//! Every other shipped policy keys its decision on (codelet, size)
//! alone; this one also reads the [`RuntimeSnapshot`]:
//!
//! * **Banded learning** — measured execution times are bucketed by
//!   (variant, size band, load band). Interference is real: a variant
//!   that wins on an idle machine can lose badly when its device is
//!   contended, and a single global mean can never represent both
//!   phases. Each band keeps its own exponentially-decayed mean
//!   ([`EWMA_ALPHA`]), so the ranking under load is learned from
//!   observations made under load.
//! * **Transfer adjustment** — the score of every candidate is its
//!   banded estimate *plus* the modeled cost of moving the task's
//!   non-resident operand bytes to the queried architecture
//!   ([`SelectionQuery::transfer_penalty_secs`]). A GPU variant loses
//!   to a CPU variant when the inputs are CPU-resident and small
//!   enough that the PCIe round trip dominates.
//! * **Queue adjustment** — the modeled backlog already queued on the
//!   queried architecture ([`RuntimeSnapshot::queued_secs`]) is added
//!   too, so a deep device queue pushes selection toward the idle
//!   architecture even under schedulers that do no completion-time
//!   modeling of their own. (Under dmda the backlog is also counted at
//!   placement; the double weight is deliberate — it steers *harder*
//!   away from contended devices, which is the conservative direction.)
//! * **Hint priors per band** — a pre-compiler `prefer()` hint
//!   ([`crate::taskrt::Codelet::with_hint`]) discounts the hinted
//!   variant by [`HINT_PRIOR`] in every band that has no observations
//!   yet, so the component author's expected winner is favored until
//!   the band has real data (and ignored as soon as it does).
//!
//! Forced pins are unaffected: a per-task [`super::Forced`] override
//! still wins over any snapshot state, because the override replaces
//! this policy entirely ([`SchedCtx::policy_for`]).
//!
//! [`RuntimeSnapshot`]: super::RuntimeSnapshot
//! [`SchedCtx::policy_for`]: crate::taskrt::scheduler::SchedCtx::policy_for

use std::collections::BTreeMap;
use std::sync::atomic::AtomicUsize;
use std::sync::Mutex;

use super::query::SelectionQuery;
use super::{best_by, explore_pool, SelectReason, SelectionPolicy, VariantChoice};
use crate::taskrt::perfmodel::{key, EWMA_ALPHA};
use crate::util::json::Json;

/// Multiplier applied to the hinted variant's score in bands without
/// observations: the author's `prefer()` expectation breaks near-ties
/// until measured data exists for the band.
pub const HINT_PRIOR: f64 = 0.9;

/// Log2 size band: observations at 48 and 63 share a band, 64 starts
/// the next one. Coarse on purpose — the bands only need to separate
/// "small" from "large", the per-size models stay in [`PerfModels`].
///
/// [`PerfModels`]: crate::taskrt::PerfModels
pub fn size_band(size: usize) -> u8 {
    (usize::BITS - size.max(1).leading_zeros()) as u8
}

/// One (variant, size band, load band) observation bucket.
#[derive(Debug, Clone, Copy, Default)]
struct BandBucket {
    count: u64,
    ewma: f64,
}

impl BandBucket {
    fn record(&mut self, secs: f64) {
        self.count += 1;
        self.ewma = if self.count == 1 {
            secs
        } else {
            self.ewma + EWMA_ALPHA * (secs - self.ewma)
        };
    }
}

/// Context-aware selection: banded observations + transfer- and
/// queue-adjusted scoring (see the module docs).
pub struct Contextual {
    rr: AtomicUsize,
    /// ("codelet:variant", size band, load band) -> decayed mean.
    buckets: Mutex<BTreeMap<(String, u8, u8), BandBucket>>,
}

impl Contextual {
    pub fn new() -> Contextual {
        Contextual {
            rr: AtomicUsize::new(0),
            buckets: Mutex::new(BTreeMap::new()),
        }
    }

    /// Observations recorded for (codelet, variant) in a band
    /// (diagnostics / tests).
    pub fn band_observations(
        &self,
        codelet: &str,
        variant: &str,
        size: usize,
        load_band: u8,
    ) -> u64 {
        self.buckets
            .lock()
            .unwrap()
            .get(&(key(codelet, variant), size_band(size), load_band))
            .map(|b| b.count)
            .unwrap_or(0)
    }

    /// Banded execution estimate for implementation `i`: the band's
    /// decayed mean when the band has data, else the drift-tracking
    /// global estimate (discounted by [`HINT_PRIOR`] for the hinted
    /// variant while the band is cold).
    fn band_estimate(&self, q: &SelectionQuery, i: usize) -> Option<f64> {
        let band = (
            key(q.codelet_name(), q.variant_name(i)),
            size_band(q.size()),
            q.snapshot.load_band(),
        );
        if let Some(b) = self.buckets.lock().unwrap().get(&band) {
            if b.count > 0 {
                return Some(b.ewma);
            }
        }
        let base = q.recent_estimate(i).or_else(|| q.exec_estimate(i))?;
        let hinted = q.task.codelet.hint.as_deref() == Some(q.variant_name(i));
        Some(if hinted { base * HINT_PRIOR } else { base })
    }

    /// The transfer- and queue-adjusted score the ranking minimizes.
    fn adjusted(&self, q: &SelectionQuery, i: usize, transfer: f64) -> Option<f64> {
        self.band_estimate(q, i)
            .map(|est| est + transfer + q.snapshot.queued_secs)
    }
}

impl Default for Contextual {
    fn default() -> Self {
        Self::new()
    }
}

impl SelectionPolicy for Contextual {
    fn name(&self) -> String {
        "contextual".into()
    }

    fn select(&self, q: &SelectionQuery) -> Option<VariantChoice> {
        let eligible = q.eligible();
        if eligible.is_empty() {
            return None;
        }
        // cold start behaves exactly like Greedy: explore variants the
        // global models know nothing about (hinted variant first)
        let unknown: Vec<usize> = eligible
            .iter()
            .copied()
            .filter(|&i| q.exec_estimate(i).is_none())
            .collect();
        if let Some(c) = explore_pool(q, &unknown, &self.rr) {
            return Some(c);
        }
        // the transfer term is per (task, arch), not per variant:
        // compute it once outside the ranking closure
        let transfer = q.transfer_penalty_secs();
        best_by(&eligible, |i| self.adjusted(q, i, transfer))
            .map(|c| c.with_reason(SelectReason::ContextualBand))
    }

    fn feedback(&self, q: &SelectionQuery, variant: &str, secs: f64) {
        let band = (
            key(q.codelet_name(), variant),
            size_band(q.size()),
            q.snapshot.load_band(),
        );
        self.buckets
            .lock()
            .unwrap()
            .entry(band)
            .or_default()
            .record(secs);
    }

    /// Band summaries for gossip: one object per (key, size band, load
    /// band) bucket, so a graph plan on another shard prices variants
    /// with this shard's interference evidence.
    fn export_bands(&self) -> Option<Json> {
        let buckets = self.buckets.lock().unwrap();
        if buckets.is_empty() {
            return None;
        }
        let arr = buckets
            .iter()
            .map(|((k, sb, lb), b)| {
                let mut o = BTreeMap::new();
                o.insert("key".to_string(), Json::Str(k.clone()));
                o.insert("size_band".to_string(), Json::Num(*sb as f64));
                o.insert("load_band".to_string(), Json::Num(*lb as f64));
                o.insert("count".to_string(), Json::Num(b.count as f64));
                o.insert("ewma".to_string(), Json::Num(b.ewma));
                Json::Obj(o)
            })
            .collect();
        Some(Json::Arr(arr))
    }

    /// Merge a peer's band summaries: a remote bucket replaces the
    /// local one only when it has strictly more observations, so
    /// re-importing the same summary is a no-op and local learning is
    /// never regressed by stale gossip.
    fn import_bands(&self, bands: &Json) -> usize {
        let Some(entries) = bands.as_arr() else {
            return 0;
        };
        let mut merged = 0;
        let mut buckets = self.buckets.lock().unwrap();
        for e in entries {
            let (Some(k), Some(sb), Some(lb), Some(count), Some(ewma)) = (
                e.get("key").and_then(|v| v.as_str()),
                e.get("size_band").and_then(|v| v.as_f64()),
                e.get("load_band").and_then(|v| v.as_f64()),
                e.get("count").and_then(|v| v.as_f64()),
                e.get("ewma").and_then(|v| v.as_f64()),
            ) else {
                continue;
            };
            let band = (k.to_string(), sb as u8, lb as u8);
            let slot = buckets.entry(band).or_default();
            if count as u64 > slot.count {
                slot.count = count as u64;
                slot.ewma = ewma;
                merged += 1;
            }
        }
        merged
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::Ordering;
    use std::sync::Arc;

    use super::super::{Forced, Greedy};
    use super::*;
    use crate::taskrt::codelet::Codelet;
    use crate::taskrt::data::DataRegistry;
    use crate::taskrt::device::Arch;
    use crate::taskrt::perfmodel::{PerfModels, MIN_SAMPLES};
    use crate::taskrt::scheduler::dmda::Dmda;
    use crate::taskrt::scheduler::{ReadyTask, SchedCtx, WorkerInfo};

    /// One CPU worker (node 0) + one CUDA-analog worker (node 1), perf
    /// models warmed so the device variant wins when idle.
    fn two_arch_ctx(
        selector: Arc<dyn crate::taskrt::selection::SelectionPolicy>,
    ) -> SchedCtx {
        let workers = vec![
            WorkerInfo {
                id: 0,
                arch: Arch::Cpu,
                mem_node: 0,
            },
            WorkerInfo {
                id: 1,
                arch: Arch::Cuda,
                mem_node: 1,
            },
        ];
        let perf = Arc::new(PerfModels::new());
        for _ in 0..MIN_SAMPLES {
            perf.record("c", "cuda", 64, 1e-3);
            perf.record("c", "omp", 64, 5e-3);
        }
        SchedCtx::new(
            workers,
            perf,
            Arc::new(DataRegistry::new()),
            None,
            selector,
            7,
        )
    }

    fn cross_arch_task(hint: Option<&str>) -> ReadyTask {
        let mut cl = Codelet::new("c", "sort", vec![])
            .with_native("omp", Arch::Cpu, Arc::new(|_| Ok(())))
            .with_native("cuda", Arch::Cuda, Arc::new(|_| Ok(())));
        if let Some(h) = hint {
            cl = cl.with_hint(h);
        }
        ReadyTask {
            id: 0,
            codelet: Arc::new(cl),
            size: 64,
            handles: vec![],
            selector: None,
            priority: 0,
            ctx: 0,
            chosen_impl: None,
            est_cost_ns: 0,
            tag: 0,
            trace: 0,
            enqueued_ns: 0,
        }
    }

    fn pressure(ctx: &SchedCtx, inflight: usize, depth: isize) {
        ctx.running[1].store(inflight, Ordering::Relaxed);
        ctx.pending.store(depth, Ordering::Relaxed);
    }

    #[test]
    fn banded_interference_flips_the_placement_greedy_does_not() {
        let p = Arc::new(Contextual::new());
        let ctx = two_arch_ctx(p.clone());
        let task = cross_arch_task(None);

        // idle: dmda places the device variant (globally fastest)
        let (_, i, _) = Dmda::place(&task, &ctx, |_, _, _| 0.0).unwrap();
        assert_eq!(task.codelet.impls[i].name, "cuda");

        // contended phase: the device variant is observed 50x slower
        // (interference); the observation lands in the loaded band.
        // (At most one in-flight task per worker — capture() asserts
        // the occupancy invariant; the queue depth carries the band.)
        pressure(&ctx, 1, 4);
        p.feedback(&ctx.query(&task, Arch::Cuda), "cuda", 5e-2);
        p.feedback(&ctx.query(&task, Arch::Cpu), "omp", 5e-3);
        assert_eq!(p.band_observations("c", "cuda", 64, 2), 1);

        // still contended: the banded ranking now prefers the CPU
        // variant — dmda sees nothing (its deque model ignores the
        // in-flight counters), the flip is the policy's alone
        let (_, i, _) = Dmda::place(&task, &ctx, |_, _, _| 0.0).unwrap();
        assert_eq!(task.codelet.impls[i].name, "omp", "contextual flips under load");

        // ...whereas Greedy in the identical state keeps the device
        let greedy_ctx = two_arch_ctx(Arc::new(Greedy::new()));
        pressure(&greedy_ctx, 1, 4);
        let (_, i, _) = Dmda::place(&task, &greedy_ctx, |_, _, _| 0.0).unwrap();
        assert_eq!(task.codelet.impls[i].name, "cuda", "greedy cannot see the load");

        // back to idle: the idle band is untouched, the device wins again
        pressure(&ctx, 0, 0);
        let (_, i, _) = Dmda::place(&task, &ctx, |_, _, _| 0.0).unwrap();
        assert_eq!(task.codelet.impls[i].name, "cuda", "idle band unaffected");
    }

    #[test]
    fn queue_backlog_penalizes_the_contended_arch_without_banded_data() {
        let p = Contextual::new();
        let ctx = two_arch_ctx(Arc::new(Greedy::new()));
        let task = cross_arch_task(None);

        // idle: the device estimate is the better one
        let cuda = p.select(&ctx.query(&task, Arch::Cuda)).unwrap();
        let cpu = p.select(&ctx.query(&task, Arch::Cpu)).unwrap();
        assert!(cuda.est.unwrap() < cpu.est.unwrap());

        // 50 ms of modeled backlog on the device: the adjusted device
        // estimate now loses, with zero banded observations
        ctx.charge(1, 50_000_000);
        let cuda = p.select(&ctx.query(&task, Arch::Cuda)).unwrap();
        let cpu = p.select(&ctx.query(&task, Arch::Cpu)).unwrap();
        assert!(
            cuda.est.unwrap() > cpu.est.unwrap(),
            "queued backlog must inflate the device score"
        );
    }

    #[test]
    fn forced_pin_wins_over_any_snapshot_state() {
        // regression: a per-task Forced override is a different policy,
        // so no amount of snapshot pressure may override the pin
        let ctx = two_arch_ctx(Arc::new(Contextual::new()));
        let task = cross_arch_task(None);
        pressure(&ctx, 1, 64);
        ctx.charge(1, 500_000_000);
        let pin = Forced::new("cuda");
        let c = pin.select(&ctx.query(&task, Arch::Cuda)).unwrap();
        assert_eq!(task.codelet.impls[c.impl_idx].name, "cuda");
        assert!(pin.can_serve(&ctx.query(&task, Arch::Cuda)));
    }

    #[test]
    fn hint_prior_breaks_near_ties_in_cold_bands_only() {
        let workers = vec![WorkerInfo {
            id: 0,
            arch: Arch::Cpu,
            mem_node: 0,
        }];
        let perf = Arc::new(PerfModels::new());
        for _ in 0..MIN_SAMPLES {
            perf.record("c", "fast", 64, 0.95e-3);
            perf.record("c", "hinted", 64, 1.0e-3);
        }
        let ctx = SchedCtx::new(
            workers,
            perf,
            Arc::new(DataRegistry::new()),
            None,
            Arc::new(Greedy::new()),
            7,
        );
        let mut cl = Codelet::new("c", "sort", vec![])
            .with_native("fast", Arch::Cpu, Arc::new(|_| Ok(())))
            .with_native("hinted", Arch::Cpu, Arc::new(|_| Ok(())));
        cl = cl.with_hint("hinted");
        let task = ReadyTask {
            id: 0,
            codelet: Arc::new(cl),
            size: 64,
            handles: vec![],
            selector: None,
            priority: 0,
            ctx: 0,
            chosen_impl: None,
            est_cost_ns: 0,
            tag: 0,
            trace: 0,
            enqueued_ns: 0,
        };
        let p = Contextual::new();
        // cold band: the prefer() prior discounts the hinted variant
        // below the marginally-faster rival
        let c = p.select(&ctx.query(&task, Arch::Cpu)).unwrap();
        assert_eq!(task.codelet.impls[c.impl_idx].name, "hinted");
        // once the band has data, measurements win over the prior
        p.feedback(&ctx.query(&task, Arch::Cpu), "hinted", 2e-3);
        p.feedback(&ctx.query(&task, Arch::Cpu), "fast", 0.95e-3);
        let c = p.select(&ctx.query(&task, Arch::Cpu)).unwrap();
        assert_eq!(task.codelet.impls[c.impl_idx].name, "fast");
    }

    #[test]
    fn band_export_import_is_idempotent_and_monotone() {
        let src = Contextual::new();
        let ctx = two_arch_ctx(Arc::new(Greedy::new()));
        let task = cross_arch_task(None);
        pressure(&ctx, 1, 4);
        src.feedback(&ctx.query(&task, Arch::Cuda), "cuda", 5e-2);
        src.feedback(&ctx.query(&task, Arch::Cuda), "cuda", 5e-2);
        let bands = src.export_bands().expect("has banded state");

        // fresh peer accepts every bucket; a re-import is a no-op
        let dst = Contextual::new();
        assert!(dst.export_bands().is_none(), "cold policy exports nothing");
        assert_eq!(dst.import_bands(&bands), 1);
        assert_eq!(dst.band_observations("c", "cuda", 64, 2), 2);
        assert_eq!(dst.import_bands(&bands), 0, "idempotent re-import");

        // local evidence with more observations is never regressed
        dst.feedback(&ctx.query(&task, Arch::Cuda), "cuda", 1e-2);
        assert_eq!(dst.import_bands(&bands), 0, "stale gossip loses");
        assert_eq!(dst.band_observations("c", "cuda", 64, 2), 3);

        // malformed payloads are ignored wholesale
        assert_eq!(dst.import_bands(&Json::Str("junk".into())), 0);
    }

    #[test]
    fn size_bands_are_log2() {
        assert_eq!(size_band(1), 1);
        assert_eq!(size_band(48), size_band(63));
        assert_ne!(size_band(63), size_band(64));
        assert_eq!(size_band(0), size_band(1), "size 0 clamps");
    }
}
