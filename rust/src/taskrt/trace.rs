//! Execution-trace export — the StarPU FxT/Vite analog, emitting the
//! chrome://tracing (Trace Event Format) JSON so runs can be inspected
//! visually: one lane per worker, one complete event per task with the
//! selected variant and transfer bytes as arguments.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::metrics::TaskResult;
use super::scheduler::WorkerInfo;
use crate::util::json::{to_string, Json};

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Build the Trace Event Format JSON value.
pub fn chrome_trace(results: &[TaskResult], workers: &[WorkerInfo]) -> Json {
    let mut events = Vec::new();
    // thread-name metadata per worker lane
    for w in workers {
        let mut args = BTreeMap::new();
        args.insert(
            "name".into(),
            s(&format!("worker {} ({})", w.id, w.arch.name())),
        );
        let mut ev = BTreeMap::new();
        ev.insert("ph".into(), s("M"));
        ev.insert("name".into(), s("thread_name"));
        ev.insert("pid".into(), num(1.0));
        ev.insert("tid".into(), num(w.id as f64));
        ev.insert("args".into(), Json::Obj(args));
        events.push(Json::Obj(ev));
    }
    for r in results {
        let mut args = BTreeMap::new();
        args.insert("variant".into(), s(&r.variant));
        args.insert("ctx".into(), num(r.ctx as f64));
        args.insert("size".into(), num(r.size as f64));
        args.insert("transfer_bytes".into(), num(r.transfer_bytes as f64));
        args.insert("modeled_exec_us".into(), num(r.modeled_exec * 1e6));
        args.insert(
            "modeled_transfer_us".into(),
            num(r.modeled_transfer * 1e6),
        );
        if r.trace != 0 {
            // request-scoped trace id (0 = untraced local submit)
            args.insert("trace".into(), num(r.trace as f64));
        }
        let mut ev = BTreeMap::new();
        ev.insert("ph".into(), s("X")); // complete event
        ev.insert("name".into(), s(&format!("{}:{}", r.codelet, r.variant)));
        ev.insert("cat".into(), s("task"));
        ev.insert("pid".into(), num(1.0));
        ev.insert("tid".into(), num(r.worker as f64));
        ev.insert("ts".into(), num(r.t_start * 1e6)); // µs
        ev.insert("dur".into(), num(((r.t_end - r.t_start) * 1e6).max(0.01)));
        ev.insert("args".into(), Json::Obj(args));
        events.push(Json::Obj(ev));
    }
    let mut root = BTreeMap::new();
    root.insert("traceEvents".into(), Json::Arr(events));
    root.insert("displayTimeUnit".into(), s("ms"));
    Json::Obj(root)
}

/// Write the trace to `path`.
pub fn export_chrome_trace(
    results: &[TaskResult],
    workers: &[WorkerInfo],
    path: &Path,
) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, to_string(&chrome_trace(results, workers)))
        .with_context(|| format!("writing trace to {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskrt::device::Arch;

    fn sample_result() -> TaskResult {
        TaskResult {
            task: 3,
            codelet: "mmul".into(),
            variant: "cuda".into(),
            worker: 1,
            ctx: 0,
            size: 128,
            wall: 0.001,
            modeled_exec: 0.002,
            modeled_transfer: 0.0005,
            transfer_bytes: 65536,
            t_start: 0.01,
            t_end: 0.011,
            tag: 0,
            trace: 0,
        }
    }

    fn sample_workers() -> Vec<WorkerInfo> {
        vec![
            WorkerInfo {
                id: 0,
                arch: Arch::Cpu,
                mem_node: 0,
            },
            WorkerInfo {
                id: 1,
                arch: Arch::Cuda,
                mem_node: 1,
            },
        ]
    }

    #[test]
    fn trace_structure() {
        let j = chrome_trace(&[sample_result()], &sample_workers());
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata + 1 task
        assert_eq!(events.len(), 3);
        let task = &events[2];
        assert_eq!(task.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(task.get("tid").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            task.get("args").unwrap().get("variant").unwrap().as_str(),
            Some("cuda")
        );
        // serializes to parseable JSON
        let text = to_string(&j);
        assert!(crate::util::json::parse(&text).is_ok());
    }

    #[test]
    fn export_writes_file() {
        let p = std::env::temp_dir().join(format!("compar_trace_{}.json", std::process::id()));
        export_chrome_trace(&[sample_result()], &sample_workers(), &p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("traceEvents"));
        let _ = std::fs::remove_file(&p);
    }
}
