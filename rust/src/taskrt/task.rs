//! Tasks and the dependency table.
//!
//! A task = one invocation of a codelet on a set of data handles
//! (StarPU `starpu_task`). Dependencies are implicit, derived from data
//! access order exactly like StarPU's sequential-consistency mode: the
//! `DataRegistry` reports which earlier tasks a new access conflicts
//! with, and the table holds the reverse edges until they resolve.

use std::collections::HashMap;
use std::sync::Arc;

use super::codelet::Codelet;
use super::data::{AccessMode, HandleId};
use super::selection::{Forced, SelectionPolicy};

pub type TaskId = u64;

/// What the application submits.
#[derive(Clone)]
pub struct TaskSpec {
    pub codelet: Arc<Codelet>,
    /// (handle, mode) per parameter, in declaration order.
    pub handles: Vec<(HandleId, AccessMode)>,
    /// Scale parameter for perf models / artifact lookup (paper `size`).
    pub size: usize,
    /// Per-task selection-policy override (None = the scheduling
    /// context's policy decides — the paper's headline feature). A
    /// pinned variant rides as a [`Forced`] policy; the serve layer
    /// attaches per-session policies here.
    pub selector: Option<Arc<dyn SelectionPolicy>>,
    /// Scheduling priority (higher runs earlier among ready tasks;
    /// StarPU's `starpu_task::priority`).
    pub priority: i32,
    /// Explicit dependencies in addition to the implicit data-driven
    /// ones (StarPU's `starpu_task_declare_deps`).
    pub after: Vec<TaskId>,
    /// Scheduling context to run under (StarPU's `sched_ctx`); tasks are
    /// placed only on the context's worker partition. 0 = default.
    pub ctx: crate::taskrt::CtxId,
    /// Opaque application tag carried through to [`super::metrics::TaskResult`]
    /// (StarPU's `starpu_task::tag_id`). The stream layer stamps each
    /// pipeline task with its chunk sequence number so per-chunk
    /// feedback and acks can be attributed without a side table. 0 =
    /// untagged.
    pub tag: u64,
    /// Cross-layer trace id (see [`crate::obs`]): minted by the serve
    /// layer at request admission, carried through the ready queues
    /// into [`super::metrics::TaskResult`] and every span the task
    /// emits, so one request's work correlates end-to-end in a
    /// `dump_trace` export. 0 = untraced.
    pub trace: u64,
}

impl TaskSpec {
    /// Build with the codelet's declared modes.
    pub fn new(codelet: Arc<Codelet>, handles: Vec<HandleId>, size: usize) -> TaskSpec {
        assert_eq!(
            handles.len(),
            codelet.modes.len(),
            "codelet {} wants {} parameters, got {}",
            codelet.name,
            codelet.modes.len(),
            handles.len()
        );
        let modes = codelet.modes.clone();
        TaskSpec {
            codelet,
            handles: handles.into_iter().zip(modes).collect(),
            size,
            selector: None,
            priority: 0,
            after: Vec::new(),
            ctx: crate::taskrt::DEFAULT_CTX,
            tag: 0,
            trace: 0,
        }
    }

    /// Submit under a scheduling context (see [`crate::taskrt::Runtime::create_context`]).
    pub fn in_context(mut self, ctx: crate::taskrt::CtxId) -> TaskSpec {
        self.ctx = ctx;
        self
    }

    /// Pin this task to one variant: sugar for a per-task [`Forced`]
    /// selection policy.
    pub fn with_variant(self, v: &str) -> TaskSpec {
        self.with_selector(Arc::new(Forced::new(v)))
    }

    /// Run this task under its own selection policy instead of the
    /// scheduling context's (per-session policies in the serve layer).
    pub fn with_selector(mut self, s: Arc<dyn SelectionPolicy>) -> TaskSpec {
        self.selector = Some(s);
        self
    }

    pub fn with_priority(mut self, p: i32) -> TaskSpec {
        self.priority = p;
        self
    }

    /// Explicit ordering: this task runs only after `deps` finish.
    pub fn after(mut self, deps: &[TaskId]) -> TaskSpec {
        self.after.extend_from_slice(deps);
        self
    }

    /// Stamp an opaque application tag (carried into the task's result).
    pub fn with_tag(mut self, tag: u64) -> TaskSpec {
        self.tag = tag;
        self
    }

    /// Stamp the cross-layer trace id (carried into the task's result
    /// and every span it emits). 0 = untraced.
    pub fn with_trace(mut self, trace: u64) -> TaskSpec {
        self.trace = trace;
        self
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskState {
    /// Waiting on dependencies.
    Blocked,
    /// In a scheduler queue.
    Ready,
    /// Executing on a worker.
    Running,
    Done,
    Failed,
}

pub struct TaskRecord {
    pub spec: TaskSpec,
    pub state: TaskState,
    pub remaining_deps: usize,
    pub dependents: Vec<TaskId>,
    pub error: Option<String>,
}

/// Dependency table. All mutation happens under the runtime's lock.
#[derive(Default)]
pub struct TaskTable {
    next_id: TaskId,
    pub records: HashMap<TaskId, TaskRecord>,
}

impl TaskTable {
    pub fn new() -> TaskTable {
        Self::default()
    }

    /// The id the next `insert` will assign.
    pub fn next_id(&self) -> TaskId {
        self.next_id
    }

    /// Insert a new task with its dependency list; returns (id, ready).
    pub fn insert(&mut self, spec: TaskSpec, deps: &[TaskId]) -> (TaskId, bool) {
        let id = self.next_id;
        self.next_id += 1;
        // Only count deps that are still live and not finished.
        let mut remaining = 0;
        for &d in deps {
            if let Some(rec) = self.records.get_mut(&d) {
                if rec.state != TaskState::Done && rec.state != TaskState::Failed {
                    rec.dependents.push(id);
                    remaining += 1;
                }
            }
        }
        let ready = remaining == 0;
        self.records.insert(
            id,
            TaskRecord {
                spec,
                state: if ready {
                    TaskState::Ready
                } else {
                    TaskState::Blocked
                },
                remaining_deps: remaining,
                dependents: Vec::new(),
                error: None,
            },
        );
        (id, ready)
    }

    /// Mark `id` finished; returns dependents that became ready.
    pub fn complete(&mut self, id: TaskId, error: Option<String>) -> Vec<TaskId> {
        let dependents = {
            let rec = self.records.get_mut(&id).expect("unknown task");
            rec.state = if error.is_some() {
                TaskState::Failed
            } else {
                TaskState::Done
            };
            rec.error = error;
            std::mem::take(&mut rec.dependents)
        };
        let mut ready = Vec::new();
        for d in dependents {
            if let Some(rec) = self.records.get_mut(&d) {
                rec.remaining_deps -= 1;
                if rec.remaining_deps == 0 && rec.state == TaskState::Blocked {
                    rec.state = TaskState::Ready;
                    ready.push(d);
                }
            }
        }
        ready
    }

    pub fn state(&self, id: TaskId) -> Option<TaskState> {
        self.records.get(&id).map(|r| r.state)
    }

    /// First stored error, if any task failed.
    pub fn first_error(&self) -> Option<String> {
        self.records
            .values()
            .find_map(|r| r.error.clone())
    }

    /// Error recorded for a specific task, if it failed.
    pub fn error(&self, id: TaskId) -> Option<String> {
        self.records.get(&id).and_then(|r| r.error.clone())
    }

    /// Drop the records of finished (Done/Failed) tasks so a long-running
    /// service does not accumulate one record per request forever. Tasks
    /// still Blocked/Ready/Running are left alone; dependents of a reaped
    /// task were already released at completion time.
    pub fn remove_finished(&mut self, ids: &[TaskId]) {
        for id in ids {
            if matches!(
                self.records.get(id).map(|r| r.state),
                Some(TaskState::Done) | Some(TaskState::Failed)
            ) {
                self.records.remove(id);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskrt::codelet::Codelet;
    use crate::taskrt::data::AccessMode;

    fn spec() -> TaskSpec {
        let c = Arc::new(Codelet::new("t", "matmul", vec![AccessMode::Read]));
        TaskSpec::new(c, vec![HandleId(0)], 8)
    }

    #[test]
    fn no_deps_is_ready() {
        let mut t = TaskTable::new();
        let (id, ready) = t.insert(spec(), &[]);
        assert!(ready);
        assert_eq!(t.state(id), Some(TaskState::Ready));
    }

    #[test]
    fn chain_releases_in_order() {
        let mut t = TaskTable::new();
        let (a, _) = t.insert(spec(), &[]);
        let (b, ready_b) = t.insert(spec(), &[a]);
        let (c, ready_c) = t.insert(spec(), &[b]);
        assert!(!ready_b && !ready_c);
        let freed = t.complete(a, None);
        assert_eq!(freed, vec![b]);
        let freed = t.complete(b, None);
        assert_eq!(freed, vec![c]);
    }

    #[test]
    fn diamond_waits_for_both() {
        let mut t = TaskTable::new();
        let (a, _) = t.insert(spec(), &[]);
        let (b, _) = t.insert(spec(), &[]);
        let (c, ready) = t.insert(spec(), &[a, b]);
        assert!(!ready);
        assert!(t.complete(a, None).is_empty());
        assert_eq!(t.complete(b, None), vec![c]);
    }

    #[test]
    fn deps_on_finished_tasks_ignored() {
        let mut t = TaskTable::new();
        let (a, _) = t.insert(spec(), &[]);
        t.complete(a, None);
        let (_b, ready) = t.insert(spec(), &[a]);
        assert!(ready, "dependency on a Done task must not block");
    }

    #[test]
    fn failure_propagates_error() {
        let mut t = TaskTable::new();
        let (a, _) = t.insert(spec(), &[]);
        t.complete(a, Some("boom".into()));
        assert_eq!(t.state(a), Some(TaskState::Failed));
        assert_eq!(t.first_error().as_deref(), Some("boom"));
    }

    #[test]
    #[should_panic(expected = "parameters")]
    fn arity_mismatch_panics() {
        let c = Arc::new(Codelet::new("t", "x", vec![AccessMode::Read, AccessMode::Write]));
        TaskSpec::new(c, vec![HandleId(0)], 8);
    }

    #[test]
    fn in_context_sets_ctx() {
        assert_eq!(spec().ctx, 0);
        assert_eq!(spec().in_context(3).ctx, 3);
    }

    #[test]
    fn with_tag_sets_tag() {
        assert_eq!(spec().tag, 0);
        assert_eq!(spec().with_tag(17).tag, 17);
    }

    #[test]
    fn with_trace_sets_trace() {
        assert_eq!(spec().trace, 0);
        assert_eq!(spec().with_trace(99).trace, 99);
    }

    #[test]
    fn remove_finished_reaps_only_done() {
        let mut t = TaskTable::new();
        let (a, _) = t.insert(spec(), &[]);
        let (b, _) = t.insert(spec(), &[a]);
        t.complete(a, Some("boom".into()));
        t.remove_finished(&[a, b]);
        assert_eq!(t.state(a), None, "failed task reaped");
        assert!(t.state(b).is_some(), "ready task kept");
        assert_eq!(t.error(a), None);
    }
}
