//! History-based performance models — StarPU's `starpu_perfmodel` analog.
//!
//! Each (codelet, variant) pair owns a model keyed by input footprint
//! (the task's `size` parameter). Observed execution times accumulate
//! into per-size buckets (Welford running mean/variance, plus an
//! exponentially-decayed mean for drift tracking); estimates for unseen
//! sizes come from a power-law regression t = a * n^b fitted over the
//! bucket means in log-log space — the same family StarPU's
//! `STARPU_REGRESSION_BASED` models use.
//!
//! ## Cluster gossip
//!
//! Since the `compar cluster` work a store holds two layers:
//!
//! * **local** — observations measured by *this* process. This is what
//!   [`PerfModels::to_json`] serializes, what persists to disk, and the
//!   only thing a shard ever ships over the wire (`perf_pull`).
//! * **remote** — a gossip overlay: the Welford-combined summary of the
//!   *other* shards' local observations, installed wholesale by
//!   `perf_push` ([`PerfModels::set_remote_json`]). Replacing (rather
//!   than accumulating) the overlay keeps gossip idempotent — repeated
//!   rounds can never double-count a sample — and because each bucket
//!   ships as a fixed-size summary (count, mean, M2, ewma, updated), a
//!   gossip message is bounded by the number of (codelet, variant, size)
//!   triples regardless of traffic volume. Decayed means merge by
//!   *recency* (the fresher [`Bucket::updated`] stamp wins), so a
//!   drifting shard's observations dominate stale ones.
//!
//! Every query (estimate / calibration status / sample counts) answers
//! from the pairwise Welford-combine of both layers, so a variant
//! calibrated on one shard is immediately calibrated everywhere the
//! gossip reaches.
//!
//! Models persist as JSON under `$COMPAR_PERFMODEL_DIR` so calibration
//! survives across runs (StarPU's ~/.starpu/sampling analog).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::RwLock;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// Minimum observations in a bucket before its mean is trusted.
pub const MIN_SAMPLES: usize = 3;

/// Per-observation weight of [`Bucket::ewma`], the exponentially-decayed
/// mean: after a real performance shift the decayed estimate recovers in
/// O(1/alpha) observations while the cumulative mean needs O(count).
pub const EWMA_ALPHA: f64 = 0.3;

/// One footprint bucket: Welford accumulator + decayed mean.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Bucket {
    pub count: usize,
    pub mean: f64,
    m2: f64,
    /// Exponentially-decayed mean (weight [`EWMA_ALPHA`] per sample);
    /// policies opt in via [`VariantModel::estimate_recent`].
    pub ewma: f64,
    /// Unix seconds of the last recorded observation — what
    /// [`Bucket::merge`] uses to weight decayed means by *recency*
    /// (gossip: a drifting shard's fresh observations must dominate a
    /// stale count-heavy history).
    pub updated: f64,
}

fn unix_now() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

impl Bucket {
    pub fn record(&mut self, t: f64) {
        self.count += 1;
        let delta = t - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (t - self.mean);
        self.ewma = if self.count == 1 {
            t
        } else {
            self.ewma + EWMA_ALPHA * (t - self.ewma)
        };
        self.updated = unix_now();
    }

    pub fn stddev(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            (self.m2 / (self.count - 1) as f64).sqrt()
        }
    }

    /// Combine another accumulator into this one (Chan et al.'s
    /// parallel-Welford update): the result is bit-for-bit the same
    /// count/mean and the same variance as if both sample streams had
    /// been recorded into a single bucket. The decayed means (which are
    /// order-dependent by construction) combine by *recency*: the side
    /// with the fresher [`Bucket::updated`] stamp wins outright, so a
    /// drifting shard's recent observations dominate another shard's
    /// stale count-heavy history (exact timestamp ties — e.g. streams
    /// split from one recording process — blend count-weighted). Either
    /// merge order yields the same result.
    pub fn merge(&mut self, other: &Bucket) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        let na = self.count as f64;
        let nb = other.count as f64;
        let n = na + nb;
        let delta = other.mean - self.mean;
        self.mean += delta * nb / n;
        self.m2 += other.m2 + delta * delta * na * nb / n;
        self.ewma = if other.updated > self.updated {
            other.ewma
        } else if self.updated > other.updated {
            self.ewma
        } else {
            (self.ewma * na + other.ewma * nb) / n
        };
        self.updated = self.updated.max(other.updated);
        self.count += other.count;
    }
}

/// Model for one (codelet, variant) pair.
#[derive(Debug, Clone, Default)]
pub struct VariantModel {
    /// size -> observations
    pub buckets: BTreeMap<usize, Bucket>,
}

impl VariantModel {
    pub fn record(&mut self, size: usize, t: f64) {
        self.buckets.entry(size).or_default().record(t);
    }

    pub fn total_samples(&self) -> usize {
        self.buckets.values().map(|b| b.count).sum()
    }

    /// Welford-combine another model's buckets into this one.
    pub fn merge(&mut self, other: &VariantModel) {
        for (size, b) in &other.buckets {
            self.buckets.entry(*size).or_default().merge(b);
        }
    }

    /// Power-law fit t = a * n^b over trusted buckets (log-log least
    /// squares). Returns (a, b) when >= 2 trusted buckets exist.
    pub fn regression(&self) -> Option<(f64, f64)> {
        let pts: Vec<(f64, f64)> = self
            .buckets
            .iter()
            .filter(|(s, b)| b.count >= MIN_SAMPLES && **s > 0 && b.mean > 0.0)
            .map(|(s, b)| ((*s as f64).ln(), b.mean.ln()))
            .collect();
        if pts.len() < 2 {
            return None;
        }
        let n = pts.len() as f64;
        let sx: f64 = pts.iter().map(|p| p.0).sum();
        let sy: f64 = pts.iter().map(|p| p.1).sum();
        let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
        let denom = n * sxx - sx * sx;
        if denom.abs() < 1e-12 {
            return None;
        }
        let b = (n * sxy - sx * sy) / denom;
        let a = ((sy - b * sx) / n).exp();
        Some((a, b))
    }

    /// Estimated execution time at `size`, if the model knows enough:
    /// exact trusted bucket first, regression fallback second.
    pub fn estimate(&self, size: usize) -> Option<f64> {
        if let Some(b) = self.buckets.get(&size) {
            if b.count >= MIN_SAMPLES {
                return Some(b.mean);
            }
        }
        self.regression().map(|(a, b)| a * (size as f64).powf(b))
    }

    /// Like [`VariantModel::estimate`] but the trusted-bucket answer is
    /// the exponentially-decayed mean, so drift-tracking policies see a
    /// recent shift within a few observations instead of waiting for the
    /// cumulative mean to move.
    pub fn estimate_recent(&self, size: usize) -> Option<f64> {
        if let Some(b) = self.buckets.get(&size) {
            if b.count >= MIN_SAMPLES {
                return Some(b.ewma);
            }
        }
        self.regression().map(|(a, b)| a * (size as f64).powf(b))
    }

    /// Whether `size` still needs calibration runs.
    pub fn needs_calibration(&self, size: usize) -> bool {
        self.buckets.get(&size).map_or(true, |b| b.count < MIN_SAMPLES)
    }
}

// ----------------------------------------------------- (de)serialization

/// Serialize a model map (the gossip wire form and the on-disk form):
/// `{ "codelet:variant": { "SIZE": {count, mean, m2, ewma, updated} } }`.
pub fn models_to_json(models: &BTreeMap<String, VariantModel>) -> Json {
    let mut obj = BTreeMap::new();
    for (k, m) in models {
        let mut buckets = BTreeMap::new();
        for (size, b) in &m.buckets {
            let mut rec = BTreeMap::new();
            rec.insert("count".into(), Json::Num(b.count as f64));
            rec.insert("mean".into(), Json::Num(b.mean));
            rec.insert("m2".into(), Json::Num(b.m2));
            rec.insert("ewma".into(), Json::Num(b.ewma));
            rec.insert("updated".into(), Json::Num(b.updated));
            buckets.insert(size.to_string(), Json::Obj(rec));
        }
        obj.insert(k.clone(), Json::Obj(buckets));
    }
    Json::Obj(obj)
}

/// Parse a model map (tolerant: malformed entries are skipped).
pub fn parse_models(v: &Json) -> BTreeMap<String, VariantModel> {
    let mut out: BTreeMap<String, VariantModel> = BTreeMap::new();
    if let Some(obj) = v.as_obj() {
        for (k, buckets) in obj {
            let m = out.entry(k.clone()).or_default();
            if let Some(bo) = buckets.as_obj() {
                for (size, rec) in bo {
                    if let (Ok(size), Some(count), Some(mean)) = (
                        size.parse::<usize>(),
                        rec.get("count").and_then(Json::as_f64),
                        rec.get("mean").and_then(Json::as_f64),
                    ) {
                        let b = m.buckets.entry(size).or_default();
                        b.count = count as usize;
                        b.mean = mean;
                        b.m2 = rec.get("m2").and_then(Json::as_f64).unwrap_or(0.0);
                        b.ewma = rec.get("ewma").and_then(Json::as_f64).unwrap_or(mean);
                        // pre-recency records count as infinitely stale
                        b.updated = rec.get("updated").and_then(Json::as_f64).unwrap_or(0.0);
                    }
                }
            }
        }
    }
    out
}

/// Welford-combine `from` into `into` (the router's cross-shard merge).
pub fn merge_models(
    into: &mut BTreeMap<String, VariantModel>,
    from: &BTreeMap<String, VariantModel>,
) {
    for (k, m) in from {
        into.entry(k.clone()).or_default().merge(m);
    }
}

// ---------------------------------------------------------- the registry

/// Registry of all models, keyed "codelet:variant": locally observed
/// samples plus a replaceable gossip overlay of remote observations
/// (see the module docs).
#[derive(Default)]
pub struct PerfModels {
    /// Observations measured by this process (serialized / persisted).
    models: RwLock<BTreeMap<String, VariantModel>>,
    /// Gossip overlay: combined summary of other shards' local models.
    remote: RwLock<BTreeMap<String, VariantModel>>,
}

/// The composite "codelet:variant" map key — shared with the selection
/// policies so observation counters and models stay keyed identically.
pub(crate) fn key(codelet: &str, variant: &str) -> String {
    format!("{codelet}:{variant}")
}

impl PerfModels {
    pub fn new() -> PerfModels {
        Self::default()
    }

    pub fn record(&self, codelet: &str, variant: &str, size: usize, t: f64) {
        self.models
            .write()
            .unwrap()
            .entry(key(codelet, variant))
            .or_default()
            .record(size, t);
    }

    /// Run `f` over the combined (local ⊕ remote) model for `k`, without
    /// cloning when only one layer knows the key. Lock order is always
    /// local-then-remote.
    fn with_combined<R>(&self, k: &str, f: impl FnOnce(&VariantModel) -> R) -> Option<R> {
        let models = self.models.read().unwrap();
        let remote = self.remote.read().unwrap();
        match (models.get(k), remote.get(k)) {
            (None, None) => None,
            (Some(l), None) => Some(f(l)),
            (None, Some(r)) => Some(f(r)),
            (Some(l), Some(r)) => {
                let mut m = l.clone();
                m.merge(r);
                Some(f(&m))
            }
        }
    }

    /// Combined (local ⊕ remote) bucket for (key, size) — the fast path
    /// of the bucket-exact queries below: merges just two small buckets
    /// instead of cloning a whole model. These queries sit on the
    /// scheduler's per-decision path, once per eligible variant.
    fn combined_bucket(&self, k: &str, size: usize) -> Option<Bucket> {
        let models = self.models.read().unwrap();
        let remote = self.remote.read().unwrap();
        let lb = models.get(k).and_then(|m| m.buckets.get(&size));
        let rb = remote.get(k).and_then(|m| m.buckets.get(&size));
        match (lb, rb) {
            (None, None) => None,
            (Some(b), None) | (None, Some(b)) => Some(b.clone()),
            (Some(l), Some(r)) => {
                let mut b = l.clone();
                b.merge(r);
                Some(b)
            }
        }
    }

    pub fn estimate(&self, codelet: &str, variant: &str, size: usize) -> Option<f64> {
        let k = key(codelet, variant);
        if let Some(b) = self.combined_bucket(&k, size) {
            if b.count >= MIN_SAMPLES {
                return Some(b.mean);
            }
        }
        // untrusted/unseen size: regression over the merged model (the
        // rare path — this one does pay for a full combine)
        self.with_combined(&k, |m| {
            m.regression().map(|(a, b)| a * (size as f64).powf(b))
        })
        .flatten()
    }

    /// Decayed-mean estimate (drift-tracking policies opt in).
    pub fn estimate_recent(&self, codelet: &str, variant: &str, size: usize) -> Option<f64> {
        let k = key(codelet, variant);
        if let Some(b) = self.combined_bucket(&k, size) {
            if b.count >= MIN_SAMPLES {
                return Some(b.ewma);
            }
        }
        self.with_combined(&k, |m| {
            m.regression().map(|(a, b)| a * (size as f64).powf(b))
        })
        .flatten()
    }

    pub fn needs_calibration(&self, codelet: &str, variant: &str, size: usize) -> bool {
        self.combined_bucket(&key(codelet, variant), size)
            .map_or(true, |b| b.count < MIN_SAMPLES)
    }

    pub fn samples(&self, codelet: &str, variant: &str) -> usize {
        let k = key(codelet, variant);
        let models = self.models.read().unwrap();
        let remote = self.remote.read().unwrap();
        models.get(&k).map_or(0, |m| m.total_samples())
            + remote.get(&k).map_or(0, |m| m.total_samples())
    }

    /// Serialize the *locally observed* models only — the gossip payload
    /// (`perf_pull`) and the persistence record. The remote overlay is
    /// deliberately excluded so a shard never re-ships samples it
    /// received through gossip (which would double-count them).
    pub fn to_json(&self) -> Json {
        models_to_json(&self.models.read().unwrap())
    }

    /// Merge serialized models into the local layer (persistence load).
    pub fn load_json(&self, v: &Json) {
        let parsed = parse_models(v);
        merge_models(&mut self.models.write().unwrap(), &parsed);
    }

    /// Install a gossip overlay (`perf_push`), *replacing* the previous
    /// one — idempotent by construction. Returns the number of (key,
    /// size) buckets installed.
    pub fn set_remote_json(&self, v: &Json) -> usize {
        let parsed = parse_models(v);
        let n = parsed.values().map(|m| m.buckets.len()).sum();
        *self.remote.write().unwrap() = parsed;
        n
    }

    /// Buckets currently in the gossip overlay (diagnostics / tests).
    pub fn remote_buckets(&self) -> usize {
        self.remote
            .read()
            .unwrap()
            .values()
            .map(|m| m.buckets.len())
            .sum()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, json::to_string(&self.to_json()))
            .with_context(|| format!("writing perf models to {}", path.display()))
    }

    pub fn load(&self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading perf models from {}", path.display()))?;
        let v = json::parse(&text)?;
        self.load_json(&v);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_welford() {
        let mut b = Bucket::default();
        for t in [1.0, 2.0, 3.0] {
            b.record(t);
        }
        assert_eq!(b.count, 3);
        assert!((b.mean - 2.0).abs() < 1e-12);
        assert!((b.stddev() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn welford_combine_matches_single_stream() {
        // property: merging the buckets of any split of a sample stream
        // reproduces the single-stream count, mean and variance
        let samples: Vec<f64> = (0..40)
            .map(|i| 0.5 + 0.013 * (i as f64) + if i % 3 == 0 { 0.2 } else { -0.1 })
            .collect();
        let mut whole = Bucket::default();
        for &t in &samples {
            whole.record(t);
        }
        for split in [1usize, 7, 20, 39] {
            let (mut a, mut b) = (Bucket::default(), Bucket::default());
            for &t in &samples[..split] {
                a.record(t);
            }
            for &t in &samples[split..] {
                b.record(t);
            }
            a.merge(&b);
            assert_eq!(a.count, whole.count, "split {split}");
            assert!((a.mean - whole.mean).abs() < 1e-12, "split {split}");
            assert!((a.stddev() - whole.stddev()).abs() < 1e-9, "split {split}");
        }
        // merging an empty bucket in either direction is the identity
        let mut a = whole.clone();
        a.merge(&Bucket::default());
        assert_eq!(a, whole);
        let mut e = Bucket::default();
        e.merge(&whole);
        assert_eq!(e, whole);
    }

    #[test]
    fn merge_prefers_fresher_decayed_mean_in_either_order() {
        // "stale" shard: a long, count-heavy history that converged at
        // 1 ms long ago; "fresh" shard: few recent samples at 100 ms
        // (post-drift). The merged decayed mean must be the fresh one —
        // count-weighting would bury the drift under the stale history.
        let mut stale = Bucket::default();
        for _ in 0..100 {
            stale.record(1e-3);
        }
        stale.updated = 1_000.0;
        let mut fresh = Bucket::default();
        for _ in 0..3 {
            fresh.record(0.1);
        }
        fresh.updated = 2_000.0;

        let mut ab = stale.clone();
        ab.merge(&fresh);
        let mut ba = fresh.clone();
        ba.merge(&stale);
        for (label, m) in [("stale<-fresh", &ab), ("fresh<-stale", &ba)] {
            assert!(
                (m.ewma - fresh.ewma).abs() < 1e-12,
                "{label}: decayed mean {} should be the fresh {}",
                m.ewma,
                fresh.ewma
            );
            assert_eq!(m.updated, 2_000.0, "{label}");
            // the Welford layer still combines exactly
            assert_eq!(m.count, 103, "{label}");
        }
        assert!((ab.mean - ba.mean).abs() < 1e-12, "merge is order-independent");
        // equal timestamps (one stream split in two) blend count-weighted
        let mut a = Bucket {
            count: 1,
            mean: 1.0,
            ewma: 1.0,
            updated: 5.0,
            ..Bucket::default()
        };
        let b = Bucket {
            count: 3,
            mean: 2.0,
            ewma: 2.0,
            updated: 5.0,
            ..Bucket::default()
        };
        a.merge(&b);
        assert!((a.ewma - 1.75).abs() < 1e-12, "tie blends by count: {}", a.ewma);
    }

    #[test]
    fn bucket_timestamp_survives_the_wire() {
        let mut m: BTreeMap<String, VariantModel> = BTreeMap::new();
        m.entry("c:x".into()).or_default().record(8, 1.0);
        let stamped = m["c:x"].buckets[&8].updated;
        assert!(stamped > 0.0, "record() must stamp recency");
        let back = parse_models(&models_to_json(&m));
        assert_eq!(back["c:x"].buckets[&8].updated, stamped);
        // records without a stamp (pre-recency wire format) parse as
        // infinitely stale rather than failing
        let legacy = json::parse(r#"{"c:x":{"8":{"count":3,"mean":0.5}}}"#).unwrap();
        let parsed = parse_models(&legacy);
        assert_eq!(parsed["c:x"].buckets[&8].updated, 0.0);
    }

    #[test]
    fn ewma_recovers_from_drift_faster_than_cumulative_mean() {
        let mut b = Bucket::default();
        for _ in 0..50 {
            b.record(0.001);
        }
        for _ in 0..5 {
            b.record(1.0);
        }
        // cumulative mean barely moved; the decayed mean is mostly there
        assert!(b.mean < 0.2, "cumulative {}", b.mean);
        assert!(b.ewma > 0.5, "decayed {}", b.ewma);
    }

    #[test]
    fn estimate_prefers_exact_bucket() {
        let mut m = VariantModel::default();
        for _ in 0..MIN_SAMPLES {
            m.record(64, 0.5);
        }
        assert_eq!(m.estimate(64), Some(0.5));
        assert_eq!(m.estimate_recent(64), Some(0.5));
    }

    #[test]
    fn regression_extrapolates_cubic() {
        let mut m = VariantModel::default();
        // t = 1e-9 * n^3
        for n in [64usize, 128, 256] {
            for _ in 0..MIN_SAMPLES {
                m.record(n, 1e-9 * (n as f64).powi(3));
            }
        }
        let (a, b) = m.regression().unwrap();
        assert!((b - 3.0).abs() < 0.01, "exponent {b}");
        assert!((a - 1e-9).abs() / 1e-9 < 0.05, "coeff {a}");
        let est = m.estimate(1024).unwrap();
        let truth = 1e-9 * 1024f64.powi(3);
        assert!((est - truth).abs() / truth < 0.05);
    }

    #[test]
    fn calibration_threshold() {
        let mut m = VariantModel::default();
        assert!(m.needs_calibration(32));
        for _ in 0..MIN_SAMPLES {
            m.record(32, 1.0);
        }
        assert!(!m.needs_calibration(32));
        // other sizes still uncalibrated
        assert!(m.needs_calibration(64));
    }

    #[test]
    fn registry_roundtrip() {
        let p = PerfModels::new();
        for _ in 0..4 {
            p.record("mmul", "cuda", 128, 0.25);
        }
        let j = p.to_json();
        let q = PerfModels::new();
        q.load_json(&j);
        assert_eq!(q.estimate("mmul", "cuda", 128), Some(0.25));
        assert_eq!(q.samples("mmul", "cuda"), 4);
    }

    #[test]
    fn remote_overlay_calibrates_and_replaces() {
        // "shard A" observed enough samples; "shard B" has none locally
        let a = PerfModels::new();
        for _ in 0..MIN_SAMPLES {
            a.record("mmul", "omp", 48, 0.01);
        }
        let b = PerfModels::new();
        assert!(b.needs_calibration("mmul", "omp", 48));
        let installed = b.set_remote_json(&a.to_json());
        assert_eq!(installed, 1);
        // B is now calibrated at that size without local observations
        assert!(!b.needs_calibration("mmul", "omp", 48));
        assert_eq!(b.estimate("mmul", "omp", 48), Some(0.01));
        assert_eq!(b.samples("mmul", "omp"), MIN_SAMPLES);
        // queries combine local + remote pairwise
        b.record("mmul", "omp", 48, 0.03);
        assert_eq!(b.samples("mmul", "omp"), MIN_SAMPLES + 1);
        let est = b.estimate("mmul", "omp", 48).unwrap();
        let want = (0.01 * MIN_SAMPLES as f64 + 0.03) / (MIN_SAMPLES + 1) as f64;
        assert!((est - want).abs() < 1e-12, "{est} vs {want}");
        // re-pushing the same overlay replaces it: no double counting
        b.set_remote_json(&a.to_json());
        assert_eq!(b.samples("mmul", "omp"), MIN_SAMPLES + 1);
        // B's own wire payload ships only its local observation
        let shipped = parse_models(&b.to_json());
        assert_eq!(shipped["mmul:omp"].total_samples(), 1);
        // clearing the overlay decalibrates again
        b.set_remote_json(&Json::Obj(BTreeMap::new()));
        assert_eq!(b.remote_buckets(), 0);
        assert!(b.needs_calibration("mmul", "omp", 48));
    }

    #[test]
    fn model_map_merge_and_roundtrip() {
        let mut a: BTreeMap<String, VariantModel> = BTreeMap::new();
        a.entry("c:x".into()).or_default().record(8, 1.0);
        a.entry("c:x".into()).or_default().record(8, 3.0);
        let mut b: BTreeMap<String, VariantModel> = BTreeMap::new();
        b.entry("c:x".into()).or_default().record(8, 2.0);
        b.entry("c:y".into()).or_default().record(16, 5.0);
        let mut merged = a.clone();
        merge_models(&mut merged, &b);
        assert_eq!(merged["c:x"].buckets[&8].count, 3);
        assert!((merged["c:x"].buckets[&8].mean - 2.0).abs() < 1e-12);
        assert_eq!(merged["c:y"].total_samples(), 1);
        // wire roundtrip preserves the welford state
        let back = parse_models(&models_to_json(&merged));
        assert_eq!(back["c:x"].buckets[&8], merged["c:x"].buckets[&8]);
    }

    #[test]
    fn persistence() {
        let dir = std::env::temp_dir().join("compar_pm_test");
        let path = dir.join("models.json");
        let p = PerfModels::new();
        for _ in 0..3 {
            p.record("sort", "omp", 1024, 0.001);
        }
        p.save(&path).unwrap();
        let q = PerfModels::new();
        q.load(&path).unwrap();
        assert_eq!(q.estimate("sort", "omp", 1024), Some(0.001));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
