//! History-based performance models — StarPU's `starpu_perfmodel` analog.
//!
//! Each (codelet, variant) pair owns a model keyed by input footprint
//! (the task's `size` parameter). Observed execution times accumulate
//! into per-size buckets (Welford running mean/variance); estimates for
//! unseen sizes come from a power-law regression t = a * n^b fitted over
//! the bucket means in log-log space — the same family StarPU's
//! `STARPU_REGRESSION_BASED` models use.
//!
//! Models persist as JSON under `$COMPAR_PERFMODEL_DIR` so calibration
//! survives across runs (StarPU's ~/.starpu/sampling analog).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::RwLock;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// Minimum observations in a bucket before its mean is trusted.
pub const MIN_SAMPLES: usize = 3;

/// One footprint bucket: Welford accumulator.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Bucket {
    pub count: usize,
    pub mean: f64,
    m2: f64,
}

impl Bucket {
    pub fn record(&mut self, t: f64) {
        self.count += 1;
        let delta = t - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (t - self.mean);
    }

    pub fn stddev(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            (self.m2 / (self.count - 1) as f64).sqrt()
        }
    }
}

/// Model for one (codelet, variant) pair.
#[derive(Debug, Clone, Default)]
pub struct VariantModel {
    /// size -> observations
    pub buckets: BTreeMap<usize, Bucket>,
}

impl VariantModel {
    pub fn record(&mut self, size: usize, t: f64) {
        self.buckets.entry(size).or_default().record(t);
    }

    pub fn total_samples(&self) -> usize {
        self.buckets.values().map(|b| b.count).sum()
    }

    /// Power-law fit t = a * n^b over trusted buckets (log-log least
    /// squares). Returns (a, b) when >= 2 trusted buckets exist.
    pub fn regression(&self) -> Option<(f64, f64)> {
        let pts: Vec<(f64, f64)> = self
            .buckets
            .iter()
            .filter(|(s, b)| b.count >= MIN_SAMPLES && **s > 0 && b.mean > 0.0)
            .map(|(s, b)| ((*s as f64).ln(), b.mean.ln()))
            .collect();
        if pts.len() < 2 {
            return None;
        }
        let n = pts.len() as f64;
        let sx: f64 = pts.iter().map(|p| p.0).sum();
        let sy: f64 = pts.iter().map(|p| p.1).sum();
        let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
        let denom = n * sxx - sx * sx;
        if denom.abs() < 1e-12 {
            return None;
        }
        let b = (n * sxy - sx * sy) / denom;
        let a = ((sy - b * sx) / n).exp();
        Some((a, b))
    }

    /// Estimated execution time at `size`, if the model knows enough:
    /// exact trusted bucket first, regression fallback second.
    pub fn estimate(&self, size: usize) -> Option<f64> {
        if let Some(b) = self.buckets.get(&size) {
            if b.count >= MIN_SAMPLES {
                return Some(b.mean);
            }
        }
        self.regression().map(|(a, b)| a * (size as f64).powf(b))
    }

    /// Whether `size` still needs calibration runs.
    pub fn needs_calibration(&self, size: usize) -> bool {
        self.buckets.get(&size).map_or(true, |b| b.count < MIN_SAMPLES)
    }
}

/// Registry of all models, keyed "codelet:variant".
#[derive(Default)]
pub struct PerfModels {
    models: RwLock<BTreeMap<String, VariantModel>>,
}

/// The composite "codelet:variant" map key — shared with the selection
/// policies so observation counters and models stay keyed identically.
pub(crate) fn key(codelet: &str, variant: &str) -> String {
    format!("{codelet}:{variant}")
}

impl PerfModels {
    pub fn new() -> PerfModels {
        Self::default()
    }

    pub fn record(&self, codelet: &str, variant: &str, size: usize, t: f64) {
        self.models
            .write()
            .unwrap()
            .entry(key(codelet, variant))
            .or_default()
            .record(size, t);
    }

    pub fn estimate(&self, codelet: &str, variant: &str, size: usize) -> Option<f64> {
        self.models
            .read()
            .unwrap()
            .get(&key(codelet, variant))
            .and_then(|m| m.estimate(size))
    }

    pub fn needs_calibration(&self, codelet: &str, variant: &str, size: usize) -> bool {
        self.models
            .read()
            .unwrap()
            .get(&key(codelet, variant))
            .map_or(true, |m| m.needs_calibration(size))
    }

    pub fn samples(&self, codelet: &str, variant: &str) -> usize {
        self.models
            .read()
            .unwrap()
            .get(&key(codelet, variant))
            .map_or(0, |m| m.total_samples())
    }

    /// Serialize all models to JSON.
    pub fn to_json(&self) -> Json {
        let models = self.models.read().unwrap();
        let mut obj = BTreeMap::new();
        for (k, m) in models.iter() {
            let mut buckets = BTreeMap::new();
            for (size, b) in &m.buckets {
                let mut rec = BTreeMap::new();
                rec.insert("count".into(), Json::Num(b.count as f64));
                rec.insert("mean".into(), Json::Num(b.mean));
                rec.insert("m2".into(), Json::Num(b.m2));
                buckets.insert(size.to_string(), Json::Obj(rec));
            }
            obj.insert(k.clone(), Json::Obj(buckets));
        }
        Json::Obj(obj)
    }

    pub fn load_json(&self, v: &Json) {
        let mut models = self.models.write().unwrap();
        if let Some(obj) = v.as_obj() {
            for (k, buckets) in obj {
                let m = models.entry(k.clone()).or_default();
                if let Some(bo) = buckets.as_obj() {
                    for (size, rec) in bo {
                        if let (Ok(size), Some(count), Some(mean)) = (
                            size.parse::<usize>(),
                            rec.get("count").and_then(Json::as_f64),
                            rec.get("mean").and_then(Json::as_f64),
                        ) {
                            let b = m.buckets.entry(size).or_default();
                            b.count = count as usize;
                            b.mean = mean;
                            b.m2 = rec.get("m2").and_then(Json::as_f64).unwrap_or(0.0);
                        }
                    }
                }
            }
        }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, json::to_string(&self.to_json()))
            .with_context(|| format!("writing perf models to {}", path.display()))
    }

    pub fn load(&self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading perf models from {}", path.display()))?;
        let v = json::parse(&text)?;
        self.load_json(&v);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_welford() {
        let mut b = Bucket::default();
        for t in [1.0, 2.0, 3.0] {
            b.record(t);
        }
        assert_eq!(b.count, 3);
        assert!((b.mean - 2.0).abs() < 1e-12);
        assert!((b.stddev() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn estimate_prefers_exact_bucket() {
        let mut m = VariantModel::default();
        for _ in 0..MIN_SAMPLES {
            m.record(64, 0.5);
        }
        assert_eq!(m.estimate(64), Some(0.5));
    }

    #[test]
    fn regression_extrapolates_cubic() {
        let mut m = VariantModel::default();
        // t = 1e-9 * n^3
        for n in [64usize, 128, 256] {
            for _ in 0..MIN_SAMPLES {
                m.record(n, 1e-9 * (n as f64).powi(3));
            }
        }
        let (a, b) = m.regression().unwrap();
        assert!((b - 3.0).abs() < 0.01, "exponent {b}");
        assert!((a - 1e-9).abs() / 1e-9 < 0.05, "coeff {a}");
        let est = m.estimate(1024).unwrap();
        let truth = 1e-9 * 1024f64.powi(3);
        assert!((est - truth).abs() / truth < 0.05);
    }

    #[test]
    fn calibration_threshold() {
        let mut m = VariantModel::default();
        assert!(m.needs_calibration(32));
        for _ in 0..MIN_SAMPLES {
            m.record(32, 1.0);
        }
        assert!(!m.needs_calibration(32));
        // other sizes still uncalibrated
        assert!(m.needs_calibration(64));
    }

    #[test]
    fn registry_roundtrip() {
        let p = PerfModels::new();
        for _ in 0..4 {
            p.record("mmul", "cuda", 128, 0.25);
        }
        let j = p.to_json();
        let q = PerfModels::new();
        q.load_json(&j);
        assert_eq!(q.estimate("mmul", "cuda", 128), Some(0.25));
        assert_eq!(q.samples("mmul", "cuda"), 4);
    }

    #[test]
    fn persistence() {
        let dir = std::env::temp_dir().join("compar_pm_test");
        let path = dir.join("models.json");
        let p = PerfModels::new();
        for _ in 0..3 {
            p.record("sort", "omp", 1024, 0.001);
        }
        p.save(&path).unwrap();
        let q = PerfModels::new();
        q.load(&path).unwrap();
        assert_eq!(q.estimate("sort", "omp", 1024), Some(0.001));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
