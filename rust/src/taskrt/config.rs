//! Runtime configuration, mirroring the StarPU environment variables the
//! paper uses in its evaluation (§3.2): `STARPU_NCPU=0` forces GPU-only,
//! `STARPU_NCUDA=0` forces CPU-only. We accept both the `COMPAR_*` names
//! and the `STARPU_*` aliases.

use std::time::Duration;

use super::selection::SelectorKind;

/// Scheduling policy selector (see `scheduler/`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Greedy FIFO shared by all workers (StarPU "eager").
    Eager,
    /// Uniform-random worker choice (StarPU "random").
    Random,
    /// Per-worker deques with work stealing (StarPU "ws").
    WorkStealing,
    /// Deque Model Data Aware: minimize modeled completion = exec model +
    /// transfer model (StarPU "dmda"). The paper's selection mechanism.
    Dmda,
    /// Heterogeneous Earliest Finish Time over the task window.
    Heft,
}

impl SchedPolicy {
    pub fn parse(s: &str) -> Option<SchedPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "eager" => Some(SchedPolicy::Eager),
            "random" => Some(SchedPolicy::Random),
            "ws" | "work-stealing" | "work_stealing" => Some(SchedPolicy::WorkStealing),
            "dmda" | "dm" => Some(SchedPolicy::Dmda),
            "heft" => Some(SchedPolicy::Heft),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedPolicy::Eager => "eager",
            SchedPolicy::Random => "random",
            SchedPolicy::WorkStealing => "ws",
            SchedPolicy::Dmda => "dmda",
            SchedPolicy::Heft => "heft",
        }
    }
}

/// How execution time is attributed for scheduling / reporting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeMode {
    /// Calibrated analytic device model (paper hardware, DESIGN.md §3).
    /// This is the default: it reproduces the heterogeneous testbed.
    Modeled,
    /// Raw wall-clock on this machine (useful for overhead benches).
    Wall,
}

/// Runtime configuration. Build with [`Config::default()`] +. setters, or
/// [`Config::from_env()`] for CLI use.
#[derive(Debug, Clone)]
pub struct Config {
    /// CPU worker threads (the paper's multi-core resource).
    pub ncpu: usize,
    /// CUDA-analog device workers (each owns an XLA service handle).
    pub ncuda: usize,
    pub sched: SchedPolicy,
    /// Default variant-selection policy for scheduling contexts (see
    /// [`crate::taskrt::selection`]). Contexts created through
    /// [`crate::taskrt::Runtime::create_context_with`] may override it.
    pub selector: SelectorKind,
    /// Force full per-size calibration like STARPU_CALIBRATE=1: when the
    /// selector is the default Greedy, contexts run the Calibrating
    /// policy instead (see [`Config::effective_selector`]).
    pub calibrate: bool,
    pub time_mode: TimeMode,
    /// Directory for persisted performance models.
    pub perfmodel_dir: Option<std::path::PathBuf>,
    /// Deterministic seed for the modeled-time noise + random scheduler.
    pub seed: u64,
    /// dmda/heft consider data-transfer cost (the "data aware" part).
    /// Disabling this is the ablation of DESIGN.md — dmda degrades to a
    /// pure execution-model policy.
    pub data_aware: bool,
    /// Worker poll timeout (idle workers re-check shutdown this often).
    pub poll: Duration,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            ncpu: 4,
            ncuda: 1,
            sched: SchedPolicy::Dmda,
            selector: SelectorKind::Greedy,
            calibrate: false,
            time_mode: TimeMode::Modeled,
            perfmodel_dir: None,
            seed: 0xc0f1a5,
            data_aware: true,
            poll: Duration::from_millis(10),
        }
    }
}

fn env_usize(names: &[&str]) -> Option<usize> {
    for n in names {
        if let Ok(v) = std::env::var(n) {
            if let Ok(x) = v.trim().parse() {
                return Some(x);
            }
        }
    }
    None
}

fn env_str(names: &[&str]) -> Option<String> {
    names.iter().find_map(|n| std::env::var(n).ok())
}

impl Config {
    /// Read `COMPAR_*` (or legacy `STARPU_*`) environment variables.
    /// The default CPU worker count comes from the hwloc-analog probe
    /// (paper §4: resources are "automatically collected ... using
    /// tools like hwloc") unless overridden.
    pub fn from_env() -> Config {
        let mut c = Config::default();
        c.ncpu = super::hwloc::MachineTopology::detect().recommended_ncpu();
        if let Some(n) = env_usize(&["COMPAR_NCPU", "STARPU_NCPU"]) {
            c.ncpu = n;
        }
        if let Some(n) = env_usize(&["COMPAR_NCUDA", "STARPU_NCUDA"]) {
            c.ncuda = n;
        }
        if let Some(s) = env_str(&["COMPAR_SCHED", "STARPU_SCHED"]) {
            if let Some(p) = SchedPolicy::parse(&s) {
                c.sched = p;
            }
        }
        if let Some(s) = env_str(&["COMPAR_SELECTOR"]) {
            if let Some(k) = SelectorKind::parse(&s) {
                c.selector = k;
            }
        }
        if let Some(n) = env_usize(&["COMPAR_CALIBRATE", "STARPU_CALIBRATE"]) {
            c.calibrate = n != 0;
        }
        if let Some(s) = env_str(&["COMPAR_TIME_MODE"]) {
            if s.eq_ignore_ascii_case("wall") {
                c.time_mode = TimeMode::Wall;
            }
        }
        if let Some(s) = env_str(&["COMPAR_PERFMODEL_DIR"]) {
            c.perfmodel_dir = Some(s.into());
        }
        if let Some(n) = env_usize(&["COMPAR_SEED"]) {
            c.seed = n as u64;
        }
        if let Some(n) = env_usize(&["COMPAR_DATA_AWARE"]) {
            c.data_aware = n != 0;
        }
        c
    }

    /// CPU-only execution (paper: STARPU_NCUDA=0).
    pub fn cpu_only(mut self) -> Config {
        self.ncuda = 0;
        self
    }

    /// GPU-only execution (paper: STARPU_NCPU=0).
    pub fn gpu_only(mut self) -> Config {
        self.ncpu = 0;
        self
    }

    pub fn with_sched(mut self, s: SchedPolicy) -> Config {
        self.sched = s;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Config {
        self.seed = seed;
        self
    }

    pub fn with_selector(mut self, k: SelectorKind) -> Config {
        self.selector = k;
        self
    }

    /// The selector new contexts get by default: the configured one,
    /// with STARPU_CALIBRATE upgrading the default Greedy to Calibrating.
    pub fn effective_selector(&self) -> SelectorKind {
        if self.calibrate && self.selector == SelectorKind::Greedy {
            SelectorKind::Calibrating
        } else {
            self.selector.clone()
        }
    }

    pub fn total_workers(&self) -> usize {
        self.ncpu + self.ncuda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse() {
        assert_eq!(SchedPolicy::parse("dmda"), Some(SchedPolicy::Dmda));
        assert_eq!(SchedPolicy::parse("EAGER"), Some(SchedPolicy::Eager));
        assert_eq!(SchedPolicy::parse("nope"), None);
    }

    #[test]
    fn calibrate_upgrades_default_selector() {
        let mut c = Config::default();
        assert_eq!(c.effective_selector(), SelectorKind::Greedy);
        c.calibrate = true;
        assert_eq!(c.effective_selector(), SelectorKind::Calibrating);
        // an explicit selector wins over the calibrate flag
        c.selector = SelectorKind::EpsilonGreedy(0.2);
        assert_eq!(c.effective_selector(), SelectorKind::EpsilonGreedy(0.2));
    }

    #[test]
    fn cpu_gpu_only() {
        let c = Config::default().cpu_only();
        assert_eq!(c.ncuda, 0);
        assert!(c.ncpu > 0);
        let g = Config::default().gpu_only();
        assert_eq!(g.ncpu, 0);
    }
}
