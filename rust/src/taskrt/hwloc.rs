//! Hardware-topology discovery — the paper's hwloc usage ("our approach
//! ... automatically collects details about available computing
//! resources using tools like hwloc", §4). A small native prober: CPU
//! package/core counts and cache sizes from /proc/cpuinfo + sysfs, and
//! accelerator presence from the artifact manifest (the CUDA-analog
//! device exists exactly when AOT artifacts are available).

use std::path::Path;

/// Discovered machine description.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MachineTopology {
    /// Logical CPUs visible to this process.
    pub logical_cpus: usize,
    /// Physical cores (logical / threads-per-core when detectable).
    pub physical_cores: usize,
    /// CPU sockets ("physical id" count), >= 1.
    pub sockets: usize,
    /// Model name string, if exposed.
    pub model_name: Option<String>,
    /// Last-level cache size in bytes, if exposed.
    pub llc_bytes: Option<usize>,
}

impl MachineTopology {
    /// Probe the running machine.
    pub fn detect() -> MachineTopology {
        let logical = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let cpuinfo = std::fs::read_to_string("/proc/cpuinfo").unwrap_or_default();
        Self::from_cpuinfo(&cpuinfo, logical)
    }

    /// Parse a /proc/cpuinfo text (separated out for tests).
    pub fn from_cpuinfo(cpuinfo: &str, logical: usize) -> MachineTopology {
        let mut sockets = std::collections::BTreeSet::new();
        let mut cores = std::collections::BTreeSet::new();
        let mut model_name = None;
        let mut llc_bytes = None;
        let mut cur_socket = 0usize;
        for line in cpuinfo.lines() {
            let mut split = line.splitn(2, ':');
            let key = split.next().unwrap_or("").trim();
            let val = split.next().unwrap_or("").trim();
            match key {
                "physical id" => {
                    cur_socket = val.parse().unwrap_or(0);
                    sockets.insert(cur_socket);
                }
                "core id" => {
                    if let Ok(c) = val.parse::<usize>() {
                        cores.insert((cur_socket, c));
                    }
                }
                "model name" if model_name.is_none() => {
                    model_name = Some(val.to_string());
                }
                "cache size" if llc_bytes.is_none() => {
                    // "cache size : 20480 KB"
                    let mut parts = val.split_whitespace();
                    if let (Some(n), Some(unit)) = (parts.next(), parts.next()) {
                        if let Ok(n) = n.parse::<usize>() {
                            llc_bytes = Some(match unit {
                                "KB" | "kB" => n * 1024,
                                "MB" => n * 1024 * 1024,
                                _ => n,
                            });
                        }
                    }
                }
                _ => {}
            }
        }
        let physical = if cores.is_empty() { logical } else { cores.len() };
        MachineTopology {
            logical_cpus: logical,
            physical_cores: physical.max(1),
            sockets: sockets.len().max(1),
            model_name,
            llc_bytes,
        }
    }

    /// Recommended CPU worker count for the runtime: one worker per
    /// physical core, minus one core reserved for the leader thread and
    /// the XLA engine thread (StarPU reserves a core for its own
    /// drivers the same way).
    pub fn recommended_ncpu(&self) -> usize {
        self.physical_cores.saturating_sub(1).max(1)
    }
}

/// Are CUDA-analog devices available? True when AOT artifacts exist —
/// the accelerator in this reproduction is the XLA engine.
pub fn accelerators_available(artifacts_dir: &Path) -> bool {
    artifacts_dir.join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
processor\t: 0
physical id\t: 0
core id\t: 0
model name\t: Intel(R) Xeon(R) CPU E5-2695 v2 @ 2.40GHz
cache size\t: 30720 KB

processor\t: 1
physical id\t: 0
core id\t: 1

processor\t: 2
physical id\t: 1
core id\t: 0

processor\t: 3
physical id\t: 1
core id\t: 1
";

    #[test]
    fn parses_sockets_and_cores() {
        let t = MachineTopology::from_cpuinfo(SAMPLE, 4);
        assert_eq!(t.sockets, 2);
        assert_eq!(t.physical_cores, 4);
        assert_eq!(t.logical_cpus, 4);
        assert_eq!(t.llc_bytes, Some(30720 * 1024));
        assert!(t.model_name.unwrap().contains("E5-2695"));
    }

    #[test]
    fn empty_cpuinfo_falls_back() {
        let t = MachineTopology::from_cpuinfo("", 8);
        assert_eq!(t.physical_cores, 8);
        assert_eq!(t.sockets, 1);
        assert_eq!(t.recommended_ncpu(), 7);
    }

    #[test]
    fn detect_runs_on_this_machine() {
        let t = MachineTopology::detect();
        assert!(t.logical_cpus >= 1);
        assert!(t.recommended_ncpu() >= 1);
    }

    #[test]
    fn smt_detection() {
        // 2 logical per core
        let two_threads = "\
processor\t: 0\nphysical id\t: 0\ncore id\t: 0\n
processor\t: 1\nphysical id\t: 0\ncore id\t: 0\n";
        let t = MachineTopology::from_cpuinfo(two_threads, 2);
        assert_eq!(t.physical_cores, 1);
        assert_eq!(t.logical_cpus, 2);
    }
}
