//! Pluggable scheduling policies — the StarPU scheduler zoo.
//!
//! The paper delegates variant selection to StarPU's scheduler (§2.2);
//! dmda (deque model data aware) is the policy its evaluation exercises.
//! We implement five policies behind one trait so the ablation benches
//! can compare selection quality:
//!
//! * [`eager::Eager`] — shared FIFO, first compatible worker wins.
//! * [`random::RandomSched`] — uniform random eligible worker.
//! * [`ws::WorkStealing`] — per-worker deques + stealing.
//! * [`dmda::Dmda`] — minimize modeled completion time (exec model +
//!   transfer model + queued work). The paper's selection mechanism.
//! * [`heft::Heft`] — dmda plus write-back cost (earliest finish time).

pub mod dmda;
pub mod eager;
pub mod heft;
pub mod random;
pub mod ws;

use std::sync::atomic::{AtomicIsize, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use super::codelet::{Codelet, ImplKind};
use super::data::{AccessMode, DataRegistry, HandleId};
use super::device::{transfer_model, Arch};
use super::perfmodel::PerfModels;
use super::selection::{RuntimeSnapshot, SelectionPolicy, SelectionQuery, VariantChoice};
use super::task::TaskId;
use crate::runtime::Manifest;
use crate::util::rng::Rng;

/// A task that cleared its dependencies and awaits a worker.
#[derive(Clone)]
pub struct ReadyTask {
    pub id: TaskId,
    pub codelet: Arc<Codelet>,
    pub size: usize,
    pub handles: Vec<(HandleId, AccessMode)>,
    /// Per-task selection-policy override (e.g. a pinned variant rides
    /// as a `Forced` policy); `None` = the context's policy decides.
    pub selector: Option<Arc<dyn SelectionPolicy>>,
    /// Scheduling priority (higher first within a queue).
    pub priority: i32,
    /// Scheduling context the task was submitted under.
    pub ctx: crate::taskrt::CtxId,
    /// Implementation chosen at push time (model-aware policies).
    pub chosen_impl: Option<usize>,
    /// Cost the policy charged to the worker's queue (to undo on finish).
    pub est_cost_ns: u64,
    /// Opaque application tag from the spec (stream chunk seq; 0 = none).
    pub tag: u64,
    /// Cross-layer trace id from the spec (0 = untraced); rides into
    /// the task's result and spans (see [`crate::obs`]).
    pub trace: u64,
    /// When the task entered a ready queue, in nanoseconds since the
    /// runtime's [`crate::obs::Obs`] epoch (0 = not stamped, e.g.
    /// selection probes). Workers observe `pop time − enqueued_ns` as
    /// the queue-wait histogram.
    pub enqueued_ns: u64,
}

/// Static description of one worker thread.
#[derive(Debug, Clone)]
pub struct WorkerInfo {
    pub id: usize,
    pub arch: Arch,
    pub mem_node: usize,
}

/// Everything a policy may consult when placing a task.
///
/// Since the scheduling-context refactor, one `SchedCtx` exists per
/// *context* (worker partition): `workers` still describes the full
/// machine (lanes and `queued_ns` are indexed by global worker id), and
/// `members` lists the worker ids this context may place tasks on.
pub struct SchedCtx {
    pub workers: Vec<WorkerInfo>,
    /// Global worker ids belonging to this scheduling context. Policies
    /// must only place tasks on member workers. Behind a lock since the
    /// autoscale work: membership can change *live* (worker migration
    /// between contexts) without rebuilding the slot — read through
    /// [`SchedCtx::members`] / [`SchedCtx::member_workers`].
    members: RwLock<Vec<usize>>,
    /// Migration gate: task pushes hold a read lock while they place
    /// into this context's scheduler; a worker migration holds the
    /// write lock while it evicts the leaving worker's lane. This
    /// closes the race where a push placed onto a worker that left the
    /// partition between the placement scan and the lane insert — such
    /// a task would strand (the worker now pops from another context).
    pub(crate) migration: RwLock<()>,
    pub perf: Arc<PerfModels>,
    pub data: Arc<DataRegistry>,
    pub manifest: Option<Arc<Manifest>>,
    /// This context's variant-selection policy; tasks may carry a
    /// per-task override ([`ReadyTask::selector`]).
    pub selector: Arc<dyn SelectionPolicy>,
    /// Model transfer costs in placement decisions (dmda's "DA").
    pub data_aware: bool,
    /// Modeled ns of work queued per worker (the "deque model").
    pub queued_ns: Vec<AtomicU64>,
    /// Tasks pushed to this context's scheduler and not yet popped
    /// (maintained by the worker layer; feeds [`RuntimeSnapshot`]).
    /// Signed and clamped at read: the increment lands *after* the
    /// push so a push-time selection query never counts the task being
    /// placed as pressure (idle must stay observable), and a racing
    /// pop may therefore transiently drive the counter to -1.
    ///
    /// [`RuntimeSnapshot`]: super::selection::RuntimeSnapshot
    pub pending: AtomicIsize,
    /// 1 while the worker is executing a task from this context
    /// (indexed by global worker id; feeds the snapshot's in-flight
    /// counts and occupancy).
    pub running: Vec<AtomicUsize>,
    /// Serve-layer sessions currently sharing the runtime (co-tenant
    /// count; the serve layer maintains it via
    /// [`crate::taskrt::Runtime::tenant_started`]).
    pub tenants: Arc<AtomicUsize>,
    /// Round-robin cursor for calibration-phase worker placement.
    pub rr: AtomicUsize,
    pub rng: Mutex<Rng>,
    /// Observability plane: [`SchedCtx::select_impl`] times every
    /// policy consult and records the decision audit here. Contexts
    /// built through the runtime share its `Obs`; a bare
    /// [`SchedCtx::new`] (tests, simulations) gets its own.
    pub obs: Arc<crate::obs::Obs>,
}

impl SchedCtx {
    pub fn new(
        workers: Vec<WorkerInfo>,
        perf: Arc<PerfModels>,
        data: Arc<DataRegistry>,
        manifest: Option<Arc<Manifest>>,
        selector: Arc<dyn SelectionPolicy>,
        seed: u64,
    ) -> SchedCtx {
        let queued_ns = (0..workers.len()).map(|_| AtomicU64::new(0)).collect();
        let running = (0..workers.len()).map(|_| AtomicUsize::new(0)).collect();
        let members = (0..workers.len()).collect();
        SchedCtx {
            workers,
            members: RwLock::new(members),
            migration: RwLock::new(()),
            perf,
            data,
            manifest,
            selector,
            data_aware: true,
            queued_ns,
            pending: AtomicIsize::new(0),
            running,
            tenants: Arc::new(AtomicUsize::new(0)),
            rr: AtomicUsize::new(0),
            rng: Mutex::new(Rng::new(seed)),
            obs: Arc::new(crate::obs::Obs::new()),
        }
    }

    /// Restrict this context to a worker subset (scheduling contexts).
    /// Takes `&self`: since the autoscale work, membership is interior-
    /// mutable so workers can migrate between live contexts without
    /// rebuilding the slot (which would orphan queued tasks and the
    /// occupancy counters held by in-flight executions).
    pub fn set_members(&self, mut members: Vec<usize>) {
        members.sort_unstable();
        members.dedup();
        members.retain(|&w| w < self.workers.len());
        *self.members.write().unwrap() = members;
    }

    /// Current member worker ids (a snapshot — membership can change
    /// under live worker migration).
    pub fn members(&self) -> Vec<usize> {
        self.members.read().unwrap().clone()
    }

    /// Read-locked view of the member list (hot paths that only scan).
    pub(crate) fn members_read(&self) -> std::sync::RwLockReadGuard<'_, Vec<usize>> {
        self.members.read().unwrap()
    }

    pub fn member_count(&self) -> usize {
        self.members.read().unwrap().len()
    }

    pub fn is_member(&self, worker: usize) -> bool {
        self.members.read().unwrap().contains(&worker)
    }

    /// The member workers' static descriptions (snapshot).
    pub fn member_workers(&self) -> Vec<WorkerInfo> {
        self.members
            .read()
            .unwrap()
            .iter()
            .map(|&w| self.workers[w].clone())
            .collect()
    }

    /// Where to park a task that has no eligible placement: a *member*
    /// worker's queue, so the error surfaces on this context's next pop
    /// instead of stranding in another partition's lane. (Submit
    /// pre-validates executability, so this is a defensive corner.)
    pub fn fallback_worker(&self) -> usize {
        self.members.read().unwrap().first().copied().unwrap_or(0)
    }

    /// Distinct architectures present in this context's partition.
    pub fn member_archs(&self) -> Vec<Arch> {
        let members = self.members_read();
        let mut archs = Vec::new();
        for &w in members.iter() {
            let arch = self.workers[w].arch;
            if !archs.contains(&arch) {
                archs.push(arch);
            }
        }
        archs
    }

    /// Is implementation `idx` of `task` executable on `arch` right now?
    /// (arch match + artifact availability). Variant pinning is a policy
    /// concern: see [`SchedCtx::can_run`] / [`SchedCtx::select_impl`].
    pub fn impl_eligible(&self, task: &ReadyTask, idx: usize, arch: Arch) -> bool {
        let imp = &task.codelet.impls[idx];
        if imp.arch != arch {
            return false;
        }
        match &imp.kind {
            ImplKind::Native(_) => true,
            ImplKind::Artifact { artifact_variant } => self
                .manifest
                .as_ref()
                .map(|m| {
                    m.find(&task.codelet.app, artifact_variant, task.size)
                        .is_some()
                })
                .unwrap_or(false),
        }
    }

    /// Indices of eligible implementations for `arch`.
    pub fn eligible_impls(&self, task: &ReadyTask, arch: Arch) -> Vec<usize> {
        (0..task.codelet.impls.len())
            .filter(|&i| self.impl_eligible(task, i, arch))
            .collect()
    }

    /// Member workers the task's selection policy can serve.
    /// (`can_run` probes with an empty snapshot and never re-enters the
    /// member lock, so scanning under the read guard is safe.)
    pub fn eligible_workers(&self, task: &ReadyTask) -> Vec<usize> {
        let members = self.members_read();
        members
            .iter()
            .copied()
            .filter(|&w| self.can_run(task, self.workers[w].arch))
            .collect()
    }

    /// The selection policy governing `task`: its per-task override if
    /// any, else this context's policy.
    pub fn policy_for<'a>(&'a self, task: &'a ReadyTask) -> &'a dyn SelectionPolicy {
        match &task.selector {
            Some(s) => s.as_ref(),
            None => self.selector.as_ref(),
        }
    }

    /// Build the [`SelectionQuery`] for one (task, arch) decision:
    /// codelet, size and arch plus a snapshot of this context's runtime
    /// state (queue depth, occupancy, backlog, co-tenancy).
    pub fn query<'a>(&'a self, task: &'a ReadyTask, arch: Arch) -> SelectionQuery<'a> {
        SelectionQuery::capture(task, arch, self)
    }

    /// THE selection entry point: every layer (schedulers, workers)
    /// resolves "which implementation runs on `arch`" through here, and
    /// every resolution carries a full [`SelectionQuery`]. Being the
    /// single funnel, this is also where the observability plane taps
    /// in: the policy consult is timed into the select histogram and
    /// every decision lands in the audit ring with the query snapshot,
    /// candidate estimates and the policy's reason tag. (The audit
    /// push is `try_lock`-guarded — it can be shed, never block.)
    pub fn select_impl(&self, task: &ReadyTask, arch: Arch) -> Option<VariantChoice> {
        let q = self.query(task, arch);
        let t0 = std::time::Instant::now();
        let choice = self.policy_for(task).select(&q);
        self.obs
            .select_seconds()
            .observe(t0.elapsed().as_secs_f64());
        if let Some(c) = &choice {
            let candidates = q
                .eligible()
                .iter()
                .map(|&i| (q.variant_name(i).to_string(), q.exec_estimate(i)))
                .collect();
            self.obs.record_decision(crate::obs::DecisionRecord {
                seq: 0,
                task: task.id,
                trace: task.trace,
                codelet: task.codelet.name.clone(),
                ctx: task.ctx as u64,
                size: task.size,
                size_band: super::selection::contextual::size_band(task.size) as u32,
                load_band: q.snapshot.load_band(),
                queue_depth: q.snapshot.queue_depth,
                arch: arch.name().to_string(),
                transfer_penalty_secs: q.transfer_penalty_secs(),
                candidates,
                chosen: q.variant_name(c.impl_idx).to_string(),
                est: c.est,
                reason: c.reason.as_str(),
            });
        }
        choice
    }

    /// Side-effect-free probe: can the governing policy serve `task` on
    /// `arch`? Used by worker placement, stealing and submit validation
    /// — all tight loops, so the probe query carries an empty snapshot
    /// instead of paying a capture per scan item (eligibility is
    /// load-independent by contract; see
    /// [`SelectionPolicy::can_serve`]).
    pub fn can_run(&self, task: &ReadyTask, arch: Arch) -> bool {
        let q = SelectionQuery::with_snapshot(task, arch, self, RuntimeSnapshot::default());
        self.policy_for(task).can_serve(&q)
    }

    /// Report a measured execution back to the governing policy (the
    /// online-learning loop; shared [`PerfModels`] are fed separately).
    /// The query re-captures the runtime snapshot, so context-aware
    /// policies learn which load band the measurement was taken under.
    pub fn feedback(&self, task: &ReadyTask, arch: Arch, variant: &str, secs: f64) {
        let q = self.query(task, arch);
        self.policy_for(task).feedback(&q, variant, secs);
    }

    /// Modeled bytes that would move if `task` ran on `worker`.
    pub fn transfer_bytes(&self, task: &ReadyTask, worker: usize) -> usize {
        let node = self.workers[worker].mem_node;
        task.handles
            .iter()
            .map(|(h, _)| self.data.transfer_bytes(*h, node).unwrap_or(0))
            .sum()
    }

    /// Modeled transfer seconds for `task` on `worker` (zero when the
    /// data-aware term is disabled — the dmda ablation).
    pub fn transfer_secs(&self, task: &ReadyTask, worker: usize) -> f64 {
        if !self.data_aware {
            return 0.0;
        }
        transfer_model(self.transfer_bytes(task, worker))
    }

    /// Perf-model estimate for (task, impl); None = uncalibrated.
    pub fn exec_estimate(&self, task: &ReadyTask, idx: usize) -> Option<f64> {
        let imp = &task.codelet.impls[idx];
        self.perf.estimate(&task.codelet.name, &imp.name, task.size)
    }

    /// Exponentially-decayed estimate for (task, impl) — what the
    /// drift-tracking `epsilon-decayed` policy exploits.
    pub fn recent_estimate(&self, task: &ReadyTask, idx: usize) -> Option<f64> {
        let imp = &task.codelet.impls[idx];
        self.perf
            .estimate_recent(&task.codelet.name, &imp.name, task.size)
    }

    /// Charge a placement to the deque model.
    pub fn charge(&self, worker: usize, ns: u64) {
        self.queued_ns[worker].fetch_add(ns, Ordering::Relaxed);
    }

    /// Undo a charge when the task leaves the worker.
    pub fn discharge(&self, worker: usize, ns: u64) {
        // saturating: races with charge are harmless for a heuristic
        let _ = self.queued_ns[worker].fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |v| Some(v.saturating_sub(ns)),
        );
    }

    pub fn queued_secs(&self, worker: usize) -> f64 {
        self.queued_ns[worker].load(Ordering::Relaxed) as f64 * 1e-9
    }
}

/// A scheduling policy. `push` is called with ready tasks; workers call
/// `pop` in a loop (with a timeout so they can observe shutdown).
pub trait Scheduler: Send + Sync {
    fn push(&self, task: ReadyTask, ctx: &SchedCtx);
    fn pop(&self, worker: usize, ctx: &SchedCtx, timeout: Duration) -> Option<ReadyTask>;
    /// Tasks currently queued (diagnostics).
    fn queued(&self) -> usize;
    fn name(&self) -> &'static str;
    /// Remove every task parked in `worker`'s private lane, for
    /// re-placement when the worker migrates out of this scheduling
    /// context. Schedulers with one shared queue (eager) have nothing
    /// worker-private to evict and keep the default.
    fn evict(&self, _worker: usize) -> Vec<ReadyTask> {
        Vec::new()
    }
}

/// Instantiate a policy by config value.
pub fn make(policy: super::config::SchedPolicy) -> Box<dyn Scheduler> {
    use super::config::SchedPolicy::*;
    match policy {
        Eager => Box::new(eager::Eager::new()),
        Random => Box::new(random::RandomSched::new()),
        WorkStealing => Box::new(ws::WorkStealing::new()),
        Dmda => Box::new(dmda::Dmda::new()),
        Heft => Box::new(heft::Heft::new()),
    }
}

/// Shared building block: one FIFO per worker with its own lock and
/// condvar, so a push wakes exactly the target worker and unrelated
/// workers never contend on one global mutex (§Perf: this halved the
/// per-task overhead vs the original single-Mutex design).
pub(crate) struct PerWorkerQueues {
    lanes: std::sync::RwLock<Vec<Arc<Lane>>>,
    /// Work-stealing pops wait here so a push anywhere can wake them.
    any_cv: std::sync::Condvar,
    any_mx: Mutex<()>,
}

struct Lane {
    q: Mutex<std::collections::VecDeque<ReadyTask>>,
    cv: std::sync::Condvar,
}

impl PerWorkerQueues {
    pub fn new() -> PerWorkerQueues {
        PerWorkerQueues {
            lanes: std::sync::RwLock::new(Vec::new()),
            any_cv: std::sync::Condvar::new(),
            any_mx: Mutex::new(()),
        }
    }

    fn lane(&self, n: usize) -> Arc<Lane> {
        {
            let lanes = self.lanes.read().unwrap();
            if let Some(l) = lanes.get(n) {
                return l.clone();
            }
        }
        let mut lanes = self.lanes.write().unwrap();
        while lanes.len() <= n {
            lanes.push(Arc::new(Lane {
                q: Mutex::new(std::collections::VecDeque::new()),
                cv: std::sync::Condvar::new(),
            }));
        }
        lanes[n].clone()
    }

    pub fn push_to(&self, worker: usize, task: ReadyTask) {
        let lane = self.lane(worker);
        {
            let mut q = lane.q.lock().unwrap();
            // priority order within a queue: insert before the first
            // lower-priority task (FIFO among equals)
            let pos = q
                .iter()
                .position(|t| t.priority < task.priority)
                .unwrap_or(q.len());
            q.insert(pos, task);
        }
        lane.cv.notify_one();
        self.any_cv.notify_all(); // wake stealers (no-op without waiters)
    }

    /// Pop from own queue front; if empty and `steal`, take from the
    /// back of the longest other queue whose task this worker can run.
    pub fn pop(
        &self,
        worker: usize,
        ctx: &SchedCtx,
        timeout: Duration,
        steal: bool,
    ) -> Option<ReadyTask> {
        let arch = ctx.workers[worker].arch;
        let lane = self.lane(worker);
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if let Some(t) = lane.q.lock().unwrap().pop_front() {
                return Some(t);
            }
            if steal {
                let lanes: Vec<Arc<Lane>> = self.lanes.read().unwrap().clone();
                // longest victim queue first
                let mut victims: Vec<(usize, usize)> = lanes
                    .iter()
                    .enumerate()
                    .filter(|(v, _)| *v != worker)
                    .map(|(v, l)| (v, l.q.lock().unwrap().len()))
                    .collect();
                victims.sort_by_key(|&(_, len)| std::cmp::Reverse(len));
                for (v, _) in victims {
                    let mut q = lanes[v].q.lock().unwrap();
                    // steal only what this worker's policy can serve
                    if let Some(pos) = q.iter().rposition(|t| ctx.can_run(t, arch)) {
                        return q.remove(pos);
                    }
                }
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            if steal {
                // wait for a push anywhere
                let g = self.any_mx.lock().unwrap();
                let _ = self.any_cv.wait_timeout(g, deadline - now).unwrap();
            } else {
                let q = lane.q.lock().unwrap();
                if !q.is_empty() {
                    continue;
                }
                let _ = lane.cv.wait_timeout(q, deadline - now).unwrap();
            }
        }
    }

    pub fn queued(&self) -> usize {
        self.lanes
            .read()
            .unwrap()
            .iter()
            .map(|l| l.q.lock().unwrap().len())
            .sum()
    }

    /// Drain everything parked in `worker`'s lane (worker migration:
    /// the departing worker will never pop this queue again).
    pub fn take_lane(&self, worker: usize) -> Vec<ReadyTask> {
        let lanes = self.lanes.read().unwrap();
        match lanes.get(worker) {
            Some(l) => l.q.lock().unwrap().drain(..).collect(),
            None => Vec::new(),
        }
    }
}
