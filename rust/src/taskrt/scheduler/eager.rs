//! StarPU "eager": a single shared FIFO; any idle worker takes the first
//! task it can execute. No model, no data awareness — the baseline the
//! paper's dmda results implicitly compare against.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::{ReadyTask, SchedCtx, Scheduler};

pub struct Eager {
    queue: Mutex<VecDeque<ReadyTask>>,
    cv: Condvar,
}

impl Eager {
    pub fn new() -> Eager {
        Eager {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
        }
    }
}

impl Default for Eager {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Eager {
    fn push(&self, task: ReadyTask, _ctx: &SchedCtx) {
        let mut q = self.queue.lock().unwrap();
        let pos = q
            .iter()
            .position(|t| t.priority < task.priority)
            .unwrap_or(q.len());
        q.insert(pos, task);
        drop(q);
        self.cv.notify_all();
    }

    fn pop(&self, worker: usize, ctx: &SchedCtx, timeout: Duration) -> Option<ReadyTask> {
        let arch = ctx.workers[worker].arch;
        let mut q = self.queue.lock().unwrap();
        let deadline = Instant::now() + timeout;
        loop {
            // first task this worker can run (not strictly FIFO across
            // archs, otherwise a CPU-only task at the head starves GPUs)
            if let Some(pos) = q.iter().position(|t| ctx.can_run(t, arch)) {
                return q.remove(pos);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (quard, _) = self.cv.wait_timeout(q, deadline - now).unwrap();
            q = quard;
        }
    }

    fn queued(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    fn name(&self) -> &'static str {
        "eager"
    }
}
