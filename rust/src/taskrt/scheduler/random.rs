//! StarPU "random": each ready task goes to a uniformly random worker
//! among those that can execute it. Terrible but cheap — the scheduling
//! lower bound in the ablations.

use std::time::Duration;

use super::{PerWorkerQueues, ReadyTask, SchedCtx, Scheduler};

pub struct RandomSched {
    queues: PerWorkerQueues,
}

impl RandomSched {
    pub fn new() -> RandomSched {
        RandomSched {
            queues: PerWorkerQueues::new(),
        }
    }
}

impl Default for RandomSched {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for RandomSched {
    fn push(&self, task: ReadyTask, ctx: &SchedCtx) {
        let eligible = ctx.eligible_workers(&task);
        if eligible.is_empty() {
            self.queues.push_to(ctx.fallback_worker(), task);
            return;
        }
        let k = ctx.rng.lock().unwrap().below(eligible.len());
        self.queues.push_to(eligible[k], task);
    }

    fn pop(&self, worker: usize, ctx: &SchedCtx, timeout: Duration) -> Option<ReadyTask> {
        self.queues.pop(worker, ctx, timeout, false)
    }

    fn queued(&self) -> usize {
        self.queues.queued()
    }

    fn name(&self) -> &'static str {
        "random"
    }

    fn evict(&self, worker: usize) -> Vec<ReadyTask> {
        self.queues.take_lane(worker)
    }
}
