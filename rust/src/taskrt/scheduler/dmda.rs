//! StarPU "dmda" — Deque Model Data Aware. The paper's selection engine.
//!
//! At push time, for every (worker, implementation) pair the policy
//! estimates the task's completion:
//!
//! ```text
//! completion(w, i) = queued_work(w)               // deque model
//!                  + transfer_model(bytes -> w)   // data awareness
//!                  + perf_model(codelet, i, size) // history model
//! ```
//!
//! and commits the task to the argmin. While any implementation is still
//! uncalibrated for this size, the policy round-robins over the unknown
//! options instead — this is StarPU's calibration phase, and it is what
//! makes the paper's mmul experiment pick "sub-optimal options" until
//! the models converge (§3.2).

use std::time::Duration;

use super::{PerWorkerQueues, ReadyTask, SchedCtx, Scheduler};

pub struct Dmda {
    queues: PerWorkerQueues,
}

impl Dmda {
    pub fn new() -> Dmda {
        Dmda {
            queues: PerWorkerQueues::new(),
        }
    }

    /// (worker, impl) candidates with their completion estimates;
    /// `None` estimate = uncalibrated.
    fn candidates(task: &ReadyTask, ctx: &SchedCtx) -> Vec<(usize, usize, Option<f64>)> {
        let mut out = Vec::new();
        // §Perf: transfer cost depends only on the memory node, so cache
        // it per node instead of recomputing per worker (each lookup
        // walks the data registry under its lock).
        let mut node_transfer: [Option<f64>; 8] = [None; 8];
        for w in &ctx.workers {
            for i in ctx.eligible_impls(task, w.arch) {
                let est = ctx.exec_estimate(task, i).map(|exec| {
                    let t = if w.mem_node < node_transfer.len() {
                        *node_transfer[w.mem_node]
                            .get_or_insert_with(|| ctx.transfer_secs(task, w.id))
                    } else {
                        ctx.transfer_secs(task, w.id)
                    };
                    ctx.queued_secs(w.id) + t + exec
                });
                out.push((w.id, i, est));
            }
        }
        out
    }

    pub(crate) fn place(
        task: &ReadyTask,
        ctx: &SchedCtx,
        extra: impl Fn(&ReadyTask, usize, usize) -> f64,
    ) -> Option<(usize, usize, f64)> {
        let cands = Self::candidates(task, ctx);
        if cands.is_empty() {
            return None;
        }
        // calibration phase: explore unknown implementations round-robin
        let unknown: Vec<&(usize, usize, Option<f64>)> =
            cands.iter().filter(|c| c.2.is_none()).collect();
        if !unknown.is_empty() {
            let k = ctx.rr.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let (w, i, _) = *unknown[k % unknown.len()];
            // charge a neutral guess so parallel pushes spread out
            let cost = ctx.transfer_secs(task, w) + 1e-3;
            return Some((w, i, cost));
        }
        cands
            .into_iter()
            .map(|(w, i, est)| (w, i, est.unwrap() + extra(task, w, i)))
            .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
    }
}

impl Default for Dmda {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Dmda {
    fn push(&self, mut task: ReadyTask, ctx: &SchedCtx) {
        match Self::place(&task, ctx, |_, _, _| 0.0) {
            Some((w, i, cost)) => {
                task.chosen_impl = Some(i);
                task.est_cost_ns = (cost.max(0.0) * 1e9) as u64;
                ctx.charge(w, task.est_cost_ns);
                self.queues.push_to(w, task);
            }
            None => self.queues.push_to(0, task), // surfaced as exec error
        }
    }

    fn pop(&self, worker: usize, ctx: &SchedCtx, timeout: Duration) -> Option<ReadyTask> {
        self.queues.pop(worker, ctx, timeout, false)
    }

    fn queued(&self) -> usize {
        self.queues.queued()
    }

    fn name(&self) -> &'static str {
        "dmda"
    }
}
