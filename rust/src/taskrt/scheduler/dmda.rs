//! StarPU "dmda" — Deque Model Data Aware. The paper's selection engine.
//!
//! At push time, for every (worker, implementation) pair the policy
//! estimates the task's completion:
//!
//! ```text
//! completion(w, i) = queued_work(w)               // deque model
//!                  + transfer_model(bytes -> w)   // data awareness
//!                  + perf_model(codelet, i, size) // history model
//! ```
//!
//! and commits the task to the argmin. *Which* implementation runs per
//! architecture is decided by the context's [`SelectionPolicy`]
//! (`ctx.select_impl`); dmda only decides *where*. While the policy is
//! still exploring (no estimate yet), placements round-robin over the
//! member workers instead — this is StarPU's calibration phase, and it
//! is what makes the paper's mmul experiment pick "sub-optimal options"
//! until the models converge (§3.2).
//!
//! [`SelectionPolicy`]: crate::taskrt::selection::SelectionPolicy

use std::time::Duration;

use super::{PerWorkerQueues, ReadyTask, SchedCtx, Scheduler};

pub struct Dmda {
    queues: PerWorkerQueues,
}

impl Dmda {
    pub fn new() -> Dmda {
        Dmda {
            queues: PerWorkerQueues::new(),
        }
    }

    /// (worker, impl) candidates with their completion estimates;
    /// `None` estimate = the selection policy is exploring. The variant
    /// per architecture comes from the task's [`SelectionPolicy`] (one
    /// `select` per distinct member arch, memoized across workers); dmda
    /// only decides *where* the chosen variant runs.
    fn candidates(task: &ReadyTask, ctx: &SchedCtx) -> Vec<(usize, usize, Option<f64>)> {
        use crate::taskrt::selection::VariantChoice;
        let mut out = Vec::new();
        let mut per_arch: Vec<(crate::taskrt::Arch, Option<VariantChoice>)> = Vec::new();
        // §Perf: transfer cost depends only on the memory node, so cache
        // it per node instead of recomputing per worker (each lookup
        // walks the data registry under its lock). Sized from the actual
        // topology — a fixed-size cache silently stopped caching (and
        // before that, missed nodes entirely) past 8 memory nodes.
        let n_nodes = ctx
            .workers
            .iter()
            .map(|w| w.mem_node + 1)
            .max()
            .unwrap_or(1);
        let mut node_transfer: Vec<Option<f64>> = vec![None; n_nodes];
        // snapshot, not a held read guard: select_impl below re-enters
        // the member lock (query capture), and std's RwLock is not
        // re-entrant once a writer (a live migration) is queued
        for w in ctx.member_workers() {
            let choice = match per_arch.iter().find(|(a, _)| *a == w.arch) {
                Some((_, c)) => c.clone(),
                None => {
                    let c = ctx.select_impl(task, w.arch);
                    per_arch.push((w.arch, c.clone()));
                    c
                }
            };
            let Some(c) = choice else { continue };
            let est = c.est.map(|exec| {
                let t = *node_transfer[w.mem_node]
                    .get_or_insert_with(|| ctx.transfer_secs(task, w.id));
                ctx.queued_secs(w.id) + t + exec
            });
            out.push((w.id, c.impl_idx, est));
        }
        out
    }

    pub(crate) fn place(
        task: &ReadyTask,
        ctx: &SchedCtx,
        extra: impl Fn(&ReadyTask, usize, usize) -> f64,
    ) -> Option<(usize, usize, f64)> {
        let cands = Self::candidates(task, ctx);
        if cands.is_empty() {
            return None;
        }
        // exploration phase (policy returned no estimate): run the
        // least-sampled variant first so calibration spreads evenly
        // across variants, rotating over workers among ties
        let unknown: Vec<&(usize, usize, Option<f64>)> =
            cands.iter().filter(|c| c.2.is_none()).collect();
        if !unknown.is_empty() {
            let k = ctx.rr.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let n = unknown.len();
            let pick = (0..n)
                .map(|j| unknown[(k + j) % n])
                .min_by_key(|&&(_, i, _)| {
                    ctx.perf
                        .samples(&task.codelet.name, &task.codelet.impls[i].name)
                })
                .expect("unknown is non-empty");
            let (w, i, _) = *pick;
            // charge a neutral guess so parallel pushes spread out
            let cost = ctx.transfer_secs(task, w) + 1e-3;
            return Some((w, i, cost));
        }
        cands
            .into_iter()
            .map(|(w, i, est)| (w, i, est.unwrap() + extra(task, w, i)))
            .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
    }
}

impl Default for Dmda {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Dmda {
    fn push(&self, mut task: ReadyTask, ctx: &SchedCtx) {
        match Self::place(&task, ctx, |_, _, _| 0.0) {
            Some((w, i, cost)) => {
                task.chosen_impl = Some(i);
                task.est_cost_ns = (cost.max(0.0) * 1e9) as u64;
                ctx.charge(w, task.est_cost_ns);
                self.queues.push_to(w, task);
            }
            None => self.queues.push_to(ctx.fallback_worker(), task),
        }
    }

    fn pop(&self, worker: usize, ctx: &SchedCtx, timeout: Duration) -> Option<ReadyTask> {
        self.queues.pop(worker, ctx, timeout, false)
    }

    fn queued(&self) -> usize {
        self.queues.queued()
    }

    fn name(&self) -> &'static str {
        "dmda"
    }

    fn evict(&self, worker: usize) -> Vec<ReadyTask> {
        self.queues.take_lane(worker)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::super::{SchedCtx, WorkerInfo};
    use super::*;
    use crate::runtime::Tensor;
    use crate::taskrt::codelet::Codelet;
    use crate::taskrt::data::{AccessMode, DataRegistry};
    use crate::taskrt::perfmodel::{PerfModels, MIN_SAMPLES};

    /// A topology with one worker per memory node, `n` nodes total.
    fn wide_ctx(n: usize) -> (SchedCtx, crate::taskrt::HandleId) {
        let workers: Vec<WorkerInfo> = (0..n)
            .map(|i| WorkerInfo {
                id: i,
                arch: crate::taskrt::Arch::Cpu,
                mem_node: i,
            })
            .collect();
        let data = Arc::new(DataRegistry::new());
        let h = data.register(Tensor::vector(vec![0.0; 1024]));
        let perf = Arc::new(PerfModels::new());
        for _ in 0..MIN_SAMPLES {
            perf.record("c", "omp", 64, 1e-3);
        }
        let selector = Arc::new(crate::taskrt::selection::Greedy::new());
        (SchedCtx::new(workers, perf, data, None, selector, 7), h)
    }

    fn ready(h: crate::taskrt::HandleId) -> ReadyTask {
        let cl = Arc::new(
            Codelet::new("c", "sort", vec![AccessMode::Read]).with_native(
                "omp",
                crate::taskrt::Arch::Cpu,
                Arc::new(|_| Ok(())),
            ),
        );
        ReadyTask {
            id: 0,
            codelet: cl,
            size: 64,
            handles: vec![(h, AccessMode::Read)],
            selector: None,
            priority: 0,
            ctx: 0,
            chosen_impl: None,
            est_cost_ns: 0,
            tag: 0,
            trace: 0,
            enqueued_ns: 0,
        }
    }

    #[test]
    fn place_handles_more_than_eight_mem_nodes() {
        // regression: the old [Option<f64>; 8] cache broke node >= 8
        let (ctx, h) = wide_ctx(12);
        let (w, _i, cost) = Dmda::place(&ready(h), &ctx, |_, _, _| 0.0).unwrap();
        // data lives on node 0, so the node-0 worker avoids all transfer
        assert_eq!(w, 0, "should pick the transfer-free worker");
        assert!(cost > 0.0);
    }

    #[test]
    fn place_respects_context_members() {
        let (ctx, h) = wide_ctx(12);
        ctx.set_members(vec![9, 10, 11]);
        for _ in 0..32 {
            let (w, _, _) = Dmda::place(&ready(h), &ctx, |_, _, _| 0.0).unwrap();
            assert!((9..=11).contains(&w), "placed outside partition: {w}");
        }
    }

    #[test]
    fn empty_partition_yields_no_placement() {
        let (ctx, h) = wide_ctx(4);
        ctx.set_members(vec![]);
        assert!(Dmda::place(&ready(h), &ctx, |_, _, _| 0.0).is_none());
    }
}
