//! HEFT-style policy: dmda's completion estimate plus the write-back
//! cost of results that will have to return to main memory. For tasks
//! whose outputs are consumed on the CPU next (the common pattern in the
//! paper's benchmarks), this penalizes accelerator placement of small
//! tasks slightly more accurately than plain dmda.

use std::time::Duration;

use super::dmda::Dmda;
use super::{PerWorkerQueues, ReadyTask, SchedCtx, Scheduler};
use crate::taskrt::device::transfer_model;

pub struct Heft {
    queues: PerWorkerQueues,
}

impl Heft {
    pub fn new() -> Heft {
        Heft {
            queues: PerWorkerQueues::new(),
        }
    }
}

impl Default for Heft {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Heft {
    fn push(&self, mut task: ReadyTask, ctx: &SchedCtx) {
        let writeback = |t: &ReadyTask, w: usize, _i: usize| {
            let node = ctx.workers[w].mem_node;
            if node == crate::taskrt::data::MAIN_MEMORY {
                return 0.0;
            }
            let bytes: usize = t
                .handles
                .iter()
                .filter(|(_, m)| m.writes())
                .map(|(h, _)| ctx.data.byte_size(*h).unwrap_or(0))
                .sum();
            transfer_model(bytes)
        };
        match Dmda::place(&task, ctx, writeback) {
            Some((w, i, cost)) => {
                task.chosen_impl = Some(i);
                task.est_cost_ns = (cost.max(0.0) * 1e9) as u64;
                ctx.charge(w, task.est_cost_ns);
                self.queues.push_to(w, task);
            }
            None => self.queues.push_to(ctx.fallback_worker(), task),
        }
    }

    fn pop(&self, worker: usize, ctx: &SchedCtx, timeout: Duration) -> Option<ReadyTask> {
        self.queues.pop(worker, ctx, timeout, false)
    }

    fn queued(&self) -> usize {
        self.queues.queued()
    }

    fn name(&self) -> &'static str {
        "heft"
    }

    fn evict(&self, worker: usize) -> Vec<ReadyTask> {
        self.queues.take_lane(worker)
    }
}
