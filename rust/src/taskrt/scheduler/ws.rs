//! StarPU "ws" (work stealing): per-worker deques; tasks land round-robin
//! on eligible workers; idle workers steal from the back of the longest
//! compatible queue.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use super::{PerWorkerQueues, ReadyTask, SchedCtx, Scheduler};

pub struct WorkStealing {
    queues: PerWorkerQueues,
    next: AtomicUsize,
}

impl WorkStealing {
    pub fn new() -> WorkStealing {
        WorkStealing {
            queues: PerWorkerQueues::new(),
            next: AtomicUsize::new(0),
        }
    }
}

impl Default for WorkStealing {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for WorkStealing {
    fn push(&self, task: ReadyTask, ctx: &SchedCtx) {
        let eligible = ctx.eligible_workers(&task);
        if eligible.is_empty() {
            self.queues.push_to(ctx.fallback_worker(), task);
            return;
        }
        let k = self.next.fetch_add(1, Ordering::Relaxed);
        self.queues.push_to(eligible[k % eligible.len()], task);
    }

    fn pop(&self, worker: usize, ctx: &SchedCtx, timeout: Duration) -> Option<ReadyTask> {
        self.queues.pop(worker, ctx, timeout, true)
    }

    fn queued(&self) -> usize {
        self.queues.queued()
    }

    fn name(&self) -> &'static str {
        "ws"
    }

    fn evict(&self, worker: usize) -> Vec<ReadyTask> {
        self.queues.take_lane(worker)
    }
}
