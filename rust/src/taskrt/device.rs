//! Device topology + calibrated analytic performance model.
//!
//! The paper's testbed (Table 1) is a dual Xeon E5-2695v2 (24 cores,
//! 2×59.7 GB/s) plus an NVIDIA Titan Xp (3840 cores, 12.15 TFLOP/s f32,
//! 547.6 GB/s, PCIe 3.0 x16). We have neither GPU nor CUDA, so per
//! DESIGN.md §3 the *compute* still runs for real (native Rust or XLA
//! artifacts — numerics are fully verified) while the *time* attributed
//! to each device comes from this calibrated analytic model. Schedulers,
//! performance-model learning and the Fig. 1 sweeps all operate on these
//! modeled times; wall-clock is recorded alongside.
//!
//! Model form per (app, variant): t(n) = overhead + work(n) / throughput,
//! with throughputs derived from Table 1 peaks times per-variant
//! efficiency factors (documented on each constant below). A seeded ±5%
//! multiplicative noise term reproduces the "stochastic variability" the
//! paper attributes its COMPAR-vs-CUDA deltas to.

use crate::util::rng::Rng;
use std::sync::Mutex;

/// Processor architecture of a worker / implementation variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Arch {
    Cpu,
    Cuda,
}

impl Arch {
    pub fn parse(s: &str) -> Option<Arch> {
        match s.to_ascii_lowercase().as_str() {
            "cpu" | "openmp" | "omp" | "seq" | "blas" => Some(Arch::Cpu),
            "cuda" | "gpu" | "opencl" | "cublas" => Some(Arch::Cuda),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Arch::Cpu => "cpu",
            Arch::Cuda => "cuda",
        }
    }
}

/// PCIe 3.0 x16 transfer model (Table 1 testbed).
pub const PCIE_BANDWIDTH: f64 = 15.75e9; // bytes/s
pub const PCIE_LATENCY: f64 = 10e-6; // per transfer

/// Table 1 hardware peaks.
pub mod peaks {
    /// 2x Xeon E5-2695v2: 24 cores x 2.4 GHz x 8 f32 FLOP/cycle (AVX FMA).
    pub const CPU_FLOPS: f64 = 460e9;
    /// Aggregate CPU memory bandwidth (2 sockets x 59.7 GB/s).
    pub const CPU_BW: f64 = 119.4e9;
    /// Titan Xp f32 peak.
    pub const GPU_FLOPS: f64 = 12.15e12;
    /// Titan Xp memory bandwidth.
    pub const GPU_BW: f64 = 547.6e9;
}

/// The modeled execution time of one implementation variant at size n.
///
/// `app` and `variant` are the paper's names: variants "omp", "seq",
/// "blas" run on [`Arch::Cpu`]; "cuda", "cublas" on [`Arch::Cuda`].
/// Unknown combinations fall back to a bandwidth-bound estimate so new
/// apps degrade gracefully rather than panic.
pub fn exec_model(app: &str, variant: &str, n: usize) -> f64 {
    let nf = n as f64;
    match (app, variant) {
        // ------------------------------------------------ matmul (Fig 1e)
        // flops = 2 n^3. Efficiencies: naive seq ~1.5 GF/s; OpenMP naive
        // triple loop ~6% of peak; MKL-class BLAS ~65% of peak; naive CUDA
        // tiled kernel ~16% of GPU peak; CUBLAS ~75% of GPU peak but with
        // a large one-off library/handle overhead (the paper observed
        // CUDA beating CUBLAS at n=4096 and losing at 8192 — that
        // crossover pins the overhead at ~80 ms).
        ("matmul", "seq") => 1e-6 + 2.0 * nf.powi(3) / 1.5e9,
        ("matmul", "omp") => 12e-6 + 2.0 * nf.powi(3) / (0.06 * peaks::CPU_FLOPS),
        ("matmul", "blas") => 2e-6 + 2.0 * nf.powi(3) / (0.65 * peaks::CPU_FLOPS),
        ("matmul", "cuda") => 18e-6 + 2.0 * nf.powi(3) / (0.16 * peaks::GPU_FLOPS),
        ("matmul", "cublas") => 80e-3 + 2.0 * nf.powi(3) / (0.75 * peaks::GPU_FLOPS),

        // ---------------------------------------------- hotspot (Fig 1a)
        // 5-point stencil, STEPS iterations; memory bound: ~3 arrays of
        // n^2 f32 touched per step. OpenMP reaches ~60% of CPU bw; the
        // CUDA kernel ~70% of GPU bw with a per-step launch cost.
        ("hotspot", "seq") => 1e-6 + hotspot_bytes(nf) / (0.25 * peaks::CPU_BW),
        ("hotspot", "omp") => 15e-6 + hotspot_bytes(nf) / (0.60 * peaks::CPU_BW),
        ("hotspot", "cuda") => {
            STEPS as f64 * 8e-6 + hotspot_bytes(nf) / (0.70 * peaks::GPU_BW)
        }

        // -------------------------------------------- hotspot3D (Fig 1b)
        ("hotspot3d", "seq") => 1e-6 + hs3d_bytes(nf) / (0.25 * peaks::CPU_BW),
        ("hotspot3d", "omp") => 15e-6 + hs3d_bytes(nf) / (0.55 * peaks::CPU_BW),
        ("hotspot3d", "cuda") => STEPS as f64 * 8e-6 + hs3d_bytes(nf) / (0.65 * peaks::GPU_BW),

        // -------------------------------------------------- lud (Fig 1c)
        // 2/3 n^3 flops; the panel factorization serializes, so CPU
        // efficiency is low (~4% OpenMP); Rodinia's blocked CUDA kernel
        // reaches ~10% of GPU peak.
        ("lud", "seq") => 1e-6 + 0.6667 * nf.powi(3) / 1.2e9,
        ("lud", "omp") => 20e-6 + 0.6667 * nf.powi(3) / (0.04 * peaks::CPU_FLOPS),
        ("lud", "cuda") => {
            // one kernel launch per panel (n / 16 panels in Rodinia)
            (nf / 16.0) * 6e-6 + 0.6667 * nf.powi(3) / (0.10 * peaks::GPU_FLOPS)
        }

        // --------------------------------------------------- nw (Fig 1d)
        // (n+1)^2 DP cells, ~10 ops each; anti-diagonal wavefront limits
        // parallelism: OpenMP ~1.2 Gcell/s, CUDA ~12 Gcell/s with 2n
        // diagonal kernel launches.
        ("nw", "seq") => 1e-6 + nf * nf / 0.35e9,
        ("nw", "omp") => 15e-6 + nf * nf / 1.2e9,
        ("nw", "cuda") => 2.0 * nf * 4e-6 + nf * nf / 12e9,

        // ------------------------------------------------ sort (Listing 1.3)
        ("sort", "seq") => 0.5e-6 + nf * nf.log2().max(1.0) * 9e-9,
        ("sort", "omp") => 10e-6 + nf * nf.log2().max(1.0) * 1.4e-9,
        ("sort", "cuda") => 25e-6 + nf * nf.log2().max(1.0) * 0.11e-9,

        // -------------------------------------------------- fallback
        _ => {
            let bytes = 4.0 * nf * nf;
            let bw = if Arch::parse(variant) == Some(Arch::Cuda) {
                0.5 * peaks::GPU_BW
            } else {
                0.5 * peaks::CPU_BW
            };
            10e-6 + bytes / bw
        }
    }
}

/// Steps baked into the stencil artifacts (matches python model.py).
pub const STEPS: usize = 8;

fn hotspot_bytes(nf: f64) -> f64 {
    STEPS as f64 * 3.0 * 4.0 * nf * nf
}

fn hs3d_bytes(nf: f64) -> f64 {
    // 8 layers (model.py HOTSPOT3D_LAYERS), 3 arrays touched per step
    STEPS as f64 * 3.0 * 4.0 * 8.0 * nf * nf
}

/// Modeled PCIe transfer time for `bytes` moved to/from the GPU.
pub fn transfer_model(bytes: usize) -> f64 {
    if bytes == 0 {
        0.0
    } else {
        PCIE_LATENCY + bytes as f64 / PCIE_BANDWIDTH
    }
}

/// Deterministic multiplicative noise source for modeled times (±~5%),
/// reproducing the run-to-run variability of a real testbed.
pub struct NoiseSource {
    rng: Mutex<Rng>,
    amplitude: f64,
}

impl NoiseSource {
    pub fn new(seed: u64, amplitude: f64) -> NoiseSource {
        NoiseSource {
            rng: Mutex::new(Rng::new(seed)),
            amplitude,
        }
    }

    /// Multiply a modeled time by (1 + amplitude * u), u uniform [-1, 1).
    pub fn apply(&self, t: f64) -> f64 {
        let mut rng = self.rng.lock().unwrap();
        let u = 2.0 * rng.next_f32() as f64 - 1.0;
        t * (1.0 + self.amplitude * u)
    }
}

/// A device in the simulated topology.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub name: String,
    pub arch: Arch,
    /// Memory node for the coherence tracker (0 = main memory).
    pub mem_node: usize,
    /// Worker threads this device contributes.
    pub workers: usize,
}

/// The evaluation testbed of Table 1.
pub fn paper_topology(ncpu: usize, ncuda: usize) -> Vec<DeviceSpec> {
    let mut v = Vec::new();
    if ncpu > 0 {
        v.push(DeviceSpec {
            name: "2x Xeon E5-2695v2 (Ivy Bridge, 24c)".into(),
            arch: Arch::Cpu,
            mem_node: 0,
            workers: ncpu,
        });
    }
    if ncuda > 0 {
        v.push(DeviceSpec {
            name: "NVIDIA Titan Xp (GP102)".into(),
            arch: Arch::Cuda,
            mem_node: 1,
            workers: ncuda,
        });
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_wins_large_hotspot() {
        // Fig 1a shape: GPU decisively faster at large grids.
        assert!(exec_model("hotspot", "cuda", 4096) < exec_model("hotspot", "omp", 4096));
    }

    #[test]
    fn cpu_competitive_small() {
        // Launch overheads make the CPU competitive at tiny sizes.
        assert!(exec_model("matmul", "blas", 8) < exec_model("matmul", "cuda", 8));
        assert!(exec_model("hotspot", "omp", 64) < exec_model("hotspot", "cuda", 64));
    }

    #[test]
    fn matmul_cuda_cublas_crossover() {
        // Fig 1e: CUDA wins at 4096, CUBLAS wins at 8192.
        assert!(exec_model("matmul", "cuda", 4096) < exec_model("matmul", "cublas", 4096));
        assert!(exec_model("matmul", "cublas", 8192) < exec_model("matmul", "cuda", 8192));
    }

    #[test]
    fn transfer_zero_is_free() {
        assert_eq!(transfer_model(0), 0.0);
        assert!(transfer_model(1) > 0.0);
    }

    #[test]
    fn noise_bounded_and_deterministic() {
        let a = NoiseSource::new(9, 0.05);
        let b = NoiseSource::new(9, 0.05);
        for _ in 0..100 {
            let x = a.apply(1.0);
            assert!((0.95..=1.05).contains(&x));
            assert_eq!(x, b.apply(1.0));
        }
    }

    #[test]
    fn arch_parse() {
        assert_eq!(Arch::parse("cublas"), Some(Arch::Cuda));
        assert_eq!(Arch::parse("omp"), Some(Arch::Cpu));
        assert_eq!(Arch::parse("tpu"), None);
    }

    #[test]
    fn monotone_in_size() {
        for app in ["matmul", "hotspot", "lud", "nw", "sort"] {
            for v in ["omp", "cuda"] {
                assert!(
                    exec_model(app, v, 1024) > exec_model(app, v, 64),
                    "{app}/{v} not monotone"
                );
            }
        }
    }
}
